"""Fault-tolerant runtime benchmark (EXPERIMENTS.md §Perf-J).

Measures what resilience costs — and what the caches buy back when it
engages:

* **injection overhead** — ``Compiled.run`` with no hook vs an
  installed no-fault plan (the always-on cost of the hook points);
* **retry overhead** — a healthy call through
  :class:`~repro.runtime.resilient.ResilientExecutor` vs the bare
  artifact (one try/except + output validation);
* **cold vs warm recovery** — injected persistent device loss on an
  8-device mesh forces the degraded-mesh path (7 devices): the *cold*
  number recompiles the program on the shrunk mesh from scratch; the
  *warm* number hits the persistent AOT store populated by the first
  recovery.  Acceptance bar: warm >= 5x faster than cold;
* **weighted schedule overhead** — the straggler-weighted chunk deal
  vs the cyclic one (same program, same mesh, warm).

Self-contained: forces 8 virtual CPU devices, prints
``resilience_*,us,derived`` CSV rows (relayed by ``benchmarks/run.py
--sections resilience``; the committed ``benchmarks/BENCH_resilience.json``
is that section's ``--json`` payload).
"""
from __future__ import annotations

import os
import sys
import tempfile
import time

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax  # noqa: E402

jax.config.update("jax_platforms", "cpu")

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402


def _row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}", flush=True)


def _timeit(fn, warmup=2, iters=5):
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def main() -> None:
    from repro import omp
    from repro.compat import make_mesh
    from repro.runtime.fault_injection import FaultPlan, FaultSpec, inject
    from repro.runtime.resilient import ResilientExecutor, RetryPolicy

    cache_dir = tempfile.mkdtemp(prefix="repro-resilience-")
    omp.enable_persistent_cache(cache_dir)

    n = 4096
    mesh = make_mesh((8,), ("data",))

    @omp.parallel_for(stop=n, name="resil", schedule=omp.dynamic(64))
    def block(i, env):
        return {"y": omp.at(i, env["x"][i] * 1.0001 + 0.5)}

    env = {"x": jnp.arange(n, dtype=jnp.float32),
           "y": jnp.zeros(n, jnp.float32)}
    compiled = omp.compile(block, mesh, env_like=env)
    ref = np.asarray(block(env)["y"])
    base = compiled.run(env)
    np.testing.assert_array_equal(np.asarray(base["y"]), ref)

    # -- injection-hook overhead (no faults scripted) ----------------------
    bare_us = _timeit(lambda: jax.block_until_ready(compiled.run(env)["y"]))
    with inject(FaultPlan()):
        hooked_us = _timeit(
            lambda: jax.block_until_ready(compiled.run(env)["y"]))
    _row("resilience_hook_overhead", hooked_us - bare_us,
         f"bare_us={bare_us:.1f};hooked_us={hooked_us:.1f}")

    # -- retry-wrapper overhead (healthy path) -----------------------------
    rex = ResilientExecutor(compiled)
    wrapped_us = _timeit(lambda: jax.block_until_ready(rex.run(env)["y"]))
    _row("resilience_wrapper_overhead", wrapped_us - bare_us,
         f"bare_us={bare_us:.1f};wrapped_us={wrapped_us:.1f}")

    # -- cold vs warm degraded-mesh recovery -------------------------------
    def recover_once() -> float:
        rex = ResilientExecutor(compiled,
                                policy=RetryPolicy(max_retries=0))
        plan = FaultPlan((FaultSpec(call=0, kind="device_loss", rank=3),))
        with inject(plan):
            t0 = time.perf_counter()
            out = rex.run(env)
            dt = time.perf_counter() - t0
        assert rex.degraded, "recovery did not engage"
        np.testing.assert_array_equal(np.asarray(out["y"]), ref)
        rex.reset()
        return dt * 1e6

    omp.clear_compile_cache()        # cold: no in-process entry, AOT
    cold_us = recover_once()         # store has only the 8-device key
    omp.clear_compile_cache()        # warm: in-process cache cleared,
    warm_us = recover_once()         # 7-device AOT entry now on disk
    ratio = cold_us / max(warm_us, 1e-9)
    _row("resilience_recovery_cold", cold_us, "devices=8to7")
    _row("resilience_recovery_warm", warm_us,
         f"devices=8to7;speedup={ratio:.1f};ok={int(ratio >= 5.0)}")
    if ratio < 5.0:
        print(f"WARNING: warm recovery speedup {ratio:.1f}x < 5x bar",
              file=sys.stderr)

    # -- straggler-weighted schedule overhead ------------------------------
    weighted = omp.compile(
        block, mesh, lowering="collective",
        chunk_weights=[2.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 0.5],
        env_like=env)
    out_w = weighted.run(env)
    np.testing.assert_array_equal(np.asarray(out_w["y"]), ref)
    weighted_us = _timeit(
        lambda: jax.block_until_ready(weighted.run(env)["y"]))
    _row("resilience_weighted_schedule", weighted_us,
         f"cyclic_us={bare_us:.1f};"
         f"overhead_pct={100.0 * (weighted_us - bare_us) / bare_us:.1f}")


if __name__ == "__main__":
    main()
