"""Region-fusion benchmark: multi-loop chains, fused vs per-loop staging.

The acceptance experiment for the ParallelRegion subsystem
(EXPERIMENTS.md §Perf-C): on ≥2-loop chains, count the collective ops
and per-chip wire bytes in the optimized SPMD HLO for

* ``region_fused``   — ``omp.compile`` fused lowering (one shard_map, residency
  planner elides inter-loop gather→rebroadcast round trips),
* ``staged_coll``    — the same loops transformed one at a time with the
  collective lowering (``lowering="collective"``),
* ``staged_mw``      — per-loop master/worker staging, the paper's
  pattern (all traffic through rank 0's links).

Chains (polybench-style):
* ``jacobi_chain``  — fdtd-ish: two pointwise sweeps + a reduction; all
  handoffs layout-compatible (full elision),
* ``stencil_chain`` — jacobi-2d row stencil consuming a produced array
  (forced minimal reshard),
* ``norm_chain``    — map → reduce → serial glue → map (mixed).

This script must see 8 virtual devices, so it forces XLA_FLAGS *before*
importing jax — run it directly (``python benchmarks/region_chains.py``)
or through ``benchmarks/run.py`` (which subprocesses it).  Wall-clock on
forced host devices is NOT a cluster measurement; the op/byte counts
are the backend-independent result.
"""
from __future__ import annotations

import os
import time

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

jax.config.update("jax_platforms", "cpu")

RANKS = 8


def make_jacobi_chain(n=4096):
    """Two pointwise sweeps + reduction — every handoff elidable."""
    from repro import omp

    @omp.parallel_for(stop=n, name="sweep1")
    def sweep1(i, env):
        return {"u": omp.at(i, env["a"][i] * 0.5 + 1.0)}

    @omp.parallel_for(stop=n, name="sweep2")
    def sweep2(i, env):
        return {"v": omp.at(i, env["u"][i] * env["u"][i])}

    @omp.parallel_for(stop=n, reduction={"norm": "+"}, name="norm")
    def norm(i, env):
        return {"norm": omp.red(env["v"][i])}

    env = {"a": jnp.arange(n, dtype=jnp.float32),
           "u": jnp.zeros(n, jnp.float32), "v": jnp.zeros(n, jnp.float32),
           "norm": jnp.float32(0)}
    return omp.region(sweep1, sweep2, norm, name="jacobi_chain"), env


def make_stencil_chain(n=2048):
    """Produce u, then consume it through a 3-point row stencil — the
    stencil window forces one minimal reshard instead of residency."""
    from repro import omp

    @omp.parallel_for(stop=n, name="fill")
    def fill(i, env):
        return {"u": omp.at(i, env["a"][i] + 1.0)}

    @omp.parallel_for(start=1, stop=n - 1, name="smooth")
    def smooth(i, env):
        v = (env["u"][i - 1] + env["u"][i] + env["u"][i + 1]) / 3.0
        return {"w": omp.at(i, v)}

    env = {"a": jnp.arange(n, dtype=jnp.float32),
           "u": jnp.zeros(n, jnp.float32), "w": jnp.zeros(n, jnp.float32)}
    return omp.region(fill, smooth, name="stencil_chain"), env


def make_norm_chain(n=4096):
    """map → reduce → serial glue (scale factor) → map."""
    from repro import omp

    @omp.parallel_for(stop=n, name="square")
    def square(i, env):
        return {"sq": omp.at(i, env["x"][i] * env["x"][i])}

    @omp.parallel_for(stop=n, reduction={"ss": "+"}, name="sumsq")
    def sumsq(i, env):
        return {"ss": omp.red(env["sq"][i])}

    glue = omp.serial(
        lambda env: {"scale": 1.0 / jnp.sqrt(env["ss"] + 1e-6)[None]},
        reads=("ss",), name="rsqrt")

    @omp.parallel_for(stop=n, name="normalize")
    def normalize(i, env):
        return {"y": omp.at(i, env["x"][i] * env["scale"][0])}

    env = {"x": jnp.arange(n, dtype=jnp.float32) * 1e-3,
           "sq": jnp.zeros(n, jnp.float32), "ss": jnp.float32(0),
           "scale": jnp.zeros(1, jnp.float32),
           "y": jnp.zeros(n, jnp.float32)}
    return omp.region(square, sumsq, glue, normalize, name="norm_chain"), env


def _timeit(fn, *args, warmup=2, iters=5):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def bench_chain(make):
    from repro import omp
    from repro.compat import make_mesh
    from repro.launch import hlo_analysis as ha

    mesh = make_mesh((RANKS,), ("data",))
    reg, env = make()
    ref = reg(env)
    avals = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
             for k, v in env.items()}

    variants = [
        ("region_fused", omp.compile(reg, mesh, env_like=env)),
        ("staged_coll", omp.compile(reg, mesh, lowering="collective")),
        ("staged_mw", omp.compile(reg, mesh, lowering="master_worker")),
    ]
    rows = []
    for vname, prog in variants:
        jitted = jax.jit(lambda e, prog=prog: prog(e))
        got = jitted(env)
        for k in ref:
            np.testing.assert_allclose(np.asarray(got[k]),
                                       np.asarray(ref[k]),
                                       rtol=1e-3, atol=1e-3)
        co = jitted.lower(avals).compile()
        rep = ha.analyze_hlo(co.as_text(), num_devices=RANKS)
        n_ops = sum(c.multiplier for c in rep.collectives)
        us = _timeit(jitted, env)
        extra = ""
        if vname == "region_fused":
            extra = (f";elided={prog.plan.n_elided}"
                     f";reshards={prog.plan.n_reshards}")
        rows.append((f"region_{reg.name}_{vname}", us,
                     f"collective_ops={n_ops}"
                     f";wire_bytes={int(rep.total_wire_bytes)}{extra}"))
    return rows


def main() -> None:
    print("name,us_per_call,derived")
    for make in (make_jacobi_chain, make_stencil_chain, make_norm_chain):
        for name, us, derived in bench_chain(make):
            print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
