"""Halo-exchange benchmark: cost-modeled boundaries vs the all-gather rule.

The acceptance experiment for the communication planner
(EXPERIMENTS.md §Perf-D): on a 3-loop ping-pong stencil chain (the
paper's Jacobi/heat shape, §4 — each sweep consumes the previous
sweep's array through a 3-point window and overwrites the one before
it), compare the optimized-HLO collective traffic of

* ``fused_halo``    — ``omp.compile(..., comm="auto")``: the
  planner lowers each stencil boundary to neighbor ``ppermute`` ring
  shifts moving O(halo · chunks) rows,
* ``fused_gather``  — ``comm="gather"``: the PR 1 rule (one
  ``all_gather`` per incompatible boundary, O(N) rows),
* ``staged_mw``     — per-loop master/worker staging, the paper's
  pattern.

A second section measures the **communication scheduler** (ISSUE 5) on
a multi-field variant of the same chain (3 arrays sharing every halo
boundary): ``comm_schedule="aggregate"`` packs the per-boundary
``ppermute`` payloads into one launch per ring direction, against the
``"inline"`` per-buffer baseline — same wire bytes, ~3x fewer boundary
collective launches (``multifield_*`` rows; the acceptance bar is
``inline >= 2 x aggregate`` collective ops).

The headline number is **boundary wire bytes**: the exit materialisation
of the final slabs is identical in both fused variants (XLA gathers the
region outputs at the jit boundary either way), so

``boundary_gather = all_gather_bytes(fused_gather) - all_gather_bytes(fused_halo)``
``boundary_halo   = collective_permute_bytes(fused_halo)``

and the acceptance bar is ``boundary_gather >= 5 * boundary_halo``.

The ping-pong shape matters: a chain that *returns* every intermediate
still pays one gather per buffer at exit, so halo planning only changes
*where* that gather happens; when intermediates are overwritten (every
real stencil iteration), the boundary traffic is the whole story.

This script must see 8 virtual devices, so it forces XLA_FLAGS *before*
importing jax — run it directly (``python benchmarks/stencil_halo.py``)
or through ``benchmarks/run.py``.  Wall-clock on forced host devices is
NOT a cluster measurement; the byte counts are the backend-independent
result.
"""
from __future__ import annotations

import os
import time

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

jax.config.update("jax_platforms", "cpu")

RANKS = 8
N = 4096
CHUNK = 64


def make_heat_chain(n=N, c=CHUNK):
    """3 ping-pong Jacobi sweeps: a -> b -> a -> b (each sweep reads the
    previous array through a 3-point window and overwrites the other)."""
    from repro import omp

    def sweep(src, dst, name):
        @omp.parallel_for(start=1, stop=n - 1, schedule=omp.static(c),
                          name=name)
        def body(i, env):
            v = 0.25 * (env[src][i - 1] + 2.0 * env[src][i]
                        + env[src][i + 1])
            return {dst: omp.at(i, v)}
        return body

    reg = omp.region(
        sweep("a", "b", "sweep1"),
        sweep("b", "a", "sweep2"),
        sweep("a", "b", "sweep3"),
        name="heat3",
    )
    env = {"a": jnp.sin(jnp.arange(n, dtype=jnp.float32) * 0.01),
           "b": jnp.zeros(n, jnp.float32)}
    return reg, env


def make_multifield_chain(n=N, c=CHUNK, fields=3, sweeps=5):
    """Ping-pong Jacobi sweeps over ``fields`` arrays at once: every
    boundary carries ``fields`` buffers across the same ring — the
    aggregation target of the communication scheduler.

    Mirror of ``tests/test_comm.py::_multifield_region`` (kept separate
    because this script must force XLA_FLAGS at import, which the test
    process cannot absorb — same convention as heat2d); keep the sweep
    body in sync with the test's so Perf-G measures the pinned program.
    """
    from repro import omp

    a_names = tuple(f"a{k}" for k in range(fields))
    b_names = tuple(f"b{k}" for k in range(fields))

    def sweep(srcs, dsts, name):
        @omp.parallel_for(start=1, stop=n - 1, schedule=omp.static(c),
                          name=name)
        def body(i, env):
            return {d: omp.at(i, 0.25 * (env[s][i - 1] + 2.0 * env[s][i]
                                         + env[s][i + 1]))
                    for s, d in zip(srcs, dsts)}
        return body

    stages = []
    cur, nxt = a_names, b_names
    for k in range(sweeps):
        stages.append(sweep(cur, nxt, f"mf{k + 1}"))
        cur, nxt = nxt, cur
    reg = omp.region(*stages, name="multifield")
    env = {k: jnp.sin((j + 1) * jnp.arange(n, dtype=jnp.float32) * 0.01)
           for j, k in enumerate(a_names)}
    env.update({k: jnp.zeros(n, jnp.float32) for k in b_names})
    return reg, env


def _timeit(fn, *args, warmup=2, iters=5):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def measure():
    from repro import omp
    from repro.compat import make_mesh
    from repro.launch import hlo_analysis as ha

    mesh = make_mesh((RANKS,), ("data",))
    reg, env = make_heat_chain()
    ref = reg(env)
    avals = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
             for k, v in env.items()}

    variants = [
        ("fused_halo", omp.compile(reg, mesh, env_like=env, comm="auto")),
        ("fused_gather", omp.compile(reg, mesh, env_like=env,
                                     comm="gather")),
        ("staged_mw", omp.compile(reg, mesh, lowering="master_worker")),
    ]
    rows, kinds = [], {}
    for vname, prog in variants:
        jitted = jax.jit(lambda e, prog=prog: prog(e))
        got = jitted(env)
        for k in ref:
            np.testing.assert_allclose(np.asarray(got[k]),
                                       np.asarray(ref[k]),
                                       rtol=1e-4, atol=1e-4)
        co = jitted.lower(avals).compile()
        rep = ha.analyze_hlo(co.as_text(), num_devices=RANKS)
        by_kind = rep.by_kind()
        kinds[vname] = by_kind
        n_ops = sum(c.multiplier for c in rep.collectives)
        us = _timeit(jitted, env)
        extra = ""
        if vname.startswith("fused"):
            ops = ",".join(bc.op for bc in prog.plan.comms)
            extra = (f";halo={prog.plan.n_halo}"
                     f";reshards={prog.plan.n_reshards}"
                     f";boundary_ops={ops}"
                     f";modeled_wire={prog.plan.planned_wire_bytes}")
        rows.append((f"stencil_halo_{vname}", us,
                     f"collective_ops={n_ops}"
                     f";wire_bytes={int(rep.total_wire_bytes)}{extra}"))

    boundary_halo = int(kinds["fused_halo"].get("collective-permute", 0))
    boundary_gather = int(kinds["fused_gather"].get("all-gather", 0)
                          - kinds["fused_halo"].get("all-gather", 0))
    ratio = boundary_gather / max(1, boundary_halo)
    rows.append(("stencil_halo_boundary", 0.0,
                 f"halo_bytes={boundary_halo}"
                 f";gather_bytes={boundary_gather}"
                 f";ratio={ratio:.1f}"))
    return rows, ratio


def measure_multifield():
    """Communication scheduler on the multi-field chain: aggregated
    packed payloads vs the inline per-buffer rings (ISSUE 5)."""
    from repro import omp
    from repro.compat import make_mesh
    from repro.launch import hlo_analysis as ha

    mesh = make_mesh((RANKS,), ("data",))
    reg, env = make_multifield_chain()
    ref = reg(env)
    avals = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
             for k, v in env.items()}

    rows, stats = [], {}
    for vname, mode in (("aggregate", "aggregate"), ("inline", "inline")):
        prog = omp.compile(reg, mesh, env_like=env, comm_schedule=mode)
        jitted = jax.jit(lambda e, prog=prog: prog(e))
        got = jitted(env)
        for k in ref:
            np.testing.assert_allclose(np.asarray(got[k]),
                                       np.asarray(ref[k]),
                                       rtol=1e-4, atol=1e-4)
        co = jitted.lower(avals).compile()
        rep = ha.analyze_hlo(co.as_text(), num_devices=RANKS)
        n_ops = sum(c.multiplier for c in rep.collectives)
        n_pp = sum(c.multiplier for c in rep.collectives
                   if c.kind == "collective-permute")
        us = _timeit(jitted, env)
        sched = prog.comm_schedule
        stats[vname] = (n_ops, n_pp, int(rep.total_wire_bytes))
        rows.append((f"stencil_multifield_{vname}", us,
                     f"collective_ops={n_ops}"
                     f";ppermute_ops={n_pp}"
                     f";wire_bytes={int(rep.total_wire_bytes)}"
                     f";launches_inline={sched.launches_inline}"
                     f";launches_scheduled={sched.launches_scheduled}"
                     f";n_hoisted={sched.n_hoisted}"))

    ops_i, pp_i, wire_i = stats["inline"]
    ops_a, pp_a, wire_a = stats["aggregate"]
    op_ratio = ops_i / max(1, ops_a)
    rows.append(("stencil_multifield_schedule", 0.0,
                 f"op_ratio={op_ratio:.2f}"
                 f";ppermute_inline={pp_i};ppermute_aggregate={pp_a}"
                 f";wire_inline={wire_i};wire_aggregate={wire_a}"))
    return rows, op_ratio, wire_a, wire_i


def main() -> None:
    print("name,us_per_call,derived")
    rows, ratio = measure()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
    mrows, op_ratio, wire_a, wire_i = measure_multifield()
    for name, us, derived in mrows:
        print(f"{name},{us:.1f},{derived}", flush=True)
    assert ratio >= 5.0, (
        f"halo boundaries must move >=5x fewer wire bytes (got {ratio:.1f}x)")
    assert op_ratio >= 2.0, (
        f"aggregated schedule must emit >=2x fewer collective ops "
        f"(got {op_ratio:.2f}x)")
    assert wire_a <= 1.05 * wire_i, (
        f"aggregation must not inflate wire bytes (+5% cap): "
        f"{wire_a} vs {wire_i}")


if __name__ == "__main__":
    main()
