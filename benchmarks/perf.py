import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb runner: lower a cell under a named variant, record the
roofline deltas (hypothesis -> change -> before -> after).

Variants are explicit, reviewable configurations; each run writes
results/perf/<cell>__<variant>.json with the same record schema as the
dry-run, so benchmarks.roofline can render them side by side.

Experiments (see EXPERIMENTS.md §Perf for the full log):

A. paper-representative (pragma engine, Polybench on 8 ranks):
     master_worker (faithful) -> collective -> +shard_inputs
B. worst roofline fraction (gemma3-1b train_4k):
     dp_tp baseline -> dp_only (batch over all 256 chips, ZeRO params)
C. most collective-bound (qwen1.5-110b train_4k):
     microbatch=16 baseline -> 8 -> 4 (ZeRO re-gather amortisation)
"""
import argparse
import dataclasses
import json


def run_lm_variant(arch: str, shape_name: str, variant: str,
                   overrides: dict, out_dir: str = "results/perf",
                   cfg_patch=None):
    import jax

    from repro.configs import (SHAPES, get_config,
                               recommended_train_config)
    from repro.launch import hlo_analysis as ha
    from repro.launch.dryrun import _write
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import lower_cell, make_cell

    cid = f"{arch}__{shape_name}__{variant}"
    path = os.path.join(out_dir, cid + ".json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    cfg = get_config(arch)
    if cfg_patch is not None:
        cfg = cfg_patch(cfg)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh()
    train_cfg = dataclasses.replace(recommended_train_config(cfg),
                                    **overrides)
    cell = make_cell(cfg, shape, mesh, train_cfg=train_cfg)
    compiled = lower_cell(cell).compile()
    ma = compiled.memory_analysis()
    rep = ha.analyze_hlo(compiled.as_text(), num_devices=mesh.size,
                         default_trip=cfg.n_layers)
    record = {
        "cell": cid, "arch": arch, "shape": shape_name,
        "mesh": "pod16x16", "kind": shape.kind, "devices": mesh.size,
        "variant": variant, "overrides": {k: str(v) for k, v
                                          in overrides.items()},
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_per_device_gb": round(
                (ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
                / 2**30, 3),
            "peak_tpu_adjusted_gb": round(
                (ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + ma.temp_size_in_bytes - ma.alias_size_in_bytes
                 - rep.f32_param_convert_bytes) / 2**30, 3),
        },
        "hlo": {
            "dot_flops": rep.dot_flops,
            "dot_bytes": rep.dot_bytes,
            "wire_bytes": rep.total_wire_bytes,
            "collective_bytes_by_kind": rep.by_kind(),
            "f32_param_convert_bytes": rep.f32_param_convert_bytes,
        },
        "status": "ok",
    }
    _write(path, record)
    return record


def run_polybench_lowering_compare(out_dir: str = "results/perf"):
    """Experiment A: wire bytes of the pragma engine's lowerings on the
    gemm and 2mm kernels over 8 ranks."""
    import jax
    import jax.numpy as jnp

    from benchmarks.polybench import make_2mm, make_gemm
    from repro import omp
    from repro.compat import make_mesh
    from repro.launch import hlo_analysis as ha

    mesh = make_mesh((8,), ("data",))
    results = {}
    for make in (make_gemm, make_2mm):
        k = make()
        env = k.env_fn(k.n)
        for variant, kw in [
            ("master_worker", dict(lowering="master_worker")),
            ("collective", dict(lowering="collective")),
            ("collective_shardin", dict(lowering="collective",
                                        shard="slice")),
        ]:
            def pipeline(env, kw=kw, k=k):
                out = dict(env)
                for prog in k.programs:
                    out = omp.compile(prog, mesh, **kw)(out)
                return out

            avals = {kk: jax.ShapeDtypeStruct(v.shape, v.dtype)
                     for kk, v in env.items()}
            compiled = jax.jit(pipeline).lower(avals).compile()
            rep = ha.analyze_hlo(compiled.as_text(), num_devices=8)
            results[f"{k.name}__{variant}"] = {
                "wire_bytes": rep.total_wire_bytes,
                "by_kind": rep.by_kind(),
                "dot_flops": rep.dot_flops,
            }
            print(f"{k.name:6s} {variant:20s} "
                  f"wire={rep.total_wire_bytes/1e6:9.2f} MB "
                  f"{rep.by_kind()}", flush=True)
    path = os.path.join(out_dir, "polybench_lowerings.json")
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(results, f, indent=2, default=float)
    return results


EXPERIMENTS = {
    # C: qwen110 ZeRO x microbatch traffic
    "qwen110_micro8": ("qwen1.5-110b", "train_4k",
                       {"microbatch": 8}),
    "qwen110_micro4": ("qwen1.5-110b", "train_4k",
                       {"microbatch": 4}),
    "qwen110_micro2": ("qwen1.5-110b", "train_4k",
                       {"microbatch": 2}),
    # B: gemma3 strategy
    "gemma3_dponly": ("gemma3-1b", "train_4k",
                      {"strategy": "dp_only", "zero3": True,
                       "optimizer": "adafactor"}),
    "gemma3_dponly_micro1": ("gemma3-1b", "train_4k",
                             {"strategy": "dp_only", "zero3": True,
                              "optimizer": "adafactor",
                              "microbatch": 1}),
    # D: worst roofline fraction: mamba2 (attn-free, TP-hostile dims)
    "mamba2_dponly": ("mamba2-130m", "train_4k",
                      {"strategy": "dp_only", "zero3": True,
                       "optimizer": "adafactor", "microbatch": 1}),
    "mamba2_micro1": ("mamba2-130m", "train_4k", {"microbatch": 1}),
    # E: most collective-bound: qwen2-moe (60 experts on a 16-way axis)
    "qwen2moe_micro1": ("qwen2-moe-a2.7b", "train_4k", {"microbatch": 1}),
    "qwen2moe_dponly": ("qwen2-moe-a2.7b", "train_4k",
                        {"strategy": "dp_only", "zero3": True,
                         "optimizer": "adafactor", "microbatch": 1}),
    # E2: pad experts 60 -> 64 to unlock EP sharding (beyond-paper)
    "qwen2moe_pad64": ("qwen2-moe-a2.7b", "train_4k", {},
                       "pad_experts_64"),
    "qwen2moe_pad64_micro1": ("qwen2-moe-a2.7b", "train_4k",
                              {"microbatch": 1}, "pad_experts_64"),
    "qwen2moe_pad64_micro8": ("qwen2-moe-a2.7b", "train_4k",
                              {"microbatch": 8}, "pad_experts_64"),
    # G: streamed adafactor update (optimizer f32 transient memory)
    "arctic_stream": ("arctic-480b", "train_4k", {}),
    # C2: sequence-parallel activations (Megatron-SP, beyond-paper)
    "qwen110_micro8_sp": ("qwen1.5-110b", "train_4k",
                          {"microbatch": 8, "seq_parallel": True}),
    "qwen110_micro16_sp": ("qwen1.5-110b", "train_4k",
                           {"seq_parallel": True}),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--experiment", action="append", default=None,
                    help="named experiment (repeatable); default: all")
    ap.add_argument("--polybench", action="store_true")
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args()
    if args.polybench:
        run_polybench_lowering_compare(args.out)
        return
    patches = {
        "pad_experts_64": lambda cfg: dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, n_padded=64)),
    }
    names = args.experiment or list(EXPERIMENTS)
    for name in names:
        spec = EXPERIMENTS[name]
        arch, shape, overrides = spec[0], spec[1], spec[2]
        patch = patches[spec[3]] if len(spec) > 3 else None
        rec = run_lm_variant(arch, shape, name, overrides, args.out,
                             cfg_patch=patch)
        print(f"{rec['cell']}: mem={rec['memory']['peak_per_device_gb']}GB"
              f" (adj {rec['memory']['peak_tpu_adjusted_gb']})"
              f" wire={rec['hlo']['wire_bytes']/2**30:.1f}GB", flush=True)


if __name__ == "__main__":
    main()
