"""Sustained-load serving benchmark (EXPERIMENTS.md §Perf-I).

Two phases over the full polybench program set (the paper's Fig. 6
workload, reused as the service's request mix):

* **cross-process warm start** — a child process compiles + first-calls
  every polybench block against an empty persistent store (cold), then
  a second fresh process does the same against the populated store
  (warm).  The warm process restores serialized AOT executables instead
  of re-planning and re-compiling; the ISSUE acceptance bar is >= 10x.
* **concurrent in-process load** — a :class:`repro.serving.CompileService`
  under N client threads x M sweeps of the program mix: throughput,
  warm-hit rate, and the single-flight guarantee (exactly one cold
  compile per structural key, racing clients coalesced).

Run directly (``PYTHONPATH=src python benchmarks/serving_load.py``) or
through ``benchmarks/run.py --sections serving`` (which subprocesses
it).  The committed ``benchmarks/BENCH_serving.json`` is the
``--sections serving --json`` payload.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import threading
import time

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax  # noqa: E402

jax.config.update("jax_platforms", "cpu")


# ---------------------------------------------------------------------------
# child mode: compile + first-call every polybench block in THIS process
# ---------------------------------------------------------------------------


def _child(cache_dir: str) -> None:
    # REPRO_AOT_CACHE_DIR was set by the parent before we imported repro,
    # so the persistent store is already enabled.
    from benchmarks.polybench import ALL_KERNELS
    from repro import omp
    from repro.compat import make_mesh

    mesh = make_mesh((len(jax.devices()),), ("data",))
    total_s = 0.0
    n = restored = 0
    # time compile + first call per program; program/env construction is
    # identical in both processes and stays outside the clock
    for make in ALL_KERNELS:
        k = make()
        env = k.env_fn(k.n)
        for prog in k.programs:
            t0 = time.perf_counter()
            c = omp.compile(prog, mesh, env_like=env)
            env = c(env)          # first call: build (or restore) + run
            total_s += time.perf_counter() - t0
            n += 1
            restored += int(c.restored)
    stats = omp.compile_cache_stats()
    print(json.dumps({"programs": n, "restored": restored,
                      "total_s": total_s,
                      "disk_hits": stats["disk_hits"],
                      "disk_misses": stats["disk_misses"],
                      "disk_errors": stats["disk_errors"]}))


def _run_child(cache_dir: str) -> dict:
    env = dict(os.environ, REPRO_AOT_CACHE_DIR=cache_dir)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child", cache_dir],
        capture_output=True, text=True, env=env, timeout=540)
    if proc.returncode != 0:
        raise RuntimeError(f"child failed: {proc.stderr[-400:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def bench_cross_process() -> None:
    with tempfile.TemporaryDirectory(prefix="repro-aot-bench-") as d:
        cold = _run_child(d)
        warm = _run_child(d)
    n = cold["programs"]
    speedup = cold["total_s"] / max(warm["total_s"], 1e-9)
    print(f"serving_cold_process,{cold['total_s'] * 1e6 / n:.1f},"
          f"programs={n};disk_hits={cold['disk_hits']}", flush=True)
    print(f"serving_warm_process,{warm['total_s'] * 1e6 / n:.1f},"
          f"speedup={speedup:.1f};restored={warm['restored']};"
          f"disk_hits={warm['disk_hits']};"
          f"disk_errors={warm['disk_errors']}", flush=True)
    assert warm["restored"] == n, (
        f"warm process restored {warm['restored']}/{n} executables")
    assert speedup >= 10.0, (
        f"cross-process warm start only {speedup:.1f}x (bar: 10x)")


# ---------------------------------------------------------------------------
# concurrent in-process load over CompileService
# ---------------------------------------------------------------------------


def bench_concurrent_load(n_threads: int = 8, sweeps: int = 3) -> None:
    from benchmarks.polybench import ALL_KERNELS
    from repro import omp
    from repro.compat import make_mesh
    from repro.serving import CompileService

    omp.clear_compile_cache()
    # request mix: every polybench block, each with the env shapes it
    # sees in sequence (later blocks read earlier blocks' outputs)
    pairs = []
    for make in ALL_KERNELS:
        k = make()
        env = k.env_fn(k.n)
        for prog in k.programs:
            pairs.append((prog, dict(env)))
            env = prog(env)

    svc = CompileService(make_mesh((len(jax.devices()),), ("data",)))
    errors: list = []
    barrier = threading.Barrier(n_threads + 1)

    def client():
        try:
            barrier.wait()
            for _ in range(sweeps):
                for prog, env in pairs:
                    svc.run(prog, env)
        except Exception as e:          # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=client) for _ in range(n_threads)]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    assert not errors, errors
    s = svc.stats
    total = n_threads * sweeps * len(pairs)
    assert s.requests == total
    assert s.cold_compiles == len(pairs), (
        f"single-flight violated: {s.cold_compiles} cold compiles for "
        f"{len(pairs)} structural keys")
    print(f"serving_load_request,{wall * 1e6 / total:.1f},"
          f"throughput_rps={total / wall:.0f};clients={n_threads};"
          f"requests={total};cold_compiles={s.cold_compiles};"
          f"warm_hits={s.warm_hits};coalesced={s.coalesced}", flush=True)


def main() -> None:
    if len(sys.argv) > 1 and sys.argv[1] == "--child":
        _child(sys.argv[2])
        return
    print("name,us_per_call,derived")
    bench_cross_process()
    bench_concurrent_load()


if __name__ == "__main__":
    main()
