"""Pallas-vs-lax roofline harness (EXPERIMENTS.md §Perf-H).

Measures the two chunk-compute backends of the SAME compiled pipeline
on the two stencil acceptance shapes:

* ``stencil``  — the 3-sweep 1-D ping-pong Jacobi chain of
  benchmarks/stencil_halo.py (8 ranks on the ``data`` axis),
* ``heat2d``   — the 3-sweep 2-D five-point chain of
  benchmarks/heat2d.py (4x2 mesh, ``collapse(2)`` nests),

each compiled twice: ``comm="auto"`` (the lax lowering — vmapped chunk
bodies under ``lax.scan``) and ``lowering="pallas"`` (tiled shard-local
kernels).  Outputs are checked ``allclose`` against the shared-memory
reference before timing; rows carry the pallas tile geometry (spans,
grid, tile/masked lanes) from the recorded ``KernelPlan`` so the
committed snapshot shows WHAT was measured, plus the wall-clock ratio.

HONESTY NOTE: this container has no TPU, so the pallas kernels run in
**interpret mode** on 8 forced host devices.  Interpret wall-clock
measures the lowering pipeline + merge overhead, NOT kernel quality —
expect pallas slower than lax here; the committed
``benchmarks/BENCH_pallas.json`` documents the backend's overhead
floor and the geometry it would launch on real hardware (the paper's
§5 "starting point that still can be further optimized").

This script must see 8 virtual devices, so it forces XLA_FLAGS *before*
importing jax — run it directly (``python benchmarks/roofline.py``) or
through ``benchmarks/run.py --sections roofline``.
"""
from __future__ import annotations

import os
import sys
import time

# make ``benchmarks.*`` importable when run directly (script mode puts
# only benchmarks/ itself on sys.path)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()

import jax  # noqa: E402
import numpy as np  # noqa: E402

jax.config.update("jax_platforms", "cpu")

# moderate sizes: interpret mode pays a per-grid-step overhead, and the
# whole section must fit the run.py subprocess budget
STENCIL_N, STENCIL_CHUNK = 2048, 64
HEAT2D_N, HEAT2D_M, HEAT2D_CHUNK = 128, 64, 8


def _timeit(fn, *args, warmup=2, iters=5):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _geometry(compiled) -> str:
    """``k=v`` fields (no commas — run.py parses ``;``-joined pairs)
    describing the KernelPlan actually lowered."""
    kp = compiled.kernel_plan
    spans = kp.spans
    grid = "x".join(str(g) for g in spans[0].grid) if spans else "-"
    tile = ("x".join(str(t.tile) for t in spans[0].tiles)
            if spans else "-")
    masked = ("x".join(str(t.masked_lanes) for t in spans[0].tiles)
              if spans else "-")
    return (f"spans={kp.n_kernels};max_fused={kp.max_fused};"
            f"grid={grid};tile={tile};masked={masked}")


def _measure_pair(tag: str, reg, env, mesh) -> list[tuple[str, float, str]]:
    from repro import omp

    ref = reg(env)
    lax_c = omp.compile(reg, mesh, env_like=env, comm="auto")
    pal_c = omp.compile(reg, mesh, env_like=env, lowering="pallas")
    rows = []
    times = {}
    for vname, prog in (("lax", lax_c), ("pallas", pal_c)):
        jitted = jax.jit(lambda e, prog=prog: prog(e))
        got = jitted(env)
        for k in ref:
            np.testing.assert_allclose(
                np.asarray(got[k]), np.asarray(ref[k]),
                rtol=1e-4, atol=1e-4,
                err_msg=f"{tag}/{vname} key={k!r}")
        us = _timeit(jitted, env)
        times[vname] = us
        derived = (_geometry(pal_c) + ";interpret=1"
                   if vname == "pallas" else "")
        rows.append((f"roofline_{tag}_{vname}", us, derived))
    ratio = times["pallas"] / times["lax"]
    rows.append((f"roofline_{tag}_ratio", 0.0,
                 f"ratio={ratio:.2f};note=interpret-mode overhead floor"))
    return rows


def measure() -> list[tuple[str, float, str]]:
    from benchmarks.heat2d import make_heat2d_chain
    from benchmarks.stencil_halo import make_heat_chain
    from repro.compat import make_mesh

    rows = []
    mesh1 = make_mesh((8,), ("data",))
    reg, env = make_heat_chain(n=STENCIL_N, c=STENCIL_CHUNK)
    rows += _measure_pair("stencil", reg, env, mesh1)

    mesh2 = make_mesh((4, 2), ("i", "j"))
    reg2, env2 = make_heat2d_chain(n=HEAT2D_N, m=HEAT2D_M, c=HEAT2D_CHUNK)
    rows += _measure_pair("heat2d", reg2, env2, mesh2)
    return rows


def main() -> None:
    print("name,us_per_call,derived")
    for name, us, derived in measure():
        print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
