"""Roofline table builder: reads dry-run JSON cells, emits §Roofline rows.

Terms (per device, TPU v5e constants from the brief):
  compute    = dot_flops / 197e12      (scan-corrected HLO MXU flops)
  memory     = hlo_bytes / 819e9       (scan-corrected dot bytes — weight
                                        + activation streaming; a lower
                                        bound on HBM traffic)
  collective = wire_bytes / 50e9       (HLO collectives x trip counts)

MODEL_FLOPS uses 6*N_active*tokens (train) / 2*N_active*tokens
(prefill/decode); the ratio MODEL_FLOPS / HLO_FLOPs exposes remat and
redundant-compute waste.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES, get_config
from repro.launch.hlo_analysis import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    roofline_terms,
)


def model_flops_per_device(rec: dict) -> float:
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    n_active = cfg.active_param_count()
    dev = rec.get("devices", 256)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / dev
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / dev
    tokens = shape.global_batch          # decode: one token per sequence
    return 2.0 * n_active * tokens / dev


def load_cells(out_dir: str) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def row_for(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    hlo = rec["hlo"]
    terms = roofline_terms(
        hlo_flops=hlo["dot_flops"],
        hlo_bytes=hlo["dot_bytes"],
        wire_bytes=hlo["wire_bytes"],
    )
    mf = model_flops_per_device(rec)
    return {
        "cell": rec["cell"],
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "compute_s": terms.compute_s,
        "memory_s": terms.memory_s,
        "collective_s": terms.collective_s,
        "dominant": terms.dominant,
        "roofline_fraction": terms.roofline_fraction,
        "model_flops": mf,
        "useful_ratio": mf / hlo["dot_flops"] if hlo["dot_flops"] else 0.0,
        "hbm_gb": rec["memory"]["peak_per_device_gb"],
        "hbm_adj_gb": rec["memory"].get("peak_tpu_adjusted_gb"),
        "wire_gb": hlo["wire_bytes"] / 2**30,
    }


def render_markdown(rows: list[dict]) -> str:
    hdr = ("| cell | compute_s | memory_s | collective_s | dominant | "
           "roofline_frac | useful_ratio | HBM(adj) GB |\n"
           "|---|---|---|---|---|---|---|---|")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['cell']} | {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['dominant']} "
            f"| {r['roofline_fraction']:.3f} | {r['useful_ratio']:.2f} "
            f"| {r['hbm_gb']:.1f} ({r['hbm_adj_gb']}) |")
    return "\n".join(out)


def main(out_dir: str = "results/dryrun") -> None:
    rows = [r for r in (row_for(c) for c in load_cells(out_dir)) if r]
    rows.sort(key=lambda r: r["roofline_fraction"])
    print(render_markdown(rows))
    print()
    print("# hardware: %.0f TFLOP/s bf16, %.0f GB/s HBM, %.0f GB/s link"
          % (PEAK_FLOPS / 1e12, HBM_BW / 1e9, ICI_BW / 1e9))
    # the three hillclimb candidates
    if rows:
        worst = rows[0]
        coll = max(rows, key=lambda r: r["collective_s"]
                   / max(r["compute_s"], 1e-12))
        print(f"# worst roofline fraction : {worst['cell']}")
        print(f"# most collective-bound   : {coll['cell']}")


if __name__ == "__main__":
    import sys

    main(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
