"""Polybench kernels expressed as OMP2MPI pragma programs (paper §4).

The paper compiled a Polybench subset with OMP2MPI and compared the
generated MPI code against the original OpenMP and sequential versions
(Fig. 6).  Here every kernel is written once against the pragma IR; the
harness then runs it three ways:

* ``seq``   — single-device, lax.map over iterations (no vectorised
  parallelism): the sequential baseline,
* ``omp``   — the shared-memory reference executor (vmap over the loop):
  the OpenMP analogue,
* ``mpi``   — the OMP2MPI transformation under shard_map (this container
  has one real device, so wall-time parity is expected; the *projected*
  cluster speed-up is derived from the plan's compute/communication
  split — the Fig. 6 analogue for a dry-run environment).

Kernels: the paper's Table 1 pi-style example, gemm, 2mm, 3mm, atax,
bicg, mvt, gesummv, syrk, syr2k, covariance, jacobi-2d (stencil:
whole-array reads — exercises the replicate path).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import omp


@dataclasses.dataclass
class PolyKernel:
    name: str
    programs: list            # list[ParallelFor] executed in order
    env_fn: Callable[[int], dict]
    check_keys: tuple[str, ...]
    n: int                    # problem size actually used


def _rng(n, *shape):
    rng = np.random.default_rng(abs(hash(shape)) % 2**31)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * 0.1)


def make_pi(n=2048):
    """Paper Table 1: sum[i] = 4/(1+x*x); total += sum[i]."""

    @omp.parallel_for(stop=n, schedule=omp.dynamic(), name="pi_fill")
    def fill(i, env):
        x = (i + 0.5) / n
        return {"sum": omp.at(i, 4.0 / (1.0 + x * x))}

    @omp.parallel_for(stop=n, reduction={"total": "+"}, name="pi_reduce")
    def reduce(i, env):
        return {"total": omp.red(env["sum"][i] / n)}

    def env_fn(n):
        return {"sum": jnp.zeros(n, jnp.float32), "total": jnp.float32(0)}

    return PolyKernel("pi", [fill, reduce], env_fn, ("total",), n)


def make_gemm(n=192):
    @omp.parallel_for(stop=n, name="gemm")
    def gemm(i, env):
        row = 1.5 * (env["A"][i] @ env["B"]) + 1.2 * env["C"][i]
        return {"C": omp.at(i, row)}

    def env_fn(n):
        return {"A": _rng(n, n, n), "B": _rng(n, n, n),
                "C": _rng(n, n, n)}

    return PolyKernel("gemm", [gemm], env_fn, ("C",), n)


def make_2mm(n=160):
    @omp.parallel_for(stop=n, name="mm1")
    def mm1(i, env):
        return {"tmp": omp.at(i, env["A"][i] @ env["B"])}

    @omp.parallel_for(stop=n, name="mm2")
    def mm2(i, env):
        return {"D": omp.at(i, env["tmp"][i] @ env["C"] + env["D"][i])}

    def env_fn(n):
        return {"A": _rng(n, n, n), "B": _rng(n, n, n), "C": _rng(n, n, n),
                "tmp": jnp.zeros((n, n)), "D": _rng(n, n, n)}

    return PolyKernel("2mm", [mm1, mm2], env_fn, ("D",), n)


def make_3mm(n=128):
    @omp.parallel_for(stop=n, name="p1")
    def p1(i, env):
        return {"E": omp.at(i, env["A"][i] @ env["B"])}

    @omp.parallel_for(stop=n, name="p2")
    def p2(i, env):
        return {"F": omp.at(i, env["C"][i] @ env["D"])}

    @omp.parallel_for(stop=n, name="p3")
    def p3(i, env):
        return {"G": omp.at(i, env["E"][i] @ env["F"])}

    def env_fn(n):
        return {"A": _rng(n, n, n), "B": _rng(n, n, n), "C": _rng(n, n, n),
                "D": _rng(n, n, n), "E": jnp.zeros((n, n)),
                "F": jnp.zeros((n, n)), "G": jnp.zeros((n, n))}

    return PolyKernel("3mm", [p1, p2, p3], env_fn, ("G",), n)


def make_atax(n=512):
    @omp.parallel_for(stop=n, name="ax")
    def ax(i, env):
        return {"tmp": omp.at(i, jnp.dot(env["A"][i], env["x"]))}

    @omp.parallel_for(stop=n, reduction=None, name="aty")
    def aty(i, env):
        # y = A^T tmp computed row-wise via scatter of A[i]*tmp[i]
        return {"partial": omp.at(i, env["A"][i] * env["tmp"][i])}

    @omp.parallel_for(stop=n, reduction={"y": "+"}, name="fold")
    def fold(i, env):
        return {"y": omp.red(env["partial"][i])}

    def env_fn(n):
        return {"A": _rng(n, n, n), "x": _rng(n + 1, n),
                "tmp": jnp.zeros(n), "partial": jnp.zeros((n, n)),
                "y": jnp.zeros(n)}

    return PolyKernel("atax", [ax, aty, fold], env_fn, ("y",), n)


def make_bicg(n=512):
    @omp.parallel_for(stop=n, name="q")
    def q(i, env):
        return {"q": omp.at(i, jnp.dot(env["A"][i], env["p"]))}

    @omp.parallel_for(stop=n, reduction={"s": "+"}, name="s")
    def s(i, env):
        return {"s": omp.red(env["A"][i] * env["r"][i])}

    def env_fn(n):
        return {"A": _rng(n, n, n), "p": _rng(n + 2, n),
                "r": _rng(n + 3, n), "q": jnp.zeros(n),
                "s": jnp.zeros(n)}

    return PolyKernel("bicg", [q, s], env_fn, ("q", "s"), n)


def make_mvt(n=512):
    @omp.parallel_for(stop=n, name="x1")
    def x1(i, env):
        return {"x1": omp.at(i, env["x1"][i] + jnp.dot(env["A"][i],
                                                       env["y1"]))}

    @omp.parallel_for(stop=n, reduction={"x2": "+"}, name="x2")
    def x2(i, env):
        return {"x2": omp.red(env["A"][i] * env["y2"][i])}

    def env_fn(n):
        return {"A": _rng(n, n, n), "y1": _rng(n + 4, n),
                "y2": _rng(n + 5, n), "x1": _rng(n + 6, n),
                "x2": jnp.zeros(n)}

    return PolyKernel("mvt", [x1, x2], env_fn, ("x1", "x2"), n)


def make_gesummv(n=384):
    @omp.parallel_for(stop=n, name="gesummv")
    def g(i, env):
        t = jnp.dot(env["A"][i], env["x"])
        s = jnp.dot(env["B"][i], env["x"])
        return {"y": omp.at(i, 1.5 * t + 1.2 * s)}

    def env_fn(n):
        return {"A": _rng(n, n, n), "B": _rng(n + 7, n, n),
                "x": _rng(n + 8, n), "y": jnp.zeros(n)}

    return PolyKernel("gesummv", [g], env_fn, ("y",), n)


def make_syrk(n=160):
    @omp.parallel_for(stop=n, name="syrk")
    def syrk(i, env):
        return {"C": omp.at(i, 1.2 * env["C"][i]
                            + 1.5 * env["A"][i] @ env["A"].T)}

    def env_fn(n):
        return {"A": _rng(n + 9, n, n), "C": _rng(n + 10, n, n)}

    return PolyKernel("syrk", [syrk], env_fn, ("C",), n)


def make_syr2k(n=128):
    @omp.parallel_for(stop=n, name="syr2k")
    def syr2k(i, env):
        v = env["A"][i] @ env["B"].T + env["B"][i] @ env["A"].T
        return {"C": omp.at(i, 1.2 * env["C"][i] + 1.5 * v)}

    def env_fn(n):
        return {"A": _rng(n + 11, n, n), "B": _rng(n + 12, n, n),
                "C": _rng(n + 13, n, n)}

    return PolyKernel("syr2k", [syr2k], env_fn, ("C",), n)


def make_covariance(n=192):
    @omp.parallel_for(stop=n, name="center")
    def center(i, env):
        col = env["data"][:, i] if False else env["data"][i]
        return {"centered": omp.at(i, col - jnp.mean(col))}

    @omp.parallel_for(stop=n, name="cov")
    def cov(i, env):
        return {"C": omp.at(i, env["centered"] @ env["centered"][i]
                            / (env["centered"].shape[1] - 1))}

    def env_fn(n):
        return {"data": _rng(n + 14, n, n),
                "centered": jnp.zeros((n, n)), "C": jnp.zeros((n, n))}

    return PolyKernel("covariance", [center, cov], env_fn, ("C",), n)


def make_jacobi2d(n=256, steps=1):
    """Stencil: reads i-1, i, i+1 rows -> whole-array (replicate) path."""

    @omp.parallel_for(start=1, stop=n - 1, name="jacobi")
    def jac(i, env):
        a = env["A"]
        row = 0.25 * (a[i - 1] + a[i + 1] + jnp.roll(a[i], 1)
                      + jnp.roll(a[i], -1))
        return {"B": omp.at(i, row)}

    def env_fn(n):
        return {"A": _rng(n + 15, n, n), "B": jnp.zeros((n, n))}

    return PolyKernel("jacobi2d", [jac], env_fn, ("B",), n)


ALL_KERNELS = [
    make_pi, make_gemm, make_2mm, make_3mm, make_atax, make_bicg,
    make_mvt, make_gesummv, make_syrk, make_syr2k, make_covariance,
    make_jacobi2d,
]
