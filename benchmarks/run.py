"""Benchmark harness. One section per paper table/figure; prints
``name,us_per_call,derived`` CSV rows, and with ``--json out.json``
also writes the machine-readable result set (wall time per benchmark
plus any wire-byte counters parsed out of ``derived``) so the perf
trajectory can be recorded run over run.

Sections:
* polybench_* (paper Fig. 6): seq vs OpenMP-analogue vs OMP2MPI-generated
  execution; ``derived`` is the projected 64-rank speed-up from the
  plan's compute/communication split (this container has one real CPU
  device, so cluster scaling cannot be wall-clocked — the projection is
  the Fig. 6 analogue; real distributed numbers come from the dry-run).
* region_* / stencil_halo_* / heat2d_*: fused-region and halo-vs-gather
  comparisons (8 virtual devices in subprocesses; HLO-measured bytes).
* compile_cache_*: cold vs warm ``omp.compile`` (the structural
  compilation cache); the ``--json`` payload carries the totals in its
  ``compile_cache`` section.
* serving_*: the compile-and-serve service (EXPERIMENTS §Perf-I) —
  cross-process warm start off the persistent AOT store (cold vs
  restored) and concurrent client load over CompileService; the
  committed benchmarks/BENCH_serving.json is this section's --json
  payload.
* resilience_*: fault-tolerant runtime (EXPERIMENTS §Perf-J) —
  injection/retry overheads and cold-vs-warm degraded-mesh recovery;
  the committed benchmarks/BENCH_resilience.json is this section's
  --json payload.
* kernels_*: Pallas interpret-mode kernels vs jnp oracles.
* train_step_* / decode_step_*: smoke-size LM steps (end-to-end
  substrate sanity + µs tracking).
"""
from __future__ import annotations

import os
import sys
import time

# Make ``benchmarks.*`` importable under the documented invocation
# ``PYTHONPATH=src python benchmarks/run.py`` (script mode puts only
# benchmarks/ itself on sys.path).
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_platforms", "cpu")

# Every _row lands here; ``--json`` serialises it at exit.
RESULTS: list[dict] = []


def _timeit(fn, *args, warmup=2, iters=5):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6  # us


def _parse_derived(derived: str) -> dict:
    """Split ``k=v;k=v`` derived strings into typed fields (ints/floats
    where they parse; wire-byte counters become machine-readable)."""
    fields: dict = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            fields[k] = int(v)
        except ValueError:
            try:
                fields[k] = float(v)
            except ValueError:
                fields[k] = v
    return fields


def _row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}", flush=True)
    RESULTS.append({
        "name": name,
        "us_per_call": round(float(us), 1),
        "derived": derived,
        **_parse_derived(derived),
    })


# ---------------------------------------------------------------------------
# Polybench (paper Fig. 6)
# ---------------------------------------------------------------------------


def _projected_speedup(programs, env, ranks=64, flops_time_us=None):
    """T_1 / (T_1/P + comm/link_bw): the Fig. 6 projection."""
    from repro.core.plan import make_plan
    from repro.core.report import _comm_summary

    comm_bytes = 0
    for prog in programs:
        plan = make_plan(prog, env, ranks)
        line = _comm_summary(plan)[-1]
        comm_bytes += int(line.split("~")[1].split()[0])
    t1 = (flops_time_us or 1.0) * 1e-6
    tp = t1 / ranks + comm_bytes / 50e9
    return t1 / tp


def bench_polybench():
    from benchmarks.polybench import ALL_KERNELS
    from repro import omp
    from repro.compat import make_mesh

    mesh = make_mesh((len(jax.devices()),), ("data",))

    for make in ALL_KERNELS:
        k = make()
        env = k.env_fn(k.n)

        def run_seq(env=env, k=k):
            out = dict(env)
            for prog in k.programs:
                # sequential: lax.map over iterations (one at a time)
                loop_out = out
                t = prog.stop - prog.start
                idx = prog.start + jnp.arange(t) * prog.step
                vals = jax.lax.map(lambda i: prog.body(i, loop_out), idx)
                from repro.core import pragma, reduction as red_mod

                for key, upd in vals.items():
                    if isinstance(upd, pragma.At):
                        loop_out[key] = loop_out[key].at[upd.idx].set(
                            upd.value)
                    elif isinstance(upd, pragma.Red):
                        rop = red_mod.get_reduction(prog.reduction[key])
                        folded = rop.local_fold(upd.value, 0)
                        loop_out[key] = rop.pairwise(loop_out[key], folded)
                out = loop_out
            return out

        def run_omp(env=env, k=k):
            out = dict(env)
            for prog in k.programs:
                out = prog(out)
            return out

        dists = [omp.compile(p, mesh) for p in k.programs]

        def run_mpi(env=env, dists=dists):
            out = dict(env)
            for d in dists:
                out = d(out)
            return out

        seq_j = jax.jit(run_seq)
        omp_j = jax.jit(run_omp)
        mpi_j = jax.jit(run_mpi)

        ref = omp_j(env)
        got = mpi_j(env)
        for key in k.check_keys:
            np.testing.assert_allclose(np.asarray(got[key]),
                                       np.asarray(ref[key]),
                                       rtol=1e-3, atol=1e-3)

        us_seq = _timeit(seq_j)
        us_omp = _timeit(omp_j)
        us_mpi = _timeit(mpi_j)
        # Fig. 6 analogue: projected speed-up of the generated program on
        # 64 ranks vs the SEQUENTIAL baseline (the paper's y-axis)
        proj = _projected_speedup(k.programs, env, ranks=64,
                                  flops_time_us=us_seq)
        _row(f"polybench_{k.name}_seq", us_seq)
        _row(f"polybench_{k.name}_omp", us_omp,
             f"speedup_vs_seq={us_seq / us_omp:.2f}")
        _row(f"polybench_{k.name}_mpi", us_mpi,
             f"proj_speedup64_vs_seq={proj:.1f};overhead_vs_omp="
             f"{us_mpi / us_omp:.2f}")


# ---------------------------------------------------------------------------
# Region fusion (EXPERIMENTS.md §Perf-C)
# ---------------------------------------------------------------------------


def _bench_subprocess(script: str, prefix: str, row_name: str):
    """Run a multi-device benchmark script in a subprocess (it forces its
    own 8 virtual devices while this process already initialised jax on
    the single real one) and relay its CSV rows.  ``prefix`` may be one
    prefix or a tuple; ``row_name`` labels the failure row when the
    script dies."""
    import os
    import subprocess
    import sys

    here = os.path.dirname(os.path.abspath(__file__))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(here), "src")
    env.pop("XLA_FLAGS", None)  # the script forces its own device count
    try:
        proc = subprocess.run(
            [sys.executable, os.path.join(here, script)],
            capture_output=True, text=True, env=env, timeout=560,
        )
    except subprocess.TimeoutExpired:
        _row(row_name, 0.0, "failed:timeout")
        return
    if proc.returncode != 0:
        _row(row_name, 0.0, f"failed:{proc.stderr[-200:]!r}")
        return
    for line in proc.stdout.splitlines():
        if line.startswith(prefix):
            name, us, derived = line.split(",", 2)
            _row(name, float(us), derived)


def bench_region():
    """Multi-loop chains: fused region vs per-loop staging."""
    _bench_subprocess("region_chains.py", "region_", "region_chains")


def bench_stencil_halo():
    """Cost-modeled halo boundaries vs the all-gather rule
    (EXPERIMENTS.md §Perf-D) plus the multi-field aggregated schedule
    vs the inline per-buffer rings (§Perf-G)."""
    _bench_subprocess("stencil_halo.py",
                      ("stencil_halo_", "stencil_multifield_"),
                      "stencil_halo")


def bench_heat2d():
    """2-D five-point heat: row+column halo rings vs all-gather over a
    4x2 mesh (EXPERIMENTS.md §Perf-E)."""
    _bench_subprocess("heat2d.py", "heat2d_", "heat2d")


def bench_roofline():
    """Pallas-vs-lax chunk compute on the stencil acceptance shapes
    (EXPERIMENTS.md §Perf-H; interpret mode on CPU — the committed
    benchmarks/BENCH_pallas.json is this section's --json payload)."""
    _bench_subprocess("roofline.py", "roofline_", "roofline")


def bench_serving():
    """Compile-and-serve: cross-process AOT warm start + concurrent
    client load (EXPERIMENTS.md §Perf-I).  Subprocessed because the
    cross-process phase spawns its own cold/warm children."""
    _bench_subprocess("serving_load.py", "serving_", "serving_load")


def bench_resilience():
    """Fault-tolerant runtime: injection-hook / retry-wrapper overhead,
    cold vs warm degraded-mesh recovery (the >= 5x warm-AOT bar), and
    the straggler-weighted schedule cost (EXPERIMENTS.md §Perf-J; the
    committed benchmarks/BENCH_resilience.json is this section's --json
    payload)."""
    _bench_subprocess("resilience.py", "resilience_", "resilience")


# ---------------------------------------------------------------------------
# Compilation cache (omp.compile cold vs warm)
# ---------------------------------------------------------------------------

# Filled by bench_compile_cache; serialised as the ``compile_cache``
# section of the --json payload.
COMPILE_CACHE: dict = {}


def bench_compile_cache():
    """Cold vs warm ``omp.compile``: the structural compilation cache
    must make repeated compiles (benchmark sweeps, the differential
    harness) skip re-planning entirely."""
    from benchmarks.polybench import ALL_KERNELS
    from repro import omp
    from repro.compat import make_mesh

    mesh = make_mesh((len(jax.devices()),), ("data",))
    cold_us = warm_us = 0.0
    n_programs = 0
    omp.clear_compile_cache()
    for make in ALL_KERNELS:
        k = make()
        env = k.env_fn(k.n)
        for prog in k.programs:
            n_programs += 1
            t0 = time.perf_counter()
            omp.compile(prog, mesh, env_like=env)
            cold_us += (time.perf_counter() - t0) * 1e6
            t0 = time.perf_counter()
            c = omp.compile(prog, mesh, env_like=env)
            warm_us += (time.perf_counter() - t0) * 1e6
            assert c.cache_hit, f"warm compile of {prog.name} missed the cache"
            env = prog(env)  # next block sees this block's outputs
    stats = omp.compile_cache_stats()
    speedup = cold_us / max(warm_us, 1e-9)
    COMPILE_CACHE.update({
        "n_programs": n_programs,
        "cold_us_total": round(cold_us, 1),
        "warm_us_total": round(warm_us, 1),
        "speedup": round(speedup, 1),
        "hits": stats["hits"],
        "misses": stats["misses"],
    })
    _row("compile_cache_cold", cold_us / n_programs,
         f"programs={n_programs}")
    _row("compile_cache_warm", warm_us / n_programs,
         f"speedup={speedup:.1f};hits={stats['hits']};"
         f"misses={stats['misses']}")


# ---------------------------------------------------------------------------
# Pallas kernels
# ---------------------------------------------------------------------------


def bench_kernels():
    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    b, s, h, kv, hd = 1, 256, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, kv, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, kv, hd)).astype(np.float32))
    us = _timeit(lambda: ops.flash_attention(q, k, v, kind="causal"))
    ref_us = _timeit(jax.jit(lambda: ref.flash_attention_ref(
        q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2))))
    _row("kernels_flash_attention_interp", us,
         f"oracle_us={ref_us:.0f}")

    x = jnp.asarray(rng.normal(size=(1, 256, 2, 32)).astype(np.float32))
    dt = jnp.abs(jnp.asarray(rng.normal(size=(1, 256, 2))
                             .astype(np.float32))) * 0.1
    A = jnp.asarray((-np.abs(rng.normal(size=(2,))) - 0.1)
                    .astype(np.float32))
    Bm = jnp.asarray(rng.normal(size=(1, 256, 16)).astype(np.float32))
    Cm = jnp.asarray(rng.normal(size=(1, 256, 16)).astype(np.float32))
    D = jnp.asarray(rng.normal(size=(2,)).astype(np.float32))
    us = _timeit(lambda: ops.ssd_scan(x, dt, A, Bm, Cm, D, chunk=64))
    ref_us = _timeit(jax.jit(lambda: ref.ssd_ref(x, dt, A, Bm, Cm, D)[0]))
    _row("kernels_ssd_scan_interp", us, f"oracle_us={ref_us:.0f}")


# ---------------------------------------------------------------------------
# LM steps (smoke size)
# ---------------------------------------------------------------------------


def bench_lm_steps():
    from repro.configs import smoke_config
    from repro.models import build_model

    for arch in ("gemma3-1b", "mamba2-130m", "qwen2-moe-a2.7b"):
        cfg = smoke_config(arch)
        model = build_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        b, s = 2, 128
        key = jax.random.PRNGKey(1)
        batch = {"tokens": jax.random.randint(key, (b, s), 0,
                                              cfg.vocab_size),
                 "labels": jax.random.randint(key, (b, s), 0,
                                              cfg.vocab_size)}
        loss_j = jax.jit(lambda p, bt: model.loss_fn(p, bt)[0])
        us = _timeit(loss_j, params, batch)
        _row(f"loss_{arch}", us, f"tokens={b * s}")

        cache = model.init_cache(b, 64, dtype=jnp.float32)
        dec_j = jax.jit(lambda p, c, t, q: model.decode_step(p, c, t, q))
        tok = jnp.zeros((b,), jnp.int32)
        pos = jnp.full((b,), 1, jnp.int32)
        # decode donates nothing here; measure steady-state step
        us = _timeit(dec_j, params, cache, tok, pos)
        _row(f"decode_{arch}", us, "cache_len=64")


def main(argv=None) -> None:
    import argparse

    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--json", metavar="PATH", default=None,
        help="also write the results as machine-readable JSON "
             "(wall time + wire-byte counters per benchmark)")
    parser.add_argument(
        "--sections", default=None,
        help="comma-separated subset of sections to run "
             "(polybench,region,stencil_halo,heat2d,roofline,"
             "compile_cache,serving,resilience,kernels,lm)")
    args = parser.parse_args(argv)

    sections = {
        "polybench": bench_polybench,
        "region": bench_region,
        "stencil_halo": bench_stencil_halo,
        "heat2d": bench_heat2d,
        "roofline": bench_roofline,
        "compile_cache": bench_compile_cache,
        "serving": bench_serving,
        "resilience": bench_resilience,
        "kernels": bench_kernels,
        "lm": bench_lm_steps,
    }
    wanted = (args.sections.split(",") if args.sections
              else list(sections))
    unknown = [s for s in wanted if s not in sections]
    if unknown:
        parser.error(f"unknown sections {unknown}; pick from "
                     f"{sorted(sections)}")

    print("name,us_per_call,derived")
    for name in wanted:
        sections[name]()

    if args.json:
        import json

        payload = {
            "schema": "repro-bench-v1",
            "device_count": len(jax.devices()),
            "sections": wanted,
            "results": RESULTS,
        }
        if COMPILE_CACHE:   # only when the compile_cache section ran
            payload["compile_cache"] = COMPILE_CACHE
        # The communication snapshot: every row that carries collective
        # ops / wire-byte / launch counters, so the perf trajectory of
        # the comm planner + scheduler is recorded run over run (the
        # committed benchmarks/BENCH_comm.json is this section from
        # `--sections stencil_halo,heat2d`; CI regenerates and uploads
        # it as an artifact).
        comm_rows = [r for r in RESULTS
                     if any(k in r for k in (
                         "collective_ops", "wire_bytes", "modeled_wire",
                         "launches_scheduled", "op_ratio", "ratio"))]
        if comm_rows:
            payload["comm"] = comm_rows
        # The serving snapshot: cross-process warm start + concurrent
        # load rows (the committed benchmarks/BENCH_serving.json is
        # this section from `--sections serving`).
        serving_rows = [r for r in RESULTS
                        if r["name"].startswith("serving_")]
        if serving_rows:
            payload["serving"] = serving_rows
        # The resilience snapshot: fault-injection overheads + cold/warm
        # degraded-mesh recovery (the committed
        # benchmarks/BENCH_resilience.json is this section from
        # `--sections resilience`).
        resilience_rows = [r for r in RESULTS
                           if r["name"].startswith("resilience_")]
        if resilience_rows:
            payload["resilience"] = resilience_rows
        with open(args.json, "w", encoding="utf-8") as fh:
            json.dump(payload, fh, indent=2)
        print(f"# wrote {len(RESULTS)} results to {args.json}", flush=True)


if __name__ == "__main__":
    main()
