"""2-D five-point heat benchmark: row+column halo rings vs all-gather.

The acceptance experiment for the 2-D mesh decomposition
(EXPERIMENTS.md §Perf-E): three ping-pong Jacobi sweeps over an
``n x m`` grid, each a ``collapse(2)`` nest consuming the previous
array through the 5-point window and overwriting the one before it —
the paper's dominant benchmark shape (§4: Jacobi/heat), now decomposed
over BOTH grid axes on a 4x2 mesh.

Variants:

* ``fused_halo``   — ``omp.compile(..., comm="auto")``: each 2-D
  boundary lowers to row-ring + column-ring ``ppermute`` shifts moving
  O(halo · perimeter) cells (corners ride the second pass),
* ``fused_gather`` — ``comm="gather"``: the PR 1 rule (one
  ``all_gather`` of the whole padded slab per boundary, O(n·m) cells).

The headline numbers are the **modeled boundary wire bytes** (the comm
cost model's per-boundary decisions) and the optimized-HLO collective
traffic; the acceptance bar is ``gather >= 5 x halo`` modeled bytes.

This script must see 8 virtual devices, so it forces XLA_FLAGS *before*
importing jax — run it directly (``python benchmarks/heat2d.py``) or
through ``benchmarks/run.py``.  Wall-clock on forced host devices is
NOT a cluster measurement; the byte counts are the backend-independent
result.
"""
from __future__ import annotations

import os
import time

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

jax.config.update("jax_platforms", "cpu")

MESH_SHAPE = (4, 2)
N, M = 256, 128
CHUNK = 16


def make_heat2d_chain(n=N, m=M, c=CHUNK):
    """3 ping-pong 5-point sweeps: a -> b -> a -> b over the interior."""
    from repro import omp

    def sweep(src, dst, name):
        @omp.parallel_for(start=(1, 1), stop=(n - 1, m - 1), collapse=2,
                          schedule=omp.static(c), name=name)
        def body(i, j, env):
            v = 0.25 * (env[src][i - 1, j] + env[src][i + 1, j]
                        + env[src][i, j - 1] + env[src][i, j + 1])
            return {dst: omp.at((i, j), v)}
        return body

    reg = omp.region(
        sweep("a", "b", "sweep1"),
        sweep("b", "a", "sweep2"),
        sweep("a", "b", "sweep3"),
        name="heat2d",
    )
    env = {"a": jnp.sin(jnp.arange(n * m, dtype=jnp.float32) * 0.01)
                   .reshape(n, m),
           "b": jnp.zeros((n, m), jnp.float32)}
    return reg, env


def _timeit(fn, *args, warmup=2, iters=5):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def measure():
    from repro import omp
    from repro.compat import make_mesh
    from repro.launch import hlo_analysis as ha

    mesh = make_mesh(MESH_SHAPE, ("i", "j"))
    reg, env = make_heat2d_chain()
    ref = reg(env)
    avals = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
             for k, v in env.items()}

    variants = [
        ("fused_halo", omp.compile(reg, mesh, env_like=env, comm="auto")),
        ("fused_gather", omp.compile(reg, mesh, env_like=env,
                                     comm="gather")),
    ]
    rows = []
    modeled = {}
    for vname, prog in variants:
        jitted = jax.jit(lambda e, prog=prog: prog(e))
        got = jitted(env)
        for k in ref:
            np.testing.assert_allclose(np.asarray(got[k]),
                                       np.asarray(ref[k]),
                                       rtol=1e-4, atol=1e-4)
        co = jitted.lower(avals).compile()
        rep = ha.analyze_hlo(co.as_text(), num_devices=int(np.prod(MESH_SHAPE)))
        n_ops = sum(c.multiplier for c in rep.collectives)
        us = _timeit(jitted, env)
        modeled[vname] = prog.plan.planned_wire_bytes
        ops = ",".join(bc.op for bc in prog.plan.comms)
        rows.append((f"heat2d_{vname}", us,
                     f"collective_ops={n_ops}"
                     f";wire_bytes={int(rep.total_wire_bytes)}"
                     f";halo={prog.plan.n_halo}"
                     f";reshards={prog.plan.n_reshards}"
                     f";boundary_ops={ops}"
                     f";modeled_wire={prog.plan.planned_wire_bytes}"
                     f";modeled_gather_wire={prog.plan.gather_wire_bytes}"))

    ratio = modeled["fused_gather"] / max(1, modeled["fused_halo"])
    rows.append(("heat2d_boundary", 0.0,
                 f"modeled_halo_bytes={modeled['fused_halo']}"
                 f";modeled_gather_bytes={modeled['fused_gather']}"
                 f";ratio={ratio:.1f}"))
    return rows, ratio


def main() -> None:
    print("name,us_per_call,derived")
    rows, ratio = measure()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
    assert ratio >= 5.0, (
        f"2-D halo boundaries must move >=5x fewer modeled wire bytes "
        f"(got {ratio:.1f}x)")


if __name__ == "__main__":
    main()
