"""Quickstart: the paper's Table 1 program, transformed.

Reproduces the paper's running example — an OpenMP program with two
parallel blocks (an array computation and a reduction) — through the
whole OMP2MPI pipeline: shared-memory reference execution, context
analysis, the generated distribution plan (the Tables 2/3 analogue), and
the distributed execution, verified equal.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import omp
from repro.compat import make_mesh

N = 1000


# --- the OpenMP program (paper Table 1) -----------------------------------
# #pragma omp parallel for target mpi
# for (i=0; i<N; ++i) sum[i] = 4.0/(1.0 + x*x);
@omp.parallel_for(stop=N, schedule=omp.dynamic(), name="table1_block1")
def block1(i, env):
    x = (i + 0.5) / N
    return {"sum": omp.at(i, 4.0 / (1.0 + x * x))}


# #pragma omp parallel for reduction(+: total)
# for (i=0; i<N; ++i) total += sum[i];
@omp.parallel_for(stop=N, reduction={"total": "+"}, name="table1_block2")
def block2(i, env):
    return {"total": omp.red(env["sum"][i] / N)}


def main() -> None:
    env = {"sum": jnp.zeros(N, jnp.float32), "total": jnp.float32(0)}

    # 1) shared-memory ("OpenMP") execution — the reference
    ref = block2(block1(env))
    print(f"OpenMP reference:   pi ~= {float(ref['total']):.6f}")

    # 2) the OMP2MPI transformation — the staged compiler pipeline
    #    (analyze -> schedule -> plan -> plan_comm -> lower)
    mesh = make_mesh((len(jax.devices()),), ("data",))
    d1 = omp.compile(block1, mesh, env_like=env)
    d2 = omp.compile(block2, mesh, env_like=block1(env))
    print("\npipeline:", " -> ".join(p.name for p in d1.passes))

    # 3) the generated "MPI program" report (paper Tables 2/3 analogue)
    print()
    print(d1.report())
    print()
    print(d2.report())

    # 4) distributed execution — correct by construction
    out = d2(d1(env))
    print(f"\nMPI (transformed):  pi ~= {float(out['total']):.6f}")
    np.testing.assert_allclose(float(out["total"]), float(ref["total"]),
                               rtol=1e-6)
    print("transform == reference: OK")


if __name__ == "__main__":
    main()
