"""End-to-end training example: a small LM for a few hundred steps.

Uses the full production substrate — synthetic data pipeline, plan-derived
shardings, microbatched train step, fault-tolerant loop with async
checkpoints — on a CPU-sized model.  The loss must drop well below the
unigram entropy of the synthetic Markov stream, proving the pipeline
learns the transition structure end-to-end.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
      (pass --arch mamba2-130m --full for the real 130M config)
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import ShapeConfig, TrainConfig, get_config, smoke_config
from repro.configs.base import ModelConfig
from repro.checkpoint import Checkpointer
from repro.data import make_batch_iterator
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import make_train_cell
from repro.models import build_model
from repro.optim import make_optimizer

DEMO_CONFIG = ModelConfig(
    name="demo-20m", family="dense", n_layers=8, d_model=256,
    n_heads=8, n_kv_heads=4, d_ff=1024, vocab_size=2048, head_dim=32,
    max_seq_len=1024, tie_embeddings=True, sub_quadratic=False,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default="results/example_ckpt")
    args = ap.parse_args()

    if args.arch:
        cfg = get_config(args.arch) if args.full else smoke_config(args.arch)
    else:
        cfg = DEMO_CONFIG
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params)")

    train_cfg = TrainConfig(
        learning_rate=3e-3, warmup_steps=20, total_steps=args.steps,
        optimizer="adamw", remat=False, compute_dtype="float32")
    mesh = make_local_mesh()
    shape = ShapeConfig("example", args.seq_len, args.batch, "train")
    cell = make_train_cell(cfg, shape, mesh, train_cfg)

    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    opt = make_optimizer(train_cfg.optimizer)
    opt_state = opt.init(params)
    step_j = jax.jit(cell.step_fn, donate_argnums=(0, 1))

    data = make_batch_iterator(vocab_size=cfg.vocab_size, batch=args.batch,
                               seq_len=args.seq_len, seed=0)
    ckpt = Checkpointer(args.ckpt_dir, keep=2)

    losses = []
    t0 = time.time()
    for step in range(args.steps):
        batch = next(data)
        params, opt_state, m = step_j(params, opt_state, batch,
                                      jnp.int32(step))
        if step % 20 == 0 or step == args.steps - 1:
            loss = float(m["loss"])
            losses.append(loss)
            print(f"step {step:4d}  loss {loss:.4f}  "
                  f"({(time.time()-t0)/(step+1):.2f}s/step)")
        if (step + 1) % 100 == 0:
            ckpt.save_async(step + 1, (params, opt_state))
    ckpt.wait()
    print(f"\nloss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({args.steps} steps, {time.time()-t0:.0f}s)")
    assert losses[-1] < losses[0] - 0.5, "model failed to learn"
    print("OK: the pipeline learns the synthetic Markov structure")


if __name__ == "__main__":
    main()
