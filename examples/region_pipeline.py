"""ParallelRegion: a whole OpenMP program transformed at once.

The paper transforms each ``parallel for`` in isolation, so consecutive
loops round-trip their data through rank 0 (Fig. 1b).  This example
builds the multi-block program

    // #pragma omp parallel for          (sweep: u[i] = a[i]/2 + 1)
    // #pragma omp parallel for          (square: v[i] = u[i]^2)
    // serial glue                       (scale = 1/sqrt(sum))
    // #pragma omp parallel for reduction(+: total)

as ONE :class:`~repro.core.pragma.ParallelRegion`, transforms it with
``omp.compile`` (fused lowering), prints the inter-loop residency plan (which
buffers stay distributed across loop boundaries, which need a minimal
reshard), and verifies the fused execution against the shared-memory
reference — then contrasts its collective traffic with the paper's
per-loop master/worker staging.

Run:  PYTHONPATH=src python examples/region_pipeline.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import omp
from repro.compat import make_mesh

N = 1024


@omp.parallel_for(stop=N, name="sweep")
def sweep(i, env):
    return {"u": omp.at(i, env["a"][i] * 0.5 + 1.0)}


@omp.parallel_for(stop=N, name="square")
def square(i, env):
    return {"v": omp.at(i, env["u"][i] * env["u"][i])}


@omp.parallel_for(stop=N, reduction={"ss": "+"}, name="sumsq")
def sumsq(i, env):
    return {"ss": omp.red(env["v"][i])}


rescale = omp.serial(
    lambda env: {"scale": 1.0 / jnp.sqrt(env["ss"] + 1e-6)[None]},
    reads=("ss",), name="rescale")


@omp.parallel_for(stop=N, name="normalize")
def normalize(i, env):
    return {"y": omp.at(i, env["v"][i] * env["scale"][0])}


def main() -> None:
    program = omp.region(sweep, square, sumsq, rescale, normalize,
                         name="pipeline")
    env = {"a": jnp.arange(N, dtype=jnp.float32),
           "u": jnp.zeros(N, jnp.float32), "v": jnp.zeros(N, jnp.float32),
           "ss": jnp.float32(0), "scale": jnp.zeros(1, jnp.float32),
           "y": jnp.zeros(N, jnp.float32)}

    # 1) shared-memory ("OpenMP") execution — the oracle
    ref = program(env)
    print(f"OpenMP reference:   ||y|| ~= "
          f"{float(jnp.sum(ref['y'] ** 2)):.6f}")

    # 2) the whole-program transformation (Lowering.FUSED is the
    #    default: ONE shard_map, arrays resident between loops)
    mesh = make_mesh((len(jax.devices()),), ("data",))
    dist = omp.compile(program, mesh, env_like=env)

    # 3) the residency plan — the whole-program analogue of Tables 2/3
    print()
    print(dist.report())

    # 4) fused distributed execution — correct by construction
    out = dist(env)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=1e-4, atol=1e-4)
    print("\nfused transform == reference: OK "
          f"({dist.plan.n_elided} resident handoffs, "
          f"{dist.plan.n_reshards} reshards)")

    # 5) contrast with the paper's per-loop staging (plan estimates;
    #    measured HLO counts live in benchmarks/region_chains.py)
    staged = omp.compile(program, mesh, lowering="collective")
    out_staged = staged(env)
    np.testing.assert_allclose(np.asarray(out_staged["y"]),
                               np.asarray(ref["y"]), rtol=1e-4, atol=1e-4)
    print("per-loop staged execution matches too — but every loop "
          "boundary round-trips its buffers;\nsee benchmarks/"
          "region_chains.py for the measured collective-op comparison.")


if __name__ == "__main__":
    main()
