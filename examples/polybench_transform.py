"""Polybench through the compiler: schedules and lowerings compared.

Transforms the gemm kernel under every schedule clause and both
lowerings, prints the paper-Tables-2/3-style reports, and contrasts the
communication volume of the faithful master/worker pattern (paper
Fig. 1b: all traffic through rank 0) against the balanced collective
lowering — the beyond-paper optimization quantified in EXPERIMENTS.md
§Perf-A.

This example doubles as the **legacy-shim demonstration**: the final
execution check runs once through ``omp.compile`` (the current API)
and once through the deprecated ``omp.to_mpi`` shim, showing that the
shim emits a ``DeprecationWarning`` and produces identical results.

Run:  PYTHONPATH=src python examples/polybench_transform.py
"""
import os
import sys
import warnings

import jax
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))  # for benchmarks.*

from benchmarks.polybench import make_gemm
from repro import omp
from repro.compat import make_mesh
from repro.core.plan import make_plan
from repro.core.report import _comm_summary, render_plan


def comm_total(plan) -> int:
    return int(_comm_summary(plan)[-1].split("~")[1].split()[0])


def main() -> None:
    k = make_gemm(n=64)
    gemm = k.programs[0]
    env = k.env_fn(64)
    ranks = 8

    print("=" * 70)
    print("gemm under the three schedule clauses (8 ranks)")
    print("=" * 70)
    for sched in (omp.static(), omp.dynamic(), omp.guided()):
        gemm.schedule = sched
        plan = make_plan(gemm, env, ranks)
        print(f"\nschedule({sched.kind}): chunk={plan.chunks.chunk}, "
              f"{plan.chunks.num_chunks} chunks, "
              f"comm ~{comm_total(plan)} B")

    gemm.schedule = omp.dynamic()
    print()
    print("=" * 70)
    print("collective vs master/worker lowering (the paper's Fig. 1b)")
    print("=" * 70)
    p_col = make_plan(gemm, env, ranks, lowering="collective")
    p_mw = make_plan(gemm, env, ranks, lowering="master_worker")
    c, m = comm_total(p_col), comm_total(p_mw)
    print(f"\ncollective   : ~{c/1e6:.2f} MB moved")
    print(f"master/worker: ~{m/1e6:.2f} MB moved "
          f"({m/c:.1f}x — all through rank 0's links)")

    print()
    print(render_plan(p_col))

    # execute and verify against the shared-memory reference, through
    # the current API and through the deprecated shim (same result,
    # plus a DeprecationWarning pointing at omp.compile)
    mesh = make_mesh((len(jax.devices()),), ("data",))
    ref = gemm(env)
    out = omp.compile(gemm, mesh, lowering="collective")(env)
    np.testing.assert_allclose(np.asarray(out["C"]), np.asarray(ref["C"]),
                               rtol=1e-4, atol=1e-4)
    print("\nexecution check (collective lowering): OK")

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        legacy = omp.to_mpi(gemm, mesh)(env)
    deprecations = [w for w in caught
                    if issubclass(w.category, DeprecationWarning)]
    assert deprecations
    np.testing.assert_allclose(np.asarray(legacy["C"]),
                               np.asarray(out["C"]), rtol=1e-6)
    print("legacy omp.to_mpi shim: DeprecationWarning emitted "
          f"({deprecations[0].message}), output identical")


if __name__ == "__main__":
    main()
