"""Serving example: continuous batching over a small LM.

Submits a queue of prompts to the slot-based engine; decode steps are
batched across live requests, and finished slots are immediately refilled
from the queue (vLLM-style continuous batching, DESIGN.md §3).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import build_model
from repro.serving import Request, ServeEngine


def main() -> None:
    cfg = smoke_config("gemma3-1b")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, n_slots=4, cache_len=128,
                         compute_dtype=jnp.float32)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        size=rng.integers(4, 10)).tolist(),
                    max_new_tokens=12)
            for i in range(10)]
    for r in reqs:
        engine.submit(r)

    t0 = time.time()
    ticks = 0
    while any(not r.done for r in reqs):
        live = engine.tick()
        ticks += 1
        if ticks % 5 == 0:
            done = sum(r.done for r in reqs)
            print(f"tick {ticks:3d}: {live} live slots, {done} done")
    dt = time.time() - t0
    tokens = sum(len(r.output) for r in reqs)
    print(f"\nserved {len(reqs)} requests / {tokens} tokens "
          f"in {dt:.2f}s over {ticks} ticks")
    for r in reqs[:3]:
        print(f"  req {r.rid}: {r.prompt} -> {r.output}")


if __name__ == "__main__":
    main()
