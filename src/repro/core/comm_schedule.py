"""Region-wide communication scheduling (the ``schedule_comm`` pass).

The paper closes by calling its generated MPI "a starting point that
still can be further optimized by software engineers"; the single most
standard such optimization is **message aggregation and communication/
computation overlap**.  The cost-modeled planner (:mod:`repro.core.comm`)
decides *what* each boundary moves; this pass decides *how the region
moves it*: it builds a region-wide DAG of the planned exchanges and

* **aggregates** — every buffer crossing the same (mesh-axis, shift)
  boundary at the same issue point is packed into ONE ``ppermute``
  payload per ring direction (pack → single collective → unpack; mixed
  dtypes and unequal halo widths ride a byte-level concat through
  ``lax.bitcast_convert_type``), so k same-boundary exchanges cost one
  launch instead of k;
* **fuses** — the per-stage cross-device reduction combines (``psum`` /
  ``pmax`` / ``pmin`` partials, scatter buf+mask pairs, ``put``
  broadcasts) concatenate their flattened operands per (collective,
  dtype) group and cross the mesh in one collective call (this JAX
  lowers a *tuple* ``psum`` to one all-reduce per leaf, so the fusion
  must be an explicit concat — verified bit-identical);
* **hoists** — each exchange is issued at the earliest stage after its
  producer, so fused regions *prefetch* halos while the intervening
  stages compute (XLA overlaps the in-flight collective with the
  compute between producer and consumer).

The pass sits between **plan_comm** and **lower** in the
:func:`repro.core.api.compile` pipeline, is recorded as a first-class
artifact (:class:`CommSchedule` on ``Compiled.passes``), and is toggled
by ``Options(comm_schedule="aggregate"|"inline")`` — ``inline`` pins
the PR 4 per-buffer behavior for measurement.  Wire bytes are identical
in both modes (packing concatenates, it never pads); what changes is
the *launch* count, which the aggregated cost model prices at
:data:`repro.core.comm.ALPHA_LAUNCH_BYTES` byte-equivalents per launch.

The executors (:func:`repro.core.region._execute_region` /
``_execute_region2`` and the collective lowerings in
:mod:`repro.core.transform`) consume the schedule instead of emitting
per-buffer rings inline; the packing emitters below delegate to
:func:`repro.core.comm.halo_exchange` for single-buffer groups so a
lone boundary never pays pack/unpack overhead.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict, deque
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import comm as comm_mod
from repro.core import reduction as red_mod

SCHEDULE_MODES = ("aggregate", "inline")

_FUSABLE = ("psum", "pmax", "pmin")


# ---------------------------------------------------------------------------
# Schedule IR
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CommEvent:
    """One planned halo exchange, placed in the stage timeline.

    ``shifts`` is per-axis ``(delta_min, delta_max)`` relative to the
    producing slab's base — exactly what the ring emitters consume —
    and ``producer_idx``/``consumer_idx`` index ``RegionPlan.stages``.
    The event is *issued* right after its producer (the hoist) and
    consumed at ``consumer_idx``.
    """

    key: str
    consumer: str
    consumer_idx: int
    producer: str
    producer_idx: int
    rank: int
    shifts: tuple                  # per-axis (delta_min, delta_max)
    chunks: tuple                  # per-axis chunk sizes
    num_devices: tuple             # per-axis ring sizes
    wire_bytes: int
    hops: int                      # inline ppermute launches

    @property
    def span(self) -> int:
        """Stages of compute the prefetch can overlap with."""
        return self.consumer_idx - self.producer_idx - 1


@dataclasses.dataclass(frozen=True)
class CommGroup:
    """Events packed into one exchange, issued after ``issue_idx``."""

    issue_idx: int
    issue_stage: str
    events: tuple[CommEvent, ...]
    launches_inline: int
    launches_packed: int

    @property
    def keys(self) -> tuple[str, ...]:
        return tuple(ev.key for ev in self.events)


@dataclasses.dataclass(frozen=True)
class ReduceFusion:
    """Per-stage fusion of cross-device combines into flat collectives."""

    stage: str
    stage_idx: int
    paths: tuple[str, ...]         # key (or key.mask) per combine operand
    launches_inline: int
    launches_fused: int            # one per (collective, dtype) group


@dataclasses.dataclass
class CommSchedule:
    """The schedule_comm artifact: the event timeline plus the launch
    accounting before/after aggregation."""

    mode: str
    rank: int
    events: tuple[CommEvent, ...]
    groups: tuple[CommGroup, ...]          # empty in inline mode
    reduce_fusions: tuple[ReduceFusion, ...]
    launches_inline: int
    launches_scheduled: int
    wire_bytes: int
    n_hoisted: int = 0                     # events with span >= 1

    def __post_init__(self) -> None:
        self._by_issue: dict[int, list[CommGroup]] = defaultdict(list)
        for g in self.groups:
            self._by_issue[g.issue_idx].append(g)

    def groups_after(self, stage_idx: int) -> list[CommGroup]:
        """Groups to issue right after ``stage_idx`` executes."""
        return self._by_issue.get(stage_idx, [])

    @property
    def launches_saved(self) -> int:
        return self.launches_inline - self.launches_scheduled

    def modeled_cost_bytes(self) -> tuple[int, int]:
        """(inline, scheduled) alpha-model costs in byte equivalents."""
        return (comm_mod.modeled_cost_bytes(self.wire_bytes,
                                            self.launches_inline),
                comm_mod.modeled_cost_bytes(self.wire_bytes,
                                            self.launches_scheduled))

    def describe_lines(self) -> list[str]:
        lines = []
        for g in self.groups:
            dests = ", ".join(
                f"{ev.key!r}->{ev.consumer}"
                + (f" (+{ev.span} stage overlap)" if ev.span else "")
                for ev in g.events)
            lines.append(
                f"after {g.issue_stage}: pack [{dests}] -> "
                f"{g.launches_packed} ppermute launch(es) "
                f"(inline: {g.launches_inline})")
        for rf in self.reduce_fusions:
            lines.append(
                f"{rf.stage}: fuse {rf.launches_inline} combine(s) "
                f"{list(rf.paths)} -> {rf.launches_fused} collective "
                "call(s)")
        before, after = self.modeled_cost_bytes()
        lines.append(
            f"collective launches: {self.launches_inline} inline -> "
            f"{self.launches_scheduled} scheduled "
            f"(alpha={comm_mod.ALPHA_LAUNCH_BYTES} B/launch: "
            f"~{before} -> ~{after} B-equiv)")
        return lines


# ---------------------------------------------------------------------------
# Building the schedule from a RegionPlan
# ---------------------------------------------------------------------------


def _packed_launches(events, rank: int) -> int:
    """Ring launches of one packed group: one per used direction per
    axis (the per-buffer payloads concat into one array each)."""
    n = 0
    for d in range(rank):
        if any(max(0, -ev.shifts[d][0]) > 0 for ev in events):
            n += 1
        if any(max(0, ev.shifts[d][1]) > 0 for ev in events):
            n += 1
    return n


def _stage_combines(plan, rank: int) -> list[tuple[str, str, str]]:
    """(path, collective, dtype) per cross-device combine the stage's
    output merge will issue — the fusable all-reduce population."""
    out: list[tuple[str, str, str]] = []
    for key, dec in plan.vars.items():
        if dec.out_strategy == "reduce":
            rop = red_mod.get_reduction(dec.reduction_op)
            if rop.collective in _FUSABLE:
                info = plan.context.vars[key]
                out.append((key, rop.collective,
                            str(info.write.value_dtype)))
        elif dec.out_strategy == "scatter" and rank == 1:
            info = plan.context.vars[key]
            out.append((key, "psum", str(info.dtype)))
            out.append((key + ".mask", "psum", "int32"))
        elif dec.out_strategy == "put" and rank == 1:
            info = plan.context.vars[key]
            out.append((key, "psum", str(info.dtype)))
    return out


def build_comm_schedule(rp, *, mode: str = "aggregate") -> CommSchedule:
    """Schedule a planned region's communication: the **schedule_comm**
    pass.  Walks ``rp.stages`` in order, pairing every ``halo`` feed
    with its :class:`~repro.core.comm.BoundaryComm`, tracking the last
    slab writer per key (the producer), and — in ``"aggregate"`` mode —
    grouping events by issue point and fusing per-stage reduction
    combines.  ``"inline"`` records the same events with no groups (the
    PR 4 per-buffer baseline, kept measurable)."""
    if mode not in SCHEDULE_MODES:
        raise ValueError(
            f"unknown comm schedule mode {mode!r}; expected {SCHEDULE_MODES}")
    rank = rp.rank
    pending: dict[tuple[str, str], deque] = defaultdict(deque)
    for bc in rp.comms:
        if bc.op == comm_mod.HALO:
            pending[(bc.stage, bc.key)].append(bc)

    events: list[CommEvent] = []
    reduce_fusions: list[ReduceFusion] = []
    reduce_inline = reduce_fused = 0
    last_writer: dict[str, tuple[int, str]] = {}
    for si, se in enumerate(rp.stages):
        if se.kind != "loop" or se.plan is None:
            continue
        plan = se.plan
        if plan.nest.total_trip == 0:
            continue
        for key, feed in se.feeds.items():
            if feed != "halo":
                continue
            bc = pending[(se.name, key)].popleft()
            prod_idx, prod_name = last_writer[key]
            if rank == 2:
                chunks = tuple(c.chunk for c in plan.chunks_axes)
                nd = tuple(c.num_devices for c in plan.chunks_axes)
                shifts = tuple(bc.shift)
            else:
                chunks = (plan.chunks.chunk,)
                nd = (plan.chunks.num_devices,)
                shifts = (bc.shift,)
            events.append(CommEvent(
                key=key, consumer=se.name, consumer_idx=si,
                producer=prod_name, producer_idx=prod_idx, rank=rank,
                shifts=shifts, chunks=chunks, num_devices=nd,
                wire_bytes=bc.cost.wire_bytes, hops=bc.cost.hops))

        combines = _stage_combines(plan, rank)
        if combines:
            kinds = {(c, dt) for _, c, dt in combines}
            reduce_inline += len(combines)
            reduce_fused += len(kinds)
            if len(combines) > len(kinds):
                reduce_fusions.append(ReduceFusion(
                    stage=se.name, stage_idx=si,
                    paths=tuple(p for p, _, _ in combines),
                    launches_inline=len(combines),
                    launches_fused=len(kinds)))

        for key, dec in plan.vars.items():
            if dec.out_strategy in ("identity", "partial"):
                last_writer[key] = (si, se.name)

    halo_inline = sum(ev.hops for ev in events)
    groups: list[CommGroup] = []
    if mode == "aggregate":
        by_issue: dict[int, list[CommEvent]] = defaultdict(list)
        for ev in events:
            by_issue[ev.producer_idx].append(ev)
        for idx in sorted(by_issue):
            evs = tuple(by_issue[idx])
            groups.append(CommGroup(
                issue_idx=idx, issue_stage=evs[0].producer, events=evs,
                launches_inline=sum(ev.hops for ev in evs),
                launches_packed=_packed_launches(evs, rank)))
        halo_sched = sum(g.launches_packed for g in groups)
        red_sched = reduce_fused
    else:
        halo_sched = halo_inline
        red_sched = reduce_inline

    return CommSchedule(
        mode=mode, rank=rank, events=tuple(events), groups=tuple(groups),
        reduce_fusions=tuple(reduce_fusions) if mode == "aggregate" else (),
        launches_inline=halo_inline + reduce_inline,
        launches_scheduled=halo_sched + red_sched,
        wire_bytes=sum(ev.wire_bytes for ev in events),
        n_hoisted=sum(1 for ev in events if ev.span >= 1),
    )


# ---------------------------------------------------------------------------
# Byte-level payload packing
# ---------------------------------------------------------------------------


def pack_payloads(arrs) -> tuple[Any, tuple]:
    """Flatten arbitrary-dtype arrays into one ``uint8`` vector.

    Mixed dtypes and shapes concat byte-level through
    ``lax.bitcast_convert_type`` (bools ride as ``uint8``); the returned
    specs drive :func:`unpack_payloads` on the receiving side.
    """
    flats, specs = [], []
    for a in arrs:
        was_bool = a.dtype == jnp.bool_
        if was_bool:
            a = a.astype(jnp.uint8)
        itemsize = jnp.dtype(a.dtype).itemsize
        b = (a if a.dtype == jnp.uint8
             else jax.lax.bitcast_convert_type(a, jnp.uint8))
        flats.append(b.reshape(-1))
        nbytes = itemsize
        for s in a.shape:
            nbytes *= int(s)
        specs.append((tuple(a.shape), a.dtype, was_bool, nbytes))
    return jnp.concatenate(flats), tuple(specs)


def unpack_payloads(flat, specs) -> list:
    """Invert :func:`pack_payloads` (static offsets, no copies beyond
    the reshape/bitcast)."""
    outs, off = [], 0
    for shape, dtype, was_bool, nbytes in specs:
        seg = flat[off:off + nbytes]
        off += nbytes
        itemsize = jnp.dtype(dtype).itemsize
        if itemsize == 1:
            a = jax.lax.bitcast_convert_type(seg.reshape(shape), dtype)
        else:
            a = jax.lax.bitcast_convert_type(
                seg.reshape(shape + (itemsize,)), dtype)
        outs.append(a.astype(jnp.bool_) if was_bool else a)
    return outs


def _packed_ppermute(payloads, axis: str, perm):
    """One ring shift for many buffers: single-buffer groups go direct
    (no pack/unpack overhead); larger groups byte-pack into ONE
    ``ppermute``."""
    payloads = list(payloads)
    if len(payloads) == 1:
        return [jax.lax.ppermute(payloads[0], axis, perm=perm)]
    flat, specs = pack_payloads(payloads)
    recv = jax.lax.ppermute(flat, axis, perm=perm)
    return unpack_payloads(recv, specs)


def _ring_extend_many(entries, *, axis: str, num_devices: int, device_index,
                      stack_dim: int = 0, lane_dim: int = 1):
    """Widen many chunk-cyclic slabs at once with ONE packed ``ppermute``
    per ring direction — the aggregated
    :func:`repro.core.comm._ring_extend` (same chunk adjacency, same
    per-buffer roll corrections, byte-identical windows).

    ``entries``: ``(stacks, chunk, delta_min, delta_max)`` per buffer;
    halo widths may differ per buffer (unequal payload rows simply pack
    to different byte spans).
    """
    p = num_devices
    xs, metas = [], []
    for stacks, c, dmin, dmax in entries:
        left, right = max(0, -dmin), max(0, dmax)
        if left > c or right > c:
            raise ValueError(
                f"halo shift ({dmin}, {dmax}) exceeds one chunk (chunk={c});"
                " the planner should have chosen a gather")
        xs.append(jnp.moveaxis(stacks, (stack_dim, lane_dim), (0, 1)))
        metas.append((c, dmin, dmax, left, right))

    left_ids = [k for k, m in enumerate(metas) if m[3]]
    right_ids = [k for k, m in enumerate(metas) if m[4]]
    left_recv: dict[int, Any] = {}
    if left_ids:
        recvs = _packed_ppermute(
            [xs[k][:, metas[k][0] - metas[k][3]:] for k in left_ids],
            axis, perm=[((i - 1) % p, i) for i in range(p)])
        for k, recv in zip(left_ids, recvs):
            # device 0's chunk j-1 is the last device's PREVIOUS local chunk
            rolled = jnp.concatenate([recv[:1], recv[:-1]], axis=0)
            left_recv[k] = jnp.where(device_index == 0, rolled, recv)
    right_recv: dict[int, Any] = {}
    if right_ids:
        recvs = _packed_ppermute(
            [xs[k][:, :metas[k][4]] for k in right_ids],
            axis, perm=[((i + 1) % p, i) for i in range(p)])
        for k, recv in zip(right_ids, recvs):
            # the last device's chunk j+1 is device 0's NEXT local chunk
            rolled = jnp.concatenate([recv[1:], recv[-1:]], axis=0)
            right_recv[k] = jnp.where(device_index == p - 1, rolled, recv)

    outs = []
    for k, x in enumerate(xs):
        c, dmin, dmax, left, right = metas[k]
        parts = []
        if left:
            parts.append(left_recv[k])
        parts.append(x[:, max(0, dmin):c + min(0, dmax)])
        if right:
            parts.append(right_recv[k])
        win = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
        outs.append(jnp.moveaxis(win, (0, 1), (stack_dim, lane_dim)))
    return outs


# ---------------------------------------------------------------------------
# Aggregated exchange emitters (run inside the fused shard_map)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class HaloItem:
    """Runtime payload of one scheduled exchange: the resident slab plus
    the static geometry the prior-patch needs (per-axis tuples; rank-1
    items use 1-tuples)."""

    stacks: Any
    chunks: tuple
    shifts: tuple
    prior: Any = None
    bases: tuple = (0,)
    covers: tuple | None = None
    dtype: Any = None


def aggregated_halo_exchange(items, *, axis: str, num_devices: int,
                             device_index) -> list:
    """Rank-1 aggregated exchange: every item's left payloads pack into
    one ``ppermute``, every right payload into another; returns one
    read window per item, byte-identical to per-buffer
    :func:`repro.core.comm.halo_exchange`.  Single-item groups delegate
    to it outright (no pack/unpack on lone boundaries)."""
    if len(items) == 1:
        it = items[0]
        return [comm_mod.halo_exchange(
            it.stacks, axis=axis, num_devices=num_devices,
            device_index=device_index, chunk=it.chunks[0],
            delta_min=it.shifts[0][0], delta_max=it.shifts[0][1],
            prior=it.prior, base=it.bases[0],
            cover=None if it.covers is None else it.covers[0],
            dtype=it.dtype)]
    wins = _ring_extend_many(
        [(it.stacks, it.chunks[0], it.shifts[0][0], it.shifts[0][1])
         for it in items],
        axis=axis, num_devices=num_devices, device_index=device_index)
    return [
        comm_mod.patch_window_prior(
            win, num_devices=num_devices, device_index=device_index,
            chunk=it.chunks[0], delta_min=it.shifts[0][0], prior=it.prior,
            base=it.bases[0],
            cover=None if it.covers is None else it.covers[0],
            dtype=it.dtype)
        for win, it in zip(wins, items)]


def aggregated_halo_exchange2(items, *, axes, num_devices,
                              device_indices) -> list:
    """Rank-2 aggregated exchange: one packed row-ring pass for every
    item, then one packed column-ring pass over the *extended* windows
    — the corner cells ride the second pass exactly as in the
    per-buffer emitter (:func:`repro.core.comm.halo_exchange2`), so a
    group of 2-D stencils costs at most 4 launches total."""
    if len(items) == 1:
        it = items[0]
        return [comm_mod.halo_exchange2(
            it.stacks, axes=axes, num_devices=num_devices,
            device_indices=device_indices, chunks=it.chunks,
            deltas=it.shifts, prior=it.prior, bases=it.bases,
            covers=it.covers, dtype=it.dtype)]
    wins = _ring_extend_many(
        [(it.stacks, it.chunks[0], it.shifts[0][0], it.shifts[0][1])
         for it in items],
        axis=axes[0], num_devices=num_devices[0],
        device_index=device_indices[0], stack_dim=0, lane_dim=1)
    wins = _ring_extend_many(
        [(win, it.chunks[1], it.shifts[1][0], it.shifts[1][1])
         for win, it in zip(wins, items)],
        axis=axes[1], num_devices=num_devices[1],
        device_index=device_indices[1], stack_dim=2, lane_dim=3)
    return [
        comm_mod.patch_window_prior2(
            win, num_devices=num_devices, device_indices=device_indices,
            chunks=it.chunks, deltas=it.shifts, prior=it.prior,
            bases=it.bases, covers=it.covers, dtype=it.dtype)
        for win, it in zip(wins, items)]


# ---------------------------------------------------------------------------
# Fused reduction combines
# ---------------------------------------------------------------------------

_COLLECTIVE_FNS = {
    "psum": jax.lax.psum,
    "pmax": jax.lax.pmax,
    "pmin": jax.lax.pmin,
}


def fused_collectives(entries, axis_name):
    """Cross the mesh once per (collective, dtype) group.

    ``entries``: ``{path: (collective, value)}`` with collective in
    psum/pmax/pmin.  Same-group operands flatten and concatenate into
    one vector — a single all-reduce launch — then split back (this JAX
    emits one all-reduce per *leaf* of a tuple ``psum``, so the concat
    is what actually merges launches).  Elementwise combines commute
    with concatenation, so results are bit-identical to per-operand
    collectives.  Returns ``{path: combined}``.
    """
    out: dict[Any, Any] = {}
    groups: dict[tuple[str, str], list] = {}
    for path, (coll, val) in entries.items():
        groups.setdefault((coll, str(jnp.result_type(val))), []).append(
            (path, jnp.asarray(val)))
    for (coll, _), members in groups.items():
        fn = _COLLECTIVE_FNS[coll]
        if len(members) == 1:
            path, val = members[0]
            out[path] = fn(val, axis_name)
            continue
        flats = [v.reshape(-1) for _, v in members]
        combined = fn(jnp.concatenate(flats), axis_name)
        off = 0
        for (path, val), flat in zip(members, flats):
            n = flat.shape[0]
            out[path] = combined[off:off + n].reshape(val.shape)
            off += n
    return out


def fused_cross_device_combine(items, axis_name):
    """Fused :func:`repro.core.reduction.cross_device_combine` over many
    reduction outputs at once: psum/pmax/pmin partials group through
    :func:`fused_collectives`; gather-style ops (``*``, ``/``) keep
    their per-key all-gather fold.  ``items``: ``{key: (ReductionOp,
    partial)}``; returns ``{key: combined}``."""
    out = {}
    entries = {}
    for key, (rop, val) in items.items():
        if rop.collective in _FUSABLE:
            entries[key] = (rop.collective, val)
        else:
            out[key] = red_mod.cross_device_combine(rop, val, axis_name)
    out.update(fused_collectives(entries, axis_name))
    return out
