"""Pragma IR — the ``#pragma omp parallel for`` analogue for JAX loop nests.

OMP2MPI (Saà-Garriga et al., 2015) consumes OpenMP annotations attached to
C loops.  Here the annotation is a :class:`ParallelFor` program object that
wraps a JAX loop *body* plus the clauses the paper recognises:

* loop bounds (``start``/``stop``/``step`` — §3.1.2 Loop Analysis),
* ``schedule(static|dynamic|guided[, chunk])`` (§3.1.3),
* ``reduction(op: var)`` (§3.1.3, Table 3),
* ``target mpi`` is implicit — :func:`repro.omp.to_mpi` performs the
  transformation, mirroring the paper's ``target mpi`` clause.

The body is a function ``body(i, env) -> {name: update}`` where ``env`` is
the shared-memory environment (a dict of arrays) and each update is one of

* :func:`at`   — ``var[idx] = value`` (idx may be any affine expr of ``i``),
* :func:`put`  — whole-array write whose slot does not depend on ``i``
  (the paper's "iterator not on first dimension" rule: the full array is
  taken from the worker that executes the *last* iteration),
* :func:`red`  — a value folded into a ``reduction`` clause variable.

Reads are *not* declared: they are recovered automatically from the traced
jaxpr by :mod:`repro.core.context` (the paper's Context Analysis stage).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

import jax


# ---------------------------------------------------------------------------
# Schedule clause
# ---------------------------------------------------------------------------

STATIC = "static"
DYNAMIC = "dynamic"
GUIDED = "guided"

_VALID_SCHEDULES = (STATIC, DYNAMIC, GUIDED)


@dataclasses.dataclass(frozen=True)
class Schedule:
    """``schedule(kind[, chunk])`` clause.

    ``chunk=None`` derives the chunk size the way the paper does:
    * static  -> one contiguous block per rank,
    * dynamic -> ``N / ranks / 10`` (Table 2 line 4 over-decomposition),
    * guided  -> ``N / (2 * ranks)`` (flattened guided; see DESIGN.md).
    """

    kind: str = DYNAMIC
    chunk: int | None = None

    def __post_init__(self) -> None:
        if self.kind not in _VALID_SCHEDULES:
            raise ValueError(
                f"schedule kind must be one of {_VALID_SCHEDULES}, got {self.kind!r}"
            )
        if self.chunk is not None and self.chunk < 1:
            raise ValueError(f"schedule chunk must be >= 1, got {self.chunk}")


def static(chunk: int | None = None) -> Schedule:
    return Schedule(STATIC, chunk)


def dynamic(chunk: int | None = None) -> Schedule:
    return Schedule(DYNAMIC, chunk)


def guided(chunk: int | None = None) -> Schedule:
    return Schedule(GUIDED, chunk)


# ---------------------------------------------------------------------------
# Update wrappers (the write side of the dataflow; reads are inferred)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class At:
    """``var[idx] = value`` — idx is (an affine function of) the iterator."""

    idx: Any
    value: Any

    def tree_flatten(self):
        return (self.idx, self.value), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Put:
    """Whole-array write; the array produced by the last iteration wins."""

    value: Any

    def tree_flatten(self):
        return (self.value,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Red:
    """Per-iteration contribution to a ``reduction`` clause variable."""

    value: Any

    def tree_flatten(self):
        return (self.value,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def at(idx: Any, value: Any) -> At:
    return At(idx, value)


def put(value: Any) -> Put:
    return Put(value)


def red(value: Any) -> Red:
    return Red(value)


# ---------------------------------------------------------------------------
# ParallelFor program object
# ---------------------------------------------------------------------------


def _axis_bounds(start, stop, step, collapse):
    """Normalise (start, stop, step) clauses to per-axis bound triples.

    ``collapse=1``: scalars only.  ``collapse=2``: each clause is either a
    scalar (broadcast to both axes) or a 2-tuple of per-axis values — the
    nested ``stop=`` form of the ``collapse(2)`` pragma.
    """
    def per_axis(v, default):
        if v is None:
            v = default
        if isinstance(v, (tuple, list)):
            if len(v) != collapse:
                raise ValueError(
                    f"clause {v!r} must have {collapse} entries for "
                    f"collapse={collapse}")
            return tuple(int(e) for e in v)
        return (int(v),) * collapse

    if stop is None:
        raise ValueError("parallel_for requires a static 'stop' bound")
    starts = per_axis(start, 0)
    stops = per_axis(stop, None)
    steps = per_axis(step, 1)
    return tuple(zip(starts, stops, steps))


class ParallelFor:
    """A ``#pragma omp parallel for`` block over a JAX body.

    ``collapse=2`` declares a rank-2 nest (``#pragma omp parallel for
    collapse(2)``): ``start``/``stop``/``step`` accept per-axis tuples
    (the nested ``stop=`` bounds) and the body takes ``(i, j, env)``.

    Calling the object executes the *shared-memory* ("OpenMP") semantics on
    the local device — the reference against which the MPI transformation
    is validated (the paper's "correct by construction" claim is checked
    as ``to_mpi(pf)(env) == pf(env)`` in the test-suite).
    """

    def __init__(
        self,
        body: Callable[..., Mapping[str, Any]],
        *,
        start: int | tuple = 0,
        stop: int | tuple | None = None,
        step: int | tuple = 1,
        collapse: int = 1,
        schedule: Schedule | str | None = None,
        reduction: Mapping[str, str] | None = None,
        name: str | None = None,
    ) -> None:
        if collapse not in (1, 2):
            raise ValueError(f"collapse must be 1 or 2, got {collapse}")
        if collapse == 1 and any(isinstance(v, (tuple, list))
                                 for v in (start, stop, step)):
            raise ValueError(
                "tuple bounds need collapse=2 (the nested-loop form)")
        self.collapse = collapse
        self.bounds = _axis_bounds(start, stop, step, collapse)
        if isinstance(schedule, str):
            schedule = Schedule(schedule)
        self.body = body
        # Rank-1 scalar views (the paper's single canonical loop); rank-2
        # callers use .bounds / .schedules instead.
        self.start, self.stop, self.step = self.bounds[0]
        self.schedule = schedule or Schedule(DYNAMIC)
        self.reduction = dict(reduction or {})
        self.name = name or getattr(body, "__name__", "parallel_for")

    @property
    def rank(self) -> int:
        return self.collapse

    @property
    def schedules(self) -> tuple[Schedule, ...]:
        """Per-axis schedule clauses (one shared clause, per the paper's
        single ``schedule(...)`` on the collapsed pragma)."""
        return (self.schedule,) * self.collapse

    # The single-device reference execution lives in transform.py to keep
    # the IR free of execution machinery; bound lazily to avoid a cycle.
    def __call__(self, env: Mapping[str, Any]) -> dict[str, Any]:
        from repro.core import transform as _transform

        return _transform.run_reference(self, env)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        red_s = f", reduction={self.reduction}" if self.reduction else ""
        rngs = " x ".join(f"range({s}, {e}, {t})" for s, e, t in self.bounds)
        return (
            f"ParallelFor({self.name}, {rngs}, "
            f"schedule={self.schedule.kind}{red_s})"
        )


def parallel_for(
    *,
    start: int | tuple = 0,
    stop: int | tuple | None = None,
    step: int | tuple = 1,
    collapse: int = 1,
    schedule: Schedule | str | None = None,
    reduction: Mapping[str, str] | None = None,
    name: str | None = None,
) -> Callable[[Callable], ParallelFor]:
    """Decorator form: ``@omp.parallel_for(stop=N, schedule=omp.dynamic())``
    or, for a rank-2 nest, ``@omp.parallel_for(stop=(N, M), collapse=2)``."""

    def wrap(body: Callable) -> ParallelFor:
        return ParallelFor(
            body,
            start=start,
            stop=stop,
            step=step,
            collapse=collapse,
            schedule=schedule,
            reduction=reduction,
            name=name,
        )

    return wrap


# ---------------------------------------------------------------------------
# ParallelRegion — whole-program container (beyond-paper §3.1.4 extension)
# ---------------------------------------------------------------------------


class SerialStage:
    """Pure serial glue between parallel blocks.

    ``fn(env) -> {name: new_value}`` computes whole-array updates with no
    parallel loop (the code *between* two ``#pragma omp parallel for``
    blocks in the source program).  Inside the distributed region it runs
    redundantly on every rank over replicated buffers.

    ``reads`` restricts which environment buffers the function consumes;
    the region planner only materialises (gathers) the slab-resident
    buffers it names.  ``reads=None`` (default) is conservative: every
    buffer is materialised before the stage runs.
    """

    def __init__(self, fn: Callable[[Mapping[str, Any]], Mapping[str, Any]],
                 *, reads: tuple[str, ...] | None = None,
                 name: str | None = None) -> None:
        self.fn = fn
        self.reads = tuple(reads) if reads is not None else None
        self.name = name or getattr(fn, "__name__", "serial")

    def __call__(self, env: Mapping[str, Any]) -> dict[str, Any]:
        out = dict(env)
        out.update(self.fn(env))
        return out

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        r = f", reads={list(self.reads)}" if self.reads is not None else ""
        return f"SerialStage({self.name}{r})"


def serial(fn: Callable | None = None, *,
           reads: tuple[str, ...] | None = None,
           name: str | None = None):
    """Wrap serial glue for a :class:`ParallelRegion` (decorator or call)."""
    if fn is not None:
        return SerialStage(fn, reads=reads, name=name)

    def wrap(f: Callable) -> SerialStage:
        return SerialStage(f, reads=reads, name=name)

    return wrap


class ParallelRegion:
    """An ordered whole-program sequence of :class:`ParallelFor` blocks
    and optional :class:`SerialStage` glue.

    The paper transforms each ``parallel for`` in isolation, so data
    returns to rank 0 between consecutive loops (its Fig. 1b bottleneck).
    A region is transformed *as a whole* by :func:`repro.omp.region_to_mpi`:
    the inter-loop residency planner keeps arrays distributed across
    stage boundaries whenever the producing loop's OUT layout matches the
    consuming loop's IN requirement.

    Calling the region executes the shared-memory ("OpenMP") semantics:
    each stage's reference executor in program order — the oracle the
    fused transformation is validated against.
    """

    def __init__(self, stages, *, name: str | None = None) -> None:
        stages = tuple(stages)
        if not stages:
            raise ValueError("ParallelRegion needs at least one stage")
        for s in stages:
            if not isinstance(s, (ParallelFor, SerialStage)):
                raise TypeError(
                    "region stages must be ParallelFor or SerialStage, got "
                    f"{type(s).__name__}"
                )
        if not any(isinstance(s, ParallelFor) for s in stages):
            raise ValueError("ParallelRegion needs at least one ParallelFor")
        self.stages = stages
        self.name = name or "region"

    @property
    def loops(self) -> tuple[ParallelFor, ...]:
        return tuple(s for s in self.stages if isinstance(s, ParallelFor))

    @property
    def rank(self) -> int:
        """The nest rank shared by every loop in the region (mixed-rank
        regions cannot share one mesh decomposition)."""
        ranks = {lp.rank for lp in self.loops}
        if len(ranks) != 1:
            raise ValueError(
                f"region {self.name!r} mixes nest ranks {sorted(ranks)}; "
                "all loops must share one collapse level")
        return ranks.pop()

    def __call__(self, env: Mapping[str, Any]) -> dict[str, Any]:
        out = dict(env)
        for stage in self.stages:
            out = stage(out)
        return out

    def __iter__(self):
        return iter(self.stages)

    def __len__(self) -> int:
        return len(self.stages)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        inner = ", ".join(s.name for s in self.stages)
        return f"ParallelRegion({self.name}: [{inner}])"


def region(*stages, name: str | None = None) -> ParallelRegion:
    """Build a :class:`ParallelRegion`; accepts stages or one iterable."""
    if len(stages) == 1 and not isinstance(stages[0],
                                           (ParallelFor, SerialStage)):
        stages = tuple(stages[0])
    return ParallelRegion(stages, name=name)
