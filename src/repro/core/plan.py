"""Workload-distribution planning (paper §3.1.3).

The planning work is organised as the first three passes of the
:func:`repro.core.api.compile` pipeline:

* :func:`analyze_program`  — the **analyze** pass: loop/nest
  canonicalisation (§3.1.2) + context analysis (§3.1.1),
* :func:`plan_schedule`    — the **schedule** pass: chunking math
  (§3.1.3, Table 2),
* :func:`decide_strategies` — the **plan** pass: one transfer strategy
  per shared variable, fused into a :class:`DistPlan`.

``make_plan`` composes the three (the historical single-call surface,
still used by the region planner).  The strategies are the TPU-native
renditions of the paper's transfer rules:

==================  =====================================================
strategy            paper rule it implements
==================  =====================================================
replicate_in        IN variable: master sends the buffer to every worker
                    (SPMD: replicated ``in_specs``)
shard_in            IN/INOUT read ``x[i]``: master sends only the chunk's
                    slice (SPMD: cyclic-reshaped sharded input slab)
shard_out_identity  OUT/INOUT write ``x[i]`` covering the whole leading
                    dim: workers return only their slices (SPMD: sharded
                    output slab, reassembled by layout)
partial_identity    same but covering rows ``[b, b+T)`` only: slices are
                    written back into the master copy
scatter_psum        affine-but-strided write ``x[a*i+b]``: each worker
                    returns a masked full-size buffer, combined with a
                    psum and merged into the master copy (the paper's
                    "transfer the full modified array" case)
put_broadcast       iterator not on the leading dim: the full array is
                    taken from the worker that ran the *last* chunk
reduce_psum/...     reduction clause: identity-init partials + op-matched
                    cross-device combine
==================  =====================================================

Writes whose index is not affine in the iterator are rejected with
:class:`LoopNotCanonical` — the paper keeps such blocks as OpenMP.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

from repro.core import context as ctx_mod
from repro.core import pragma
from repro.core import schedule as schedule_mod
from repro.core.context import ReadKind, VarClass, WriteKind
from repro.core.loop import LoopInfo, LoopNotCanonical, analyze_loop
from repro.core.nest import LoopNest, NestAffine


@dataclasses.dataclass(frozen=True)
class KAffine:
    """Index map rebased to iteration number k in [0, T): ``a*k + b``."""

    a: int
    b: int

    @classmethod
    def from_iter_affine(cls, aff: ctx_mod.Affine, loop: LoopInfo) -> "KAffine":
        return cls(a=aff.a * loop.step, b=aff.a * loop.start + aff.b)

    def position(self, k: int) -> int:
        return self.a * k + self.b

    @property
    def is_identity(self) -> bool:
        return self.a == 1 and self.b == 0


def _k_axis_maps(aff: NestAffine, nest: LoopNest) -> tuple[KAffine, ...] | None:
    """Rebase a rank-2 :class:`NestAffine` to k-space and require it to
    follow exactly one nest axis (``a*k_d + b``); returns the per-axis
    :class:`KAffine` view ``(axis, KAffine)``-style or None when the map
    mixes axes (non-separable — the paper keeps such blocks as OpenMP)."""
    k = aff.k_space(nest)
    hits = [d for d, a in enumerate(k.coeffs) if a != 0]
    if len(hits) > 1:
        return None
    d = hits[0] if hits else 0
    return (d, KAffine(k.coeffs[d] if hits else 0, k.b))


@dataclasses.dataclass
class VarDecision:
    key: str
    klass: VarClass
    in_strategy: str            # "replicate" | "shard" | "shard_halo"
                                # | "none"
    out_strategy: str           # "none" | "identity" | "partial" | "scatter"
                                # | "put" | "reduce"
    read_map: KAffine | None = None
    write_map: KAffine | None = None
    reduction_op: str | None = None
    halo: tuple[int, int] | None = None   # (bk_min, bk_max) for stencils
    note: str = ""
    # rank-2 nests: per-buffer-axis k-space maps and halo windows; the
    # leading ``shard_ndim`` buffer axes are chunk-distributed (buffer
    # axis d follows nest axis d)
    read_maps: tuple | None = None        # per-axis KAffine (sharded axes)
    write_maps: tuple | None = None       # per-axis KAffine for at((i,j),v)
    halo_axes: tuple | None = None        # per-axis (b_min, b_max)
    shard_ndim: int = 0


@dataclasses.dataclass
class DistPlan:
    name: str
    loop: LoopInfo
    chunks: schedule_mod.ChunkPlan
    vars: dict[str, VarDecision]
    axis: str | tuple
    lowering: str
    shard_inputs: bool
    context: ctx_mod.ContextInfo
    nest: LoopNest | None = None
    chunks_axes: tuple = ()

    def __post_init__(self) -> None:
        if self.nest is None:
            self.nest = LoopNest((self.loop,))
        if not self.chunks_axes:
            self.chunks_axes = (self.chunks,)

    @property
    def rank(self) -> int:
        return self.nest.rank

    @property
    def axes_names(self) -> tuple[str, ...]:
        return self.axis if isinstance(self.axis, tuple) else (self.axis,)

    @property
    def sharded_in_keys(self) -> list[str]:
        return [k for k, v in self.vars.items()
                if v.in_strategy in ("shard", "shard_halo")]

    @property
    def replicated_in_keys(self) -> list[str]:
        return [k for k, v in self.vars.items() if v.in_strategy == "replicate"]


def analyze_program(
    program: pragma.ParallelFor,
    env: Mapping[str, Any],
) -> tuple[LoopNest, ctx_mod.ContextInfo]:
    """Compiler pass **analyze**: canonicalise the loop nest (§3.1.2)
    and run Context Analysis over the traced body (§3.1.1).

    Returns the :class:`LoopNest` IR plus the per-buffer
    :class:`~repro.core.context.ContextInfo` — the artifact every later
    pass consumes."""
    nest = LoopNest.from_program(program)
    ctx = ctx_mod.analyze_context(program, env, nest)
    return nest, ctx


def plan_schedule(
    program: pragma.ParallelFor,
    nest: LoopNest,
    num_devices: int | tuple,
    *,
    lowering: str = "collective",
    paper_master_excluded: bool | None = None,
    schedule: pragma.Schedule | None = None,
    weights=None,
) -> tuple:
    """Compiler pass **schedule**: the chunking math of §3.1.3 (Table 2)
    as per-axis :class:`~repro.core.schedule.ChunkPlan`\\ s.

    ``schedule`` overrides the program's own clause (the
    :class:`~repro.core.api.Options` schedule override); ``None`` keeps
    the clause written on the pragma.  ``weights`` (per-device, per-axis
    for rank 2) switches the cyclic deal to the straggler-weighted one
    — collective lowering only (the master/worker row math and the
    fused ring exchanges assume cyclic ownership)."""
    if weights is not None and lowering != "collective":
        raise LoopNotCanonical(
            "straggler-weighted schedules require the collective "
            f"lowering, not {lowering!r}")
    if nest.rank == 2:
        scheds = ((schedule,) * nest.rank if schedule is not None
                  else program.schedules)
        return schedule_mod.make_nest_chunk_plans(
            nest, scheds, num_devices, weights=weights)
    sched = schedule if schedule is not None else program.schedule
    if weights is not None and not any(
            e is None or hasattr(e, "__len__") for e in weights):
        weights = (weights,)    # flat rank-1 vector -> per-axis form
    w0 = weights[0] if weights is not None else None
    if paper_master_excluded is None:
        paper_master_excluded = lowering == "master_worker"

    compute_devices = num_devices
    if lowering == "master_worker":
        if num_devices < 2:
            raise LoopNotCanonical(
                "master_worker lowering needs >= 2 devices (rank 0 is the master)"
            )
        if num_devices > 64:
            raise LoopNotCanonical(
                "master_worker lowering emits O(P) point-to-point permutes; "
                "use lowering='collective' beyond 64 devices"
            )
        if paper_master_excluded:
            compute_devices = num_devices - 1

    return (schedule_mod.make_chunk_plan(
        nest.axes[0], sched, compute_devices,
        paper_master_excluded=False,  # already folded into compute_devices
        weights=w0,
    ),)


def make_plan(
    program: pragma.ParallelFor,
    env: Mapping[str, Any],
    num_devices: int | tuple,
    *,
    axis: str | tuple = "data",
    lowering: str = "collective",
    shard_inputs: bool = False,
    paper_master_excluded: bool | None = None,
    schedule: pragma.Schedule | None = None,
    weights=None,
) -> DistPlan:
    """analyze → schedule → plan, composed (the historical one-call
    planning surface; :func:`repro.core.api.compile` runs the passes
    individually so each artifact is recorded)."""
    if lowering not in ("collective", "master_worker"):
        raise ValueError(f"unknown lowering {lowering!r}")
    if program.rank == 2:
        if lowering != "collective":
            raise LoopNotCanonical(
                "collapse=2 nests only lower through the collective path "
                "(the paper's master/worker staging is rank-1 only)")
        if not isinstance(axis, tuple) or len(axis) != 2:
            raise ValueError(
                f"collapse=2 needs a 2-tuple of mesh axes, got {axis!r}")
        if not isinstance(num_devices, tuple) or len(num_devices) != 2:
            raise ValueError(
                f"collapse=2 needs per-axis device counts, got {num_devices!r}")
    elif isinstance(axis, tuple) or isinstance(num_devices, tuple):
        raise LoopNotCanonical(
            "a 2-D mesh axis tuple needs a collapse=2 nest; transform "
            "rank-1 loops over a single named axis")

    nest, ctx = analyze_program(program, env)
    chunks_axes = plan_schedule(
        program, nest, num_devices, lowering=lowering,
        paper_master_excluded=paper_master_excluded, schedule=schedule,
        weights=weights)
    return decide_strategies(
        program, nest, ctx, chunks_axes, axis=axis, lowering=lowering,
        shard_inputs=shard_inputs)


def decide_strategies(
    program: pragma.ParallelFor,
    nest: LoopNest,
    ctx: ctx_mod.ContextInfo,
    chunks_axes: tuple,
    *,
    axis: str | tuple = "data",
    lowering: str = "collective",
    shard_inputs: bool = False,
) -> DistPlan:
    """Compiler pass **plan**: fold the analyze + schedule artifacts into
    one transfer strategy per shared variable (paper §3.1.3's workload
    distribution decisions), returning the :class:`DistPlan`."""
    if nest.rank == 2:
        return _decide_strategies2(
            program, nest, ctx, chunks_axes, axis=axis, lowering=lowering,
            shard_inputs=shard_inputs)
    loop = nest.axes[0]
    chunks = chunks_axes[0]

    decisions: dict[str, VarDecision] = {}
    t = loop.trip_count
    for key, info in ctx.vars.items():
        read_map = None
        if info.read.kind == ReadKind.SLICED and info.read.affine is not None:
            read_map = KAffine.from_iter_affine(info.read.affine, loop)

        write_map = None
        out_strategy = "none"
        note = ""
        w = info.write
        if w.kind == WriteKind.AT:
            if w.affine is None:
                raise LoopNotCanonical(
                    f"write index of {key!r} is not an affine function of the "
                    "iterator (paper §3.1.3: block kept as OpenMP)"
                )
            write_map = KAffine.from_iter_affine(w.affine, loop)
            if write_map.a == 0 and t > 1:
                raise LoopNotCanonical(
                    f"{key!r}: every iteration writes the same element "
                    "(concurrent access; paper §3.1.3 refuses to divide)"
                )
            shape0 = info.shape[0] if info.shape else 0
            if tuple(w.value_shape) != tuple(info.shape[1:]):
                raise LoopNotCanonical(
                    f"{key!r}: per-iteration value shape {w.value_shape} does "
                    f"not match buffer row shape {info.shape[1:]}"
                )
            lo = min(write_map.position(0), write_map.position(max(0, t - 1)))
            hi = max(write_map.position(0), write_map.position(max(0, t - 1)))
            if t > 0 and (lo < 0 or hi >= shape0):
                raise LoopNotCanonical(
                    f"{key!r}: write positions [{lo}, {hi}] out of bounds for "
                    f"leading dim {shape0}"
                )
            if write_map.is_identity and t == shape0:
                out_strategy = "identity"
            elif write_map.a == 1 and 0 <= write_map.b and write_map.b + t <= shape0:
                out_strategy = "partial"
                note = f"rows [{write_map.b}, {write_map.b + t}) updated in place"
            else:
                out_strategy = "scatter"
                note = (
                    "strided affine write: full-size masked psum combine "
                    "(paper: whole modified array is transferred)"
                )
        elif w.kind == WriteKind.PUT:
            out_strategy = "put"
            if tuple(w.value_shape) != tuple(info.shape):
                raise LoopNotCanonical(
                    f"{key!r}: omp.put value shape {w.value_shape} != buffer "
                    f"shape {info.shape}"
                )
            note = "full array taken from the worker owning the last iteration"
        elif w.kind == WriteKind.RED:
            out_strategy = "reduce"

        # Input strategy: shard only when every read is the identity slice
        # x[k-affine-identity]; stencils (several unit-stride maps) shard
        # with a halo; everything else replicates (the paper's
        # master->worker full-buffer send).
        in_strategy = "none"
        halo = None
        if info.read.kind == ReadKind.WHOLE:
            in_strategy = "replicate"
        elif info.read.kind == ReadKind.SLICED:
            in_strategy = "replicate"
            if (shard_inputs and lowering == "collective"
                    and read_map is not None and info.shape):
                if read_map.is_identity and info.shape[0] == t:
                    in_strategy = "shard"
                elif (read_map.a == 1 and read_map.b >= 0
                      and read_map.b + t <= info.shape[0]):
                    # aligned unit-stride read x[k+b]: sharded slab with
                    # a degenerate (b, b) halo window — each chunk gets
                    # exactly the rows it reads (beyond-paper; enables
                    # inter-loop residency for partial-cover chains)
                    in_strategy = "shard_halo"
                    halo = (read_map.b, read_map.b)
        elif info.read.kind == ReadKind.STENCIL:
            kmaps = [KAffine.from_iter_affine(a, loop)
                     for a in info.read.affines]
            eligible = (
                shard_inputs
                and lowering == "collective"
                and all(m.a == 1 for m in kmaps)
                and info.shape
                # every read in-bounds across the iteration space
                and min(m.b for m in kmaps) >= 0
                and max(m.b for m in kmaps) + t <= info.shape[0]
            )
            if eligible:
                in_strategy = "shard_halo"
                halo = (min(m.b for m in kmaps), max(m.b for m in kmaps))
                note = (note + "; " if note else "") + (
                    f"stencil halo rows [{halo[0]}, {halo[1]}] exchanged "
                    "instead of replicating the buffer (beyond-paper)")
            else:
                in_strategy = "replicate"
        # partial/scatter merges re-read the master copy outside shard_map;
        # no extra in-strategy needed for that.

        decisions[key] = VarDecision(
            key=key,
            klass=info.klass,
            in_strategy=in_strategy,
            out_strategy=out_strategy,
            read_map=read_map,
            write_map=write_map,
            reduction_op=w.reduction_op,
            halo=halo,
            note=note,
        )

    return DistPlan(
        name=program.name,
        loop=loop,
        chunks=chunks,
        vars=decisions,
        axis=axis,
        lowering=lowering,
        shard_inputs=shard_inputs,
        context=ctx,
    )


# ---------------------------------------------------------------------------
# Rank-2 nests (``collapse=2``) over 2-D meshes
# ---------------------------------------------------------------------------


def _decide_strategies2(
    program: pragma.ParallelFor,
    nest: LoopNest,
    ctx: ctx_mod.ContextInfo,
    chunks_axes: tuple,
    *,
    axis: str | tuple,
    lowering: str,
    shard_inputs: bool,
) -> DistPlan:
    """Workload distribution for a rank-2 nest: buffer axis ``d`` is
    chunk-distributed along nest axis ``d`` over mesh axis ``axis[d]``
    (the diagonal assignment; swapped/strided maps fall back to the
    paper's replicate/reject rules)."""
    trips = nest.trip_counts
    total = nest.total_trip

    decisions: dict[str, VarDecision] = {}
    for key, info in ctx.vars.items():
        out_strategy = "none"
        write_maps = None
        note = ""
        w = info.write
        if w.kind == WriteKind.AT:
            if w.affines2 is None or any(a is None for a in w.affines2):
                raise LoopNotCanonical(
                    f"write index of {key!r} is not an affine function of "
                    "the iterators (paper §3.1.3: block kept as OpenMP)")
            kmaps = [_k_axis_maps(a, nest) for a in w.affines2]
            ok = (None not in kmaps
                  and all(m[0] == d and m[1].a == 1
                          for d, m in enumerate(kmaps)))
            if not ok:
                raise LoopNotCanonical(
                    f"{key!r}: collapse=2 writes must be unit-stride per "
                    "axis (x[i+b0, j+b1]); swapped or strided maps are "
                    "kept as OpenMP blocks")
            write_maps = tuple(m[1] for m in kmaps)
            if len(info.shape) < 2:
                raise LoopNotCanonical(
                    f"{key!r}: a collapse=2 write needs a >=2-D buffer")
            if tuple(w.value_shape) != tuple(info.shape[2:]):
                raise LoopNotCanonical(
                    f"{key!r}: per-iteration value shape {w.value_shape} "
                    f"does not match buffer cell shape {info.shape[2:]}")
            if total > 0:
                for d in range(2):
                    b = write_maps[d].b
                    if b < 0 or b + trips[d] > info.shape[d]:
                        raise LoopNotCanonical(
                            f"{key!r}: axis-{d} write window [{b}, "
                            f"{b + trips[d]}) out of bounds for dim "
                            f"{info.shape[d]}")
            if (all(m.b == 0 for m in write_maps)
                    and tuple(info.shape[:2]) == trips):
                out_strategy = "identity"
            else:
                out_strategy = "partial"
                note = (f"rows [{write_maps[0].b}, "
                        f"{write_maps[0].b + trips[0]}) x cols "
                        f"[{write_maps[1].b}, {write_maps[1].b + trips[1]}) "
                        "updated in place")
        elif w.kind == WriteKind.RED:
            out_strategy = "reduce"

        # Input strategy: chunk-shard the leading buffer axes whose every
        # access follows its own nest axis with unit stride; everything
        # else replicates (the paper's master->worker full-buffer send).
        in_strategy = "none"
        read_maps = None
        halo_axes = None
        shard_ndim = 0
        if info.read.kind == ReadKind.WHOLE:
            in_strategy = "replicate"
        elif info.read.kind in (ReadKind.SLICED, ReadKind.STENCIL):
            in_strategy = "replicate"
            r = info.read.slice_ndim
            eligible = shard_inputs and r in (1, 2) and len(info.shape) >= r
            k_accesses: list[tuple[KAffine, ...]] = []
            if eligible:
                for acc in info.read.accesses:
                    kmaps = [_k_axis_maps(a, nest) for a in acc]
                    if (None in kmaps
                            or any(m[0] != d or m[1].a != 1
                                   for d, m in enumerate(kmaps))):
                        eligible = False
                        break
                    k_accesses.append(tuple(m[1] for m in kmaps))
            if eligible:
                halos = []
                for d in range(r):
                    bs = [acc[d].b for acc in k_accesses]
                    lo, hi = min(bs), max(bs)
                    if lo < 0 or hi + trips[d] > info.shape[d]:
                        eligible = False
                        break
                    halos.append((lo, hi))
                if eligible:
                    in_strategy = "shard_halo"
                    shard_ndim = r
                    halo_axes = tuple(halos)
                    read_maps = k_accesses[0]
                    if any(h != (0, 0) for h in halos):
                        note = (note + "; " if note else "") + (
                            "halo windows " + ", ".join(
                                f"axis{d} [{h[0]}, {h[1]}]"
                                for d, h in enumerate(halos))
                            + " exchanged instead of replicating")

        decisions[key] = VarDecision(
            key=key,
            klass=info.klass,
            in_strategy=in_strategy,
            out_strategy=out_strategy,
            reduction_op=w.reduction_op,
            note=note,
            read_maps=read_maps,
            write_maps=write_maps,
            halo_axes=halo_axes,
            shard_ndim=shard_ndim,
        )

    return DistPlan(
        name=program.name,
        loop=nest.axes[0],
        chunks=chunks_axes[0],
        vars=decisions,
        axis=axis,
        lowering=lowering,
        shard_inputs=shard_inputs,
        context=ctx,
        nest=nest,
        chunks_axes=chunks_axes,
    )
