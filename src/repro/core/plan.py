"""Workload-distribution planning (paper §3.1.3).

``make_plan`` fuses the three analysis stages (loop, context, schedule)
into a :class:`DistPlan`: one strategy per shared variable plus the chunk
assignment.  The strategies are the TPU-native renditions of the paper's
transfer rules:

==================  =====================================================
strategy            paper rule it implements
==================  =====================================================
replicate_in        IN variable: master sends the buffer to every worker
                    (SPMD: replicated ``in_specs``)
shard_in            IN/INOUT read ``x[i]``: master sends only the chunk's
                    slice (SPMD: cyclic-reshaped sharded input slab)
shard_out_identity  OUT/INOUT write ``x[i]`` covering the whole leading
                    dim: workers return only their slices (SPMD: sharded
                    output slab, reassembled by layout)
partial_identity    same but covering rows ``[b, b+T)`` only: slices are
                    written back into the master copy
scatter_psum        affine-but-strided write ``x[a*i+b]``: each worker
                    returns a masked full-size buffer, combined with a
                    psum and merged into the master copy (the paper's
                    "transfer the full modified array" case)
put_broadcast       iterator not on the leading dim: the full array is
                    taken from the worker that ran the *last* chunk
reduce_psum/...     reduction clause: identity-init partials + op-matched
                    cross-device combine
==================  =====================================================

Writes whose index is not affine in the iterator are rejected with
:class:`LoopNotCanonical` — the paper keeps such blocks as OpenMP.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

from repro.core import context as ctx_mod
from repro.core import pragma, schedule
from repro.core.context import ReadKind, VarClass, WriteKind
from repro.core.loop import LoopInfo, LoopNotCanonical, analyze_loop


@dataclasses.dataclass(frozen=True)
class KAffine:
    """Index map rebased to iteration number k in [0, T): ``a*k + b``."""

    a: int
    b: int

    @classmethod
    def from_iter_affine(cls, aff: ctx_mod.Affine, loop: LoopInfo) -> "KAffine":
        return cls(a=aff.a * loop.step, b=aff.a * loop.start + aff.b)

    def position(self, k: int) -> int:
        return self.a * k + self.b

    @property
    def is_identity(self) -> bool:
        return self.a == 1 and self.b == 0


@dataclasses.dataclass
class VarDecision:
    key: str
    klass: VarClass
    in_strategy: str            # "replicate" | "shard" | "shard_halo"
                                # | "none"
    out_strategy: str           # "none" | "identity" | "partial" | "scatter"
                                # | "put" | "reduce"
    read_map: KAffine | None = None
    write_map: KAffine | None = None
    reduction_op: str | None = None
    halo: tuple[int, int] | None = None   # (bk_min, bk_max) for stencils
    note: str = ""


@dataclasses.dataclass
class DistPlan:
    name: str
    loop: LoopInfo
    chunks: schedule.ChunkPlan
    vars: dict[str, VarDecision]
    axis: str
    lowering: str
    shard_inputs: bool
    context: ctx_mod.ContextInfo

    @property
    def sharded_in_keys(self) -> list[str]:
        return [k for k, v in self.vars.items()
                if v.in_strategy in ("shard", "shard_halo")]

    @property
    def replicated_in_keys(self) -> list[str]:
        return [k for k, v in self.vars.items() if v.in_strategy == "replicate"]


def make_plan(
    program: pragma.ParallelFor,
    env: Mapping[str, Any],
    num_devices: int,
    *,
    axis: str = "data",
    lowering: str = "collective",
    shard_inputs: bool = False,
    paper_master_excluded: bool | None = None,
) -> DistPlan:
    if lowering not in ("collective", "master_worker"):
        raise ValueError(f"unknown lowering {lowering!r}")
    if paper_master_excluded is None:
        paper_master_excluded = lowering == "master_worker"

    loop = analyze_loop(program.start, program.stop, program.step)
    ctx = ctx_mod.analyze_context(program, env, loop)

    compute_devices = num_devices
    if lowering == "master_worker":
        if num_devices < 2:
            raise LoopNotCanonical(
                "master_worker lowering needs >= 2 devices (rank 0 is the master)"
            )
        if num_devices > 64:
            raise LoopNotCanonical(
                "master_worker lowering emits O(P) point-to-point permutes; "
                "use lowering='collective' beyond 64 devices"
            )
        if paper_master_excluded:
            compute_devices = num_devices - 1

    chunks = schedule.make_chunk_plan(
        loop, program.schedule, compute_devices,
        paper_master_excluded=False,  # already folded into compute_devices
    )

    decisions: dict[str, VarDecision] = {}
    t = loop.trip_count
    for key, info in ctx.vars.items():
        read_map = None
        if info.read.kind == ReadKind.SLICED and info.read.affine is not None:
            read_map = KAffine.from_iter_affine(info.read.affine, loop)

        write_map = None
        out_strategy = "none"
        note = ""
        w = info.write
        if w.kind == WriteKind.AT:
            if w.affine is None:
                raise LoopNotCanonical(
                    f"write index of {key!r} is not an affine function of the "
                    "iterator (paper §3.1.3: block kept as OpenMP)"
                )
            write_map = KAffine.from_iter_affine(w.affine, loop)
            if write_map.a == 0 and t > 1:
                raise LoopNotCanonical(
                    f"{key!r}: every iteration writes the same element "
                    "(concurrent access; paper §3.1.3 refuses to divide)"
                )
            shape0 = info.shape[0] if info.shape else 0
            if tuple(w.value_shape) != tuple(info.shape[1:]):
                raise LoopNotCanonical(
                    f"{key!r}: per-iteration value shape {w.value_shape} does "
                    f"not match buffer row shape {info.shape[1:]}"
                )
            lo = min(write_map.position(0), write_map.position(max(0, t - 1)))
            hi = max(write_map.position(0), write_map.position(max(0, t - 1)))
            if t > 0 and (lo < 0 or hi >= shape0):
                raise LoopNotCanonical(
                    f"{key!r}: write positions [{lo}, {hi}] out of bounds for "
                    f"leading dim {shape0}"
                )
            if write_map.is_identity and t == shape0:
                out_strategy = "identity"
            elif write_map.a == 1 and 0 <= write_map.b and write_map.b + t <= shape0:
                out_strategy = "partial"
                note = f"rows [{write_map.b}, {write_map.b + t}) updated in place"
            else:
                out_strategy = "scatter"
                note = (
                    "strided affine write: full-size masked psum combine "
                    "(paper: whole modified array is transferred)"
                )
        elif w.kind == WriteKind.PUT:
            out_strategy = "put"
            if tuple(w.value_shape) != tuple(info.shape):
                raise LoopNotCanonical(
                    f"{key!r}: omp.put value shape {w.value_shape} != buffer "
                    f"shape {info.shape}"
                )
            note = "full array taken from the worker owning the last iteration"
        elif w.kind == WriteKind.RED:
            out_strategy = "reduce"

        # Input strategy: shard only when every read is the identity slice
        # x[k-affine-identity]; stencils (several unit-stride maps) shard
        # with a halo; everything else replicates (the paper's
        # master->worker full-buffer send).
        in_strategy = "none"
        halo = None
        if info.read.kind == ReadKind.WHOLE:
            in_strategy = "replicate"
        elif info.read.kind == ReadKind.SLICED:
            in_strategy = "replicate"
            if (shard_inputs and lowering == "collective"
                    and read_map is not None and info.shape):
                if read_map.is_identity and info.shape[0] == t:
                    in_strategy = "shard"
                elif (read_map.a == 1 and read_map.b >= 0
                      and read_map.b + t <= info.shape[0]):
                    # aligned unit-stride read x[k+b]: sharded slab with
                    # a degenerate (b, b) halo window — each chunk gets
                    # exactly the rows it reads (beyond-paper; enables
                    # inter-loop residency for partial-cover chains)
                    in_strategy = "shard_halo"
                    halo = (read_map.b, read_map.b)
        elif info.read.kind == ReadKind.STENCIL:
            kmaps = [KAffine.from_iter_affine(a, loop)
                     for a in info.read.affines]
            eligible = (
                shard_inputs
                and lowering == "collective"
                and all(m.a == 1 for m in kmaps)
                and info.shape
                # every read in-bounds across the iteration space
                and min(m.b for m in kmaps) >= 0
                and max(m.b for m in kmaps) + t <= info.shape[0]
            )
            if eligible:
                in_strategy = "shard_halo"
                halo = (min(m.b for m in kmaps), max(m.b for m in kmaps))
                note = (note + "; " if note else "") + (
                    f"stencil halo rows [{halo[0]}, {halo[1]}] exchanged "
                    "instead of replicating the buffer (beyond-paper)")
            else:
                in_strategy = "replicate"
        # partial/scatter merges re-read the master copy outside shard_map;
        # no extra in-strategy needed for that.

        decisions[key] = VarDecision(
            key=key,
            klass=info.klass,
            in_strategy=in_strategy,
            out_strategy=out_strategy,
            read_map=read_map,
            write_map=write_map,
            reduction_op=w.reduction_op,
            halo=halo,
            note=note,
        )

    return DistPlan(
        name=program.name,
        loop=loop,
        chunks=chunks,
        vars=decisions,
        axis=axis,
        lowering=lowering,
        shard_inputs=shard_inputs,
        context=ctx,
    )
