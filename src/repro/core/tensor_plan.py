"""Tensor-level distribution planning: "pragmas for tensors".

A matmul *is* a parallel loop nest, so the paper's derivation generalises:
every model tensor (param or activation) carries a tuple of *logical axis*
names — the loop variables of the nest it participates in — and the
planner maps logical axes onto mesh axes, exactly as
:mod:`repro.core.plan` maps the explicit-loop iteration space onto the
device axis:

* a dim whose logical axis maps to a mesh axis is *chunk-distributed*
  (the paper's OUT-slice rule -> sharded),
* a dim with no mapping is *replicated* (the paper's IN-broadcast rule),
* contractions over a mapped axis become ``psum``-style partials (the
  reduction clause) — inserted by GSPMD at the jit level.

Divisibility-aware first-fit: a rule only fires when the dim size is
divisible by the mesh-axis extent (e.g. GQA kv=8 heads cannot shard over
a 16-way model axis -> replicated, noted in EXPERIMENTS.md); each mesh
axis is used at most once per tensor.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# Logical axis vocabulary used by the model stack.
BATCH = "batch"
SEQ = "seq"
SEQ_KV = "seq_kv"
D_MODEL = "d_model"
HEADS = "heads"
KV_HEADS = "kv_heads"
HEAD_DIM = "head_dim"
D_FF = "d_ff"
VOCAB = "vocab"
EXPERTS = "experts"
D_EXPERT = "d_expert"
LAYERS = "layers"          # the stacked-scan leading dim: never sharded
D_INNER = "d_inner"        # mamba
D_STATE = "d_state"
CONV = "conv"
GROUPS = "groups"          # MoE dispatch groups
FRAMES = "frames"          # whisper encoder positions


@dataclasses.dataclass(frozen=True)
class TensorPlan:
    """Maps logical axes to (prioritised lists of) mesh axes."""

    mesh_axes: tuple[str, ...]
    mesh_shape: tuple[int, ...]
    rules: Mapping[str, tuple]     # logical -> tuple of candidates; each
                                   # candidate is a mesh axis or axis-tuple
    mesh: Mesh | None = None       # needed for in-jit constraints

    def _axis_size(self, axis) -> int:
        if isinstance(axis, tuple):
            n = 1
            for a in axis:
                n *= self._axis_size(a)
            return n
        return self.mesh_shape[self.mesh_axes.index(axis)]

    def spec(self, shape: Sequence[int], axes: Sequence[str | None]) -> P:
        """Divisibility-aware first-fit assignment of mesh axes to dims."""
        if len(shape) != len(axes):
            raise ValueError(f"shape {shape} vs logical axes {axes}")
        used: set[str] = set()
        out: list = []
        for size, logical in zip(shape, axes):
            assigned = None
            for cand in self.rules.get(logical, ()):
                flat = cand if isinstance(cand, tuple) else (cand,)
                if any(a in used or a not in self.mesh_axes for a in flat):
                    continue
                if size % self._axis_size(cand) != 0:
                    continue
                # Normalise 1-tuples to the bare axis name so specs
                # compare equal across jax versions.
                assigned = flat[0] if len(flat) == 1 else cand
                used.update(flat)
                break
            out.append(assigned)
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def sharding(self, mesh: Mesh, shape, axes) -> NamedSharding:
        return NamedSharding(mesh, self.spec(shape, axes))

    def constrain(self, x, axes):
        """with_sharding_constraint by logical axes (inside jit)."""
        spec = self.spec(x.shape, axes)
        if self.mesh is None:
            return jax.lax.with_sharding_constraint(x, spec)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def tree_specs(self, params, param_axes):
        """PartitionSpec tree for a (params, axes) pair of pytrees."""
        return jax.tree_util.tree_map(
            lambda p, a: self.spec(p.shape, a),
            params, param_axes,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x),
        )


def slab_spec(mesh_axis: str | tuple) -> P:
    """PartitionSpec of a chunk-cyclic loop slab.

    Rank-1 slabs are ``(n_loc, P, c, *rest)`` over one mesh axis; a
    rank-2 nest over a 2-D mesh (``mesh_axis=("i", "j")``) parks its
    slabs as ``(n_i, P_i, c_i, n_j, P_j, c_j, *rest)`` — every third
    dim is a device axis.  The explicit-loop planner
    (:mod:`repro.core.plan`) and the region residency planner
    (:mod:`repro.core.region`) both park distributed buffers in this
    layout: the device dims make a "chunk-distributed array" an ordinary
    sharded tensor in the tensor-plan vocabulary — the bridge that lets
    loop-level residency compose with model-level sharding on one mesh.
    """
    if isinstance(mesh_axis, tuple):
        if len(mesh_axis) != 2:
            raise ValueError(
                f"slab_spec takes one axis or a 2-tuple, got {mesh_axis!r}")
        return P(None, mesh_axis[0], None, None, mesh_axis[1], None)
    return P(None, mesh_axis)


def _dp_axes(mesh_axes: tuple[str, ...]):
    return tuple(a for a in ("pod", "data") if a in mesh_axes)


def make_train_plan(mesh_axes, mesh_shape, *, zero3: bool = False,
                    strategy: str = "dp_tp",
                    mesh: Mesh | None = None) -> TensorPlan:
    """DP over (pod,data), TP/EP over model; ZeRO-3 adds param sharding
    over the data axes (gradients/optimizer state inherit it).

    ``strategy="dp_only"``: batch over EVERY axis (model included) and
    fully-sharded params over the same — the right layout for models too
    small/narrow to TP (gemma3's 4 heads on a 16-way model axis;
    EXPERIMENTS.md §Perf-B)."""
    dp = _dp_axes(mesh_axes)
    if strategy == "dp_only":
        all_axes = tuple(mesh_axes)
        rules = {
            BATCH: (all_axes, dp, "data"),
            # fully-sharded params (ZeRO-3 over the whole mesh)
            D_MODEL: (all_axes, dp, "data"),
            VOCAB: (all_axes, dp, "data"),
            D_FF: ("model",),
            D_INNER: ("model",),
            GROUPS: (all_axes, dp, "data"),
        }
        return TensorPlan(tuple(mesh_axes), tuple(mesh_shape), rules, mesh)
    rules = {
        BATCH: (dp, "data"),
        HEADS: ("model",),
        KV_HEADS: ("model",),
        D_FF: ("model",),
        D_EXPERT: ("model",),
        EXPERTS: ("model", ),
        VOCAB: ("model",),
        D_INNER: ("model",),
        D_STATE: ("model",),
        GROUPS: (dp, "data"),
        # SEQ: set by seq_parallel (Megatron-SP): residual activations
        # shard their sequence dim over the model axis between TP blocks,
        # turning each boundary all-reduce into reduce-scatter+all-gather
        # (half the bytes, spread over all links).
    }
    if zero3:
        # FSDP: the d_model dim of params shards over the data axes.
        rules[D_MODEL] = (dp, "data")
    return TensorPlan(tuple(mesh_axes), tuple(mesh_shape), rules, mesh)


def make_serve_plan(mesh_axes, mesh_shape, *, shard_seq: bool = False,
                    decode: bool = False,
                    mesh: Mesh | None = None) -> TensorPlan:
    """Inference plan. ``shard_seq`` (long_500k, batch=1): sequence/KV
    sharded over the data axes instead of batch (sequence parallelism)."""
    dp = _dp_axes(mesh_axes)
    rules = {
        BATCH: () if shard_seq else (dp, "data"),
        # KV caches shard their sequence dim: over everything available
        # in shard_seq mode (batch=1), over the model axis otherwise —
        # a batch-only-sharded 32k cache is 43 GB/chip on qwen1.5-110b
        # (EXPERIMENTS.md §Dry-run); attention over the sharded dim
        # becomes flash-decoding-style split-K with a psum combine.
        SEQ_KV: (dp + ("model",), dp, "model") if shard_seq
                else ("model",),
        SEQ: (dp, "data") if shard_seq else (),
        HEADS: ("model",),
        KV_HEADS: ("model",),
        # head_dim fallback (36H / 12H / kv=8 archs): contraction-sharded
        # attention with psum partials. DECODE ONLY — per-token scores are
        # tiny there; under chunked prefill/train attention the score-tile
        # psums explode (17.5 TB wire on starcoder2 prefill, §Dry-run).
        HEAD_DIM: ("model",) if decode else (),
        # serve params also shard d_model over the data axes (weight-
        # resident would need 14 GB/chip on qwen1.5-110b); for decode the
        # partitioner reshards the tiny activations instead of gathering
        # weights, for prefill this is ZeRO-style gathering (compute-bound)
        D_MODEL: (dp, "data"),
        D_FF: ("model",),
        # expert weights shard 2D (experts x d_expert): one model-axis
        # shard of experts would keep a full d_model*d_expert per chip
        # (94 GB/chip on jamba long_500k — EXPERIMENTS.md §Dry-run)
        D_EXPERT: ("model", dp, "data"),
        EXPERTS: ("model",),
        VOCAB: ("model",),
        D_INNER: ("model",),
        D_STATE: ("model",),
        GROUPS: () if shard_seq else (dp, "data"),
    }
    return TensorPlan(tuple(mesh_axes), tuple(mesh_shape), rules, mesh)
