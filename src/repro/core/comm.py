"""Cost-modeled communication planning for inter-loop boundaries.

The paper's §3.1.4 moves whole arrays through rank 0 at every loop
boundary (``MPI_Send``/``MPI_Recv`` of each block's data); the region
residency planner (:mod:`repro.core.region`) already reduces that to one
``all_gather`` per layout-incompatible boundary.  This module goes one
step further, in the direction real MPI ports take (MPI-rical, arXiv
2305.09438: stencil codes overwhelmingly use *neighbor* sends) and picks
the boundary operator by an explicit cost model rather than a fixed rule
(the OMP2HMPP idea, arXiv 1506.02833): every slab→consumer handoff is
lowered to the cheapest of four strategies

==============  =========================================================
op              when / what moves
==============  =========================================================
``resident``    producer OUT layout equals consumer IN layout: nothing
                moves (the residency elision of PR 1)
``halo``        consumer is a chunk-sharded (possibly stencil) read whose
                window only leaks ``L`` rows into the previous chunk and
                ``R`` rows into the next one: two ``jax.lax.ppermute``
                ring shifts move O(halo · chunks) rows instead of O(N)
``all_gather``  chunk-sharded consumer whose window cannot be served by
                neighbor shifts (or where the shifts would move more
                bytes than the gather): one ring ``all_gather``, then a
                local re-slice
``replicate``   the consumer semantically needs the full buffer on every
                rank (whole-array read, serial glue, out-merge priors):
                the ``all_gather`` is forced, not chosen
==============  =========================================================

Each decision is a :class:`BoundaryComm` carrying a :class:`CommCost`
(op, payload bytes per device, modeled total wire bytes, ring hop count)
plus the costs of the rejected alternatives — the transformation report
(:func:`repro.core.report.render_region`) prints them per boundary.

The halo *emitters* live here (:func:`halo_exchange` for rank-1 slabs,
:func:`halo_exchange2` for rank-2: row-ring then column-ring shifts,
corners riding the second pass); the shared slab-window geometry they
build against is owned by the loop-nest IR (:mod:`repro.core.nest`,
re-exported here) so the per-loop staging path
(:mod:`repro.core.transform`), the fused region path and this cost
model all address byte-identical read windows.  Rank-2 boundaries plan
through :func:`plan_boundary2` over :class:`SlabLayout2` with per-axis
halo windows, cost-modeled against the padded-slab all-gather exactly
as the 1-D rule below.

Window geometry (all in k-space, ``0 <= b_min <= b_max`` guaranteed by
:mod:`repro.core.plan` eligibility): consumer chunk ``j`` reads positions
``[j*c + b_min, (j+1)*c - 1 + b_max]``.  Relative to a producer slab
based at ``base`` the offsets are ``delta = b - base``; rows below the
chunk's own slab rows come from the *previous* chunk's tail
(``L = max(0, -delta_min)`` rows), rows above from the *next* chunk's
head (``R = max(0, delta_max)`` rows).  Rows outside the slab's cover
``[0, cover)`` are patched from the replicated prior copy (partial-write
producers keep one — the MPI analogue is the unmodified boundary rows
every rank already owns).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax
import jax.numpy as jnp

# The window geometry is owned by the loop-nest IR (repro.core.nest) —
# re-exported here so the cost model and its tests address one name; the
# per-loop staging path and the fused region path import the same
# functions, keeping all three byte-identical.
from repro.core.nest import (  # noqa: F401 (re-exports)
    device_window_rows,
    window_extent,
    window_rows,
)


# ---------------------------------------------------------------------------
# Slab residency layout (moved here from region.py so the cost model and
# the residency planner share one definition; region re-exports it).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SlabLayout:
    """Chunk-cyclic residency of one buffer between stages.

    Device ``d`` holds stacks of shape ``(local_chunks, chunk, *rest)``;
    (local chunk ``q``, lane ``r``) is global row
    ``base + (q * num_devices + d) * chunk + r``.  ``cover`` rows
    ``[base, base + cover)`` are authoritative; ``has_prior`` marks a
    partial cover whose remaining rows live in a replicated prior copy.
    """

    chunk: int
    num_devices: int
    local_chunks: int
    padded_trip: int
    base: int
    cover: int
    has_prior: bool

    @classmethod
    def of(cls, plan, *, base: int, has_prior: bool) -> "SlabLayout":
        ch = plan.chunks
        return cls(ch.chunk, ch.num_devices, ch.local_chunks,
                   ch.padded_trip, base, plan.loop.trip_count, has_prior)

    def geometry_matches(self, ch) -> bool:
        return (self.chunk == ch.chunk
                and self.num_devices == ch.num_devices
                and self.local_chunks == ch.local_chunks
                and self.padded_trip == ch.padded_trip)


@dataclasses.dataclass(frozen=True)
class AxisSlab:
    """One axis of a rank-2 chunk-cyclic residency layout."""

    chunk: int
    num_devices: int
    local_chunks: int
    padded_trip: int
    base: int
    cover: int

    def geometry_matches(self, ch) -> bool:
        return (self.chunk == ch.chunk
                and self.num_devices == ch.num_devices
                and self.local_chunks == ch.local_chunks
                and self.padded_trip == ch.padded_trip)


@dataclasses.dataclass(frozen=True)
class SlabLayout2:
    """Rank-2 chunk-cyclic residency of one buffer between stages.

    Device ``(d_i, d_j)`` holds stacks ``(n_i, c_i, n_j, c_j, *rest)``;
    (local pair ``(q_i, q_j)``, lanes ``(r_i, r_j)``) is global cell
    ``(bases[0] + (q_i*P_i + d_i)*c_i + r_i,
       bases[1] + (q_j*P_j + d_j)*c_j + r_j)``.  The cover rectangle is
    authoritative; ``has_prior`` marks a partial cover whose remaining
    cells live in a replicated prior copy.
    """

    axes: tuple[AxisSlab, AxisSlab]
    has_prior: bool

    @classmethod
    def of(cls, plan, *, bases: tuple[int, int], has_prior: bool) -> "SlabLayout2":
        axs = tuple(
            AxisSlab(ch.chunk, ch.num_devices, ch.local_chunks,
                     ch.padded_trip, b, t)
            for ch, b, t in zip(plan.chunks_axes, bases, plan.nest.trip_counts))
        return cls(axs, has_prior)

    @property
    def bases(self) -> tuple[int, int]:
        return tuple(a.base for a in self.axes)

    @property
    def covers(self) -> tuple[int, int]:
        return tuple(a.cover for a in self.axes)

    def geometry_matches(self, chunks_axes) -> bool:
        return len(chunks_axes) == 2 and all(
            a.geometry_matches(ch) for a, ch in zip(self.axes, chunks_axes))


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------

RESIDENT = "resident"
HALO = "halo"
ALL_GATHER = "all_gather"
REPLICATE = "replicate"

COMM_MODES = ("auto", "gather")

# Per-launch latency term of the aggregated cost model: every collective
# *launch* pays a fixed overhead on top of its wire bytes (dispatch,
# rendezvous, fusion barriers).  Expressed in wire-byte equivalents
# (~1 us at a 50 GB/s link, the ICI constant hlo_analysis.py uses), so
# launch counts and byte counts add in one unit.  The boundary planner
# keeps choosing ops by pure wire bytes (`plan_boundary`); this term is
# what lets the *scheduler* (repro.core.comm_schedule) justify packing k
# same-boundary exchanges into one payload: the bytes are unchanged but
# (k - 1) x alpha of launch overhead disappears.
ALPHA_LAUNCH_BYTES = 4096


def modeled_cost_bytes(wire_bytes: int, launches: int) -> int:
    """Latency-aware cost of a communication plan in byte equivalents:
    ``wire_bytes + ALPHA_LAUNCH_BYTES * launches``."""
    return int(wire_bytes) + ALPHA_LAUNCH_BYTES * int(launches)


@dataclasses.dataclass(frozen=True)
class CommCost:
    """Bytes-on-the-wire model of one boundary lowering.

    ``payload_bytes`` — bytes materialised at each receiving device;
    ``wire_bytes``    — modeled total bytes crossing device links
                        (the quantity the HLO collective counter audits);
    ``hops``          — ring ``ppermute`` shifts emitted (0 for resident
                        and for the collective ops).
    """

    op: str
    payload_bytes: int
    wire_bytes: int
    hops: int = 0


@dataclasses.dataclass(frozen=True)
class BoundaryComm:
    """The planned communication at one stage←buffer boundary."""

    stage: str
    key: str
    op: str
    cost: CommCost
    alternatives: Mapping[str, CommCost]
    reason: str
    # rank-1 halo: (delta_min, delta_max); rank-2: one such pair per axis
    shift: tuple | None = None

    def describe(self) -> str:
        s = (f"{self.stage} <- {self.key!r}: {self.op}"
             f" (payload ~{self.cost.payload_bytes} B/device,"
             f" wire ~{self.cost.wire_bytes} B, hops={self.cost.hops})")
        alts = [f"{op}~{c.wire_bytes} B"
                for op, c in sorted(self.alternatives.items())
                if op != self.op]
        if alts:
            s += " [rejected: " + ", ".join(alts) + "]"
        return s


def row_bytes(aval) -> int:
    """Bytes of one leading-dim row of ``aval``."""
    n = 1
    for s in aval.shape[1:]:
        n *= s
    return int(n) * jnp.dtype(aval.dtype).itemsize


def full_bytes(aval) -> int:
    """Bytes of the whole ``aval`` buffer."""
    n = 1
    for s in aval.shape:
        n *= s
    return int(n) * jnp.dtype(aval.dtype).itemsize


def gather_cost(layout: SlabLayout, aval, *, op: str = ALL_GATHER) -> CommCost:
    """Ring all_gather of the slab stacks, then a local re-slice: every
    device receives the ``(P-1)/P`` of the padded slab it lacks."""
    row = row_bytes(aval)
    p = layout.num_devices
    wire = layout.padded_trip * row * (p - 1)
    return CommCost(op=op, payload_bytes=full_bytes(aval), wire_bytes=wire,
                    hops=0)


def halo_cost(layout: SlabLayout, aval, delta_min: int,
              delta_max: int) -> CommCost:
    """Neighbor ring shifts: each chunk sends ``L`` tail rows left-to-
    right and ``R`` head rows right-to-left (self-sends counted too —
    on one device the gather is free and wins the comparison)."""
    row = row_bytes(aval)
    left = max(0, -delta_min)
    right = max(0, delta_max)
    num_chunks = layout.local_chunks * layout.num_devices
    wire = num_chunks * (left + right) * row
    return CommCost(
        op=HALO,
        payload_bytes=layout.local_chunks * (left + right) * row,
        wire_bytes=wire,
        hops=(1 if left else 0) + (1 if right else 0),
    )


def cell_bytes(aval, lead: int = 2) -> int:
    """Bytes of one cell of ``aval`` (everything past ``lead`` dims)."""
    n = 1
    for s in aval.shape[lead:]:
        n *= s
    return int(n) * jnp.dtype(aval.dtype).itemsize


def gather_cost2(layout: SlabLayout2, aval, *, op: str = ALL_GATHER) -> CommCost:
    """Ring all_gather of a rank-2 slab over both mesh axes, then a local
    re-slice: every device receives the ``(P-1)/P`` of the padded slab it
    lacks (P = the full 2-D mesh)."""
    cell = cell_bytes(aval)
    ax_i, ax_j = layout.axes
    p = ax_i.num_devices * ax_j.num_devices
    wire = ax_i.padded_trip * ax_j.padded_trip * cell * (p - 1)
    return CommCost(op=op, payload_bytes=full_bytes(aval), wire_bytes=wire,
                    hops=0)


def halo_cost2(layout: SlabLayout2, aval, deltas) -> CommCost:
    """Row-ring + column-ring neighbor shifts for a rank-2 window.

    The row pass moves ``L_i + R_i`` lane-rows of ``c_j`` columns per
    chunk pair; the column pass moves ``L_j + R_j`` lane-columns of the
    *extended* ``w_i = c_i + L_i + R_i`` rows — the corner cells ride
    the second pass (two hops, no diagonal sends).  Self-sends counted
    too, exactly as in the 1-D model.
    """
    cell = cell_bytes(aval)
    ax_i, ax_j = layout.axes
    (dmin_i, dmax_i), (dmin_j, dmax_j) = deltas
    li, ri = max(0, -dmin_i), max(0, dmax_i)
    lj, rj = max(0, -dmin_j), max(0, dmax_j)
    k_i = ax_i.local_chunks * ax_i.num_devices
    k_j = ax_j.local_chunks * ax_j.num_devices
    w_i = ax_i.chunk + li + ri
    per_pair = (li + ri) * ax_j.chunk + w_i * (lj + rj)
    wire = k_i * k_j * per_pair * cell
    return CommCost(
        op=HALO,
        payload_bytes=ax_i.local_chunks * ax_j.local_chunks * per_pair * cell,
        wire_bytes=wire,
        hops=sum(1 for v in (li, ri, lj, rj) if v),
    )


def plan_boundary2(
    *,
    stage: str,
    key: str,
    layout: SlabLayout2,
    chunks_axes,
    trips,
    aval,
    in_strategy: str,
    halo_axes,
    shard_ndim: int,
    needs_replicated: bool,
    mode: str = "auto",
) -> BoundaryComm:
    """Rank-2 :func:`plan_boundary`: pick the cheapest feasible lowering
    for one 2-D slab→consumer boundary (resident / row+column halo rings
    / all_gather / replicate), by the same bytes-on-the-wire model."""
    if mode not in COMM_MODES:
        raise ValueError(f"unknown comm mode {mode!r}; expected {COMM_MODES}")
    g_op = REPLICATE if needs_replicated else ALL_GATHER
    g_cost = gather_cost2(layout, aval, op=g_op)
    alternatives: dict[str, CommCost] = {g_op: g_cost}

    if needs_replicated or in_strategy != "shard_halo":
        return BoundaryComm(
            stage=stage, key=key, op=REPLICATE,
            cost=dataclasses.replace(g_cost, op=REPLICATE),
            alternatives=alternatives,
            reason="consumer needs the full buffer on every rank",
        )
    if shard_ndim != 2:
        return BoundaryComm(
            stage=stage, key=key, op=ALL_GATHER, cost=g_cost,
            alternatives=alternatives,
            reason="consumer shards only the leading axis of a 2-D slab",
        )
    if chunks_axes is None or not layout.geometry_matches(chunks_axes):
        return BoundaryComm(
            stage=stage, key=key, op=ALL_GATHER, cost=g_cost,
            alternatives=alternatives,
            reason="chunk geometry differs between producer and consumer",
        )

    halos = halo_axes if halo_axes is not None else ((0, 0), (0, 0))
    deltas = tuple(
        (h[0] - a.base, h[1] - a.base)
        for h, a in zip(halos, layout.axes))

    if all(d == (0, 0) for d in deltas) \
            and layout.covers == tuple(trips):
        cost = CommCost(op=RESIDENT, payload_bytes=0, wire_bytes=0, hops=0)
        alternatives[RESIDENT] = cost
        return BoundaryComm(
            stage=stage, key=key, op=RESIDENT, cost=cost,
            alternatives=alternatives,
            reason="producer OUT layout equals consumer IN layout",
        )

    feasible = True
    why = ""
    for d, ((dmin, dmax), ax, h, t) in enumerate(
            zip(deltas, layout.axes, halos, trips)):
        left, right = max(0, -dmin), max(0, dmax)
        if left > ax.chunk or right > ax.chunk:
            feasible = False
            why = (f"axis-{d} halo wider than one chunk "
                   "(multi-hop exchange not emitted)")
            break
        if h[0] < ax.base and not layout.has_prior:
            feasible = False
            why = (f"axis-{d} window reads below the slab and no prior "
                   "copy exists")
            break
        if t + h[1] > ax.base + ax.cover and not layout.has_prior:
            feasible = False
            why = (f"axis-{d} window reads beyond the slab cover and no "
                   "prior copy exists")
            break

    if feasible:
        h_cost = halo_cost2(layout, aval, deltas)
        alternatives[HALO] = h_cost
        if mode == "auto" and h_cost.wire_bytes < g_cost.wire_bytes:
            return BoundaryComm(
                stage=stage, key=key, op=HALO, cost=h_cost,
                alternatives=alternatives,
                reason=(f"row+column neighbor shifts move "
                        f"{h_cost.wire_bytes} B vs {g_cost.wire_bytes} B "
                        "for the gather"),
                shift=deltas,
            )
        why = ("comm mode 'gather' pins the PR 1 baseline" if mode != "auto"
               else f"gather is no more expensive "
                    f"({g_cost.wire_bytes} B <= {h_cost.wire_bytes} B)")

    return BoundaryComm(
        stage=stage, key=key, op=ALL_GATHER, cost=g_cost,
        alternatives=alternatives, reason=why,
    )


def plan_boundary(
    *,
    stage: str,
    key: str,
    layout: SlabLayout,
    chunks,
    trip: int,
    aval,
    in_strategy: str,
    halo: tuple[int, int] | None,
    needs_replicated: bool,
    mode: str = "auto",
) -> BoundaryComm:
    """Pick the cheapest feasible lowering for one slab→consumer boundary.

    ``needs_replicated`` marks consumers that must see the full buffer
    (whole-array reads, out-merge priors): the gather is then forced and
    reported as ``replicate``.  ``mode="gather"`` disables the halo
    strategy — the PR 1 baseline, kept for measurement.
    """
    if mode not in COMM_MODES:
        raise ValueError(f"unknown comm mode {mode!r}; expected {COMM_MODES}")
    g_op = REPLICATE if needs_replicated else ALL_GATHER
    g_cost = gather_cost(layout, aval, op=g_op)
    alternatives: dict[str, CommCost] = {g_op: g_cost}

    if needs_replicated or in_strategy not in ("shard", "shard_halo"):
        return BoundaryComm(
            stage=stage, key=key, op=REPLICATE,
            cost=dataclasses.replace(g_cost, op=REPLICATE),
            alternatives=alternatives,
            reason="consumer needs the full buffer on every rank",
        )

    b_min, b_max = halo if halo is not None else (0, 0)
    if chunks is None or not layout.geometry_matches(chunks):
        return BoundaryComm(
            stage=stage, key=key, op=ALL_GATHER, cost=g_cost,
            alternatives=alternatives,
            reason="chunk geometry differs between producer and consumer",
        )

    delta_min = b_min - layout.base
    delta_max = b_max - layout.base

    if delta_min == 0 and delta_max == 0 and layout.cover == trip:
        cost = CommCost(op=RESIDENT, payload_bytes=0, wire_bytes=0, hops=0)
        alternatives[RESIDENT] = cost
        return BoundaryComm(
            stage=stage, key=key, op=RESIDENT, cost=cost,
            alternatives=alternatives,
            reason="producer OUT layout equals consumer IN layout",
        )

    # Halo feasibility: one-hop shifts, and any window rows falling
    # outside the slab's cover must be servable from a replicated prior.
    left = max(0, -delta_min)
    right = max(0, delta_max)
    feasible = left <= layout.chunk and right <= layout.chunk
    why = "halo wider than one chunk (multi-hop exchange not emitted)"
    if feasible and b_min < layout.base and not layout.has_prior:
        feasible = False
        why = "window reads below the slab and no prior copy exists"
    if (feasible and trip + b_max > layout.base + layout.cover
            and not layout.has_prior):
        feasible = False
        why = "window reads beyond the slab cover and no prior copy exists"

    if feasible:
        h_cost = halo_cost(layout, aval, delta_min, delta_max)
        alternatives[HALO] = h_cost
        if mode == "auto" and h_cost.wire_bytes < g_cost.wire_bytes:
            return BoundaryComm(
                stage=stage, key=key, op=HALO, cost=h_cost,
                alternatives=alternatives,
                reason=(f"neighbor shifts move {h_cost.wire_bytes} B vs "
                        f"{g_cost.wire_bytes} B for the gather"),
                shift=(delta_min, delta_max),
            )
        why = ("comm mode 'gather' pins the PR 1 baseline" if mode != "auto"
               else f"gather is no more expensive "
                    f"({g_cost.wire_bytes} B <= {h_cost.wire_bytes} B)")

    return BoundaryComm(
        stage=stage, key=key, op=ALL_GATHER, cost=g_cost,
        alternatives=alternatives, reason=why,
    )


# ---------------------------------------------------------------------------
# The halo emitter (runs inside the fused shard_map)
# ---------------------------------------------------------------------------


def _ring_extend(stacks, *, axis: str, num_devices: int, device_index,
                 chunk: int, delta_min: int, delta_max: int,
                 stack_dim: int = 0, lane_dim: int = 1):
    """Widen one chunk-cyclic axis of a resident slab into read windows
    via neighbor ring shifts: dims ``(stack_dim, lane_dim)`` go from
    ``(n_loc, chunk)`` to ``(n_loc, chunk + extent)``.

    Chunk adjacency under the cyclic assignment: chunk ``j+1`` lives on
    device ``d+1`` at the same local index — except on the last device,
    where it wraps to device 0's *next* local index; symmetrically for
    chunk ``j-1``.  Window row ``r`` of local chunk ``q`` holds slab row
    ``j*chunk + delta_min + r`` (rows outside the producing slab are the
    caller's to patch).
    """
    p, c = num_devices, chunk
    left = max(0, -delta_min)
    right = max(0, delta_max)
    if left > c or right > c:
        raise ValueError(
            f"halo shift ({delta_min}, {delta_max}) exceeds one chunk "
            f"(chunk={c}); the planner should have chosen a gather")
    x = jnp.moveaxis(stacks, (stack_dim, lane_dim), (0, 1))
    parts = []
    if left:
        tails = x[:, c - left:]
        recv = jax.lax.ppermute(
            tails, axis, perm=[((i - 1) % p, i) for i in range(p)])
        # device 0's chunk j-1 is the last device's PREVIOUS local chunk
        rolled = jnp.concatenate([recv[:1], recv[:-1]], axis=0)
        parts.append(jnp.where(device_index == 0, rolled, recv))
    parts.append(x[:, max(0, delta_min):c + min(0, delta_max)])
    if right:
        heads = x[:, :right]
        recv = jax.lax.ppermute(
            heads, axis, perm=[((i + 1) % p, i) for i in range(p)])
        # the last device's chunk j+1 is device 0's NEXT local chunk
        rolled = jnp.concatenate([recv[1:], recv[-1:]], axis=0)
        parts.append(jnp.where(device_index == p - 1, rolled, recv))
    win = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    return jnp.moveaxis(win, (0, 1), (stack_dim, lane_dim))


def halo_exchange(
    stacks,
    *,
    axis: str,
    num_devices: int,
    device_index,
    chunk: int,
    delta_min: int,
    delta_max: int,
    prior=None,
    base: int = 0,
    cover: int | None = None,
    dtype=None,
):
    """Build each local chunk's read window from a resident slab via
    neighbor ring shifts.

    ``stacks`` is this device's produced slab ``(n_loc, chunk, *rest)``
    where (local chunk ``q``, lane ``r``) is slab row
    ``(q * num_devices + device_index) * chunk + r``.  Returns
    ``(n_loc, width, *rest)`` windows whose row ``r`` holds slab row
    ``j*chunk + delta_min + r`` — exactly the layout
    :func:`device_window_rows` produces from a replicated copy, so the
    consumer's ``nest.ShiftedWindow`` indexing is identical on both paths.

    Chunk adjacency under the cyclic assignment: chunk ``j+1`` lives on
    device ``d+1`` at the same local index — except on the last device,
    where it wraps to device 0's *next* local index; symmetrically for
    chunk ``j-1``.  Rows outside the slab's ``[0, cover)`` are patched
    from the replicated ``prior`` copy (the boundary rows a partial
    write never touched); remaining out-of-range rows are only consumed
    by masked padding lanes.
    """
    win = _ring_extend(
        stacks, axis=axis, num_devices=num_devices,
        device_index=device_index, chunk=chunk, delta_min=delta_min,
        delta_max=delta_max)
    return patch_window_prior(
        win, num_devices=num_devices, device_index=device_index,
        chunk=chunk, delta_min=delta_min, prior=prior, base=base,
        cover=cover, dtype=dtype)


def patch_window_prior(
    win,
    *,
    num_devices: int,
    device_index,
    chunk: int,
    delta_min: int,
    prior=None,
    base: int = 0,
    cover: int | None = None,
    dtype=None,
):
    """Patch window rows outside the slab's ``[0, cover)`` from the
    replicated ``prior`` copy and cast to the consumer dtype — the
    non-communicating half of :func:`halo_exchange`, shared with the
    aggregated packing emitters (:mod:`repro.core.comm_schedule`)."""
    p, c = num_devices, chunk
    if prior is not None:
        n_loc, width = win.shape[0], win.shape[1]
        rho = _window_positions(n_loc, width, p, c, device_index, delta_min)
        pos = jnp.clip(base + rho, 0, prior.shape[0] - 1)
        pvals = jnp.take(prior, pos, axis=0)
        cov = cover if cover is not None else n_loc * p * c
        inside = (rho >= 0) & (rho < cov)
        mask = inside.reshape(inside.shape + (1,) * (win.ndim - 2))
        win = jnp.where(mask, win, pvals.astype(win.dtype))
    if dtype is not None:
        win = win.astype(dtype)
    return win


def _window_positions(n_loc, width, p, c, device_index, delta_min):
    """k-space positions ``(n_loc, width)`` of this device's windows
    relative to the producing slab's base."""
    j0 = (jnp.arange(n_loc, dtype=jnp.int32)[:, None] * p
          + device_index) * c
    return j0 + delta_min + jnp.arange(width, dtype=jnp.int32)[None, :]


def halo_exchange2(
    stacks,
    *,
    axes: tuple[str, str],
    num_devices: tuple[int, int],
    device_indices,
    chunks: tuple[int, int],
    deltas,
    prior=None,
    bases: tuple[int, int] = (0, 0),
    covers: tuple[int, int] | None = None,
    dtype=None,
):
    """Rank-2 halo exchange: build each local (chunk_i, chunk_j) pair's
    2-D read window from a resident slab via row-ring then column-ring
    shifts.

    ``stacks`` is this device's produced slab ``(n_i, c_i, n_j, c_j,
    *rest)``; returns ``(n_i, w_i, n_j, w_j, *rest)`` windows.  The row
    pass widens axis 0 along the ``axes[0]`` rings; the column pass then
    widens axis 1 of the *already-extended* windows along the ``axes[1]``
    rings — so the corner blocks travel two hops (the standard 2-D halo
    corner treatment: no diagonal sends needed).  Positions outside the
    slab's cover rectangle are patched from the replicated ``prior``
    copy (the boundary rows/columns a partial write never touched).
    """
    (p_i, p_j) = num_devices
    (c_i, c_j) = chunks
    (d_i, d_j) = device_indices
    (dmin_i, dmax_i), (dmin_j, dmax_j) = deltas
    win = _ring_extend(
        stacks, axis=axes[0], num_devices=p_i, device_index=d_i,
        chunk=c_i, delta_min=dmin_i, delta_max=dmax_i,
        stack_dim=0, lane_dim=1)
    win = _ring_extend(
        win, axis=axes[1], num_devices=p_j, device_index=d_j,
        chunk=c_j, delta_min=dmin_j, delta_max=dmax_j,
        stack_dim=2, lane_dim=3)
    return patch_window_prior2(
        win, num_devices=num_devices, device_indices=device_indices,
        chunks=chunks, deltas=deltas, prior=prior, bases=bases,
        covers=covers, dtype=dtype)


def patch_window_prior2(
    win,
    *,
    num_devices: tuple[int, int],
    device_indices,
    chunks: tuple[int, int],
    deltas,
    prior=None,
    bases: tuple[int, int] = (0, 0),
    covers: tuple[int, int] | None = None,
    dtype=None,
):
    """Rank-2 :func:`patch_window_prior`: patch positions outside the
    slab's cover rectangle from the replicated ``prior`` copy."""
    (p_i, p_j) = num_devices
    (c_i, c_j) = chunks
    (d_i, d_j) = device_indices
    (dmin_i, _), (dmin_j, _) = deltas
    if prior is not None:
        n_i, w_i, n_j, w_j = win.shape[:4]
        rho_i = _window_positions(n_i, w_i, p_i, c_i, d_i, dmin_i)
        rho_j = _window_positions(n_j, w_j, p_j, c_j, d_j, dmin_j)
        pos_i = jnp.clip(bases[0] + rho_i, 0, prior.shape[0] - 1)
        pos_j = jnp.clip(bases[1] + rho_j, 0, prior.shape[1] - 1)
        pvals = jnp.take(prior, pos_i, axis=0)        # (n_i, w_i, N1, *)
        pvals = jnp.take(pvals, pos_j, axis=2)        # (n_i, w_i, n_j, w_j, *)
        cov_i = covers[0] if covers is not None else n_i * p_i * c_i
        cov_j = covers[1] if covers is not None else n_j * p_j * c_j
        inside = ((rho_i >= 0) & (rho_i < cov_i))[:, :, None, None] \
            & ((rho_j >= 0) & (rho_j < cov_j))[None, None, :, :]
        mask = inside.reshape(inside.shape + (1,) * (win.ndim - 4))
        win = jnp.where(mask, win, pvals.astype(win.dtype))
    if dtype is not None:
        win = win.astype(dtype)
    return win


# ---------------------------------------------------------------------------
# Facade
# ---------------------------------------------------------------------------


def plan_comm(
    region,
    env: Mapping[str, Any],
    num_devices: int | tuple,
    *,
    axis: str | tuple | None = None,
    comm: str = "auto",
) -> list[BoundaryComm]:
    """Plan every inter-loop boundary of a region: the cost-modeled
    communication schedule, one :class:`BoundaryComm` per slab handoff.

    Accepts a :class:`~repro.core.pragma.ParallelRegion` (or a single
    :class:`~repro.core.pragma.ParallelFor`, wrapped) plus example/aval
    inputs; returns the decisions in stage order.  Rank-2 regions take
    per-axis device counts, e.g. ``num_devices=(4, 2)``.  This is the
    planning half of :func:`repro.core.region.region_to_mpi` — the same
    decisions that lowering executes.
    """
    from repro.core import pragma
    from repro.core.region import plan_region

    if isinstance(region, pragma.ParallelFor):
        region = pragma.ParallelRegion((region,))
    if region.rank == 2:
        if axis is None:
            axis = ("i", "j")
        if not isinstance(num_devices, tuple):
            raise ValueError(
                "collapse=2 regions need per-axis device counts, "
                f"e.g. num_devices=(4, 2); got {num_devices!r}")
    elif axis is None:
        axis = "data"
    rp = plan_region(region, env, num_devices, axis=axis, comm=comm)
    return rp.comms
