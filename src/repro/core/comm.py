"""Cost-modeled communication planning for inter-loop boundaries.

The paper's §3.1.4 moves whole arrays through rank 0 at every loop
boundary (``MPI_Send``/``MPI_Recv`` of each block's data); the region
residency planner (:mod:`repro.core.region`) already reduces that to one
``all_gather`` per layout-incompatible boundary.  This module goes one
step further, in the direction real MPI ports take (MPI-rical, arXiv
2305.09438: stencil codes overwhelmingly use *neighbor* sends) and picks
the boundary operator by an explicit cost model rather than a fixed rule
(the OMP2HMPP idea, arXiv 1506.02833): every slab→consumer handoff is
lowered to the cheapest of four strategies

==============  =========================================================
op              when / what moves
==============  =========================================================
``resident``    producer OUT layout equals consumer IN layout: nothing
                moves (the residency elision of PR 1)
``halo``        consumer is a chunk-sharded (possibly stencil) read whose
                window only leaks ``L`` rows into the previous chunk and
                ``R`` rows into the next one: two ``jax.lax.ppermute``
                ring shifts move O(halo · chunks) rows instead of O(N)
``all_gather``  chunk-sharded consumer whose window cannot be served by
                neighbor shifts (or where the shifts would move more
                bytes than the gather): one ring ``all_gather``, then a
                local re-slice
``replicate``   the consumer semantically needs the full buffer on every
                rank (whole-array read, serial glue, out-merge priors):
                the ``all_gather`` is forced, not chosen
==============  =========================================================

Each decision is a :class:`BoundaryComm` carrying a :class:`CommCost`
(op, payload bytes per device, modeled total wire bytes, ring hop count)
plus the costs of the rejected alternatives — the transformation report
(:func:`repro.core.report.render_region`) prints them per boundary.

The halo *emitter* (:func:`halo_exchange`) and the shared slab-window
geometry (:func:`window_rows` / :func:`device_window_rows`) live here so
the per-loop staging path (:mod:`repro.core.transform`) and the fused
region path build byte-identical read windows.

Window geometry (all in k-space, ``0 <= b_min <= b_max`` guaranteed by
:mod:`repro.core.plan` eligibility): consumer chunk ``j`` reads positions
``[j*c + b_min, (j+1)*c - 1 + b_max]``.  Relative to a producer slab
based at ``base`` the offsets are ``delta = b - base``; rows below the
chunk's own slab rows come from the *previous* chunk's tail
(``L = max(0, -delta_min)`` rows), rows above from the *next* chunk's
head (``R = max(0, delta_max)`` rows).  Rows outside the slab's cover
``[0, cover)`` are patched from the replicated prior copy (partial-write
producers keep one — the MPI analogue is the unmodified boundary rows
every rank already owns).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Slab residency layout (moved here from region.py so the cost model and
# the residency planner share one definition; region re-exports it).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SlabLayout:
    """Chunk-cyclic residency of one buffer between stages.

    Device ``d`` holds stacks of shape ``(local_chunks, chunk, *rest)``;
    (local chunk ``q``, lane ``r``) is global row
    ``base + (q * num_devices + d) * chunk + r``.  ``cover`` rows
    ``[base, base + cover)`` are authoritative; ``has_prior`` marks a
    partial cover whose remaining rows live in a replicated prior copy.
    """

    chunk: int
    num_devices: int
    local_chunks: int
    padded_trip: int
    base: int
    cover: int
    has_prior: bool

    @classmethod
    def of(cls, plan, *, base: int, has_prior: bool) -> "SlabLayout":
        ch = plan.chunks
        return cls(ch.chunk, ch.num_devices, ch.local_chunks,
                   ch.padded_trip, base, plan.loop.trip_count, has_prior)

    def geometry_matches(self, ch) -> bool:
        return (self.chunk == ch.chunk
                and self.num_devices == ch.num_devices
                and self.local_chunks == ch.local_chunks
                and self.padded_trip == ch.padded_trip)


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------

RESIDENT = "resident"
HALO = "halo"
ALL_GATHER = "all_gather"
REPLICATE = "replicate"

COMM_MODES = ("auto", "gather")


@dataclasses.dataclass(frozen=True)
class CommCost:
    """Bytes-on-the-wire model of one boundary lowering.

    ``payload_bytes`` — bytes materialised at each receiving device;
    ``wire_bytes``    — modeled total bytes crossing device links
                        (the quantity the HLO collective counter audits);
    ``hops``          — ring ``ppermute`` shifts emitted (0 for resident
                        and for the collective ops).
    """

    op: str
    payload_bytes: int
    wire_bytes: int
    hops: int = 0


@dataclasses.dataclass(frozen=True)
class BoundaryComm:
    """The planned communication at one stage←buffer boundary."""

    stage: str
    key: str
    op: str
    cost: CommCost
    alternatives: Mapping[str, CommCost]
    reason: str
    shift: tuple[int, int] | None = None   # (delta_min, delta_max) for halo

    def describe(self) -> str:
        s = (f"{self.stage} <- {self.key!r}: {self.op}"
             f" (payload ~{self.cost.payload_bytes} B/device,"
             f" wire ~{self.cost.wire_bytes} B, hops={self.cost.hops})")
        alts = [f"{op}~{c.wire_bytes} B"
                for op, c in sorted(self.alternatives.items())
                if op != self.op]
        if alts:
            s += " [rejected: " + ", ".join(alts) + "]"
        return s


def row_bytes(aval) -> int:
    """Bytes of one leading-dim row of ``aval``."""
    n = 1
    for s in aval.shape[1:]:
        n *= s
    return int(n) * jnp.dtype(aval.dtype).itemsize


def full_bytes(aval) -> int:
    """Bytes of the whole ``aval`` buffer."""
    n = 1
    for s in aval.shape:
        n *= s
    return int(n) * jnp.dtype(aval.dtype).itemsize


def gather_cost(layout: SlabLayout, aval, *, op: str = ALL_GATHER) -> CommCost:
    """Ring all_gather of the slab stacks, then a local re-slice: every
    device receives the ``(P-1)/P`` of the padded slab it lacks."""
    row = row_bytes(aval)
    p = layout.num_devices
    wire = layout.padded_trip * row * (p - 1)
    return CommCost(op=op, payload_bytes=full_bytes(aval), wire_bytes=wire,
                    hops=0)


def halo_cost(layout: SlabLayout, aval, delta_min: int,
              delta_max: int) -> CommCost:
    """Neighbor ring shifts: each chunk sends ``L`` tail rows left-to-
    right and ``R`` head rows right-to-left (self-sends counted too —
    on one device the gather is free and wins the comparison)."""
    row = row_bytes(aval)
    left = max(0, -delta_min)
    right = max(0, delta_max)
    num_chunks = layout.local_chunks * layout.num_devices
    wire = num_chunks * (left + right) * row
    return CommCost(
        op=HALO,
        payload_bytes=layout.local_chunks * (left + right) * row,
        wire_bytes=wire,
        hops=(1 if left else 0) + (1 if right else 0),
    )


def plan_boundary(
    *,
    stage: str,
    key: str,
    layout: SlabLayout,
    chunks,
    trip: int,
    aval,
    in_strategy: str,
    halo: tuple[int, int] | None,
    needs_replicated: bool,
    mode: str = "auto",
) -> BoundaryComm:
    """Pick the cheapest feasible lowering for one slab→consumer boundary.

    ``needs_replicated`` marks consumers that must see the full buffer
    (whole-array reads, out-merge priors): the gather is then forced and
    reported as ``replicate``.  ``mode="gather"`` disables the halo
    strategy — the PR 1 baseline, kept for measurement.
    """
    if mode not in COMM_MODES:
        raise ValueError(f"unknown comm mode {mode!r}; expected {COMM_MODES}")
    g_op = REPLICATE if needs_replicated else ALL_GATHER
    g_cost = gather_cost(layout, aval, op=g_op)
    alternatives: dict[str, CommCost] = {g_op: g_cost}

    if needs_replicated or in_strategy not in ("shard", "shard_halo"):
        return BoundaryComm(
            stage=stage, key=key, op=REPLICATE,
            cost=dataclasses.replace(g_cost, op=REPLICATE),
            alternatives=alternatives,
            reason="consumer needs the full buffer on every rank",
        )

    b_min, b_max = halo if halo is not None else (0, 0)
    if chunks is None or not layout.geometry_matches(chunks):
        return BoundaryComm(
            stage=stage, key=key, op=ALL_GATHER, cost=g_cost,
            alternatives=alternatives,
            reason="chunk geometry differs between producer and consumer",
        )

    delta_min = b_min - layout.base
    delta_max = b_max - layout.base

    if delta_min == 0 and delta_max == 0 and layout.cover == trip:
        cost = CommCost(op=RESIDENT, payload_bytes=0, wire_bytes=0, hops=0)
        alternatives[RESIDENT] = cost
        return BoundaryComm(
            stage=stage, key=key, op=RESIDENT, cost=cost,
            alternatives=alternatives,
            reason="producer OUT layout equals consumer IN layout",
        )

    # Halo feasibility: one-hop shifts, and any window rows falling
    # outside the slab's cover must be servable from a replicated prior.
    left = max(0, -delta_min)
    right = max(0, delta_max)
    feasible = left <= layout.chunk and right <= layout.chunk
    why = "halo wider than one chunk (multi-hop exchange not emitted)"
    if feasible and b_min < layout.base and not layout.has_prior:
        feasible = False
        why = "window reads below the slab and no prior copy exists"
    if (feasible and trip + b_max > layout.base + layout.cover
            and not layout.has_prior):
        feasible = False
        why = "window reads beyond the slab cover and no prior copy exists"

    if feasible:
        h_cost = halo_cost(layout, aval, delta_min, delta_max)
        alternatives[HALO] = h_cost
        if mode == "auto" and h_cost.wire_bytes < g_cost.wire_bytes:
            return BoundaryComm(
                stage=stage, key=key, op=HALO, cost=h_cost,
                alternatives=alternatives,
                reason=(f"neighbor shifts move {h_cost.wire_bytes} B vs "
                        f"{g_cost.wire_bytes} B for the gather"),
                shift=(delta_min, delta_max),
            )
        why = ("comm mode 'gather' pins the PR 1 baseline" if mode != "auto"
               else f"gather is no more expensive "
                    f"({g_cost.wire_bytes} B <= {h_cost.wire_bytes} B)")

    return BoundaryComm(
        stage=stage, key=key, op=ALL_GATHER, cost=g_cost,
        alternatives=alternatives, reason=why,
    )


# ---------------------------------------------------------------------------
# Shared slab-window geometry (per-loop staging and fused region paths
# must build byte-identical read windows)
# ---------------------------------------------------------------------------


def window_extent(chunk: int, halo: tuple[int, int]) -> int:
    """Width of one chunk's read window: ``chunk + (b_max - b_min)``."""
    b_min, b_max = halo
    return chunk + (b_max - b_min)


def window_rows(ch, halo: tuple[int, int], nrows: int) -> np.ndarray:
    """Static (jit-level) row indices of every chunk's read window:
    ``(num_chunks, width)``, clipped in-bounds (out-of-range rows are
    only ever consumed by masked padding lanes)."""
    b_min, _ = halo
    width = window_extent(ch.chunk, halo)
    rows = (np.arange(ch.num_chunks)[:, None] * ch.chunk + b_min
            + np.arange(width)[None, :])
    return np.clip(rows, 0, max(0, nrows - 1))


def device_window_rows(ch, halo: tuple[int, int], device_index,
                       nrows: int):
    """Traced (in-shard_map) row indices of THIS device's chunk windows:
    ``(local_chunks, width)`` — the fused analogue of
    :func:`window_rows` for slicing a replicated buffer locally."""
    b_min, _ = halo
    width = window_extent(ch.chunk, halo)
    base = (jnp.arange(ch.local_chunks, dtype=jnp.int32)[:, None]
            * ch.num_devices + device_index) * ch.chunk
    rows = base + b_min + jnp.arange(width, dtype=jnp.int32)[None, :]
    return jnp.clip(rows, 0, max(0, nrows - 1))


# ---------------------------------------------------------------------------
# The halo emitter (runs inside the fused shard_map)
# ---------------------------------------------------------------------------


def halo_exchange(
    stacks,
    *,
    axis: str,
    num_devices: int,
    device_index,
    chunk: int,
    delta_min: int,
    delta_max: int,
    prior=None,
    base: int = 0,
    cover: int | None = None,
    dtype=None,
):
    """Build each local chunk's read window from a resident slab via
    neighbor ring shifts.

    ``stacks`` is this device's produced slab ``(n_loc, chunk, *rest)``
    where (local chunk ``q``, lane ``r``) is slab row
    ``(q * num_devices + device_index) * chunk + r``.  Returns
    ``(n_loc, width, *rest)`` windows whose row ``r`` holds slab row
    ``j*chunk + delta_min + r`` — exactly the layout
    :func:`device_window_rows` produces from a replicated copy, so the
    consumer's ``_ShiftedArray`` indexing is identical on both paths.

    Chunk adjacency under the cyclic assignment: chunk ``j+1`` lives on
    device ``d+1`` at the same local index — except on the last device,
    where it wraps to device 0's *next* local index; symmetrically for
    chunk ``j-1``.  Rows outside the slab's ``[0, cover)`` are patched
    from the replicated ``prior`` copy (the boundary rows a partial
    write never touched); remaining out-of-range rows are only consumed
    by masked padding lanes.
    """
    p = num_devices
    c = chunk
    left = max(0, -delta_min)
    right = max(0, delta_max)
    if left > c or right > c:
        raise ValueError(
            f"halo shift ({delta_min}, {delta_max}) exceeds one chunk "
            f"(chunk={c}); the planner should have chosen a gather")

    parts = []
    if left:
        tails = stacks[:, c - left:]
        recv = jax.lax.ppermute(
            tails, axis, perm=[((i - 1) % p, i) for i in range(p)])
        # device 0's chunk j-1 is the last device's PREVIOUS local chunk
        rolled = jnp.concatenate([recv[:1], recv[:-1]], axis=0)
        parts.append(jnp.where(device_index == 0, rolled, recv))
    parts.append(stacks[:, max(0, delta_min):c + min(0, delta_max)])
    if right:
        heads = stacks[:, :right]
        recv = jax.lax.ppermute(
            heads, axis, perm=[((i + 1) % p, i) for i in range(p)])
        # the last device's chunk j+1 is device 0's NEXT local chunk
        rolled = jnp.concatenate([recv[1:], recv[-1:]], axis=0)
        parts.append(jnp.where(device_index == p - 1, rolled, recv))
    win = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)

    if prior is not None:
        n_loc, width = win.shape[0], win.shape[1]
        j0 = (jnp.arange(n_loc, dtype=jnp.int32)[:, None] * p
              + device_index) * c
        rho = j0 + delta_min + jnp.arange(width, dtype=jnp.int32)[None, :]
        pos = jnp.clip(base + rho, 0, prior.shape[0] - 1)
        pvals = jnp.take(prior, pos, axis=0)
        cov = cover if cover is not None else n_loc * p * c
        inside = (rho >= 0) & (rho < cov)
        mask = inside.reshape(inside.shape + (1,) * (win.ndim - 2))
        win = jnp.where(mask, win, pvals.astype(win.dtype))
    if dtype is not None:
        win = win.astype(dtype)
    return win


# ---------------------------------------------------------------------------
# Facade
# ---------------------------------------------------------------------------


def plan_comm(
    region,
    env: Mapping[str, Any],
    num_devices: int,
    *,
    axis: str = "data",
    comm: str = "auto",
) -> list[BoundaryComm]:
    """Plan every inter-loop boundary of a region: the cost-modeled
    communication schedule, one :class:`BoundaryComm` per slab handoff.

    Accepts a :class:`~repro.core.pragma.ParallelRegion` (or a single
    :class:`~repro.core.pragma.ParallelFor`, wrapped) plus example/aval
    inputs; returns the decisions in stage order.  This is the planning
    half of :func:`repro.core.region.region_to_mpi` — the same decisions
    that lowering executes.
    """
    from repro.core import pragma
    from repro.core.region import plan_region

    if isinstance(region, pragma.ParallelFor):
        region = pragma.ParallelRegion((region,))
    rp = plan_region(region, env, num_devices, axis=axis, comm=comm)
    return rp.comms
