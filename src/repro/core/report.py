"""Human-readable transformation report.

The paper presents its output as generated MPI source (Tables 2 and 3).
The JAX rendition has no C source to show; the equivalent artifact is the
*distribution plan* — which buffer moves where, which collective plays the
role of which MPI_Send/Recv pair — plus the chunk schedule.  This module
renders that, in a layout that mirrors the paper's tables.
"""
from __future__ import annotations

from repro.core.context import VarClass
from repro.core.plan import DistPlan


_IN_DESC = {
    "replicate": "master->workers broadcast of the full buffer "
                 "(MPI_Send to every slave / replicated in_spec)",
    "shard": "master->workers chunk slices only "
             "(MPI_Send of [offset, offset+partSize) / sharded slab in_spec)",
    "shard_halo": "chunk slices + stencil halo rows "
                  "(beyond-paper: neighbour exchange instead of broadcast)",
    "none": "not transferred (unused or write-only inside the block)",
}

_OUT_DESC = {
    "identity": "workers->master slices [offset, offset+partSize) "
                "(MPI_Recv per chunk / sharded slab out_spec)",
    "partial": "workers->master slices, master updates rows in place",
    "scatter": "strided write: full-size masked buffers combined by "
               "all-reduce (paper: whole modified array transferred)",
    "put": "full array sent by the worker owning the last iteration",
    "reduce": "per-worker partials folded into the master accumulator",
    "none": "",
}


def render_plan(plan: DistPlan) -> str:
    ch = plan.chunks
    lines = [
        f"=== OMP2MPI transformation report: {plan.name} ===",
        f"lowering        : {plan.lowering}",
    ]
    if plan.rank == 2:
        names = ("i", "j")
        ranks = " x ".join(f"{c.num_devices}" for c in plan.chunks_axes)
        lines.append(
            f"mesh axes       : {plan.axis!r} ({ranks} compute ranks, "
            "2-D decomposition)")
        for d, (lp, cd) in enumerate(zip(plan.nest.axes, plan.chunks_axes)):
            lines.append(
                f"loop axis {names[d]}     : for {names[d]} in "
                f"range({lp.start}, {lp.stop}, {lp.step})  "
                f"[{lp.trip_count} iterations]")
            lines.append(
                f"chunk axis {names[d]}    : partSize={cd.chunk}, "
                f"{cd.num_chunks} chunks total ({cd.local_chunks} per rank), "
                f"cyclic chunk q -> rank q % {cd.num_devices}")
    else:
        lines += [
            f"mesh axis       : {plan.axis!r} ({ch.num_devices} compute ranks)",
            f"loop            : for i in range({plan.loop.start}, "
            f"{plan.loop.stop}, {plan.loop.step})  "
            f"[{plan.loop.trip_count} iterations]",
            f"chunk (partSize): {ch.chunk}  "
            f"[paper Table 2 line 4: N / ranks / 10 for schedule(dynamic)]",
            f"chunks          : {ch.num_chunks} total, {ch.local_chunks} "
            f"per rank, cyclic assignment chunk j -> rank j % "
            f"{ch.num_devices}",
        ]
    lines += [
        "",
        "variable classification (Context Analysis, paper Fig. 3):",
    ]
    for key, dec in plan.vars.items():
        info = plan.context.vars[key]
        klass = dec.klass.value.upper()
        shape = "x".join(map(str, info.shape)) or "scalar"
        lines.append(f"  {key:>12s}  {klass:<9s} {shape:<16s} "
                     f"dtype={str(info.dtype)}")
        if dec.read_map is not None:
            lines.append(f"  {'':>12s}  read map : x[{dec.read_map.a}*k"
                         f"{dec.read_map.b:+d}]")
        if dec.write_map is not None:
            lines.append(f"  {'':>12s}  write map: x[{dec.write_map.a}*k"
                         f"{dec.write_map.b:+d}]")
        if dec.read_maps is not None:
            inner = ", ".join(f"{m.a}*k{d}{m.b:+d}"
                              for d, m in zip("ij", dec.read_maps))
            lines.append(f"  {'':>12s}  read map : x[{inner}]")
        if dec.write_maps is not None:
            inner = ", ".join(f"{m.a}*k{d}{m.b:+d}"
                              for d, m in zip("ij", dec.write_maps))
            lines.append(f"  {'':>12s}  write map: x[{inner}]")
        if dec.halo_axes is not None and any(
                h != (0, 0) for h in dec.halo_axes):
            inner = ", ".join(f"axis{d} [{h[0]}, {h[1]}]"
                              for d, h in enumerate(dec.halo_axes))
            lines.append(f"  {'':>12s}  halo     : {inner}")
        if dec.reduction_op:
            lines.append(f"  {'':>12s}  reduction: op={dec.reduction_op!r} "
                         f"(identity init, paper Table 3)")
        in_d = _IN_DESC.get(dec.in_strategy, "")
        out_d = _OUT_DESC.get(dec.out_strategy, "")
        if in_d and dec.klass in (VarClass.IN, VarClass.INOUT):
            lines.append(f"  {'':>12s}  in : {in_d}")
        if out_d:
            lines.append(f"  {'':>12s}  out: {out_d}")
        if dec.note:
            lines.append(f"  {'':>12s}  note: {dec.note}")
    lines.append("")
    lines.append("communication summary (per block execution):")
    lines.extend(_comm_summary(plan))
    return "\n".join(lines)


def render_compiled(compiled) -> str:
    """Render the unified :class:`~repro.core.api.Compiled` artifact:
    the pass pipeline header followed by the plan view the legacy
    entry points rendered (per-block Tables 2/3 analogue or the
    whole-region residency report)."""
    from repro.core.region import RegionPlan

    lines = [
        f"=== omp.compile: {compiled.program.name} ===",
        f"options         : {compiled.options.describe()}",
        f"mesh axis       : {compiled.axis!r} "
        f"({compiled.num_devices} compute ranks)",
        "",
        "pass pipeline (analyze -> schedule -> plan -> plan_comm -> "
        "schedule_comm -> lower):",
    ]
    for pr in compiled.passes:
        lines.append(f"  {pr.describe()}")
    lines.append("")
    kp = getattr(compiled, "kernel_plan", None)
    if kp is not None:
        lines.extend(kp.describe_lines())
        lines.append("")
    plan = compiled.plan
    if isinstance(plan, RegionPlan):
        lines.append(render_region(plan))
    elif isinstance(plan, DistPlan):
        lines.append(render_plan(plan))
    else:  # staged region: per-stage plans, each loop in isolation
        lines.append("staged lowering: each loop transformed in isolation "
                     "(paper Fig. 1b round trips)")
        for name, p in plan:
            lines.append("")
            lines.append(render_plan(p))
    return "\n".join(lines)


def render_region(rp) -> str:
    """Render a :class:`~repro.core.region.RegionPlan` — the whole-program
    analogue of the per-block report: stage roster, the residency
    planner's transition journal, and the staged-vs-fused comparison."""
    from repro.core.region import REPLICATED, SlabLayout, SlabLayout2

    lines = [
        f"=== ParallelRegion transformation report: {rp.name} ===",
        f"mesh axis       : {rp.axis!r} ({rp.num_devices} compute ranks)",
        f"stages          : {len(rp.stages)} "
        f"({sum(1 for s in rp.stages if s.kind == 'loop')} parallel loops, "
        f"{sum(1 for s in rp.stages if s.kind == 'serial')} serial glue)",
        "",
        "stage roster:",
    ]
    for s in rp.stages:
        if s.kind == "serial":
            lines.append(f"  {s.name:>16s}  serial glue "
                         f"(writes {list(s.serial_writes)})")
        elif s.plan.rank == 2:
            trips = s.plan.nest.trip_counts
            chs = s.plan.chunks_axes
            lines.append(
                f"  {s.name:>16s}  loop nest t={trips[0]}x{trips[1]} "
                f"chunks={chs[0].chunk}x{chs[1].chunk} "
                f"({chs[0].num_chunks}x{chs[1].num_chunks} tiles cyclic)")
        else:
            ch = s.plan.chunks
            lines.append(
                f"  {s.name:>16s}  loop t={s.plan.loop.trip_count} "
                f"chunk={ch.chunk} ({ch.num_chunks} chunks cyclic)")
    lines.append("")
    lines.append("inter-loop residency (the beyond-paper layout planner):")
    if rp.log:
        for entry in rp.log:
            lines.append(f"  {entry}")
    else:
        lines.append("  (no inter-stage traffic: single loop or "
                     "disjoint buffers)")
    lines.append("")
    lines.append("communication plan (cost-modeled boundary lowering, "
                 "paper §3.1.4 block-boundary send/recv):")
    if rp.comms:
        for bc in rp.comms:
            lines.append(f"  {bc.describe()}")
            lines.append(f"  {'':>4s}why: {bc.reason}")
        lines.append(
            f"  planned wire total: ~{rp.planned_wire_bytes} B "
            f"(all-gather-only baseline: ~{rp.gather_wire_bytes} B)")
    else:
        lines.append("  (no slab boundaries: nothing to exchange)")
    sched = getattr(rp, "comm_sched", None)
    if sched is not None:
        what = ("aggregated ppermute payloads, fused reductions, "
                "prefetched exchanges" if sched.mode == "aggregate"
                else "per-buffer exchanges issued at the consumer "
                     "(un-scheduled baseline)")
        lines.append("")
        lines.append(
            f"communication schedule (schedule_comm, mode={sched.mode}): "
            f"{what}:")
        event_lines = sched.describe_lines()
        if len(event_lines) == 1 and not sched.events:
            lines.append("  (no exchanges to schedule)")
        for ln in event_lines:
            lines.append(f"  {ln}")
    lines.append("")
    lines.append(
        f"residency summary: {rp.n_elided} resident handoff(s) elided, "
        f"{rp.n_halo} halo ppermute exchange(s), "
        f"{rp.n_reshards} minimal reshard collective(s) inserted")
    lines.append("")
    lines.append("per-loop staged estimate (paper: every block round-trips "
                 "through the master):")
    staged_total = 0
    for s in rp.stages:
        if s.plan is None:
            continue
        _, sub = _comm_breakdown(s.plan)
        staged_total += sub
        lines.append(f"  {s.name:>16s}: ~{sub} B")
    lines.append(f"  {'TOTAL':>16s}: ~{staged_total} B if each loop is "
                 "transformed in isolation")
    lines.append("")
    lines.append("final buffer layouts:")
    for key, lay in rp.final_layout.items():
        if lay == REPLICATED:
            lines.append(f"  {key:>16s}: replicated")
        elif isinstance(lay, SlabLayout2):
            (bi, bj), (ci, cj) = lay.bases, lay.covers
            lines.append(
                f"  {key:>16s}: 2-D chunk-cyclic slab "
                f"rows [{bi}, {bi + ci}) x cols [{bj}, {bj + cj}) "
                f"(reassembled by layout at exit)")
        else:
            assert isinstance(lay, SlabLayout)
            lines.append(
                f"  {key:>16s}: chunk-cyclic slab "
                f"rows [{lay.base}, {lay.base + lay.cover}) "
                f"(reassembled by layout at exit)")
    return "\n".join(lines)


def _bytes_of(shape, dtype) -> int:
    import numpy as np

    n = 1
    for s in shape:
        n *= s
    return int(n) * np.dtype(dtype).itemsize


def _comm_summary(plan: DistPlan) -> list[str]:
    """Estimated bytes moved, in MPI terms (per rule in DESIGN.md §2)."""
    lines, total = _comm_breakdown(plan)
    lines.append(f"  {'TOTAL':>12s}: ~{total} B "
                 f"({plan.lowering} lowering estimate)")
    return lines


def _comm_breakdown(plan: DistPlan) -> tuple[list[str], int]:
    """Per-variable traffic lines plus the numeric total."""
    if plan.rank == 2:
        return _comm_breakdown2(plan)
    ch = plan.chunks
    out = []
    total = 0
    for key, dec in plan.vars.items():
        info = plan.context.vars[key]
        b = _bytes_of(info.shape, info.dtype)
        row = _bytes_of(info.shape[1:], info.dtype) if info.shape else b
        moved = 0
        parts = []
        if dec.in_strategy == "replicate":
            if plan.lowering == "master_worker":
                moved += b * (ch.num_devices)
                parts.append(f"in: {ch.num_devices} point-to-point sends x {b} B")
            else:
                moved += b
                parts.append(f"in: broadcast {b} B")
        elif dec.in_strategy == "shard":
            sl = row * ch.padded_trip
            moved += sl
            parts.append(f"in: chunk slices {sl} B total")
        elif dec.in_strategy == "shard_halo":
            width = ch.chunk + (dec.halo[1] - dec.halo[0])
            sl = row * width * ch.num_chunks
            moved += sl
            parts.append(f"in: chunk slices + halo {sl} B total "
                         f"(vs {b * ch.num_devices} B broadcast)")
        if dec.out_strategy in ("identity", "partial"):
            sl = row * ch.padded_trip
            moved += sl
            parts.append(f"out: chunk slices {sl} B total")
            if plan.lowering == "master_worker":
                moved += b * ch.num_devices
                parts.append(f"out: re-broadcast {ch.num_devices} x {b} B")
        elif dec.out_strategy == "scatter":
            moved += 2 * b * ch.num_devices
            parts.append(f"out: masked all-reduce ~{2 * b} B/rank")
        elif dec.out_strategy == "put":
            moved += b * (2 if plan.lowering == "master_worker" else 1)
            parts.append(f"out: full array {b} B from last worker")
        elif dec.out_strategy == "reduce":
            rb = _bytes_of(info.write.value_shape, info.write.value_dtype)
            moved += rb * ch.num_devices
            parts.append(f"out: {ch.num_devices} partials x {rb} B")
        if parts:
            out.append(f"  {key:>12s}: " + "; ".join(parts))
        total += moved
    return out, total


def _comm_breakdown2(plan: DistPlan) -> tuple[list[str], int]:
    """Rank-2 traffic estimate: per-axis chunk windows instead of the
    1-D slab rows (same MPI-terms accounting)."""
    ch_i, ch_j = plan.chunks_axes
    out = []
    total = 0
    for key, dec in plan.vars.items():
        info = plan.context.vars[key]
        b = _bytes_of(info.shape, info.dtype)
        cell = _bytes_of(info.shape[2:], info.dtype) if len(info.shape) >= 2 \
            else b
        moved = 0
        parts = []
        if dec.in_strategy == "replicate":
            moved += b
            parts.append(f"in: broadcast {b} B")
        elif dec.in_strategy == "shard_halo":
            halos = dec.halo_axes or ((0, 0),)
            w_i = ch_i.chunk + halos[0][1] - halos[0][0]
            if dec.shard_ndim == 2:
                w_j = ch_j.chunk + halos[1][1] - halos[1][0]
                sl = cell * w_i * w_j * ch_i.num_chunks * ch_j.num_chunks
            else:
                row = _bytes_of(info.shape[1:], info.dtype)
                sl = row * w_i * ch_i.num_chunks
            moved += sl
            parts.append(f"in: 2-D chunk windows {sl} B total "
                         f"(vs {b * ch_i.num_devices * ch_j.num_devices} B "
                         "broadcast)")
        if dec.out_strategy in ("identity", "partial"):
            sl = cell * ch_i.padded_trip * ch_j.padded_trip
            moved += sl
            parts.append(f"out: chunk tiles {sl} B total")
        elif dec.out_strategy == "reduce":
            rb = _bytes_of(info.write.value_shape, info.write.value_dtype)
            p = ch_i.num_devices * ch_j.num_devices
            moved += rb * p
            parts.append(f"out: {p} partials x {rb} B")
        if parts:
            out.append(f"  {key:>12s}: " + "; ".join(parts))
        total += moved
    return out, total
