"""OMP2MPI core: pragma IR, analyses, planning and codegen.

The paper's compiler pipeline, stage by stage:

* :mod:`repro.core.api`       — the compiler driver: ``omp.compile``,
  ``Options``, the staged pass pipeline and the compilation cache,
* :mod:`repro.core.pragma`    — the OpenMP annotation surface,
* :mod:`repro.core.context`   — Context Analysis (IN/OUT/INOUT, §3.1.1),
* :mod:`repro.core.loop`      — Loop Analysis (§3.1.2),
* :mod:`repro.core.schedule`  — chunking math (§3.1.3),
* :mod:`repro.core.plan`      — Workload Distribution decisions (§3.1.3),
* :mod:`repro.core.transform` — codegen to shard_map programs (§3.1.3–4),
* :mod:`repro.core.region`    — whole-program ParallelRegion transformation
  with inter-loop residency planning (beyond-paper §3.1.4 extension),
* :mod:`repro.core.reduction` — reduction clause lowering,
* :mod:`repro.core.report`    — the "generated code" view (Tables 2/3).
"""
