"""Pallas lowering — tiled shard-local kernels for chunk compute.

``Lowering.PALLAS`` keeps the whole distributed machinery of the
collective/fused lowerings — chunk-cyclic staging, halo exchanges, the
aggregated comm schedule, the jit-level reassembly — and swaps ONLY the
per-device chunk compute: instead of a ``lax.scan`` of vmapped body
chunks (:func:`repro.core.transform._run_local_chunks`), each compute
span runs as one tiled :func:`pl.pallas_call` over this device's local
slab.  A *span* is a single loop stage, or — inside a fused region —
the maximal chain of consecutive loop stages between scheduled
exchanges that the ``comm_schedule`` hoist already isolates: those
stages share chunk geometry and only hand values to each other through
resident slabs, so the chain fuses into one kernel with intermediate
tiles forwarded in VMEM (never leaving the kernel).

Geometry (per axis) comes from the chunk-cyclic layout owned by
:mod:`repro.core.nest`: chunk ``j = q*P + d`` starts at ``k0 = j*c``;
its ``c`` lanes tile as :class:`~repro.core.nest.AxisTiles` (sublane
rounding per dtype, masked remainder lanes clamp to the last in-bounds
iteration exactly like the trip padding).  Window inputs enter as
full-chunk blocks ``(1, w, *rest)`` indexed ``(q, 0)`` — halo windows
overlap between chunks, so halo-awareness lives in the in-kernel row
offset ``pos - (k0 + b_min)`` rather than in the BlockSpec — and
outputs leave as ``(1, tile, *rest)`` blocks indexed ``(q, ti)``.

The kernel produces only dense per-lane body values; every merge
(scatter/put/reduce folds, slab state updates, cross-device combines)
runs outside on the sliced values via :func:`merge_chunk_values`,
which reproduces the ``(carry, ys)`` contract of ``_run_local_chunks``
bit-for-bit — that is what lets the differential test wall pin the
backend against the lax lowering and the shared-memory reference.

On CPU (this container, CI) the kernels run in interpret mode;
``Options(pallas_interpret=...)`` forces either mode, ``None`` picks
interpret off-TPU.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import nest as nest_mod
from repro.core import reduction as red_mod
from repro.core.nest import AxisTiles, ShiftedWindow, derive_axis_tiles

from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# The KernelPlan artifact (recorded on Compiled.passes, rendered by
# report.py)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelSpan:
    """One fused Pallas kernel: a chain of same-geometry loop stages
    with no exchange between them."""

    stage_names: tuple[str, ...]
    stage_indices: tuple[int, ...]
    rank: int
    grid: tuple[int, ...]
    tiles: tuple[AxisTiles, ...]
    forwarded: tuple[str, ...]      # keys forwarded tile-to-tile in VMEM
    n_outputs: int

    def describe(self) -> str:
        geo = " x ".join(
            f"{tl.n_tiles}*{tl.tile}" +
            (f" ({tl.masked_lanes} masked)" if tl.masked_lanes else "")
            for tl in self.tiles)
        line = (f"{'+'.join(self.stage_names)}: grid={self.grid} "
                f"tile={geo} chunk="
                + "x".join(str(tl.chunk) for tl in self.tiles))
        if self.forwarded:
            line += f"  vmem-forwarded: {', '.join(self.forwarded)}"
        return line


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """Tile geometry + fusion spans of a PALLAS-lowered program."""

    name: str
    rank: int
    spans: tuple[KernelSpan, ...]
    n_loop_stages: int

    @property
    def n_kernels(self) -> int:
        return len(self.spans)

    @property
    def max_fused(self) -> int:
        return max((len(s.stage_names) for s in self.spans), default=0)

    def describe_lines(self) -> list[str]:
        lines = [f"pallas kernels: {self.n_kernels} span(s) over "
                 f"{self.n_loop_stages} loop stage(s), interpret off-TPU"]
        for s in self.spans:
            lines.append("  " + s.describe())
        return lines


# ---------------------------------------------------------------------------
# Span planning
# ---------------------------------------------------------------------------


def _stage_geom(plan) -> tuple:
    return tuple((ch.chunk, ch.num_devices, ch.local_chunks,
                  ch.padded_trip, ch.trip_count)
                 for ch in plan.chunks_axes)


def _written_keys(plan) -> set:
    return {k for k, dec in plan.vars.items() if dec.out_strategy != "none"}


def compute_region_spans(rp) -> list[list[int]]:
    """Partition a region's executable loop stages into fusable spans.

    A stage joins the running span iff it shares chunk geometry, needs
    no gather/halo exchange, and every value it consumes is either
    external to the span or hand-off-able in VMEM (a resident feed from
    an in-span identity/partial producer).  Serial and zero-trip stages
    break spans (they never reach the kernel).
    """
    spans: list[list[int]] = []
    cur: list[int] | None = None
    cur_geom = None
    written: set = set()
    for si, se in enumerate(rp.stages):
        if se.kind != "loop" or se.plan is None \
                or se.plan.nest.total_trip == 0:
            cur = None
            continue
        plan = se.plan
        geom = _stage_geom(plan)
        ok = cur is not None and not se.gathers and geom == cur_geom
        if ok:
            for key in plan.context.env_keys:
                dec = plan.vars[key]
                if dec.in_strategy == "replicate":
                    if key in written:      # produced by a pending merge
                        ok = False
                        break
                elif dec.in_strategy in ("shard", "shard_halo"):
                    feed = se.feeds.get(key, "slice")
                    if feed == "halo":      # an exchange sits between
                        ok = False
                        break
                    if key in written:
                        if feed != "resident":
                            ok = False
                            break
                        if plan.rank == 2 \
                                and getattr(dec, "shard_ndim", 2) != 2:
                            ok = False      # 1-D slab of a 2-D nest:
                            break           # not lane-aligned in VMEM
        if ok:
            cur.append(si)
        else:
            cur = [si]
            spans.append(cur)
            cur_geom = geom
            written = set()
        written |= _written_keys(plan)
    return spans


def _span_dtype(plans) -> Any:
    """Tile-granularity dtype for a span: its first output's value
    dtype (geometry only — masked lanes, sublane rounding)."""
    for plan in plans:
        for key in sorted(plan.vars):
            dec = plan.vars[key]
            if dec.out_strategy != "none":
                return plan.context.vars[key].write.value_dtype
    return jnp.float32


def _span_meta(plans, names, indices) -> KernelSpan:
    plan0 = plans[0]
    dt = _span_dtype(plans)
    tiles = tuple(derive_axis_tiles(ch.chunk, dt)
                  for ch in plan0.chunks_axes)
    chs = plan0.chunks_axes
    if plan0.rank == 1:
        grid = (chs[0].local_chunks, tiles[0].n_tiles)
    else:
        grid = (chs[0].local_chunks, chs[1].local_chunks,
                tiles[0].n_tiles, tiles[1].n_tiles)
    # keys a later span stage consumes from an earlier one's tiles
    written: set = set()
    fwd: list[str] = []
    for pi, plan in enumerate(plans):
        if pi:
            for key in plan.context.env_keys:
                dec = plan.vars[key]
                if dec.in_strategy in ("shard", "shard_halo") \
                        and key in written and key not in fwd:
                    fwd.append(key)
        written |= _written_keys(plan)
    n_out = sum(len(_written_keys(p)) for p in plans)
    return KernelSpan(stage_names=tuple(names),
                      stage_indices=tuple(indices),
                      rank=plan0.rank, grid=grid, tiles=tiles,
                      forwarded=tuple(fwd), n_outputs=n_out)


def plan_block_kernel(plan, name: str | None = None) -> KernelPlan:
    """KernelPlan of a single ParallelFor block (one span)."""
    if plan.nest.total_trip == 0 or not _written_keys(plan):
        return KernelPlan(name=name or plan.name, rank=plan.rank,
                          spans=(), n_loop_stages=0)
    span = _span_meta([plan], [name or plan.name], [0])
    return KernelPlan(name=name or plan.name, rank=plan.rank,
                      spans=(span,), n_loop_stages=1)


def plan_region_kernels(rp) -> KernelPlan:
    """KernelPlan of a fused region: one span per exchange-free chain."""
    spans = []
    for idxs in compute_region_spans(rp):
        plans = [rp.stages[i].plan for i in idxs]
        names = [rp.stages[i].name for i in idxs]
        spans.append(_span_meta(plans, names, idxs))
    return KernelPlan(name=rp.name, rank=rp.rank, spans=tuple(spans),
                      n_loop_stages=sum(len(s.stage_indices)
                                        for s in spans))


# ---------------------------------------------------------------------------
# Kernel execution
# ---------------------------------------------------------------------------


def resolve_interpret(option, mesh) -> bool:
    """None -> interpret off-TPU (CPU/CI fallback); True/False forces."""
    if option is not None:
        return bool(option)
    try:
        platform = mesh.devices.flat[0].platform
    except Exception:  # pragma: no cover - defensive
        platform = jax.default_backend()
    return platform != "tpu"


@dataclasses.dataclass
class SpanStage:
    """One stage's kernel-side feeds, assembled by the executor."""

    name: str
    plan: Any
    program: Any
    ext_windows: dict          # key -> local slab stacks (kernel input)
    env_repl: dict             # key -> replicated array (kernel input)
    forwarded: frozenset       # keys served from in-span producer tiles


def _halo_base(dec, axis: int = 0) -> int:
    if dec.in_strategy != "shard_halo":
        return 0
    if getattr(dec, "halo_axes", None) is not None:
        return dec.halo_axes[axis][0]
    return dec.halo[0] if dec.halo is not None else 0


def _collect_io(stages, rank: int, tiles):
    """Input arrays/specs (after the SMEM meta scalar) and output
    shapes/specs, in stable order."""
    n_grid = 2 if rank == 1 else 4

    def zero_map(ndim):
        return lambda *_g: (0,) * ndim

    def win_map(ndim):                       # (q, 0, ...) full-chunk block
        if rank == 1:
            return lambda q, ti: (q,) + (0,) * (ndim - 1)
        return lambda qi, qj, ti, tj: (qi,) + (0,) * (ndim - 1)

    def win2_map(ndim):                      # (qi, 0, qj, 0, ...)
        return lambda qi, qj, ti, tj: (qi, 0, qj) + (0,) * (ndim - 3)

    def out_map(nrest):
        if rank == 1:
            return lambda q, ti: (q, ti) + (0,) * nrest
        return lambda qi, qj, ti, tj: (qi, ti, qj, tj) + (0,) * nrest

    del n_grid
    inputs, in_specs, loaders = [], [], []
    for si, sp in enumerate(stages):
        for key in sorted(sp.ext_windows):
            arr = sp.ext_windows[key]
            two_d = rank == 2 and getattr(sp.plan.vars[key],
                                          "shard_ndim", 1) == 2
            if two_d:
                blk = (1, arr.shape[1], 1, arr.shape[3]) + arr.shape[4:]
                in_specs.append(pl.BlockSpec(blk, win2_map(arr.ndim)))
            else:
                blk = (1,) + arr.shape[1:]
                in_specs.append(pl.BlockSpec(blk, win_map(arr.ndim)))
            inputs.append(arr)
            loaders.append(("win2" if two_d else "win", si, key))
        for key in sorted(sp.env_repl):
            arr = jnp.asarray(sp.env_repl[key])
            kind = "scalar" if arr.ndim == 0 else "repl"
            if arr.ndim == 0:
                arr = arr.reshape(1)
            in_specs.append(pl.BlockSpec(arr.shape, zero_map(arr.ndim)))
            inputs.append(arr)
            loaders.append((kind, si, key))
    out_shapes, out_specs, out_keys = [], [], []
    for si, sp in enumerate(stages):
        plan = sp.plan
        chs = plan.chunks_axes
        for key in sorted(plan.vars):
            dec = plan.vars[key]
            if dec.out_strategy == "none":
                continue
            info = plan.context.vars[key]
            vshape = tuple(info.write.value_shape)
            vdt = info.write.value_dtype
            if rank == 1:
                full = (chs[0].local_chunks, tiles[0].padded) + vshape
                blk = (1, tiles[0].tile) + vshape
            else:
                full = (chs[0].local_chunks, tiles[0].padded,
                        chs[1].local_chunks, tiles[1].padded) + vshape
                blk = (1, tiles[0].tile, 1, tiles[1].tile) + vshape
            out_shapes.append(jax.ShapeDtypeStruct(full, vdt))
            out_specs.append(pl.BlockSpec(blk, out_map(len(vshape))))
            out_keys.append((si, key))
    return inputs, in_specs, loaders, out_shapes, out_specs, out_keys


def execute_span(stages: list[SpanStage], device_indices: tuple,
                 interpret: bool) -> list[tuple[dict, dict]]:
    """Run a span's loop bodies as ONE tiled pallas_call; returns the
    ``(carry, ys)`` pair of every stage (the ``_run_local_chunks``
    contract), merges computed outside the kernel."""
    plan0 = stages[0].plan
    rank = plan0.rank
    chs = plan0.chunks_axes
    dt = _span_dtype([sp.plan for sp in stages])
    tiles = tuple(derive_axis_tiles(ch.chunk, dt) for ch in chs)

    (inputs, in_specs, loaders,
     out_shapes, out_specs, out_keys) = _collect_io(stages, rank, tiles)
    if not out_keys:
        return [({}, {}) for _ in stages]

    meta = jnp.stack([jnp.asarray(d, jnp.int32) for d in device_indices])
    n_in = len(loaders)

    def kernel(*refs):
        meta_ref = refs[0]
        in_refs = refs[1:1 + n_in]
        out_refs = refs[1 + n_in:]
        if rank == 1:
            q, ti = pl.program_id(0), pl.program_id(1)
            d = meta_ref[0]
            k0 = (q * chs[0].num_devices + d) * chs[0].chunk
            bases = (k0 + ti * tiles[0].tile,)
            k0s = (k0,)
            lane_ks = (bases[0]
                       + jax.lax.iota(jnp.int32, tiles[0].tile),)
        else:
            qi, qj = pl.program_id(0), pl.program_id(1)
            ti, tj = pl.program_id(2), pl.program_id(3)
            d_i, d_j = meta_ref[0], meta_ref[1]
            k0_i = (qi * chs[0].num_devices + d_i) * chs[0].chunk
            k0_j = (qj * chs[1].num_devices + d_j) * chs[1].chunk
            bases = (k0_i + ti * tiles[0].tile,
                     k0_j + tj * tiles[1].tile)
            k0s = (k0_i, k0_j)
            lane_ks = (bases[0] + jax.lax.iota(jnp.int32, tiles[0].tile),
                       bases[1] + jax.lax.iota(jnp.int32, tiles[1].tile))

        loaded = {}
        for (kind, si, key), ref in zip(loaders, in_refs):
            val = ref[...]
            if kind == "win":
                loaded[(si, key)] = val[0]
            elif kind == "win2":
                loaded[(si, key)] = val[0, :, 0]
            elif kind == "scalar":
                loaded[(si, key)] = val[0]
            else:
                loaded[(si, key)] = val

        span_vals: dict[str, Any] = {}
        for si, sp in enumerate(stages):
            plan, prog = sp.plan, sp.program
            loops = plan.nest.axes
            # masked remainder lanes clamp to the last in-bounds
            # iteration, exactly like the chunk-cyclic trip padding
            ivecs = []
            for ax, (loop, ks) in enumerate(zip(loops, lane_ks)):
                kc = jnp.minimum(ks, max(0, loop.trip_count - 1))
                ivecs.append(loop.start + loop.step * kc)
            env_sub: dict[str, Any] = {}
            for key in plan.context.env_keys:
                dec = plan.vars[key]
                info = plan.context.vars[key]
                if dec.in_strategy in ("shard", "shard_halo"):
                    ndim_sh = (getattr(dec, "shard_ndim", 1)
                               if rank == 2 else 1)
                    if key in sp.forwarded:
                        offs = tuple(bases[a] + _halo_base(dec, a)
                                     for a in range(rank))
                        env_sub[key] = ShiftedWindow(
                            span_vals[key], offs, info.shape, info.dtype)
                    else:
                        offs = tuple(k0s[a] + _halo_base(dec, a)
                                     for a in range(ndim_sh))
                        env_sub[key] = ShiftedWindow(
                            loaded[(si, key)], offs,
                            info.shape, info.dtype)
                elif dec.in_strategy == "replicate":
                    env_sub[key] = loaded[(si, key)]
                else:
                    env_sub[key] = jnp.zeros(info.shape, info.dtype)
            if rank == 1:
                updates = jax.vmap(
                    lambda i: prog.body(i, env_sub))(ivecs[0])
            else:
                updates = jax.vmap(lambda i: jax.vmap(
                    lambda jv: prog.body(i, jv, env_sub))(ivecs[1])
                )(ivecs[0])
            for oi, (osi, key) in enumerate(out_keys):
                if osi != si:
                    continue
                v = updates[key].value.astype(out_shapes[oi].dtype)
                out_refs[oi][...] = (v[None] if rank == 1
                                     else v[None, :, None])
                if sp.plan.vars[key].out_strategy in ("identity",
                                                      "partial"):
                    span_vals[key] = v

    if rank == 1:
        grid = (chs[0].local_chunks, tiles[0].n_tiles)
    else:
        grid = (chs[0].local_chunks, chs[1].local_chunks,
                tiles[0].n_tiles, tiles[1].n_tiles)
    outs = pl.pallas_call(
        kernel, grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM)] + in_specs,
        out_specs=out_specs, out_shape=out_shapes,
        interpret=interpret,
    )(meta, *inputs)
    if not isinstance(outs, (list, tuple)):
        outs = [outs]

    results = []
    for si, sp in enumerate(stages):
        vals = {}
        for oi, (osi, key) in enumerate(out_keys):
            if osi != si:
                continue
            v = outs[oi]
            if rank == 1:
                vals[key] = v[:, :tiles[0].chunk]
            else:
                vals[key] = v[:, :tiles[0].chunk, :, :tiles[1].chunk]
        if rank == 1:
            results.append(merge_chunk_values(sp.plan, vals,
                                              device_indices[0]))
        else:
            results.append(merge_chunk_values2(sp.plan, vals,
                                               device_indices))
    return results


def run_local_chunks_pallas(plan, program, env_in, slab_stacks,
                            device_index, *, interpret: bool):
    """Drop-in for ``transform._run_local_chunks`` backed by one
    pallas_call over this device's slab."""
    sp = SpanStage(name=plan.name, plan=plan, program=program,
                   ext_windows=slab_stacks, env_repl=env_in,
                   forwarded=frozenset())
    (carry, ys), = execute_span([sp], (device_index,), interpret)
    return carry, ys


def run_local_chunks_pallas2(plan, program, env_in, slab_stacks,
                             device_indices, *, interpret: bool):
    """Rank-2 drop-in for ``transform._run_local_chunks2``."""
    sp = SpanStage(name=plan.name, plan=plan, program=program,
                   ext_windows=slab_stacks, env_repl=env_in,
                   forwarded=frozenset())
    (carry, ys), = execute_span([sp], tuple(device_indices), interpret)
    return carry, ys


# ---------------------------------------------------------------------------
# Merges — outside the kernel, reproducing the _run_local_chunks /
# _run_local_chunks2 (carry, ys) contract from dense per-lane values
# ---------------------------------------------------------------------------


def merge_chunk_values(plan, values, device_index):
    """(n_loc, c, *value_shape) dense values -> (carry, ys) exactly as
    ``_run_local_chunks`` would have produced them."""
    ch = plan.chunks
    t = plan.loop.trip_count
    js = (jnp.arange(ch.local_chunks, dtype=jnp.int32) * ch.num_devices
          + device_index)
    ks = (js[:, None] * ch.chunk
          + jnp.arange(ch.chunk, dtype=jnp.int32)[None, :])
    valid = ks < t
    carry: dict[str, Any] = {}
    ys: dict[str, Any] = {}
    for key, dec in plan.vars.items():
        if dec.out_strategy == "none":
            continue
        v = values[key]
        info = plan.context.vars[key]
        if dec.out_strategy in ("identity", "partial"):
            ys[key] = v
        elif dec.out_strategy == "scatter":
            shape0 = info.shape[0]
            pos = dec.write_map.a * ks + dec.write_map.b
            pos = jnp.where(valid, pos, shape0).reshape(-1)
            flat = v.reshape((-1,) + v.shape[2:])
            buf = jnp.zeros(info.shape, info.dtype) \
                .at[pos].set(flat, mode="drop")
            mask = jnp.zeros((shape0,), jnp.bool_) \
                .at[pos].set(True, mode="drop")
            carry[key] = (buf, mask)
        elif dec.out_strategy == "put":
            j_star = (t - 1) // ch.chunk
            lane = (t - 1) - j_star * ch.chunk
            q_star = j_star // ch.num_devices
            row = v[q_star, lane]
            carry[key] = jnp.where(js[q_star] == j_star, row,
                                   jnp.zeros(info.shape, info.dtype))
        elif dec.out_strategy == "reduce":
            rop = red_mod.get_reduction(dec.reduction_op)
            ident = red_mod.identity_like(rop, v)
            vmask = valid.reshape(valid.shape + (1,) * (v.ndim - 2))
            flat = jnp.where(vmask, v, ident) \
                .reshape((-1,) + v.shape[2:])
            carry0 = red_mod.identity_like(
                rop, jnp.zeros(info.write.value_shape,
                               info.write.value_dtype))
            carry[key] = rop.pairwise(carry0, rop.local_fold(flat, 0))
    return carry, ys


def merge_chunk_values2(plan, values, device_indices):
    """(n_i, c_i, n_j, c_j, *value_shape) dense values -> (carry, ys)
    exactly as ``_run_local_chunks2`` would have produced them."""
    ch_i, ch_j = plan.chunks_axes
    loop_i, loop_j = plan.nest.axes
    d_i, d_j = device_indices
    ks_i = ((jnp.arange(ch_i.local_chunks, dtype=jnp.int32)
             * ch_i.num_devices + d_i)[:, None] * ch_i.chunk
            + jnp.arange(ch_i.chunk, dtype=jnp.int32)[None, :])
    ks_j = ((jnp.arange(ch_j.local_chunks, dtype=jnp.int32)
             * ch_j.num_devices + d_j)[:, None] * ch_j.chunk
            + jnp.arange(ch_j.chunk, dtype=jnp.int32)[None, :])
    valid = (ks_i < loop_i.trip_count)[:, :, None, None] \
        & (ks_j < loop_j.trip_count)[None, None, :, :]
    carry: dict[str, Any] = {}
    ys: dict[str, Any] = {}
    for key, dec in plan.vars.items():
        if dec.out_strategy == "none":
            continue
        v = values[key]
        info = plan.context.vars[key]
        if dec.out_strategy in ("identity", "partial"):
            ys[key] = v
        elif dec.out_strategy == "reduce":
            rop = red_mod.get_reduction(dec.reduction_op)
            ident = red_mod.identity_like(rop, v)
            vmask = valid.reshape(valid.shape + (1,) * (v.ndim - 4))
            flat = jnp.where(vmask, v, ident) \
                .reshape((-1,) + v.shape[4:])
            carry0 = red_mod.identity_like(
                rop, jnp.zeros(info.write.value_shape,
                               info.write.value_dtype))
            carry[key] = rop.pairwise(carry0, rop.local_fold(flat, 0))
    return carry, ys
