"""Persistent AOT executable store — the compile cache that survives
the process.

The structural compilation cache in :mod:`repro.core.api` makes warm
compiles ~63x faster than cold (EXPERIMENTS §Perf-F), but it dies with
the process: every fresh worker pays the full planning + XLA
compilation cost again.  This module persists the *executable* — the
end-to-end jitted ``Compiled.run`` lowered and XLA-compiled, then
serialized via :mod:`jax.experimental.serialize_executable` — in a
versioned on-disk store, so a fresh process restores the compiled
binary instead of re-planning and re-compiling (EXPERIMENTS §Perf-I
measures the cross-process warm start).

Keys must be stable *across processes*, which the in-memory cache key
is not (it pins loop bodies by ``id()``).  :func:`fingerprint` derives
a structural content hash instead: function bodies hash by bytecode +
consts + closure values (recursively — nested code objects hash by
structure, never by ``repr`` which embeds addresses), programs by the
same shape as the in-memory signature, arrays by shape/dtype/bytes.

Robustness contract: a corrupt, truncated, version-skewed or otherwise
unreadable entry is a *miss*, never a crash — the caller falls back to
a cold compile and the store counts the error.  Writes are atomic
(temp file + rename) so a concurrent reader never observes a partial
entry.

Entry layout (one file per key, ``<key>.aot``)::

    MAGIC | u32 header_len | header JSON | sha256(body) | body

where the header records the store version, the jax/jaxlib versions
and the backend (any mismatch is a miss), and the body is the pickled
``(payload, in_tree, out_tree)`` triple from
``serialize_executable.serialize``.
"""
from __future__ import annotations

import dataclasses
import enum
import hashlib
import json
import os
import pickle
import struct
import tempfile
import types
from typing import Any

import jax
import numpy as np

STORE_VERSION = 1
_MAGIC = b"RPROAOT\x01"

#: Environment variable naming the store directory; when set, the
#: compile pipeline (:mod:`repro.core.api`) enables persistence at
#: import — this is how subprocess benchmarks and CI opt in.
ENV_VAR = "REPRO_AOT_CACHE_DIR"

#: Optional size cap (bytes) on the store directory.  When the cap is
#: set — via this variable or the ``max_bytes=`` constructor argument —
#: every save sweeps least-recently-*used* entries (mtime order; load
#: hits touch the file) until the directory fits.  Unset/invalid/<=0
#: means unbounded.
ENV_MAX_BYTES = "REPRO_AOT_CACHE_MAX_BYTES"


# ---------------------------------------------------------------------------
# Stable structural fingerprints
# ---------------------------------------------------------------------------


def _code_token(code: types.CodeType, seen: set) -> tuple:
    """Structural identity of a code object.  ``repr`` of a code object
    embeds its address — recurse into the fields that define behavior
    instead."""
    return ("code", code.co_name, code.co_argcount, code.co_nlocals,
            code.co_code, _token(code.co_consts, seen),
            code.co_names, code.co_varnames, code.co_freevars)


def _function_token(fn, seen: set) -> tuple:
    key = id(fn)
    if key in seen:
        return ("recursive-fn", fn.__qualname__)
    seen = seen | {key}
    closure = ()
    if fn.__closure__:
        closure = tuple(_token(c.cell_contents, seen)
                        for c in fn.__closure__)
    return ("fn", fn.__module__, fn.__qualname__,
            _code_token(fn.__code__, seen),
            _token(fn.__defaults__, seen), closure)


def _token(v: Any, seen: set) -> Any:
    """A repr-stable token for ``v``: equal program structure gives an
    equal token in every process; addresses never leak in."""
    if v is None or isinstance(v, (bool, int, float, complex, str, bytes)):
        return v
    if isinstance(v, enum.Enum):
        return ("enum", type(v).__name__, v.value)
    if isinstance(v, types.CodeType):
        return _code_token(v, seen)
    if isinstance(v, types.FunctionType):
        return _function_token(v, seen)
    if hasattr(v, "shape") and hasattr(v, "dtype"):
        arr = np.asarray(v)
        return ("array", arr.shape, str(arr.dtype),
                hashlib.sha256(arr.tobytes()).hexdigest())
    if isinstance(v, (tuple, list)):
        return (type(v).__name__,) + tuple(_token(x, seen) for x in v)
    if isinstance(v, dict):
        return ("dict",) + tuple(
            (_token(k, seen), _token(v[k], seen))
            for k in sorted(v, key=repr))
    if isinstance(v, (set, frozenset)):
        return ("set",) + tuple(sorted(repr(_token(x, seen)) for x in v))
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        return ("dc", type(v).__name__) + tuple(
            (f.name, _token(getattr(v, f.name), seen))
            for f in dataclasses.fields(v))
    # Fallback: type identity only.  A bare repr may embed an address
    # (``<object at 0x...>``) which would defeat cross-process reuse.
    r = repr(v)
    return ("obj", type(v).__name__, r if " at 0x" not in r else "")


def fingerprint(*parts: Any) -> str:
    """SHA-256 hex digest of the stable token of ``parts``."""
    tok = _token(tuple(parts), set())
    return hashlib.sha256(repr(tok).encode()).hexdigest()


# ---------------------------------------------------------------------------
# The store
# ---------------------------------------------------------------------------


def empty_stats() -> dict:
    return {"disk_hits": 0, "disk_misses": 0, "disk_errors": 0,
            "disk_bytes_read": 0, "disk_bytes_written": 0,
            "evictions": 0, "evicted_bytes": 0}


class AOTStore:
    """One directory of serialized executables, one file per key.

    ``load``/``save`` never raise on a bad entry or an unwritable
    directory — persistence is an accelerator, not a correctness
    dependency — every failure is counted in :attr:`stats`.
    """

    def __init__(self, path: str, max_bytes: int | None = None) -> None:
        self.path = os.path.abspath(os.path.expanduser(path))
        os.makedirs(self.path, exist_ok=True)
        self.stats = empty_stats()
        if max_bytes is None:
            raw = os.environ.get(ENV_MAX_BYTES, "")
            try:
                max_bytes = int(raw) if raw else None
            except ValueError:
                max_bytes = None
        self.max_bytes = (max_bytes
                          if max_bytes is not None and max_bytes > 0
                          else None)

    # -- key -> file -------------------------------------------------------

    def _entry_path(self, key: str) -> str:
        return os.path.join(self.path, f"{key}.aot")

    def _header(self, key: str) -> dict:
        return {"store_version": STORE_VERSION, "key": key,
                "jax": jax.__version__,
                "backend": jax.default_backend()}

    def entries(self) -> list[str]:
        try:
            return sorted(f[:-4] for f in os.listdir(self.path)
                          if f.endswith(".aot"))
        except OSError:
            return []

    # -- save --------------------------------------------------------------

    def save(self, key: str, compiled_exe) -> bool:
        """Serialize a ``jax.stages.Compiled`` under ``key``.  Returns
        whether the entry landed on disk."""
        from jax.experimental import serialize_executable as se

        try:
            payload, in_tree, out_tree = se.serialize(compiled_exe)
            body = pickle.dumps((payload, in_tree, out_tree),
                                protocol=pickle.HIGHEST_PROTOCOL)
            header = json.dumps(self._header(key)).encode()
            blob = (_MAGIC + struct.pack("<I", len(header)) + header
                    + hashlib.sha256(body).digest() + body)
            fd, tmp = tempfile.mkstemp(dir=self.path, suffix=".tmp")
            try:
                with os.fdopen(fd, "wb") as fh:
                    fh.write(blob)
                os.replace(tmp, self._entry_path(key))
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
        except Exception:
            self.stats["disk_errors"] += 1
            return False
        self.stats["disk_bytes_written"] += len(blob)
        self._evict(protect=key)
        return True

    # -- eviction ----------------------------------------------------------

    def _evict(self, protect: str | None = None) -> None:
        """LRU sweep: drop oldest-by-mtime entries until the directory
        fits :attr:`max_bytes`.  The just-written ``protect`` key is
        never dropped (a cap smaller than one entry must not turn every
        save into an immediate self-eviction).  Like everything else in
        the store, a file vanishing or erroring mid-sweep is tolerated,
        never raised."""
        if self.max_bytes is None:
            return
        try:
            names = os.listdir(self.path)
        except OSError:
            return
        entries, total = [], 0
        for f in names:
            if not f.endswith(".aot"):
                continue
            p = os.path.join(self.path, f)
            try:
                st = os.stat(p)
            except OSError:
                continue
            entries.append((st.st_mtime, st.st_size, p, f[:-4]))
            total += st.st_size
        entries.sort()
        for _mtime, size, p, k in entries:
            if total <= self.max_bytes:
                break
            if k == protect:
                continue
            try:
                os.unlink(p)
            except OSError:
                continue
            total -= size
            self.stats["evictions"] += 1
            self.stats["evicted_bytes"] += size

    # -- load --------------------------------------------------------------

    def load(self, key: str):
        """The loaded executable for ``key``, or ``None`` on miss.
        Corrupt/truncated/version-skewed entries count as misses (plus
        ``disk_errors`` when the file existed but could not be used)
        and the bad file is removed so it is not re-probed forever."""
        from jax.experimental import serialize_executable as se

        path = self._entry_path(key)
        try:
            with open(path, "rb") as fh:
                blob = fh.read()
        except OSError:
            self.stats["disk_misses"] += 1
            return None
        try:
            if blob[:len(_MAGIC)] != _MAGIC:
                raise ValueError("bad magic")
            off = len(_MAGIC)
            (hlen,) = struct.unpack_from("<I", blob, off)
            off += 4
            header = json.loads(blob[off:off + hlen].decode())
            off += hlen
            want = self._header(key)
            for field in ("store_version", "jax", "backend"):
                if header.get(field) != want[field]:
                    raise ValueError(
                        f"version skew: {field}={header.get(field)!r} "
                        f"(want {want[field]!r})")
            digest, body = blob[off:off + 32], blob[off + 32:]
            if hashlib.sha256(body).digest() != digest:
                raise ValueError("checksum mismatch")
            payload, in_tree, out_tree = pickle.loads(body)
            exe = se.deserialize_and_load(payload, in_tree, out_tree)
        except Exception:
            self.stats["disk_errors"] += 1
            self.stats["disk_misses"] += 1
            try:
                os.unlink(path)
            except OSError:
                pass
            return None
        self.stats["disk_hits"] += 1
        self.stats["disk_bytes_read"] += len(blob)
        try:
            os.utime(path)               # refresh LRU recency on hit
        except OSError:
            pass
        return exe
