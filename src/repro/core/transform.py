"""OMP→"MPI" code generation (paper §3.1.3–3.1.4).

Two executors for a :class:`~repro.core.pragma.ParallelFor` program:

* :func:`run_reference` — the *shared-memory* ("OpenMP") semantics on the
  local device.  This is the oracle: the paper's "correct by construction"
  claim is validated as ``omp.compile(pf, mesh)(env) == pf(env)``.

* :class:`DistributedProgram` (built by :func:`repro.core.api.compile`'s
  **lower** pass) — executes the block over a mesh axis under
  ``jax.shard_map`` using the :class:`~repro.core.plan.DistPlan`
  strategies.  Two lowerings:

  - ``"collective"`` — TPU-native: chunk-cyclic layout + balanced
    collectives (psum / sharded slabs).  This is the production path.
  - ``"master_worker"`` — paper-faithful: rank 0 owns the shared memory;
    every IN buffer is *sent* from rank 0 to each worker and every OUT
    slab is sent back and re-broadcast, as explicit
    ``collective-permute`` pairs.  It reproduces the communication shape
    of the paper's Fig. 1b (all traffic through the master's links) and
    exists as the measurable baseline for EXPERIMENTS.md §Perf-A.

Both executors transform ONE block.  Whole programs (chains of blocks
with inter-loop residency planning) compile to a
:class:`repro.core.region.DistributedRegion`, which reuses this module's
chunk-execution machinery (`_run_local_chunks`) inside a single fused
shard_map; per-loop staging via this module is its measurable baseline
(EXPERIMENTS.md §Perf-C).

The public surface is :func:`repro.core.api.compile`; :func:`to_mpi`
remains as a deprecation shim over it.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core import nest as nest_mod
from repro.core import pragma, reduction as red_mod
from repro.core.context import ReadKind, VarClass, WriteKind
from repro.core.loop import LoopNotCanonical, analyze_loop
from repro.core.nest import LoopNest, ShiftedWindow, SubstitutionFailed  # noqa: F401 (re-export)
from repro.core.plan import DistPlan, make_plan


# ---------------------------------------------------------------------------
# Shared-memory reference executor ("the OpenMP block")
# ---------------------------------------------------------------------------


def run_reference(program: pragma.ParallelFor, env: Mapping[str, Any]) -> dict:
    """Execute with OpenMP shared-memory semantics on the local device.

    Reads observe the pre-loop environment (iterations are concurrent in
    OpenMP; racy read-after-write across iterations is UB there and
    unsupported here — see DESIGN.md).
    """
    if program.rank == 2:
        return _run_reference2(program, env)
    loop = analyze_loop(program.start, program.stop, program.step)
    env = {k: jnp.asarray(v) for k, v in env.items()}
    out = dict(env)
    t = loop.trip_count
    if t == 0:
        # A zero-trip loop writes nothing — except that a reduction
        # clause *defines* its variable as the op identity even over an
        # empty iteration space (OpenMP initialises the private copy
        # before any iteration runs).  Buffers already in env keep their
        # value (identity folds are no-ops); fresh reduction outputs
        # must still exist, matching the distributed executors.
        fresh = [k for k in program.reduction if k not in out]
        if fresh:
            upds = jax.eval_shape(
                program.body, jax.ShapeDtypeStruct((), jnp.int32), env)
            for key in fresh:
                rop = red_mod.get_reduction(program.reduction[key])
                out[key] = red_mod.identity_like(
                    rop, jnp.zeros(upds[key].value.shape,
                                   upds[key].value.dtype))
        return out

    ivec = program.start + program.step * jnp.arange(t, dtype=jnp.int32)
    updates = jax.vmap(lambda i: program.body(i, env))(ivec)
    for key, upd in updates.items():
        if isinstance(upd, pragma.At):
            out[key] = out[key].at[upd.idx].set(upd.value)
        elif isinstance(upd, pragma.Put):
            out[key] = upd.value[t - 1]
        elif isinstance(upd, pragma.Red):
            rop = red_mod.get_reduction(program.reduction[key])
            folded = rop.local_fold(upd.value, 0)
            if key in env:
                folded = rop.pairwise(env[key], folded)
            out[key] = folded
        else:
            raise LoopNotCanonical(
                f"update for {key!r} must be omp.at/omp.put/omp.red"
            )
    return out


def _run_reference2(program: pragma.ParallelFor, env: Mapping[str, Any]) -> dict:
    """Shared-memory reference for a ``collapse=2`` nest: the body is
    vmapped over the full cross product of both iteration spaces."""
    nest = LoopNest.from_program(program)
    env = {k: jnp.asarray(v) for k, v in env.items()}
    out = dict(env)
    t_i, t_j = nest.trip_counts
    if t_i == 0 or t_j == 0:
        fresh = [k for k in program.reduction if k not in out]
        if fresh:
            zero = jax.ShapeDtypeStruct((), jnp.int32)
            upds = jax.eval_shape(program.body, zero, zero, env)
            for key in fresh:
                rop = red_mod.get_reduction(program.reduction[key])
                out[key] = red_mod.identity_like(
                    rop, jnp.zeros(upds[key].value.shape,
                                   upds[key].value.dtype))
        return out

    ax_i, ax_j = nest.axes
    ivec = ax_i.start + ax_i.step * jnp.arange(t_i, dtype=jnp.int32)
    jvec = ax_j.start + ax_j.step * jnp.arange(t_j, dtype=jnp.int32)
    updates = jax.vmap(
        lambda i: jax.vmap(lambda j: program.body(i, j, env))(jvec))(ivec)
    for key, upd in updates.items():
        if isinstance(upd, pragma.At):
            out[key] = out[key].at[upd.idx].set(upd.value)
        elif isinstance(upd, pragma.Red):
            rop = red_mod.get_reduction(program.reduction[key])
            folded = rop.local_fold(upd.value, (0, 1))
            if key in env:
                folded = rop.pairwise(env[key], folded)
            out[key] = folded
        else:
            raise LoopNotCanonical(
                f"update for {key!r} must be omp.at/omp.red in a "
                "collapse=2 nest"
            )
    return out


# ---------------------------------------------------------------------------
# Distributed program
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DistributedProgram:
    """The generated "MPI" program for one parallel block."""

    program: pragma.ParallelFor
    mesh: Mesh
    plan: DistPlan | None
    axis: str = "data"
    lowering: str = "collective"
    shard_inputs: bool = False
    unroll_chunks: bool = False
    paper_master_excluded: bool | None = None
    schedule_override: pragma.Schedule | None = None
    comm_schedule: str = "aggregate"    # fuse per-block combines when set
    use_pallas: bool = False            # Lowering.PALLAS: tiled kernels
    pallas_interpret: bool | None = None
    chunk_weights: tuple | None = None  # straggler-weighted chunk deal

    def __call__(self, env: Mapping[str, Any]) -> dict:
        return _execute(self, {k: jnp.asarray(v) for k, v in env.items()})

    def report(self) -> str:
        from repro.core import report as report_mod

        if self.plan is None:
            raise ValueError("call the program (or pass env_like) to build "
                             "the plan before asking for a report")
        return report_mod.render_plan(self.plan)


def resolve_axes(program_or_rank, mesh: Mesh, axis):
    """Resolve the mesh-axis clause against the program's nest rank.

    Returns ``(axis, num_devices)`` — scalars for rank-1, matching
    2-tuples for rank-2 (defaulting to ``("i", "j")`` when present in
    the mesh, else the first two mesh axes).
    """
    rank = (program_or_rank if isinstance(program_or_rank, int)
            else program_or_rank.rank)
    names = tuple(mesh.axis_names)
    if rank == 2:
        if axis is None:
            if "i" in names and "j" in names:
                axis = ("i", "j")
            elif len(names) >= 2:
                axis = names[:2]
            else:
                raise ValueError(
                    f"collapse=2 needs a 2-D mesh; got axes {names}")
        if not isinstance(axis, tuple) or len(axis) != 2 \
                or axis[0] == axis[1]:
            raise ValueError(
                f"collapse=2 needs two distinct mesh axes, got {axis!r}")
        for a in axis:
            if a not in names:
                raise ValueError(f"axis {a!r} not in mesh axes {names}")
        return axis, tuple(int(mesh.shape[a]) for a in axis)
    if axis is None:
        axis = "data"
    if axis not in names:
        raise ValueError(f"axis {axis!r} not in mesh axes {names}")
    return axis, mesh.shape[axis]


def mesh_axis_sizes(mesh: Mesh, axis):
    """Device count(s) along an already-resolved axis clause: a scalar
    for one named axis, a matching tuple for a rank-2 axis pair."""
    if isinstance(axis, tuple):
        return tuple(int(mesh.shape[a]) for a in axis)
    return mesh.shape[axis]


def to_mpi(
    program: pragma.ParallelFor,
    mesh: Mesh,
    *,
    axis: str | tuple | None = None,
    lowering: str = "collective",
    shard_inputs: bool = False,
    keep_sharded: bool = False,
    unroll_chunks: bool = False,
    env_like: Mapping[str, Any] | None = None,
    paper_master_excluded: bool | None = None,
):
    """Deprecated: use ``omp.compile(program, mesh, omp.Options(...))``.

    Thin shim: translates the legacy kwargs to
    :class:`~repro.core.api.Options` and returns the
    :class:`~repro.core.api.Compiled` artifact (callable like the
    ``DistributedProgram`` it used to return, with ``.plan`` /
    ``.report()`` intact).
    """
    import warnings

    from repro.core import api

    warnings.warn(
        "omp.to_mpi() is deprecated; use omp.compile(program, mesh, "
        "omp.Options(lowering=..., shard=...)) instead",
        DeprecationWarning, stacklevel=2)
    options = api.Options(
        axis=axis,
        lowering=lowering,
        shard=(api.ShardPolicy.SLICE if shard_inputs
               else api.ShardPolicy.REPLICATE),
        keep_sharded=keep_sharded,
        unroll_chunks=unroll_chunks,
        paper_master_excluded=paper_master_excluded,
    )
    return api.compile(program, mesh, options, env_like=env_like)


# ---------------------------------------------------------------------------
# Execution
# ---------------------------------------------------------------------------

#: Fault-injection hook (repro.runtime.fault_injection installs a
#: callable here inside ``inject()``); called with a site name at the
#: python entry of each distributed executor.  ``None`` in production.
_fault_hook = None


def _maybe_fault(site: str) -> None:
    if _fault_hook is not None:
        _fault_hook(site)


def _execute(dp: DistributedProgram, env: dict) -> dict:
    program = dp.program
    if dp.plan is None:
        dp.plan = make_plan(
            program, env, mesh_axis_sizes(dp.mesh, dp.axis), axis=dp.axis,
            lowering=dp.lowering, shard_inputs=dp.shard_inputs,
            paper_master_excluded=dp.paper_master_excluded,
            schedule=dp.schedule_override,
            weights=dp.chunk_weights,
        )
    plan = dp.plan
    t = plan.nest.total_trip
    out = dict(env)
    if t == 0:
        for key, dec in plan.vars.items():
            if dec.out_strategy == "reduce":
                rop = red_mod.get_reduction(dec.reduction_op)
                info = plan.context.vars[key]
                zero = red_mod.identity_like(
                    rop, jnp.zeros(info.write.value_shape, info.write.value_dtype))
                out[key] = rop.pairwise(env[key], zero) if key in env else zero
        return out

    if plan.rank == 2:
        return _execute_collective2(dp, env)
    if plan.lowering == "collective":
        return _execute_collective(dp, env)
    return _execute_master_worker(dp, env)


def _chunk_iteration_vectors(plan, j, dtype=jnp.int32):
    """Iteration numbers, validity mask and clamped loop indices of chunk j."""
    c = plan.chunks.chunk
    t = plan.loop.trip_count
    ks = j * c + jnp.arange(c, dtype=dtype)
    valid = ks < t
    kc = jnp.minimum(ks, t - 1)
    ivec = plan.loop.start + plan.loop.step * kc
    return ks, valid, kc, ivec


def _make_env_sub(plan, env_in, slabs_q, k0):
    """Environment seen by the body inside one chunk."""
    env_sub: dict[str, Any] = {}
    for key in plan.context.env_keys:
        dec = plan.vars[key]
        info = plan.context.vars[key]
        if dec.in_strategy == "shard":
            env_sub[key] = ShiftedWindow(
                slabs_q[key], (k0,), info.shape, info.dtype)
        elif dec.in_strategy == "shard_halo":
            # slab row t holds position k0 + b_min + t
            env_sub[key] = ShiftedWindow(
                slabs_q[key], (k0 + dec.halo[0],), info.shape, info.dtype)
        elif dec.in_strategy == "replicate":
            env_sub[key] = env_in[key]
        else:  # unused inside the body: placeholder, DCE'd by XLA
            env_sub[key] = jnp.zeros(info.shape, info.dtype)
    return env_sub


def _apply_chunk_updates(plan, updates, carry, ys, j, valid, shapes):
    """Fold one chunk's updates into the scan carry / per-chunk outputs."""
    t = plan.loop.trip_count
    for key, dec in plan.vars.items():
        if dec.out_strategy == "none":
            continue
        upd = updates[key]
        if dec.out_strategy in ("identity", "partial"):
            ys[key] = upd.value
        elif dec.out_strategy == "scatter":
            shape0 = shapes[key][0]
            # positions from true iteration numbers of this chunk
            ks = j * plan.chunks.chunk + jnp.arange(plan.chunks.chunk)
            pos = dec.write_map.a * ks + dec.write_map.b
            pos = jnp.where(valid, pos, shape0)  # OOB -> dropped
            buf, mask = carry[key]
            buf = buf.at[pos].set(upd.value, mode="drop")
            mask = mask.at[pos].set(True, mode="drop")
            carry[key] = (buf, mask)
        elif dec.out_strategy == "put":
            j_star = (t - 1) // plan.chunks.chunk
            lane = (t - 1) - j_star * plan.chunks.chunk
            row = jax.lax.dynamic_index_in_dim(upd.value, lane, 0, keepdims=False)
            carry[key] = jnp.where(j == j_star, row, carry[key])
        elif dec.out_strategy == "reduce":
            rop = red_mod.get_reduction(dec.reduction_op)
            ident = red_mod.identity_like(rop, upd.value)
            vmask = valid.reshape((-1,) + (1,) * (upd.value.ndim - 1))
            contrib = jnp.where(vmask, upd.value, ident)
            part = rop.local_fold(contrib, 0)
            carry[key] = rop.pairwise(carry[key], part)
    return carry, ys


def _init_carry(plan):
    carry: dict[str, Any] = {}
    for key, dec in plan.vars.items():
        info = plan.context.vars[key]
        if dec.out_strategy == "scatter":
            carry[key] = (
                jnp.zeros(info.shape, info.dtype),
                jnp.zeros((info.shape[0],), jnp.bool_),
            )
        elif dec.out_strategy == "put":
            carry[key] = jnp.zeros(info.shape, info.dtype)
        elif dec.out_strategy == "reduce":
            rop = red_mod.get_reduction(dec.reduction_op)
            carry[key] = red_mod.identity_like(
                rop, jnp.zeros(info.write.value_shape, info.write.value_dtype))
    return carry


def _slot_table(ch):
    """(n_loc, P) table of global chunk ids per (local chunk, device)
    slot, or ``None`` for the plain cyclic deal (where the chunk id is
    just ``q * P + d``)."""
    if ch.slot_map is None:
        return None
    return jnp.asarray(np.asarray(ch.slot_map, dtype=np.int32).reshape(
        ch.local_chunks, ch.num_devices))


def _run_local_chunks(plan, program, env_in, slab_stacks, worker_index,
                      unroll_chunks=False):
    """Scan this device's chunks; returns (carry, ys_stacked)."""
    ch = plan.chunks
    shapes = {k: plan.context.vars[k].shape for k in plan.vars}
    carry0 = _init_carry(plan)
    slot_table = _slot_table(ch)

    def one_chunk(carry, q):
        if slot_table is None:
            j = q * ch.num_devices + worker_index
        else:
            j = slot_table[q, worker_index]
        k0 = j * ch.chunk
        ks, valid, kc, ivec = _chunk_iteration_vectors(plan, j)
        if isinstance(q, int):
            # static chunk index: plain slices instead of dynamic gathers
            slabs_q = {k: v[q] for k, v in slab_stacks.items()}
        else:
            slabs_q = {k: jax.lax.dynamic_index_in_dim(v, q, 0,
                                                       keepdims=False)
                       for k, v in slab_stacks.items()}
        env_sub = _make_env_sub(plan, env_in, slabs_q, k0)
        updates = jax.vmap(lambda i: program.body(i, env_sub))(ivec)
        ys: dict[str, Any] = {}
        carry, ys = _apply_chunk_updates(plan, updates, carry, ys, j, valid, shapes)
        return carry, ys

    if ch.local_chunks == 1:
        # Fast path: exactly one chunk per device — no lax.scan carry
        # threading and no dynamic window gather; the slab body runs
        # directly on the (statically sliced) single chunk.
        carry, ys = one_chunk(carry0, 0)
        ys = {k: v[None] for k, v in ys.items()}
        return carry, ys
    qs = jnp.arange(ch.local_chunks, dtype=jnp.int32)
    unroll = ch.local_chunks if unroll_chunks else 1
    return jax.lax.scan(one_chunk, carry0, qs, unroll=unroll)


def _execute_collective(dp: DistributedProgram, env: dict) -> dict:
    _maybe_fault("collective")
    plan, program, mesh = dp.plan, dp.program, dp.mesh
    axis = plan.axis
    t = plan.loop.trip_count

    repl_keys = [k for k in plan.context.env_keys
                 if plan.vars[k].in_strategy == "replicate"]
    env_repl = {k: env[k] for k in repl_keys}
    env_slab = {}
    for k in plan.sharded_in_keys:
        dec = plan.vars[k]
        if dec.in_strategy == "shard_halo":
            env_slab[k] = nest_mod.halo_slabs(env[k], plan.chunks, dec.halo)
        else:
            env_slab[k] = nest_mod.pad_reshape(env[k], plan.chunks)

    aggregate = dp.comm_schedule == "aggregate"
    if dp.use_pallas:
        from repro.core import pallas_lower as plx

        pallas_interp = plx.resolve_interpret(dp.pallas_interpret, mesh)

    def device_fn(env_repl, env_slab):
        from repro.core import comm_schedule as cs_mod

        d = jax.lax.axis_index(axis)
        slab_stacks = {k: v[:, 0] for k, v in env_slab.items()}
        if dp.use_pallas:
            carry, ys = plx.run_local_chunks_pallas(
                plan, program, env_repl, slab_stacks, d,
                interpret=pallas_interp)
        else:
            carry, ys = _run_local_chunks(plan, program, env_repl,
                                          slab_stacks, d, dp.unroll_chunks)

        # With the aggregate schedule, every psum-family combine of the
        # block (scatter buf+mask pairs, put broadcasts, reduction
        # partials) defers into ONE fused flat collective per
        # (collective, dtype) group instead of one launch per merge.
        outs: dict[str, Any] = {}
        pending: dict[tuple[str, str], tuple[str, Any]] = {}
        for key, dec in plan.vars.items():
            if dec.out_strategy in ("identity", "partial"):
                outs[key] = ys[key][:, None]  # (n_loc, 1, c, *rest)
            elif dec.out_strategy == "scatter":
                buf, mask = carry[key]
                if aggregate:
                    pending[(key, "buf")] = ("psum", buf)
                    pending[(key, "mask")] = ("psum", mask.astype(jnp.int32))
                else:
                    outs[key] = (
                        jax.lax.psum(buf, axis),
                        jax.lax.psum(mask.astype(jnp.int32), axis),
                    )
            elif dec.out_strategy == "put":
                owner = plan.chunks.owner_of_last_iteration()
                val = jnp.where(d == owner, carry[key],
                                jnp.zeros_like(carry[key]))
                if aggregate:
                    pending[(key, "put")] = ("psum", val)
                else:
                    outs[key] = jax.lax.psum(val, axis)
            elif dec.out_strategy == "reduce":
                rop = red_mod.get_reduction(dec.reduction_op)
                if rop.collective == "gather":
                    outs[key] = carry[key][None]
                elif aggregate:
                    pending[(key, "red")] = (rop.collective, carry[key])
                else:
                    outs[key] = red_mod.cross_device_combine(rop, carry[key], axis)
        if pending:
            combined = cs_mod.fused_collectives(pending, axis)
            for key, dec in plan.vars.items():
                if dec.out_strategy == "scatter":
                    outs[key] = (combined[(key, "buf")],
                                 combined[(key, "mask")])
                elif dec.out_strategy == "put":
                    outs[key] = combined[(key, "put")]
                elif dec.out_strategy == "reduce" \
                        and (key, "red") in combined:
                    outs[key] = combined[(key, "red")]
        return outs

    in_specs = (
        {k: P() for k in env_repl},
        {k: P(None, axis) for k in env_slab},
    )
    out_specs: dict[str, Any] = {}
    for key, dec in plan.vars.items():
        if dec.out_strategy in ("identity", "partial"):
            out_specs[key] = P(None, axis)
        elif dec.out_strategy == "scatter":
            out_specs[key] = (P(), P())
        elif dec.out_strategy == "put":
            out_specs[key] = P()
        elif dec.out_strategy == "reduce":
            rop = red_mod.get_reduction(dec.reduction_op)
            out_specs[key] = P(axis) if rop.collective == "gather" else P()
    if not out_specs:
        return dict(env)

    outs = shard_map(
        device_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
    )(env_repl, env_slab)

    # --- reassembly at the jit level (layout, not messages) ---------------
    result = dict(env)
    for key, dec in plan.vars.items():
        if dec.out_strategy == "identity":
            flat = nest_mod.unpad_flat(outs[key], plan.chunks, t)
            result[key] = flat.astype(env[key].dtype)
        elif dec.out_strategy == "partial":
            flat = nest_mod.unpad_flat(outs[key], plan.chunks, t)
            b = dec.write_map.b
            result[key] = jax.lax.dynamic_update_slice_in_dim(
                env[key], flat.astype(env[key].dtype), b, 0)
        elif dec.out_strategy == "scatter":
            summed, mask = outs[key]
            vmask = (mask > 0).reshape((-1,) + (1,) * (summed.ndim - 1))
            result[key] = jnp.where(vmask, summed.astype(env[key].dtype), env[key])
        elif dec.out_strategy == "put":
            result[key] = outs[key]
        elif dec.out_strategy == "reduce":
            rop = red_mod.get_reduction(dec.reduction_op)
            val = outs[key]
            if rop.collective == "gather":
                val = rop.local_fold(val, 0)
            if key in env:
                val = rop.pairwise(env[key], val)
            result[key] = val
    return result


# ---------------------------------------------------------------------------
# Rank-2 collective lowering (``collapse=2`` over a 2-D mesh)
# ---------------------------------------------------------------------------


def _axis_lane_vectors(ch, loop, j, c_dtype=jnp.int32):
    """One axis's lane vectors for global chunk ``j``: iteration numbers,
    validity mask and clamped loop indices (the per-axis analogue of
    ``_chunk_iteration_vectors``)."""
    ks = j * ch.chunk + jnp.arange(ch.chunk, dtype=c_dtype)
    valid = ks < loop.trip_count
    kc = jnp.minimum(ks, max(0, loop.trip_count - 1))
    ivec = loop.start + loop.step * kc
    return ks, valid, kc, ivec


def _make_env_sub2(plan, env_in, slab_stacks, q_pair, k0s):
    """Environment seen by the body inside one (chunk_i, chunk_j) pair."""
    qi, qj = q_pair
    env_sub: dict[str, Any] = {}
    for key in plan.context.env_keys:
        dec = plan.vars[key]
        info = plan.context.vars[key]
        if dec.in_strategy == "shard_halo":
            stacks = slab_stacks[key]
            if isinstance(qi, int):      # one-chunk fast path: static slice
                win = stacks[qi]
            else:
                win = jax.lax.dynamic_index_in_dim(stacks, qi, 0,
                                                   keepdims=False)
            offs = [k0s[0] + dec.halo_axes[0][0]]
            if dec.shard_ndim == 2:
                # stack dim for axis 1 is now position 1 (n_j)
                if isinstance(qj, int):
                    win = win[:, qj]
                else:
                    win = jax.lax.dynamic_index_in_dim(win, qj, 1,
                                                       keepdims=False)
                offs.append(k0s[1] + dec.halo_axes[1][0])
            env_sub[key] = ShiftedWindow(win, tuple(offs),
                                         info.shape, info.dtype)
        elif dec.in_strategy == "replicate":
            env_sub[key] = env_in[key]
        else:  # unused inside the body: placeholder, DCE'd by XLA
            env_sub[key] = jnp.zeros(info.shape, info.dtype)
    return env_sub


def _run_local_chunks2(plan, program, env_in, slab_stacks, device_indices,
                       unroll_chunks=False):
    """Scan this device's (chunk_i, chunk_j) pairs; returns
    ``(carry, ys)`` with ys values laid out ``(n_i, c_i, n_j, c_j, *rest)``."""
    ch_i, ch_j = plan.chunks_axes
    loop_i, loop_j = plan.nest.axes
    d_i, d_j = device_indices
    n_i, n_j = ch_i.local_chunks, ch_j.local_chunks
    tab_i, tab_j = _slot_table(ch_i), _slot_table(ch_j)

    carry0: dict[str, Any] = {}
    for key, dec in plan.vars.items():
        if dec.out_strategy == "reduce":
            rop = red_mod.get_reduction(dec.reduction_op)
            info = plan.context.vars[key]
            carry0[key] = red_mod.identity_like(
                rop, jnp.zeros(info.write.value_shape, info.write.value_dtype))

    def one_pair(carry, q):
        qi, qj = q // n_j, q % n_j
        ji = (tab_i[qi, d_i] if tab_i is not None
              else qi * ch_i.num_devices + d_i)
        jj = (tab_j[qj, d_j] if tab_j is not None
              else qj * ch_j.num_devices + d_j)
        _, valid_i, _, ivec = _axis_lane_vectors(ch_i, loop_i, ji)
        _, valid_j, _, jvec = _axis_lane_vectors(ch_j, loop_j, jj)
        env_sub = _make_env_sub2(plan, env_in, slab_stacks, (qi, qj),
                                 (ji * ch_i.chunk, jj * ch_j.chunk))
        updates = jax.vmap(
            lambda i: jax.vmap(lambda jv: program.body(i, jv, env_sub))(jvec)
        )(ivec)                                    # values (c_i, c_j, *rest)
        ys: dict[str, Any] = {}
        for key, dec in plan.vars.items():
            if dec.out_strategy in ("identity", "partial"):
                ys[key] = updates[key].value
            elif dec.out_strategy == "reduce":
                rop = red_mod.get_reduction(dec.reduction_op)
                upd = updates[key].value
                ident = red_mod.identity_like(rop, upd)
                vmask = (valid_i[:, None] & valid_j[None, :]).reshape(
                    (ch_i.chunk, ch_j.chunk) + (1,) * (upd.ndim - 2))
                part = rop.local_fold(jnp.where(vmask, upd, ident), (0, 1))
                carry[key] = rop.pairwise(carry[key], part)
        return carry, ys

    if n_i * n_j == 1:
        # Fast path: one (chunk_i, chunk_j) pair per device — no scan,
        # static window slicing (see _run_local_chunks).
        carry, ys = one_pair(dict(carry0), 0)
        ys = {k: v[None] for k, v in ys.items()}
    else:
        qs = jnp.arange(n_i * n_j, dtype=jnp.int32)
        unroll = n_i * n_j if unroll_chunks else 1
        carry, ys = jax.lax.scan(one_pair, carry0, qs, unroll=unroll)
    # (n_i*n_j, c_i, c_j, *rest) -> (n_i, c_i, n_j, c_j, *rest)
    ys = {k: jnp.moveaxis(v.reshape((n_i, n_j) + v.shape[1:]), 1, 2)
          for k, v in ys.items()}
    return carry, ys


def _execute_collective2(dp: DistributedProgram, env: dict) -> dict:
    _maybe_fault("collective2")
    plan, program, mesh = dp.plan, dp.program, dp.mesh
    ax_i, ax_j = plan.axes_names
    ch_i, ch_j = plan.chunks_axes
    trips = plan.nest.trip_counts

    repl_keys = [k for k in plan.context.env_keys
                 if plan.vars[k].in_strategy == "replicate"]
    env_repl = {k: env[k] for k in repl_keys}
    env_slab = {}
    slab_specs = {}
    for k in plan.sharded_in_keys:
        dec = plan.vars[k]
        if dec.shard_ndim == 2:
            env_slab[k] = nest_mod.halo_slabs2(
                env[k], (ch_i, ch_j), dec.halo_axes)
            slab_specs[k] = P(None, ax_i, None, None, ax_j, None)
        else:
            env_slab[k] = nest_mod.halo_slabs(env[k], ch_i, dec.halo_axes[0])
            slab_specs[k] = P(None, ax_i, None)

    aggregate = dp.comm_schedule == "aggregate"
    if dp.use_pallas:
        from repro.core import pallas_lower as plx

        pallas_interp = plx.resolve_interpret(dp.pallas_interpret, mesh)

    def device_fn(env_repl, env_slab):
        from repro.core import comm_schedule as cs_mod

        d_i = jax.lax.axis_index(ax_i)
        d_j = jax.lax.axis_index(ax_j)
        slab_stacks = {}
        for k, v in env_slab.items():
            if plan.vars[k].shard_ndim == 2:
                slab_stacks[k] = v[:, 0][:, :, :, 0]   # (n_i, w_i, n_j, w_j, *)
            else:
                slab_stacks[k] = v[:, 0]               # (n_i, w_i, *rest)
        if dp.use_pallas:
            carry, ys = plx.run_local_chunks_pallas2(
                plan, program, env_repl, slab_stacks, (d_i, d_j),
                interpret=pallas_interp)
        else:
            carry, ys = _run_local_chunks2(plan, program, env_repl,
                                           slab_stacks, (d_i, d_j),
                                           dp.unroll_chunks)
        outs: dict[str, Any] = {}
        reduce_items: dict[str, tuple] = {}
        for key, dec in plan.vars.items():
            if dec.out_strategy in ("identity", "partial"):
                # (n_i, c_i, n_j, c_j, *) -> (n_i, 1, c_i, n_j, 1, c_j, *)
                outs[key] = ys[key][:, None, :, :, None]
            elif dec.out_strategy == "reduce":
                rop = red_mod.get_reduction(dec.reduction_op)
                if aggregate:
                    reduce_items[key] = (rop, carry[key])
                else:
                    outs[key] = red_mod.cross_device_combine(
                        rop, carry[key], (ax_i, ax_j))
        if reduce_items:
            outs.update(cs_mod.fused_cross_device_combine(
                reduce_items, (ax_i, ax_j)))
        return outs

    in_specs = ({k: P() for k in env_repl}, slab_specs)
    out_specs: dict[str, Any] = {}
    for key, dec in plan.vars.items():
        if dec.out_strategy in ("identity", "partial"):
            out_specs[key] = P(None, ax_i, None, None, ax_j, None)
        elif dec.out_strategy == "reduce":
            out_specs[key] = P()
    if not out_specs:
        return dict(env)

    outs = shard_map(
        device_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
    )(env_repl, env_slab)

    # --- reassembly at the jit level (layout, not messages) ---------------
    result = dict(env)
    for key, dec in plan.vars.items():
        if dec.out_strategy == "identity":
            flat = nest_mod.unpad_flat2(outs[key], (ch_i, ch_j), trips)
            result[key] = flat.astype(env[key].dtype)
        elif dec.out_strategy == "partial":
            flat = nest_mod.unpad_flat2(outs[key], (ch_i, ch_j), trips)
            starts = (dec.write_maps[0].b, dec.write_maps[1].b) \
                + (0,) * (flat.ndim - 2)
            result[key] = jax.lax.dynamic_update_slice(
                env[key], flat.astype(env[key].dtype), starts)
        elif dec.out_strategy == "reduce":
            rop = red_mod.get_reduction(dec.reduction_op)
            val = outs[key]
            if key in env:
                val = rop.pairwise(env[key], val)
            result[key] = val
    return result


# ---------------------------------------------------------------------------
# Master/worker lowering (paper-faithful baseline)
# ---------------------------------------------------------------------------


def _mw_send(x, src, dst, d, current, axis):
    """Point-to-point send emulation: ``dst`` receives ``x`` from ``src``."""
    msg = jax.lax.ppermute(x, axis, perm=[(src, dst)])
    return jnp.where(d == dst, msg, current)


def _execute_master_worker(dp: DistributedProgram, env: dict) -> dict:
    plan, program, mesh = dp.plan, dp.program, dp.mesh
    axis = plan.axis
    p_total = mesh.shape[axis]
    ch = plan.chunks
    w = ch.num_devices            # compute ranks (P-1 when master excluded)
    t = plan.loop.trip_count
    first_worker = p_total - w    # 1 when master excluded, else 0

    def device_fn(env_all):
        d = jax.lax.axis_index(axis)
        wd = jnp.clip(d - first_worker, 0, w - 1)

        # --- master -> worker sends of every IN buffer --------------------
        env_in: dict[str, Any] = {}
        slab_stacks: dict[str, Any] = {}
        for key in plan.context.env_keys:
            dec = plan.vars[key]
            info = plan.context.vars[key]
            if dec.in_strategy == "replicate":
                x = env_all[key]
                recv = x
                for dst in range(first_worker, p_total):
                    if dst == 0:
                        continue
                    recv = _mw_send(x, 0, dst, d, recv, axis)
                env_in[key] = recv
            elif dec.in_strategy == "shard":
                x_pad = env_all[key]  # already (n_loc, W, c, *rest)
                my = jnp.take(x_pad, wd, axis=1)
                for dst_w in range(w):
                    dst = dst_w + first_worker
                    if dst == 0:
                        continue
                    slab = x_pad[:, dst_w]
                    my = _mw_send(slab, 0, dst, d, my, axis)
                slab_stacks[key] = my
            else:
                env_in[key] = jnp.zeros(info.shape, info.dtype)

        carry, ys = _run_local_chunks(plan, program, env_in, slab_stacks, wd,
                                      dp.unroll_chunks)

        outs: dict[str, Any] = {}
        for key, dec in plan.vars.items():
            info = plan.context.vars[key]
            if dec.out_strategy in ("identity", "partial"):
                # workers -> master sends of each slab stack, master
                # assembles the padded buffer, then re-broadcasts it.
                full = jnp.zeros((ch.padded_trip,) + info.shape[1:], info.dtype)
                for src_w in range(w):
                    src = src_w + first_worker
                    stack = ys[key]  # (n_loc, c, *rest)
                    if src != 0:
                        got = jax.lax.ppermute(stack, axis, perm=[(src, 0)])
                    else:
                        got = stack
                    rows = np.concatenate([
                        np.arange(ch.chunk) + (q * w + src_w) * ch.chunk
                        for q in range(ch.local_chunks)
                    ])
                    flat = got.reshape((-1,) + info.shape[1:])
                    placed = full.at[rows].set(flat)
                    full = jnp.where(d == 0, placed, full)
                for dst in range(first_worker, p_total):
                    if dst == 0:
                        continue
                    full = _mw_send(full, 0, dst, d, full, axis)
                outs[key] = full[None]
            elif dec.out_strategy == "scatter":
                buf, mask = carry[key]
                if first_worker == 1:
                    # The excluded master duplicated worker 0's chunks
                    # (clamped wd); drop its contribution before combining.
                    is_worker = (d >= 1).astype(buf.dtype)
                    buf = buf * is_worker.reshape((1,) * buf.ndim)
                    mask = jnp.logical_and(mask, d >= 1)
                outs[key] = (
                    jax.lax.psum(buf, axis),
                    jax.lax.psum(mask.astype(jnp.int32), axis),
                )
            elif dec.out_strategy == "put":
                j_star = (t - 1) // ch.chunk
                owner = j_star % w + first_worker
                val = carry[key]
                if owner != 0:
                    val = _mw_send(val, owner, 0, d, val, axis)
                for dst in range(first_worker, p_total):
                    if dst == 0:
                        continue
                    val = _mw_send(val, 0, dst, d, val, axis)
                outs[key] = val[None]
            elif dec.out_strategy == "reduce":
                # Table 3: workers send partials; the master folds them in
                # rank order into the identity-initialised accumulator.
                rop = red_mod.get_reduction(dec.reduction_op)
                acc = red_mod.identity_like(rop, carry[key])
                for src_w in range(w):
                    src = src_w + first_worker
                    if src == 0:  # master computed its own chunks
                        acc = jnp.where(d == 0, rop.pairwise(acc, carry[key]), acc)
                        continue
                    got = jax.lax.ppermute(carry[key], axis, perm=[(src, 0)])
                    acc = jnp.where(d == 0, rop.pairwise(acc, got), acc)
                for dst in range(first_worker, p_total):
                    if dst == 0:
                        continue
                    acc = _mw_send(acc, 0, dst, d, acc, axis)
                outs[key] = acc[None]
        return outs

    env_all = {}
    for key in plan.context.env_keys:
        dec = plan.vars[key]
        if dec.in_strategy == "shard":
            env_all[key] = nest_mod.pad_reshape(env[key], plan.chunks)
        else:
            env_all[key] = env[key]
    in_specs = {k: P() for k in env_all}
    out_specs: dict[str, Any] = {}
    for key, dec in plan.vars.items():
        if dec.out_strategy in ("identity", "partial", "put", "reduce"):
            out_specs[key] = P(axis)
        elif dec.out_strategy == "scatter":
            out_specs[key] = (P(), P())
    if not out_specs:
        return dict(env)

    outs = shard_map(
        device_fn, mesh=mesh, in_specs=(in_specs,), out_specs=out_specs,
    )(env_all)

    result = dict(env)
    for key, dec in plan.vars.items():
        if dec.out_strategy == "identity":
            result[key] = outs[key][0][:t]
        elif dec.out_strategy == "partial":
            flat = outs[key][0][:t]
            result[key] = jax.lax.dynamic_update_slice_in_dim(
                env[key], flat.astype(env[key].dtype), dec.write_map.b, 0)
        elif dec.out_strategy == "scatter":
            summed, mask = outs[key]
            vmask = (mask > 0).reshape((-1,) + (1,) * (summed.ndim - 1))
            result[key] = jnp.where(vmask, summed.astype(env[key].dtype), env[key])
        elif dec.out_strategy == "put":
            result[key] = outs[key][0]
        elif dec.out_strategy == "reduce":
            rop = red_mod.get_reduction(dec.reduction_op)
            val = outs[key][0]
            if key in env:
                val = rop.pairwise(env[key], val)
            result[key] = val
    return result
