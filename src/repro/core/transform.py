"""OMP→"MPI" code generation (paper §3.1.3–3.1.4).

Two executors for a :class:`~repro.core.pragma.ParallelFor` program:

* :func:`run_reference` — the *shared-memory* ("OpenMP") semantics on the
  local device.  This is the oracle: the paper's "correct by construction"
  claim is validated as ``to_mpi(pf)(env) == pf(env)``.

* :func:`to_mpi` — the transformation.  Produces a
  :class:`DistributedProgram` that executes the block over a mesh axis
  under ``jax.shard_map`` using the :class:`~repro.core.plan.DistPlan`
  strategies.  Two lowerings:

  - ``"collective"`` — TPU-native: chunk-cyclic layout + balanced
    collectives (psum / sharded slabs).  This is the production path.
  - ``"master_worker"`` — paper-faithful: rank 0 owns the shared memory;
    every IN buffer is *sent* from rank 0 to each worker and every OUT
    slab is sent back and re-broadcast, as explicit
    ``collective-permute`` pairs.  It reproduces the communication shape
    of the paper's Fig. 1b (all traffic through the master's links) and
    exists as the measurable baseline for EXPERIMENTS.md §Perf-A.

Both executors transform ONE block.  Whole programs (chains of blocks
with inter-loop residency planning) go through
:func:`repro.core.region.region_to_mpi`, which reuses this module's
chunk-execution machinery (`_run_local_chunks`) inside a single fused
shard_map; per-loop staging via this module is its measurable baseline
(EXPERIMENTS.md §Perf-C).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core import comm as comm_mod
from repro.core import pragma, reduction as red_mod
from repro.core.context import ReadKind, VarClass, WriteKind
from repro.core.loop import LoopNotCanonical, analyze_loop
from repro.core.plan import DistPlan, make_plan


# ---------------------------------------------------------------------------
# Shared-memory reference executor ("the OpenMP block")
# ---------------------------------------------------------------------------


def run_reference(program: pragma.ParallelFor, env: Mapping[str, Any]) -> dict:
    """Execute with OpenMP shared-memory semantics on the local device.

    Reads observe the pre-loop environment (iterations are concurrent in
    OpenMP; racy read-after-write across iterations is UB there and
    unsupported here — see DESIGN.md).
    """
    loop = analyze_loop(program.start, program.stop, program.step)
    env = {k: jnp.asarray(v) for k, v in env.items()}
    out = dict(env)
    t = loop.trip_count
    if t == 0:
        # A zero-trip loop writes nothing — except that a reduction
        # clause *defines* its variable as the op identity even over an
        # empty iteration space (OpenMP initialises the private copy
        # before any iteration runs).  Buffers already in env keep their
        # value (identity folds are no-ops); fresh reduction outputs
        # must still exist, matching the distributed executors.
        fresh = [k for k in program.reduction if k not in out]
        if fresh:
            upds = jax.eval_shape(
                program.body, jax.ShapeDtypeStruct((), jnp.int32), env)
            for key in fresh:
                rop = red_mod.get_reduction(program.reduction[key])
                out[key] = red_mod.identity_like(
                    rop, jnp.zeros(upds[key].value.shape,
                                   upds[key].value.dtype))
        return out

    ivec = program.start + program.step * jnp.arange(t, dtype=jnp.int32)
    updates = jax.vmap(lambda i: program.body(i, env))(ivec)
    for key, upd in updates.items():
        if isinstance(upd, pragma.At):
            out[key] = out[key].at[upd.idx].set(upd.value)
        elif isinstance(upd, pragma.Put):
            out[key] = upd.value[t - 1]
        elif isinstance(upd, pragma.Red):
            rop = red_mod.get_reduction(program.reduction[key])
            folded = rop.local_fold(upd.value, 0)
            if key in env:
                folded = rop.pairwise(env[key], folded)
            out[key] = folded
        else:
            raise LoopNotCanonical(
                f"update for {key!r} must be omp.at/omp.put/omp.red"
            )
    return out


# ---------------------------------------------------------------------------
# Sliced-read substitution (paper: send only the needed slice)
# ---------------------------------------------------------------------------


class SubstitutionFailed(Exception):
    pass


class _ShiftedArray:
    """Stands in for a shared buffer whose only accesses are ``x[i]``-style
    identity reads; serves them from the local chunk slab instead."""

    def __init__(self, slab, k_offset, virtual_shape, dtype):
        self._slab = slab
        self._k0 = k_offset
        self.shape = virtual_shape
        self.dtype = dtype
        self.ndim = len(virtual_shape)

    def __getitem__(self, idx):
        if isinstance(idx, tuple):
            first, rest = idx[0], tuple(idx[1:])
        else:
            first, rest = idx, ()
        row = jax.lax.dynamic_index_in_dim(
            self._slab, jnp.asarray(first - self._k0, jnp.int32), 0,
            keepdims=False,
        )
        return row[rest] if rest else row

    def __len__(self):
        return self.shape[0]

    def _no(self, *a, **k):  # pragma: no cover - guard path
        raise SubstitutionFailed(
            "sliced-read substitution saw a non-getitem use; this buffer "
            "should have been classified as a whole-array read"
        )

    __add__ = __radd__ = __mul__ = __rmul__ = __sub__ = __rsub__ = _no
    __truediv__ = __rtruediv__ = __matmul__ = __rmatmul__ = _no
    __neg__ = __pow__ = __array__ = _no


# ---------------------------------------------------------------------------
# Distributed program
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DistributedProgram:
    """The generated "MPI" program for one parallel block."""

    program: pragma.ParallelFor
    mesh: Mesh
    plan: DistPlan | None
    axis: str = "data"
    lowering: str = "collective"
    shard_inputs: bool = False
    keep_sharded: bool = False
    unroll_chunks: bool = False
    paper_master_excluded: bool | None = None

    def __call__(self, env: Mapping[str, Any]) -> dict:
        return _execute(self, {k: jnp.asarray(v) for k, v in env.items()})

    def report(self) -> str:
        from repro.core import report as report_mod

        if self.plan is None:
            raise ValueError("call the program (or pass env_like) to build "
                             "the plan before asking for a report")
        return report_mod.render_plan(self.plan)


def to_mpi(
    program: pragma.ParallelFor,
    mesh: Mesh,
    *,
    axis: str = "data",
    lowering: str = "collective",
    shard_inputs: bool = False,
    keep_sharded: bool = False,
    unroll_chunks: bool = False,
    env_like: Mapping[str, Any] | None = None,
    paper_master_excluded: bool | None = None,
) -> DistributedProgram:
    """Transform an OpenMP-annotated block into a distributed program.

    ``env_like`` (shapes only) lets the plan be built eagerly; otherwise it
    is built on first call.
    """
    if axis not in mesh.axis_names:
        raise ValueError(f"axis {axis!r} not in mesh axes {mesh.axis_names}")
    num = mesh.shape[axis]
    plan = None
    if env_like is not None:
        plan = make_plan(
            program, env_like, num, axis=axis, lowering=lowering,
            shard_inputs=shard_inputs,
            paper_master_excluded=paper_master_excluded,
        )
    return DistributedProgram(
        program=program, mesh=mesh, plan=plan, axis=axis, lowering=lowering,
        shard_inputs=shard_inputs, keep_sharded=keep_sharded,
        unroll_chunks=unroll_chunks,
        paper_master_excluded=paper_master_excluded,
    )


# ---------------------------------------------------------------------------
# Execution
# ---------------------------------------------------------------------------


def _pad_reshape(x, plan):
    """(T, *rest) -> (n_loc, P_compute, c, *rest) chunk-cyclic layout."""
    ch = plan.chunks
    pad = ch.padded_trip - x.shape[0]
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
    return x.reshape((ch.local_chunks, ch.num_devices, ch.chunk) + x.shape[1:])


def _halo_slabs(x, plan, halo):
    """(N, *rest) -> (n_loc, P, c + halo_width, *rest): each chunk's slab
    carries its read window [k*c + b_min, (k+1)*c - 1 + b_max] — the
    stencil halo exchange (rows duplicated at chunk edges).  The window
    geometry is shared with the fused region path
    (:func:`repro.core.comm.window_rows` /
    :func:`repro.core.comm.halo_exchange`) so both build byte-identical
    read windows."""
    ch = plan.chunks
    width = comm_mod.window_extent(ch.chunk, halo)
    rows = comm_mod.window_rows(ch, halo, x.shape[0])
    slab = x[rows]                                   # (K', width, *rest)
    return slab.reshape((ch.local_chunks, ch.num_devices, width)
                        + x.shape[1:])


def _unpad_flat(slabs, plan, t):
    """(n_loc, P_compute, c, *rest) -> (T, *rest)."""
    ch = plan.chunks
    flat = slabs.reshape((ch.padded_trip,) + slabs.shape[3:])
    return flat[:t]


def _execute(dp: DistributedProgram, env: dict) -> dict:
    program = dp.program
    if dp.plan is None:
        dp.plan = make_plan(
            program, env, dp.mesh.shape[dp.axis], axis=dp.axis,
            lowering=dp.lowering, shard_inputs=dp.shard_inputs,
            paper_master_excluded=dp.paper_master_excluded,
        )
    plan = dp.plan
    t = plan.loop.trip_count
    out = dict(env)
    if t == 0:
        for key, dec in plan.vars.items():
            if dec.out_strategy == "reduce":
                rop = red_mod.get_reduction(dec.reduction_op)
                info = plan.context.vars[key]
                zero = red_mod.identity_like(
                    rop, jnp.zeros(info.write.value_shape, info.write.value_dtype))
                out[key] = rop.pairwise(env[key], zero) if key in env else zero
        return out

    if plan.lowering == "collective":
        return _execute_collective(dp, env)
    return _execute_master_worker(dp, env)


def _chunk_iteration_vectors(plan, j, dtype=jnp.int32):
    """Iteration numbers, validity mask and clamped loop indices of chunk j."""
    c = plan.chunks.chunk
    t = plan.loop.trip_count
    ks = j * c + jnp.arange(c, dtype=dtype)
    valid = ks < t
    kc = jnp.minimum(ks, t - 1)
    ivec = plan.loop.start + plan.loop.step * kc
    return ks, valid, kc, ivec


def _make_env_sub(plan, env_in, slabs_q, k0):
    """Environment seen by the body inside one chunk."""
    env_sub: dict[str, Any] = {}
    for key in plan.context.env_keys:
        dec = plan.vars[key]
        info = plan.context.vars[key]
        if dec.in_strategy == "shard":
            env_sub[key] = _ShiftedArray(
                slabs_q[key], k0, info.shape, info.dtype)
        elif dec.in_strategy == "shard_halo":
            # slab row t holds position k0 + b_min + t
            env_sub[key] = _ShiftedArray(
                slabs_q[key], k0 + dec.halo[0], info.shape, info.dtype)
        elif dec.in_strategy == "replicate":
            env_sub[key] = env_in[key]
        else:  # unused inside the body: placeholder, DCE'd by XLA
            env_sub[key] = jnp.zeros(info.shape, info.dtype)
    return env_sub


def _apply_chunk_updates(plan, updates, carry, ys, j, valid, shapes):
    """Fold one chunk's updates into the scan carry / per-chunk outputs."""
    t = plan.loop.trip_count
    for key, dec in plan.vars.items():
        if dec.out_strategy == "none":
            continue
        upd = updates[key]
        if dec.out_strategy in ("identity", "partial"):
            ys[key] = upd.value
        elif dec.out_strategy == "scatter":
            shape0 = shapes[key][0]
            # positions from true iteration numbers of this chunk
            ks = j * plan.chunks.chunk + jnp.arange(plan.chunks.chunk)
            pos = dec.write_map.a * ks + dec.write_map.b
            pos = jnp.where(valid, pos, shape0)  # OOB -> dropped
            buf, mask = carry[key]
            buf = buf.at[pos].set(upd.value, mode="drop")
            mask = mask.at[pos].set(True, mode="drop")
            carry[key] = (buf, mask)
        elif dec.out_strategy == "put":
            j_star = (t - 1) // plan.chunks.chunk
            lane = (t - 1) - j_star * plan.chunks.chunk
            row = jax.lax.dynamic_index_in_dim(upd.value, lane, 0, keepdims=False)
            carry[key] = jnp.where(j == j_star, row, carry[key])
        elif dec.out_strategy == "reduce":
            rop = red_mod.get_reduction(dec.reduction_op)
            ident = red_mod.identity_like(rop, upd.value)
            vmask = valid.reshape((-1,) + (1,) * (upd.value.ndim - 1))
            contrib = jnp.where(vmask, upd.value, ident)
            part = rop.local_fold(contrib, 0)
            carry[key] = rop.pairwise(carry[key], part)
    return carry, ys


def _init_carry(plan):
    carry: dict[str, Any] = {}
    for key, dec in plan.vars.items():
        info = plan.context.vars[key]
        if dec.out_strategy == "scatter":
            carry[key] = (
                jnp.zeros(info.shape, info.dtype),
                jnp.zeros((info.shape[0],), jnp.bool_),
            )
        elif dec.out_strategy == "put":
            carry[key] = jnp.zeros(info.shape, info.dtype)
        elif dec.out_strategy == "reduce":
            rop = red_mod.get_reduction(dec.reduction_op)
            carry[key] = red_mod.identity_like(
                rop, jnp.zeros(info.write.value_shape, info.write.value_dtype))
    return carry


def _run_local_chunks(plan, program, env_in, slab_stacks, worker_index,
                      unroll_chunks=False):
    """Scan this device's chunks; returns (carry, ys_stacked)."""
    ch = plan.chunks
    shapes = {k: plan.context.vars[k].shape for k in plan.vars}
    carry0 = _init_carry(plan)

    def one_chunk(carry, q):
        j = q * ch.num_devices + worker_index
        k0 = j * ch.chunk
        ks, valid, kc, ivec = _chunk_iteration_vectors(plan, j)
        slabs_q = {k: jax.lax.dynamic_index_in_dim(v, q, 0, keepdims=False)
                   for k, v in slab_stacks.items()}
        env_sub = _make_env_sub(plan, env_in, slabs_q, k0)
        updates = jax.vmap(lambda i: program.body(i, env_sub))(ivec)
        ys: dict[str, Any] = {}
        carry, ys = _apply_chunk_updates(plan, updates, carry, ys, j, valid, shapes)
        return carry, ys

    if ch.local_chunks == 1:
        carry, ys = one_chunk(carry0, jnp.int32(0))
        ys = {k: v[None] for k, v in ys.items()}
        return carry, ys
    qs = jnp.arange(ch.local_chunks, dtype=jnp.int32)
    unroll = ch.local_chunks if unroll_chunks else 1
    return jax.lax.scan(one_chunk, carry0, qs, unroll=unroll)


def _execute_collective(dp: DistributedProgram, env: dict) -> dict:
    plan, program, mesh = dp.plan, dp.program, dp.mesh
    axis = plan.axis
    t = plan.loop.trip_count

    repl_keys = [k for k in plan.context.env_keys
                 if plan.vars[k].in_strategy == "replicate"]
    env_repl = {k: env[k] for k in repl_keys}
    env_slab = {}
    for k in plan.sharded_in_keys:
        dec = plan.vars[k]
        if dec.in_strategy == "shard_halo":
            env_slab[k] = _halo_slabs(env[k], plan, dec.halo)
        else:
            env_slab[k] = _pad_reshape(env[k], plan)

    def device_fn(env_repl, env_slab):
        d = jax.lax.axis_index(axis)
        slab_stacks = {k: v[:, 0] for k, v in env_slab.items()}
        carry, ys = _run_local_chunks(plan, program, env_repl, slab_stacks, d,
                                      dp.unroll_chunks)

        outs: dict[str, Any] = {}
        for key, dec in plan.vars.items():
            if dec.out_strategy in ("identity", "partial"):
                outs[key] = ys[key][:, None]  # (n_loc, 1, c, *rest)
            elif dec.out_strategy == "scatter":
                buf, mask = carry[key]
                outs[key] = (
                    jax.lax.psum(buf, axis),
                    jax.lax.psum(mask.astype(jnp.int32), axis),
                )
            elif dec.out_strategy == "put":
                j_star = (t - 1) // plan.chunks.chunk
                owner = j_star % plan.chunks.num_devices
                val = jnp.where(d == owner, carry[key],
                                jnp.zeros_like(carry[key]))
                outs[key] = jax.lax.psum(val, axis)
            elif dec.out_strategy == "reduce":
                rop = red_mod.get_reduction(dec.reduction_op)
                if rop.collective == "gather":
                    outs[key] = carry[key][None]
                else:
                    outs[key] = red_mod.cross_device_combine(rop, carry[key], axis)
        return outs

    in_specs = (
        {k: P() for k in env_repl},
        {k: P(None, axis) for k in env_slab},
    )
    out_specs: dict[str, Any] = {}
    for key, dec in plan.vars.items():
        if dec.out_strategy in ("identity", "partial"):
            out_specs[key] = P(None, axis)
        elif dec.out_strategy == "scatter":
            out_specs[key] = (P(), P())
        elif dec.out_strategy == "put":
            out_specs[key] = P()
        elif dec.out_strategy == "reduce":
            rop = red_mod.get_reduction(dec.reduction_op)
            out_specs[key] = P(axis) if rop.collective == "gather" else P()
    if not out_specs:
        return dict(env)

    outs = shard_map(
        device_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
    )(env_repl, env_slab)

    # --- reassembly at the jit level (layout, not messages) ---------------
    result = dict(env)
    for key, dec in plan.vars.items():
        if dec.out_strategy == "identity":
            flat = _unpad_flat(outs[key], plan, t)
            result[key] = flat.astype(env[key].dtype)
        elif dec.out_strategy == "partial":
            flat = _unpad_flat(outs[key], plan, t)
            b = dec.write_map.b
            result[key] = jax.lax.dynamic_update_slice_in_dim(
                env[key], flat.astype(env[key].dtype), b, 0)
        elif dec.out_strategy == "scatter":
            summed, mask = outs[key]
            vmask = (mask > 0).reshape((-1,) + (1,) * (summed.ndim - 1))
            result[key] = jnp.where(vmask, summed.astype(env[key].dtype), env[key])
        elif dec.out_strategy == "put":
            result[key] = outs[key]
        elif dec.out_strategy == "reduce":
            rop = red_mod.get_reduction(dec.reduction_op)
            val = outs[key]
            if rop.collective == "gather":
                val = rop.local_fold(val, 0)
            if key in env:
                val = rop.pairwise(env[key], val)
            result[key] = val
    return result


# ---------------------------------------------------------------------------
# Master/worker lowering (paper-faithful baseline)
# ---------------------------------------------------------------------------


def _mw_send(x, src, dst, d, current, axis):
    """Point-to-point send emulation: ``dst`` receives ``x`` from ``src``."""
    msg = jax.lax.ppermute(x, axis, perm=[(src, dst)])
    return jnp.where(d == dst, msg, current)


def _execute_master_worker(dp: DistributedProgram, env: dict) -> dict:
    plan, program, mesh = dp.plan, dp.program, dp.mesh
    axis = plan.axis
    p_total = mesh.shape[axis]
    ch = plan.chunks
    w = ch.num_devices            # compute ranks (P-1 when master excluded)
    t = plan.loop.trip_count
    first_worker = p_total - w    # 1 when master excluded, else 0

    def device_fn(env_all):
        d = jax.lax.axis_index(axis)
        wd = jnp.clip(d - first_worker, 0, w - 1)

        # --- master -> worker sends of every IN buffer --------------------
        env_in: dict[str, Any] = {}
        slab_stacks: dict[str, Any] = {}
        for key in plan.context.env_keys:
            dec = plan.vars[key]
            info = plan.context.vars[key]
            if dec.in_strategy == "replicate":
                x = env_all[key]
                recv = x
                for dst in range(first_worker, p_total):
                    if dst == 0:
                        continue
                    recv = _mw_send(x, 0, dst, d, recv, axis)
                env_in[key] = recv
            elif dec.in_strategy == "shard":
                x_pad = env_all[key]  # already (n_loc, W, c, *rest)
                my = jnp.take(x_pad, wd, axis=1)
                for dst_w in range(w):
                    dst = dst_w + first_worker
                    if dst == 0:
                        continue
                    slab = x_pad[:, dst_w]
                    my = _mw_send(slab, 0, dst, d, my, axis)
                slab_stacks[key] = my
            else:
                env_in[key] = jnp.zeros(info.shape, info.dtype)

        carry, ys = _run_local_chunks(plan, program, env_in, slab_stacks, wd,
                                      dp.unroll_chunks)

        outs: dict[str, Any] = {}
        for key, dec in plan.vars.items():
            info = plan.context.vars[key]
            if dec.out_strategy in ("identity", "partial"):
                # workers -> master sends of each slab stack, master
                # assembles the padded buffer, then re-broadcasts it.
                full = jnp.zeros((ch.padded_trip,) + info.shape[1:], info.dtype)
                for src_w in range(w):
                    src = src_w + first_worker
                    stack = ys[key]  # (n_loc, c, *rest)
                    if src != 0:
                        got = jax.lax.ppermute(stack, axis, perm=[(src, 0)])
                    else:
                        got = stack
                    rows = np.concatenate([
                        np.arange(ch.chunk) + (q * w + src_w) * ch.chunk
                        for q in range(ch.local_chunks)
                    ])
                    flat = got.reshape((-1,) + info.shape[1:])
                    placed = full.at[rows].set(flat)
                    full = jnp.where(d == 0, placed, full)
                for dst in range(first_worker, p_total):
                    if dst == 0:
                        continue
                    full = _mw_send(full, 0, dst, d, full, axis)
                outs[key] = full[None]
            elif dec.out_strategy == "scatter":
                buf, mask = carry[key]
                if first_worker == 1:
                    # The excluded master duplicated worker 0's chunks
                    # (clamped wd); drop its contribution before combining.
                    is_worker = (d >= 1).astype(buf.dtype)
                    buf = buf * is_worker.reshape((1,) * buf.ndim)
                    mask = jnp.logical_and(mask, d >= 1)
                outs[key] = (
                    jax.lax.psum(buf, axis),
                    jax.lax.psum(mask.astype(jnp.int32), axis),
                )
            elif dec.out_strategy == "put":
                j_star = (t - 1) // ch.chunk
                owner = j_star % w + first_worker
                val = carry[key]
                if owner != 0:
                    val = _mw_send(val, owner, 0, d, val, axis)
                for dst in range(first_worker, p_total):
                    if dst == 0:
                        continue
                    val = _mw_send(val, 0, dst, d, val, axis)
                outs[key] = val[None]
            elif dec.out_strategy == "reduce":
                # Table 3: workers send partials; the master folds them in
                # rank order into the identity-initialised accumulator.
                rop = red_mod.get_reduction(dec.reduction_op)
                acc = red_mod.identity_like(rop, carry[key])
                for src_w in range(w):
                    src = src_w + first_worker
                    if src == 0:  # master computed its own chunks
                        acc = jnp.where(d == 0, rop.pairwise(acc, carry[key]), acc)
                        continue
                    got = jax.lax.ppermute(carry[key], axis, perm=[(src, 0)])
                    acc = jnp.where(d == 0, rop.pairwise(acc, got), acc)
                for dst in range(first_worker, p_total):
                    if dst == 0:
                        continue
                    acc = _mw_send(acc, 0, dst, d, acc, axis)
                outs[key] = acc[None]
        return outs

    env_all = {}
    for key in plan.context.env_keys:
        dec = plan.vars[key]
        if dec.in_strategy == "shard":
            env_all[key] = _pad_reshape(env[key], plan)
        else:
            env_all[key] = env[key]
    in_specs = {k: P() for k in env_all}
    out_specs: dict[str, Any] = {}
    for key, dec in plan.vars.items():
        if dec.out_strategy in ("identity", "partial", "put", "reduce"):
            out_specs[key] = P(axis)
        elif dec.out_strategy == "scatter":
            out_specs[key] = (P(), P())
    if not out_specs:
        return dict(env)

    outs = shard_map(
        device_fn, mesh=mesh, in_specs=(in_specs,), out_specs=out_specs,
    )(env_all)

    result = dict(env)
    for key, dec in plan.vars.items():
        if dec.out_strategy == "identity":
            result[key] = outs[key][0][:t]
        elif dec.out_strategy == "partial":
            flat = outs[key][0][:t]
            result[key] = jax.lax.dynamic_update_slice_in_dim(
                env[key], flat.astype(env[key].dtype), dec.write_map.b, 0)
        elif dec.out_strategy == "scatter":
            summed, mask = outs[key]
            vmask = (mask > 0).reshape((-1,) + (1,) * (summed.ndim - 1))
            result[key] = jnp.where(vmask, summed.astype(env[key].dtype), env[key])
        elif dec.out_strategy == "put":
            result[key] = outs[key][0]
        elif dec.out_strategy == "reduce":
            rop = red_mod.get_reduction(dec.reduction_op)
            val = outs[key][0]
            if key in env:
                val = rop.pairwise(env[key], val)
            result[key] = val
    return result
