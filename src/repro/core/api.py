"""``omp.compile`` — the one staged compiler entry point.

The paper frames OMP2MPI as a *compiler*: detect the annotated parallel
blocks, analyze them, plan the communication, emit the MPI program.
This module is that compiler's driver.  One call

    compiled = omp.compile(program, mesh, omp.Options(...))

accepts **either** a :class:`~repro.core.pragma.ParallelFor` **or** a
:class:`~repro.core.pragma.ParallelRegion` (rank-1 or rank-2) and runs
the explicit pass pipeline

    analyze  →  schedule  →  plan  →  plan_comm  →  schedule_comm  →  lower

recording each stage's input/output artifact on ``compiled.passes`` so
the intermediate representations are first-class (the lesson of the
staged follow-up systems — OMP2HMPP's instrumented variants, MPIrigen's
pipeline IRs) instead of reachable only by poking private helpers.

* **analyze**   — loop-nest canonicalisation + context analysis
  (:func:`repro.core.plan.analyze_program`),
* **schedule**  — chunking math, per axis
  (:func:`repro.core.plan.plan_schedule`),
* **plan**      — per-variable transfer strategies
  (:func:`repro.core.plan.decide_strategies`; for fused regions the
  inter-loop residency planner :func:`repro.core.region.plan_region`),
* **plan_comm** — cost-modeled boundary lowering
  (:class:`~repro.core.comm.BoundaryComm` per slab boundary),
* **schedule_comm** — region-wide communication scheduling
  (:class:`~repro.core.comm_schedule.CommSchedule`: aggregated
  ``ppermute`` payloads, fused reductions, prefetched exchanges),
* **lower**     — the executable artifact (the "generated MPI code"):
  a :class:`~repro.core.transform.DistributedProgram` or
  :class:`~repro.core.region.DistributedRegion` wrapped in
  :class:`Compiled`.

All knobs live on the frozen :class:`Options` dataclass — typed enums
instead of the historical string/bool kwargs soup — validated at
construction with actionable errors (:class:`CompileError`).  The
legacy entry points ``omp.to_mpi`` / ``omp.region_to_mpi`` survive as
thin shims that translate their kwargs to :class:`Options` and emit a
``DeprecationWarning``.

Compilation is cached: a structural key (program signature, mesh
shape/axes, Options, env shapes) lets repeated compiles — benchmark
sweeps, the differential harness — skip re-planning entirely.  The
cache is thread-safe for the concurrent compile service
(:mod:`repro.serving.compile_service`): warm hits stay lock-free, the
miss path inserts and evicts under a lock.  With
:func:`enable_persistent_cache` (or ``$REPRO_AOT_CACHE_DIR``) compiled
executables additionally persist across processes through the
versioned AOT store (:mod:`repro.core.aot_store`): cold builds export
and save the XLA executable, fresh processes restore it instead of
re-planning and re-compiling.  Stats via :func:`compile_cache_stats`
(including disk hit/miss/bytes counters); ``benchmarks/run.py --json``
records the cold/warm split in its ``compile_cache`` section.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
import math
import os
import threading
from typing import Any, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aot_store as aot_store_mod
from repro.core import pragma
from repro.core import plan as plan_mod
from repro.core.context import _aval_of
from repro.core.loop import LoopNotCanonical


class CompileError(LoopNotCanonical, ValueError):
    """Invalid :class:`Options` or an option × program combination the
    compiler cannot honor.

    Subclasses :class:`~repro.core.loop.LoopNotCanonical` (the paper's
    "block stays OpenMP" diagnostics path) *and* :class:`ValueError`
    (the historical kwargs-validation behavior), so the one new
    diagnostics path satisfies every legacy ``except`` clause.
    """


class Lowering(enum.Enum):
    """How the parallel block(s) are lowered to the device mesh."""

    FUSED = "fused"
    """One fused ``shard_map`` for the whole region; arrays stay
    resident across loop boundaries (the default).  A single
    ``ParallelFor`` has no boundaries to fuse, so this equals
    ``COLLECTIVE`` there."""

    COLLECTIVE = "collective"
    """TPU-native per-loop staging: chunk-cyclic slabs + balanced
    collectives, each loop transformed in isolation."""

    MASTER_WORKER = "master_worker"
    """Paper-faithful Fig. 1b staging: rank 0 owns the shared memory,
    all traffic moves through its links.  Rank-1 nests only."""

    PALLAS = "pallas"
    """The FUSED lowering with each compute span — a stage's chunk
    loop, or a chain of stages between scheduled exchanges — emitted as
    one tiled Pallas kernel over the local slab
    (:mod:`repro.core.pallas_lower`).  Interpret-mode fallback off-TPU;
    see ``Options.pallas_interpret``."""


class CommMode(enum.Enum):
    """Boundary planner mode for fused regions."""

    AUTO = "auto"
    """Cheapest of resident / halo ``ppermute`` / all_gather /
    replicate per boundary (the cost model of :mod:`repro.core.comm`)."""

    GATHER = "gather"
    """All-gather-only boundaries — the measurable PR 1 baseline."""


class ShardPolicy(enum.Enum):
    """IN-buffer transfer policy for the per-loop staging lowerings
    (fused regions always plan sliced inputs — that is the point of
    residency)."""

    REPLICATE = "replicate"
    """The paper's rule: the master broadcasts every IN buffer."""

    SLICE = "slice"
    """Send each rank only its chunk slices (+ stencil halo rows)."""


def _coerce_enum(enum_cls, value, field):
    if isinstance(value, enum_cls):
        return value
    if isinstance(value, str):
        try:
            return enum_cls(value.lower())
        except ValueError:
            pass
    raise CompileError(
        f"Options.{field} must be one of "
        f"{[e.value for e in enum_cls]} (or a {enum_cls.__name__}), "
        f"got {value!r}")


def _normalize_chunk_weights(cw):
    """Canonicalise ``Options.chunk_weights``: a flat tuple of floats
    (rank-1), or a 2-tuple of per-axis entries (each a float tuple or
    ``None``) for ``collapse=2``."""

    def flat(seq, where):
        try:
            vals = tuple(float(x) for x in seq)
        except (TypeError, ValueError):
            raise CompileError(
                f"Options.chunk_weights{where} must be a sequence of "
                f"numbers, got {seq!r}") from None
        if not vals:
            raise CompileError(f"Options.chunk_weights{where} is empty")
        for v in vals:
            if not math.isfinite(v) or v <= 0:
                raise CompileError(
                    f"Options.chunk_weights{where} entries must be "
                    f"finite and > 0, got {vals}")
        return vals

    if not isinstance(cw, (tuple, list)):
        raise CompileError(
            "Options.chunk_weights must be a per-device weight vector "
            "(or a 2-tuple of per-axis vectors for collapse=2), got "
            f"{cw!r}")
    if any(e is None or isinstance(e, (tuple, list)) for e in cw):
        if len(cw) != 2 or not all(
                e is None or isinstance(e, (tuple, list)) for e in cw):
            raise CompileError(
                "per-axis Options.chunk_weights must be a 2-tuple of "
                f"weight vectors (or None per axis), got {cw!r}")
        return tuple(None if e is None else flat(e, f"[{d}]")
                     for d, e in enumerate(cw))
    return flat(cw, "")


@dataclasses.dataclass(frozen=True)
class Options:
    """Compilation options — the typed replacement for the historical
    ``to_mpi``/``region_to_mpi`` kwargs.

    Every field accepts the enum member or its string value; validation
    happens at construction and raises :class:`CompileError` with an
    actionable message.
    """

    axis: Any = None
    """Mesh axis clause: a name for rank-1 nests, a 2-tuple of distinct
    names for ``collapse=2``; ``None`` resolves the default
    (``"data"``, or ``("i", "j")`` for rank-2)."""

    lowering: Lowering = Lowering.FUSED
    comm: CommMode = CommMode.AUTO
    shard: ShardPolicy = ShardPolicy.REPLICATE

    comm_schedule: str = "aggregate"
    """The **schedule_comm** pass mode (:mod:`repro.core.comm_schedule`):
    ``"aggregate"`` (default) packs same-boundary ``ppermute`` payloads
    into one launch per direction, fuses per-stage reduction combines
    into flat collectives, and hoists each exchange to just after its
    producer (prefetch); ``"inline"`` pins the per-buffer behavior —
    same wire bytes, one launch per exchange — for measurement."""

    schedule: pragma.Schedule | None = None
    """Override every loop's ``schedule(...)`` clause at compile time
    (``None`` keeps the clauses written on the pragmas)."""

    keep_sharded: bool = False
    """Historical ``to_mpi`` flag that was silently ignored (and absent
    from ``region_to_mpi``).  Sharded-exit control is not implemented by
    any lowering — every lowering reassembles outputs to the
    shared-memory layout at exit — so ``True`` is rejected here instead
    of being dropped on the floor."""

    unroll_chunks: bool = False
    paper_master_excluded: bool | None = None

    pallas_interpret: bool | None = None
    """Pallas execution mode for ``Lowering.PALLAS``: ``None`` (default)
    runs the kernels in interpret mode off-TPU (CPU/CI) and compiled on
    TPU; ``True``/``False`` forces.  Rejected under any other
    lowering."""

    chunk_weights: Any = None
    """Per-device speed weights for a straggler-weighted schedule
    (``runtime.straggler.rebalance_chunks`` apportions chunk ownership
    proportionally; faster devices run more chunks).  Rank-1: a
    sequence of P positive floats; ``collapse=2``: a 2-tuple of
    per-axis vectors (``None`` keeps an axis cyclic).  Collective
    chunk executor only — rejected under ``Lowering.MASTER_WORKER`` /
    ``Lowering.PALLAS`` and under ``Lowering.FUSED`` on regions (ring
    halo exchanges assume cyclic neighbors)."""

    def __post_init__(self) -> None:
        object.__setattr__(
            self, "lowering",
            _coerce_enum(Lowering, self.lowering, "lowering"))
        object.__setattr__(
            self, "comm", _coerce_enum(CommMode, self.comm, "comm"))
        object.__setattr__(
            self, "shard", _coerce_enum(ShardPolicy, self.shard, "shard"))

        cs = self.comm_schedule
        if isinstance(cs, str):
            cs = cs.lower()
        from repro.core.comm_schedule import SCHEDULE_MODES
        if cs not in SCHEDULE_MODES:
            raise CompileError(
                f"Options.comm_schedule must be one of {SCHEDULE_MODES}, "
                f"got {self.comm_schedule!r}")
        object.__setattr__(self, "comm_schedule", cs)

        sched = self.schedule
        if isinstance(sched, str):
            try:
                sched = pragma.Schedule(sched)
            except ValueError as e:
                raise CompileError(f"Options.schedule: {e}") from None
            object.__setattr__(self, "schedule", sched)
        elif sched is not None and not isinstance(sched, pragma.Schedule):
            raise CompileError(
                "Options.schedule must be a Schedule (omp.static()/"
                f"omp.dynamic()/omp.guided()) or None, got {sched!r}")

        if self.keep_sharded:
            raise CompileError(
                "Options.keep_sharded=True: sharded-exit control is not "
                "implemented by any lowering — outputs are always "
                "reassembled to the shared-memory layout at exit.  To keep "
                "arrays resident between loops, compile them as one "
                "omp.region(...) with Lowering.FUSED (the default)."
            )

        ax = self.axis
        if ax is not None:
            if isinstance(ax, list):
                ax = tuple(ax)
                object.__setattr__(self, "axis", ax)
            if isinstance(ax, tuple):
                if (len(ax) != 2 or not all(isinstance(a, str) for a in ax)
                        or ax[0] == ax[1]):
                    raise CompileError(
                        "Options.axis: a rank-2 axis clause must be a "
                        f"2-tuple of distinct mesh axis names, got {ax!r}")
            elif not isinstance(ax, str):
                raise CompileError(
                    "Options.axis must be a mesh axis name, a 2-tuple of "
                    f"names, or None, got {ax!r}")

        for field in ("unroll_chunks",):
            if not isinstance(getattr(self, field), bool):
                raise CompileError(
                    f"Options.{field} must be a bool, "
                    f"got {getattr(self, field)!r}")
        if self.paper_master_excluded not in (None, True, False):
            raise CompileError(
                "Options.paper_master_excluded must be True, False or None "
                f"(= derive from the lowering), got "
                f"{self.paper_master_excluded!r}")

        if self.pallas_interpret not in (None, True, False):
            raise CompileError(
                "Options.pallas_interpret must be True, False or None "
                f"(= interpret off-TPU), got {self.pallas_interpret!r}")
        if self.lowering is Lowering.PALLAS:
            if self.unroll_chunks:
                raise CompileError(
                    "Options.unroll_chunks has no effect under "
                    "Lowering.PALLAS: chunk compute runs as a tiled "
                    "Pallas kernel grid, not a lax.scan that could be "
                    "unrolled.  Drop unroll_chunks or use "
                    "Lowering.FUSED/COLLECTIVE.")
            if self.paper_master_excluded is not None:
                raise CompileError(
                    "Options.paper_master_excluded is a master/worker "
                    "staging knob; Lowering.PALLAS never stages through "
                    "a master rank (and Lowering.MASTER_WORKER has no "
                    "pallas variant).  Drop paper_master_excluded or "
                    "use Lowering.MASTER_WORKER.")
        elif self.pallas_interpret is not None:
            raise CompileError(
                "Options.pallas_interpret only applies to "
                "Lowering.PALLAS; this compile uses "
                f"lowering={self.lowering.value!r}.  Drop "
                "pallas_interpret or set lowering=\"pallas\".")

        if self.chunk_weights is not None:
            object.__setattr__(self, "chunk_weights",
                               _normalize_chunk_weights(self.chunk_weights))
            if self.lowering in (Lowering.MASTER_WORKER, Lowering.PALLAS):
                raise CompileError(
                    "Options.chunk_weights (straggler-weighted schedule) "
                    "requires the collective chunk executor; "
                    f"lowering={self.lowering.value!r} assumes cyclic "
                    "chunk ownership (explicit master/worker row math / "
                    "tiled kernel grids).  Use Lowering.COLLECTIVE, or "
                    "the default FUSED on a single block.")

    def describe(self) -> str:
        sched = (f"{self.schedule.kind}({self.schedule.chunk})"
                 if self.schedule is not None else "per-pragma")
        return (f"lowering={self.lowering.value} comm={self.comm.value} "
                f"comm_schedule={self.comm_schedule} "
                f"shard={self.shard.value} schedule={sched}")


# ---------------------------------------------------------------------------
# Pass records
# ---------------------------------------------------------------------------

PASS_NAMES = ("analyze", "schedule", "plan", "plan_comm", "schedule_comm",
              "lower")


@dataclasses.dataclass(frozen=True)
class PassRecord:
    """One pipeline stage: what went in, what came out."""

    name: str
    input: str
    """Short description of the artifact(s) the pass consumed."""
    output: Any
    """The artifact the pass produced (consumed by the next pass)."""

    def describe(self) -> str:
        out = self.output
        if isinstance(out, (tuple, list)):
            kind = f"{len(out)} artifact(s)"
        else:
            kind = type(out).__name__
        return f"{self.name}: {self.input} -> {kind}"


# ---------------------------------------------------------------------------
# The structural compilation cache
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Artifacts:
    """Mesh-independent result of the analyze→plan_comm passes; the
    ``program`` reference pins the ``id()``s used in the cache key."""

    passes: tuple[PassRecord, ...]
    exe_plan: Any           # DistPlan | RegionPlan | None (staged regions)
    program: Any


class _Counter:
    """Increment-only counter whose :meth:`inc` is a single C-level
    ``next()`` call — atomic under the GIL — so warm cache hits can
    count *exactly* without taking a lock (``_STATS[k] += 1`` is a
    read-modify-write that loses increments under threads).
    ``value`` peeks the iterator state without consuming it."""

    __slots__ = ("_it",)

    def __init__(self) -> None:
        self._it = itertools.count()

    def inc(self) -> None:
        next(self._it)

    @property
    def value(self) -> int:
        return self._it.__reduce__()[1][0]


class _Entry:
    """One cache line: the artifacts plus an LRU recency stamp.  Stamp
    refreshes are plain attribute stores (atomic under the GIL), so the
    hit path never locks; eviction — on the locked miss path — scans
    for the oldest stamp.  A racing stamp refresh during an eviction
    scan can at worst save a just-touched entry, never corrupt."""

    __slots__ = ("art", "stamp")

    def __init__(self, art: _Artifacts, stamp: int) -> None:
        self.art = art
        self.stamp = stamp


_CACHE: dict[tuple, _Entry] = {}
_CACHE_CAP = 512
_CACHE_LOCK = threading.Lock()   # guards the miss path: insert + evict
_TICK = itertools.count()        # LRU clock (atomic, see _Counter)
_HITS = _Counter()
_MISSES = _Counter()

# Persistent AOT executable store (None = in-memory only).  Enabled via
# enable_persistent_cache() or the REPRO_AOT_CACHE_DIR environment
# variable; EXPERIMENTS §Perf-I measures the cross-process warm start.
_PERSISTENT: aot_store_mod.AOTStore | None = None
_EXE_CACHE: dict[str, Any] = {}   # disk key -> loaded AOT executable
_UNEXPORTABLE: set[str] = set()   # disk keys whose executor cannot lower


def compile_cache_stats() -> dict:
    """Hit/miss counters and current size of the compilation cache,
    plus the persistent-store counters (``disk_hits`` / ``disk_misses``
    / ``disk_errors`` / ``disk_bytes_read`` / ``disk_bytes_written`` —
    zeros while persistence is disabled)."""
    stats = {"hits": _HITS.value, "misses": _MISSES.value,
             "size": len(_CACHE),
             "persistent_dir": _PERSISTENT.path if _PERSISTENT else None}
    stats.update(_PERSISTENT.stats if _PERSISTENT
                 else aot_store_mod.empty_stats())
    return stats


def clear_compile_cache() -> None:
    """Drop every cached compilation and reset the counters (the
    persistent store keeps its on-disk entries; its counters reset)."""
    global _HITS, _MISSES
    with _CACHE_LOCK:
        _CACHE.clear()
        _EXE_CACHE.clear()
        _UNEXPORTABLE.clear()
        _HITS = _Counter()
        _MISSES = _Counter()
        if _PERSISTENT is not None:
            _PERSISTENT.stats = aot_store_mod.empty_stats()


def enable_persistent_cache(path: str | None = None) -> str:
    """Turn on the on-disk AOT executable store at ``path`` (default:
    ``$REPRO_AOT_CACHE_DIR`` or ``~/.cache/repro-aot``).  Returns the
    resolved directory.  Compiles gain a disk probe on the miss path
    and an AOT export+save on cold builds; a fresh process pointed at
    the same directory restores executables instead of re-planning and
    re-compiling (EXPERIMENTS §Perf-I)."""
    global _PERSISTENT
    if path is None:
        path = os.environ.get(aot_store_mod.ENV_VAR) or os.path.join(
            os.path.expanduser("~"), ".cache", "repro-aot")
    _PERSISTENT = aot_store_mod.AOTStore(path)
    return _PERSISTENT.path


def disable_persistent_cache() -> None:
    """Back to in-memory-only caching (on-disk entries are kept)."""
    global _PERSISTENT
    _PERSISTENT = None
    _EXE_CACHE.clear()
    _UNEXPORTABLE.clear()


def _program_signature(p) -> tuple:
    """Structural identity of a program.  Bodies are compared by
    ``id()``; cache entries keep a strong reference to the program so
    the ids cannot be recycled while the entry lives."""
    if isinstance(p, pragma.ParallelRegion):
        return ("region", tuple(_program_signature(s) for s in p.stages))
    if isinstance(p, pragma.SerialStage):
        return ("serial", id(p.fn), p.reads)
    return ("for", id(p.body), p.bounds, p.collapse,
            (p.schedule.kind, p.schedule.chunk),
            tuple(sorted(p.reduction.items())))


def _env_signature(env: Mapping[str, Any]) -> tuple:
    """Shape/dtype identity of the environment, derived host-side.

    This runs on every cache probe, so it must not touch the device:
    the historical ``jnp.asarray`` fallback device-put every non-array
    env value (python scalars, lists) on the hot key path.  Python
    values type through numpy + ``canonicalize_dtype`` instead, which
    lands on the same dtype ``jnp.asarray`` would have (x64 off:
    float → float32, int → int32) without materializing anything."""
    sig = []
    for k in sorted(env):
        v = env[k]
        shape = getattr(v, "shape", None)
        dtype = getattr(v, "dtype", None)
        if shape is None or dtype is None:
            arr = np.asarray(v)
            shape = arr.shape
            dtype = jax.dtypes.canonicalize_dtype(arr.dtype)
        sig.append((k, tuple(shape), str(dtype)))
    return tuple(sig)


def _stable_program_token(p) -> tuple:
    """Cross-process analogue of :func:`_program_signature` for the
    persistent store: loop bodies hash by bytecode + consts + closure
    values (:func:`repro.core.aot_store.fingerprint`) instead of by
    ``id()``, so the same source program keys identically in every
    process."""
    if isinstance(p, pragma.ParallelRegion):
        return ("region",
                tuple(_stable_program_token(s) for s in p.stages))
    if isinstance(p, pragma.SerialStage):
        return ("serial", aot_store_mod.fingerprint(p.fn), p.reads)
    return ("for", aot_store_mod.fingerprint(p.body), p.bounds, p.collapse,
            (p.schedule.kind, p.schedule.chunk),
            tuple(sorted(p.reduction.items())))


def _mesh_signature(mesh) -> tuple:
    return tuple((str(a), int(mesh.shape[a])) for a in mesh.axis_names)


# ---------------------------------------------------------------------------
# compile()
# ---------------------------------------------------------------------------


def compile(
    program,
    mesh,
    options: Options | None = None,
    *,
    env_like: Mapping[str, Any] | None = None,
    **overrides,
) -> "Compiled":
    """Compile a :class:`~repro.core.pragma.ParallelFor` or
    :class:`~repro.core.pragma.ParallelRegion` to a distributed program.

    ``options`` carries every knob; as a convenience the fields may be
    given as keyword overrides instead (``omp.compile(p, mesh,
    lowering="master_worker")``).  ``env_like`` (shapes only) runs the
    pass pipeline eagerly; without it the pipeline runs on first call,
    when the environment shapes are known.

    Returns a :class:`Compiled` artifact: callable, ``.run(env)``,
    ``.plan`` / ``.boundaries`` / ``.passes`` / ``.report()`` /
    ``.cost_summary()``.
    """
    from repro.core import transform as tf

    if options is None:
        options = Options(**overrides)
    elif overrides:
        raise CompileError(
            "pass either an Options object or keyword overrides, not both "
            f"(got Options plus {sorted(overrides)})")
    if not isinstance(options, Options):
        raise CompileError(
            f"options must be an omp.Options, got {type(options).__name__}")
    if not isinstance(program, (pragma.ParallelFor, pragma.ParallelRegion)):
        raise CompileError(
            "omp.compile expects a ParallelFor or ParallelRegion, got "
            f"{type(program).__name__}")

    axis, num = tf.resolve_axes(program, mesh, options.axis)
    _validate_combination(program, options, num)
    compiled = Compiled(program=program, mesh=mesh, options=options,
                        axis=axis, num_devices=num)
    if env_like is not None:
        compiled._ensure(env_like)
    return compiled


def _validate_combination(program, options: Options, num) -> None:
    """Cross-field validation that needs the program: one diagnostics
    path instead of ad-hoc raises scattered through the lowerings."""
    rank = program.rank
    if options.lowering is Lowering.MASTER_WORKER:
        if rank == 2:
            raise CompileError(
                "Lowering.MASTER_WORKER × collapse=2: the paper's "
                "master/worker staging is rank-1 only.  Use "
                "Lowering.FUSED (default) or Lowering.COLLECTIVE for "
                "rank-2 nests.")
        if options.shard is ShardPolicy.SLICE:
            raise CompileError(
                "ShardPolicy.SLICE has no effect under "
                "Lowering.MASTER_WORKER (the master always sends full "
                "buffers, paper Fig. 1b); use Lowering.COLLECTIVE for "
                "sliced inputs.")
        if num < 2:
            raise CompileError(
                "Lowering.MASTER_WORKER needs >= 2 mesh ranks (rank 0 is "
                f"the master); this mesh has {num}.")

    cw = options.chunk_weights
    if cw is not None:
        if isinstance(program, pragma.ParallelRegion) \
                and options.lowering is Lowering.FUSED:
            raise CompileError(
                "Options.chunk_weights on a region requires "
                "Lowering.COLLECTIVE (per-loop staging): the fused "
                "region executor's ring halo exchanges and slab "
                "residency assume cyclic chunk ownership.")
        nested = any(e is None or isinstance(e, tuple) for e in cw)
        if rank == 2:
            if not nested:
                raise CompileError(
                    "collapse=2 needs per-axis chunk_weights: a 2-tuple "
                    "of weight vectors (or None to keep an axis "
                    f"cyclic), got {cw!r}")
            for d, (e, p_d) in enumerate(zip(cw, num)):
                if e is not None and len(e) != p_d:
                    raise CompileError(
                        f"chunk_weights[{d}] has {len(e)} entries but "
                        f"mesh axis {d} has {p_d} devices")
        else:
            if nested:
                raise CompileError(
                    "rank-1 loops need a flat per-device chunk_weights "
                    f"vector, got the per-axis form {cw!r}")
            if len(cw) != num:
                raise CompileError(
                    f"chunk_weights has {len(cw)} entries but the mesh "
                    f"axis has {num} devices")


# ---------------------------------------------------------------------------
# Pipeline execution
# ---------------------------------------------------------------------------


def _lowering_str(options: Options) -> str:
    return ("master_worker" if options.lowering is Lowering.MASTER_WORKER
            else "collective")


def _build_artifacts(program, env_like, num, axis, options) -> _Artifacts:
    env_shapes = {k: _aval_of(v) for k, v in env_like.items()}
    if isinstance(program, pragma.ParallelRegion):
        if options.lowering in (Lowering.FUSED, Lowering.PALLAS):
            return _build_region_fused(program, env_shapes, num, axis,
                                       options)
        return _build_region_staged(program, env_shapes, num, axis, options)
    return _build_block(program, env_shapes, num, axis, options)


def _pallas_pass(options: Options, kernel_plan) -> tuple:
    """The extra **pallas** PassRecord (only under Lowering.PALLAS, so
    the default 6-pass chain stays pinned)."""
    if options.lowering is not Lowering.PALLAS:
        return ()
    return (PassRecord(
        "pallas",
        input="exchange-free compute spans + chunk geometry "
              "(tile derivation per axis)",
        output=kernel_plan),)


def _build_block(program, env_shapes, num, axis, options) -> _Artifacts:
    low = _lowering_str(options)
    shard_inputs = options.shard is ShardPolicy.SLICE
    nest, ctx = plan_mod.analyze_program(program, env_shapes)
    chunks_axes = plan_mod.plan_schedule(
        program, nest, num, lowering=low,
        paper_master_excluded=options.paper_master_excluded,
        schedule=options.schedule, weights=options.chunk_weights)
    plan = plan_mod.decide_strategies(
        program, nest, ctx, chunks_axes, axis=axis, lowering=low,
        shard_inputs=shard_inputs)
    passes = (
        PassRecord("analyze",
                   input=f"block {program.name!r} + env shapes",
                   output=(nest, ctx)),
        PassRecord("schedule",
                   input="loop nest + schedule clause(s)",
                   output=chunks_axes),
        PassRecord("plan",
                   input="context + chunk plans",
                   output=plan),
        PassRecord("plan_comm",
                   input="single block: no inter-loop slab boundaries",
                   output=()),
        PassRecord("schedule_comm",
                   input="single block: no region-wide exchanges to "
                         "schedule (per-block combines fuse at lower)",
                   output=()),
    )
    if options.lowering is Lowering.PALLAS:
        from repro.core import pallas_lower as plx

        passes = passes + _pallas_pass(
            options, plx.plan_block_kernel(plan, name=program.name))
    return _Artifacts(passes=passes, exe_plan=plan, program=program)


def _build_region_fused(region, env_shapes, num, axis,
                        options) -> _Artifacts:
    from repro.core import comm_schedule as cs_mod
    from repro.core import region as region_mod

    try:
        rp = region_mod.plan_region(
            region, env_shapes, num, axis=axis, comm=options.comm.value,
            schedule=options.schedule)
    except LoopNotCanonical:
        raise
    except Exception as e:
        if options.lowering is Lowering.PALLAS:
            # almost always a host-side serial glue stage that cannot be
            # shape-traced — the pallas lowering runs everything inside
            # one shard_map and cannot leave the device for glue
            raise CompileError(
                f"Lowering.PALLAS cannot compile region {region.name!r}: "
                f"a stage is not shape-traceable "
                f"({type(e).__name__}: {e}).  Host-side serial glue "
                "(numpy conversion, I/O) runs only under the staged "
                "Lowering.COLLECTIVE path.") from e
        raise
    rp.comm_sched = cs_mod.build_comm_schedule(
        rp, mode=options.comm_schedule)
    loop_stages = [se for se in rp.stages if se.plan is not None]
    passes = (
        PassRecord("analyze",
                   input=f"region {region.name!r} "
                         f"({len(region.stages)} stages) + env shapes",
                   output=tuple((se.name, se.plan.context)
                                for se in loop_stages)),
        PassRecord("schedule",
                   input="per-stage loop nests + schedule clause(s)",
                   output=tuple((se.name, se.plan.chunks_axes)
                                for se in loop_stages)),
        PassRecord("plan",
                   input="per-stage contexts + chunk plans "
                         "(inter-loop residency planner)",
                   output=rp),
        PassRecord("plan_comm",
                   input="stage OUT layouts vs next-stage IN needs",
                   output=tuple(rp.comms)),
        PassRecord("schedule_comm",
                   input="planned boundary exchanges + stage order "
                         "(aggregate payloads / fuse combines / hoist "
                         "to producers)",
                   output=rp.comm_sched),
    )
    if options.lowering is Lowering.PALLAS:
        from repro.core import pallas_lower as plx

        passes = passes + _pallas_pass(
            options, plx.plan_region_kernels(rp))
    return _Artifacts(passes=passes, exe_plan=rp, program=region)


def _build_region_staged(region, env_shapes, num, axis,
                         options) -> _Artifacts:
    """Per-loop staging (COLLECTIVE / MASTER_WORKER on a region): each
    loop planned in isolation, environment shapes threaded through the
    stages the way the staged executor will see them.

    Serial glue is shape-traced (``jax.eval_shape``) to thread its
    output shapes.  Unlike the fused lowering — which *executes* glue
    inside the shard_map and therefore requires traceable glue — the
    staged executor runs glue eagerly on concrete arrays, so host-side
    glue (numpy conversion, I/O) is legal here: when its shapes cannot
    be traced, planning of the remaining stages is deferred to run time
    (the historical per-call behavior) instead of failing the compile."""
    low = _lowering_str(options)
    shard_inputs = options.shard is ShardPolicy.SLICE
    shapes = dict(env_shapes)
    analyses, schedules, plans = [], [], []
    deferred = None
    for stage in region.stages:
        if isinstance(stage, pragma.SerialStage):
            try:
                out_sh = jax.eval_shape(stage.fn, shapes)
            except Exception as e:  # host-side glue: shapes unknowable
                deferred = (f"serial stage {stage.name!r} is not "
                            f"shape-traceable ({type(e).__name__}); "
                            "remaining stages plan at run time")
                break
            for k, v in out_sh.items():
                shapes[k] = jax.ShapeDtypeStruct(v.shape, v.dtype)
            continue
        nest, ctx = plan_mod.analyze_program(stage, shapes)
        chunks_axes = plan_mod.plan_schedule(
            stage, nest, num, lowering=low,
            paper_master_excluded=options.paper_master_excluded,
            schedule=options.schedule, weights=options.chunk_weights)
        p = plan_mod.decide_strategies(
            stage, nest, ctx, chunks_axes, axis=axis, lowering=low,
            shard_inputs=shard_inputs)
        analyses.append((stage.name, ctx))
        schedules.append((stage.name, chunks_axes))
        plans.append((stage.name, p))
        for key, dec in p.vars.items():
            if dec.out_strategy == "reduce" and key not in shapes:
                info = p.context.vars[key]
                shapes[key] = jax.ShapeDtypeStruct(
                    info.write.value_shape, info.write.value_dtype)
    stage_plans = tuple(plans)
    plan_input = ("per-stage contexts + chunk plans "
                  "(each loop planned in isolation)")
    if deferred is not None:
        plan_input += f"; {deferred}"
    passes = (
        PassRecord("analyze",
                   input=f"region {region.name!r} "
                         f"({len(region.stages)} stages) + env shapes",
                   output=tuple(analyses)),
        PassRecord("schedule",
                   input="per-stage loop nests + schedule clause(s)",
                   output=tuple(schedules)),
        PassRecord("plan",
                   input=plan_input,
                   output=stage_plans),
        PassRecord("plan_comm",
                   input="staged lowering: every boundary round-trips "
                         "through the replicated layout (paper Fig. 1b)",
                   output=()),
        PassRecord("schedule_comm",
                   input="staged lowering: no region-wide exchanges to "
                         "schedule (per-block combines fuse at lower)",
                   output=()),
    )
    return _Artifacts(
        passes=passes,
        # a partial plan list cannot feed the executor 1:1 — fall back
        # to the historical per-call planning for the whole region
        exe_plan=None if deferred is not None else stage_plans,
        program=region)


def _make_executor(program, mesh, axis, options: Options, exe_plan):
    """The **lower** pass: bind the planned artifacts to the mesh."""
    from repro.core import region as region_mod
    from repro.core import transform as tf

    use_pallas = options.lowering is Lowering.PALLAS
    if isinstance(program, pragma.ParallelRegion):
        fused = options.lowering in (Lowering.FUSED, Lowering.PALLAS)
        return region_mod.DistributedRegion(
            region=program, mesh=mesh,
            plan=exe_plan if fused else None,
            axis=axis, lowering=_lowering_str(options), fuse=fused,
            shard_inputs=options.shard is ShardPolicy.SLICE,
            unroll_chunks=options.unroll_chunks,
            paper_master_excluded=options.paper_master_excluded,
            comm=options.comm.value,
            comm_schedule=options.comm_schedule,
            schedule_override=options.schedule,
            stage_plans=None if fused else exe_plan,
            use_pallas=use_pallas,
            pallas_interpret=options.pallas_interpret,
            chunk_weights=options.chunk_weights)
    return tf.DistributedProgram(
        program=program, mesh=mesh, plan=exe_plan, axis=axis,
        lowering=_lowering_str(options),
        shard_inputs=options.shard is ShardPolicy.SLICE,
        unroll_chunks=options.unroll_chunks,
        paper_master_excluded=options.paper_master_excluded,
        schedule_override=options.schedule,
        comm_schedule=options.comm_schedule,
        use_pallas=use_pallas,
        pallas_interpret=options.pallas_interpret,
        chunk_weights=options.chunk_weights)


def _export_and_save(dkey: str, exe, sig: tuple):
    """AOT-lower the executor end-to-end (jit → lower → XLA compile)
    and persist the serialized executable under ``dkey``.  Returns the
    compiled executable — which also serves this process's calls — or
    ``None`` when the program cannot be staged out (e.g. host-side
    serial glue in a staged region): those fall back to the per-call
    jit path, exactly as before persistence existed."""
    avals = {k: jax.ShapeDtypeStruct(
                 tuple(sh), jax.dtypes.canonicalize_dtype(np.dtype(dt)))
             for k, sh, dt in sig}
    try:
        compiled = jax.jit(lambda env: dict(exe(env))).lower(avals).compile()
    except Exception:
        return None
    _PERSISTENT.save(dkey, compiled)
    return compiled


if os.environ.get(aot_store_mod.ENV_VAR):
    enable_persistent_cache()


# ---------------------------------------------------------------------------
# The Compiled artifact
# ---------------------------------------------------------------------------

#: Fault-injection hook (repro.runtime.fault_injection installs a
#: callable here inside ``inject()``).  Called as ``hook("run")`` at
#: every ``Compiled.run`` entry and ``hook("run_exit", out)`` on exit
#: (the return value replaces ``out`` — output corruption faults).
#: ``None`` in production: the cost when inactive is one attribute
#: check per call.
_fault_hook = None


@dataclasses.dataclass
class Compiled:
    """The unified compilation artifact for blocks and regions.

    Callable (``compiled(env)`` / ``compiled.run(env)``) like the
    programs it replaces; additionally exposes the staged pipeline:

    * ``.passes``       — the analyze→lower :class:`PassRecord` chain
      (``analyze → schedule → plan → plan_comm → schedule_comm →
      lower``),
    * ``.plan``         — the planning artifact (:class:`DistPlan`,
      :class:`~repro.core.region.RegionPlan`, or per-stage plans for
      staged regions),
    * ``.boundaries``   — the planned
      :class:`~repro.core.comm.BoundaryComm` list (fused regions),
    * ``.report()``     — the rendered "generated MPI code" view,
    * ``.cost_summary()`` — modeled communication totals as a dict,
    * ``.cache_hit``    — whether the last build came from the cache.

    The pipeline needs environment *shapes*; compile with ``env_like=``
    to run it eagerly, otherwise it runs (through the compilation
    cache) on first call.  A call with different env shapes re-plans —
    and re-consults the cache — automatically.
    """

    program: Any
    mesh: Any
    options: Options
    axis: Any
    num_devices: Any
    cache_hit: bool | None = None
    _exe: Any = dataclasses.field(default=None, repr=False)
    _passes: tuple | None = dataclasses.field(default=None, repr=False)
    _env_sig: tuple | None = dataclasses.field(default=None, repr=False)
    # Persistent-store state: the AOT-compiled end-to-end executable
    # (serves run() without re-tracing), and — after a disk restore
    # that skipped planning — the env avals to rebuild the pass
    # artifacts lazily on inspection.
    _runner: Any = dataclasses.field(default=None, repr=False)
    _restored_env: Any = dataclasses.field(default=None, repr=False)

    # -- execution ---------------------------------------------------------

    def run(self, env: Mapping[str, Any]) -> dict:
        if _fault_hook is not None:
            _fault_hook("run")
        out = None
        self._ensure(env)
        if self._runner is not None:
            try:
                out = dict(self._runner(env))
            except Exception:
                # The persisted executable refused these inputs (aval /
                # layout / backend skew).  The store must never turn
                # into a crash: drop the runner and fall back to the
                # planned executor.
                self._runner = None
        if out is None:
            if self._exe is None:
                self._ensure(env, allow_restore=False)
            out = self._exe(env)
        if _fault_hook is not None:
            out = _fault_hook("run_exit", out)
        return out

    __call__ = run

    @property
    def restored(self) -> bool:
        """Whether this artifact was served from the persistent store
        (planning skipped; pass artifacts rebuild lazily on access)."""
        return self._restored_env is not None

    # -- pipeline ----------------------------------------------------------

    def _ensure(self, env_like: Mapping[str, Any], *,
                allow_restore: bool = True) -> None:
        sig = _env_signature(env_like)
        if sig == self._env_sig:
            if self._exe is not None:
                return
            if allow_restore and self._runner is not None:
                return
        key = (_program_signature(self.program), _mesh_signature(self.mesh),
               self.options, sig)
        entry = _CACHE.get(key)          # warm hits: lock-free
        if entry is not None:
            _HITS.inc()
            entry.stamp = next(_TICK)
            self.cache_hit = True
            self._bind(entry.art, sig)
            if _PERSISTENT is not None:
                self._runner = _EXE_CACHE.get(self._disk_key(sig))
            return
        if (allow_restore and _PERSISTENT is not None
                and self._try_restore(sig)):
            return
        _MISSES.inc()                    # miss path: build, then lock
        self.cache_hit = False
        art = _build_artifacts(self.program, env_like, self.num_devices,
                               self.axis, self.options)
        with _CACHE_LOCK:
            _CACHE[key] = _Entry(art, next(_TICK))
            while len(_CACHE) > _CACHE_CAP:
                oldest = min(_CACHE, key=lambda k: _CACHE[k].stamp)
                del _CACHE[oldest]
        self._bind(art, sig)
        if _PERSISTENT is not None:
            dkey = self._disk_key(sig)
            runner = _EXE_CACHE.get(dkey)
            if runner is None and dkey not in _UNEXPORTABLE:
                runner = _export_and_save(dkey, self._exe, sig)
                if runner is None:
                    _UNEXPORTABLE.add(dkey)
                else:
                    _EXE_CACHE[dkey] = runner
            self._runner = runner

    def _bind(self, art: _Artifacts, sig: tuple) -> None:
        exe = _make_executor(self.program, self.mesh, self.axis,
                             self.options, art.exe_plan)
        self._passes = art.passes + (PassRecord(
            "lower", input="planned artifacts + mesh", output=exe),)
        self._exe = exe
        self._env_sig = sig
        self._runner = None

    def _disk_key(self, sig: tuple) -> str:
        return aot_store_mod.fingerprint(
            "compiled-run", aot_store_mod.STORE_VERSION,
            _stable_program_token(self.program),
            _mesh_signature(self.mesh), self.options, self.axis, sig)

    def _try_restore(self, sig: tuple) -> bool:
        """Serve this compile from the persistent store: planning is
        skipped entirely — the pass artifacts rebuild lazily
        (deterministically) if inspected."""
        dkey = self._disk_key(sig)
        runner = _EXE_CACHE.get(dkey)
        if runner is None:
            if dkey in _UNEXPORTABLE:
                return False
            runner = _PERSISTENT.load(dkey)
            if runner is None:
                return False
            _EXE_CACHE[dkey] = runner
        self.cache_hit = True
        self._runner = runner
        self._exe = None
        self._passes = None
        self._env_sig = sig
        self._restored_env = {k: jax.ShapeDtypeStruct(tuple(sh), np.dtype(dt))
                              for k, sh, dt in sig}
        return True

    def _built(self) -> None:
        if self._passes is None and self._restored_env is not None:
            runner = self._runner
            self._ensure(self._restored_env, allow_restore=False)
            self._runner = runner
        if self._passes is None:
            raise CompileError(
                "the pass pipeline has not run yet: call the compiled "
                "program (or compile with env_like=) to build the plan "
                "before inspecting it")

    @property
    def passes(self) -> tuple:
        """The recorded ``analyze → schedule → plan → plan_comm →
        schedule_comm → lower`` :class:`PassRecord` chain."""
        self._built()
        return self._passes

    def _pass(self, name: str) -> PassRecord:
        self._built()
        for pr in self._passes:
            if pr.name == name:
                return pr
        raise KeyError(name)

    @property
    def plan(self):
        """The planning artifact: a :class:`~repro.core.plan.DistPlan`
        for a block, a :class:`~repro.core.region.RegionPlan` for a
        fused region, per-stage ``(name, DistPlan)`` pairs for a staged
        region."""
        return self._pass("plan").output

    @property
    def boundaries(self) -> tuple:
        """The planned boundary exchanges (empty for single blocks and
        staged regions — nothing crosses a fused boundary there)."""
        return self._pass("plan_comm").output

    @property
    def comm_schedule(self):
        """The **schedule_comm** artifact: a
        :class:`~repro.core.comm_schedule.CommSchedule` for fused
        regions (aggregation groups, fused combines, launch accounting);
        ``()`` for single blocks and staged regions."""
        return self._pass("schedule_comm").output

    @property
    def kernel_plan(self):
        """The **pallas** artifact
        (:class:`~repro.core.pallas_lower.KernelPlan`: tile geometry +
        fusion spans) under ``Lowering.PALLAS``; ``None`` otherwise."""
        self._built()
        for pr in self._passes:
            if pr.name == "pallas":
                return pr.output
        return None

    # -- reporting ---------------------------------------------------------

    def report(self) -> str:
        from repro.core import report as report_mod

        self._built()
        return report_mod.render_compiled(self)

    def cost_summary(self) -> dict:
        """Modeled communication totals of the chosen plan."""
        from repro.core import region as region_mod
        from repro.core import report as report_mod

        plan = self.plan
        base = {"lowering": self.options.lowering.value}
        if isinstance(plan, region_mod.RegionPlan):
            out = {
                "kind": "region", **base,
                "comm": plan.comm_mode,
                "planned_wire_bytes": plan.planned_wire_bytes,
                "gather_wire_bytes": plan.gather_wire_bytes,
                "n_elided": plan.n_elided,
                "n_halo": plan.n_halo,
                "n_reshards": plan.n_reshards,
            }
            sched = plan.comm_sched
            if sched is not None:
                out["comm_schedule"] = sched.mode
                out["launches_inline"] = sched.launches_inline
                out["launches_scheduled"] = sched.launches_scheduled
                out["n_hoisted"] = sched.n_hoisted
            return out
        if isinstance(plan, plan_mod.DistPlan):
            _, total = report_mod._comm_breakdown(plan)
            return {"kind": "block", **base, "modeled_bytes": total}
        total = sum(report_mod._comm_breakdown(p)[1] for _, p in plan)
        return {"kind": "region_staged", **base, "modeled_bytes": total,
                "n_loops": len(plan)}
