"""Reduction clause lowering (paper §3.1.3, Table 3).

OMP2MPI initialises the reduction variable with the operation's identity
(0 for ``+``/``-``, 1 for ``*``/``/``) and folds worker partials into the
master copy.  The TPU-native rendition combines chunk partials locally and
crosses devices with the matching collective (``psum``/``pmax``/``pmin``;
``*`` has no dedicated all-reduce, so partials are all-gathered and folded
locally — P scalars, negligible traffic).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

_SUM_OPS = ("+", "-")  # '-' reduces by accumulating partial sums, like OpenMP
_PROD_OPS = ("*", "/")


@dataclasses.dataclass(frozen=True)
class ReductionOp:
    name: str
    identity: float
    local_fold: Callable[[Any, int], Any]     # fold an axis of partials
    pairwise: Callable[[Any, Any], Any]       # combine two partials
    collective: str                           # "psum" | "pmax" | "pmin" | "gather"


def _fold_sum(x, axis):
    return jnp.sum(x, axis=axis)


def _fold_prod(x, axis):
    return jnp.prod(x, axis=axis)


def _fold_max(x, axis):
    return jnp.max(x, axis=axis)


def _fold_min(x, axis):
    return jnp.min(x, axis=axis)


_REDUCTIONS: dict[str, ReductionOp] = {
    "+": ReductionOp("+", 0.0, _fold_sum, lambda a, b: a + b, "psum"),
    "-": ReductionOp("-", 0.0, _fold_sum, lambda a, b: a + b, "psum"),
    "*": ReductionOp("*", 1.0, _fold_prod, lambda a, b: a * b, "gather"),
    "/": ReductionOp("/", 1.0, _fold_prod, lambda a, b: a * b, "gather"),
    "max": ReductionOp("max", -jnp.inf, _fold_max, jnp.maximum, "pmax"),
    "min": ReductionOp("min", jnp.inf, _fold_min, jnp.minimum, "pmin"),
}


def get_reduction(op: str) -> ReductionOp:
    try:
        return _REDUCTIONS[op]
    except KeyError:
        raise ValueError(
            f"unsupported reduction op {op!r}; supported: {sorted(_REDUCTIONS)}"
        ) from None


def identity_like(op: ReductionOp, value: Any):
    """Identity element broadcast to ``value``'s shape/dtype (paper: the
    starting value of the reduced variable)."""
    dtype = jnp.result_type(value)
    if op.name in ("max", "min") and not jnp.issubdtype(dtype, jnp.floating):
        info = jnp.iinfo(dtype)
        ident = info.min if op.name == "max" else info.max
    else:
        ident = op.identity
    return jnp.full(jnp.shape(value), ident, dtype=dtype)


def cross_device_combine(op: ReductionOp, partial: Any,
                         axis_name: str | tuple):
    """Combine per-device partials across ``axis_name`` (one mesh axis,
    or a tuple of axes for a 2-D mesh) inside shard_map."""
    if op.collective == "psum":
        return jax.lax.psum(partial, axis_name)
    if op.collective == "pmax":
        return jax.lax.pmax(partial, axis_name)
    if op.collective == "pmin":
        return jax.lax.pmin(partial, axis_name)
    # '*' (and '/'): all-gather the scalar partials and fold locally.
    names = axis_name if isinstance(axis_name, tuple) else (axis_name,)
    out = partial
    for nm in names:
        out = op.local_fold(jax.lax.all_gather(out, nm), 0)  # (P, ...)
    return out
