"""Pipeline parallelism (GPipe-style) over a mesh axis.

The missing letter in DP/TP/PP/EP/SP: stages of a layer stack live on
successive devices of one mesh axis; microbatches stream through, and
activations hop stage→stage via ``collective_permute`` — the exact
communication pattern the paper's master/worker dispatch becomes when the
"iterations" are *pipeline slots* instead of loop chunks.

Design (SPMD, differentiable):

* the stage body runs on EVERY device each tick (lockstep SPMD); a
  device's output is only *consumed* once the wavefront reaches it, so
  the warm-up/drain ticks compute on placeholder data (the standard
  bubble, (S-1)/(M+S-1) of the ticks);
* the activation buffer rotates with a single ``ppermute`` per tick;
* outputs are collected from the last stage and exposed through an
  ``out_specs=P(axis)`` stack (caller takes the last-stage row);
* ``jax.grad`` differentiates straight through (scan + ppermute are
  both differentiable), giving 1F1B-equivalent memory behaviour when
  combined with ``jax.checkpoint`` on the stage body.

Use :func:`pipeline_apply` inside an existing ``shard_map``; use
:func:`make_pipeline` to build a jitted end-to-end callable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


def pipeline_apply(stage_fn, stage_params, x_micro, *, axis: str,
                   num_stages: int, checkpoint: bool = True):
    """Run ``num_stages`` pipeline stages over microbatches.

    Call INSIDE shard_map over ``axis`` (device s holds stage s).

    stage_fn: (params, x) -> y with x.shape == y.shape (activations hop
      between stages, so stage boundaries share one activation shape).
    stage_params: THIS device's stage parameters.
    x_micro: (M, mb, ...) microbatched input (replicated across stages).

    Returns (M, mb, ...) outputs valid on the LAST stage (zeros
    elsewhere); combine with out_specs=P(axis) + take the last row, or
    psum if a replicated result is wanted.
    """
    m = x_micro.shape[0]
    s_idx = jax.lax.axis_index(axis)
    ticks = m + num_stages - 1
    buf0 = jnp.zeros_like(x_micro[0])
    body = stage_fn
    if checkpoint:
        body = jax.checkpoint(stage_fn)

    def tick(buf, t):
        # stage 0 ingests microbatch t (clamped during drain)
        feed = jax.lax.dynamic_index_in_dim(
            x_micro, jnp.minimum(t, m - 1), 0, keepdims=False)
        cur = jnp.where(s_idx == 0, feed, buf)
        out = body(stage_params, cur)
        # hop to the next stage (device s -> s+1)
        perm = [(i, i + 1) for i in range(num_stages - 1)]
        nxt = jax.lax.ppermute(out, axis, perm)
        # last stage emits microbatch (t - (S-1)) at tick t
        emit = jnp.where(s_idx == num_stages - 1, out,
                         jnp.zeros_like(out))
        return nxt, emit

    _, emits = jax.lax.scan(tick, buf0, jnp.arange(ticks))
    return emits[num_stages - 1:]               # (M, mb, ...)


def make_pipeline(stage_fn, mesh: Mesh, *, axis: str,
                  checkpoint: bool = True):
    """Jitted end-to-end pipeline.

    Returns ``run(stacked_params, x_micro) -> (M, mb, ...)`` where
    ``stacked_params`` has a leading stage dim sharded over ``axis``
    and ``x_micro`` is the (M, mb, ...) global microbatched input.
    """
    num_stages = mesh.shape[axis]

    def inner(stacked_params, x_micro):
        my_params = jax.tree_util.tree_map(lambda t: t[0], stacked_params)
        outs = pipeline_apply(stage_fn, my_params, x_micro, axis=axis,
                              num_stages=num_stages,
                              checkpoint=checkpoint)
        return outs[None]                        # (1, M, mb, ...)

    def run(stacked_params, x_micro):
        specs_in = (
            jax.tree_util.tree_map(lambda _: P(axis), stacked_params),
            P(),
        )
        out = shard_map(
            inner, mesh=mesh, in_specs=specs_in, out_specs=P(axis),
        )(stacked_params, x_micro)
        return out[-1]                           # last stage's emissions

    return jax.jit(run)
