"""Loop-nest IR — the single owner of iteration-space geometry.

The paper's pipeline (§3.1.2–3.1.4) reasons about ONE canonical loop; its
benchmark suite (matrix multiply, Jacobi stencils) is dominated by 2-D
kernels expressed as ``collapse(2)`` nests.  This module introduces the
:class:`LoopNest` IR that every lowering layer consumes:

* **axes** — one canonicalised :class:`~repro.core.loop.LoopInfo` per
  induction variable (rank 1 or 2), each with its own schedule-derived
  :class:`~repro.core.schedule.ChunkPlan`;
* **affine access maps** — :class:`NestAffine` tracks indices affine in
  *several* iterators (``a_i*i + a_j*j + b``), the rank-general
  analogue of :class:`repro.core.context.Affine`;
* **window geometry** — where chunk ``j``'s read window lives in the
  buffer (``window_rows`` / ``device_window_rows`` / ``window_extent``),
  shared by the per-loop staging path, the fused region path and the
  communication cost model so all three build byte-identical slabs;
* **slab slicing** — the chunk-cyclic pad/reshape staging
  (:func:`pad_reshape`, :func:`halo_slabs`, :func:`halo_slabs2`,
  :func:`unpad_flat`) and the in-shard_map local slicing
  (:func:`local_slabs`, :func:`local_slabs2`);
* **env substitution** — :class:`ShiftedWindow` serves ``x[i]`` /
  ``x[i, j]``-style body reads from a local slab with per-axis offsets.

Before this module the 1-D versions of these helpers were duplicated
three ways (``transform._halo_slabs`` / ``region._local_slabs`` /
``comm`` window geometry); they now live here alone and
:mod:`repro.core.transform`, :mod:`repro.core.region` and
:mod:`repro.core.comm` all import them.

Chunk-cyclic layout (per axis): iteration ``k`` lives in chunk
``k // c``; chunk ``j`` executes on device ``j % P`` as local chunk
``j // P``; the padded axis reshapes to ``(n_loc, P, c)`` whose middle
dim IS the device axis.  A rank-2 nest composes two such layouts: the
buffer reshapes to ``(n_i, P_i, c_i, n_j, P_j, c_j, *rest)`` over a 2-D
``(i, j)`` mesh.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.loop import LoopInfo, LoopNotCanonical, analyze_loop


# ---------------------------------------------------------------------------
# The nest IR
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LoopNest:
    """A rank-1 or rank-2 canonical loop nest.

    Axis ``d`` iterates ``i_d = start_d + k_d * step_d`` for
    ``k_d in [0, trip_d)``; the iteration space is the cross product
    (the ``collapse(2)`` semantics: one flat parallel region over
    ``trip_0 * trip_1`` iterations).
    """

    axes: tuple[LoopInfo, ...]

    def __post_init__(self) -> None:
        if not 1 <= len(self.axes) <= 2:
            raise LoopNotCanonical(
                f"loop nests of rank {len(self.axes)} are not supported "
                "(collapse(2) is the maximum)")

    @property
    def rank(self) -> int:
        return len(self.axes)

    @property
    def trip_counts(self) -> tuple[int, ...]:
        return tuple(ax.trip_count for ax in self.axes)

    @property
    def total_trip(self) -> int:
        n = 1
        for ax in self.axes:
            n *= ax.trip_count
        return n

    @classmethod
    def from_program(cls, program) -> "LoopNest":
        """Build the nest from a :class:`~repro.core.pragma.ParallelFor`
        (the Loop Analysis stage, per axis)."""
        return cls(tuple(analyze_loop(s, e, t) for s, e, t in program.bounds))


@dataclasses.dataclass(frozen=True)
class NestAffine:
    """Index affine in the nest iterators: ``sum_d coeffs[d]*i_d + b``."""

    coeffs: tuple[int, ...]
    b: int

    def __add__(self, other: "NestAffine") -> "NestAffine":
        return NestAffine(
            tuple(a + o for a, o in zip(self.coeffs, other.coeffs)),
            self.b + other.b)

    def __sub__(self, other: "NestAffine") -> "NestAffine":
        return NestAffine(
            tuple(a - o for a, o in zip(self.coeffs, other.coeffs)),
            self.b - other.b)

    def scale(self, k: int) -> "NestAffine":
        return NestAffine(tuple(a * k for a in self.coeffs), self.b * k)

    @property
    def is_const(self) -> bool:
        return all(a == 0 for a in self.coeffs)

    def k_space(self, nest: LoopNest) -> "NestAffine":
        """Rebase from iterator space to iteration-number space:
        ``i_d = start_d + k_d*step_d`` substituted per axis."""
        coeffs = tuple(a * ax.step for a, ax in zip(self.coeffs, nest.axes))
        b = self.b + sum(a * ax.start
                         for a, ax in zip(self.coeffs, nest.axes))
        return NestAffine(coeffs, b)

    def unit_axis(self) -> int | None:
        """The single nest axis this map follows with coefficient 1
        (``k_d + b``), or None if it is not such a unit map."""
        hits = [d for d, a in enumerate(self.coeffs) if a != 0]
        if len(hits) == 1 and self.coeffs[hits[0]] == 1:
            return hits[0]
        return None

    def __repr__(self) -> str:
        names = ("i", "j", "k")
        terms = [("" if a == 1 else f"{a}*") + names[d]
                 for d, a in enumerate(self.coeffs) if a != 0]
        if not terms:
            return str(self.b)
        s = "+".join(terms)
        return s if self.b == 0 else f"{s}{self.b:+d}"


# ---------------------------------------------------------------------------
# Window geometry (single source of truth; comm re-exports these so the
# cost model, the staging path and the fused path stay byte-identical)
# ---------------------------------------------------------------------------


def window_extent(chunk: int, halo: tuple[int, int]) -> int:
    """Width of one chunk's read window: ``chunk + (b_max - b_min)``."""
    b_min, b_max = halo
    return chunk + (b_max - b_min)


def slot_chunk_ids(ch) -> np.ndarray:
    """Global chunk id at each slot position.  Identity for the cyclic
    deal; the plan's ``slot_map`` permutation for straggler-weighted
    schedules (sentinel slots point at a padding chunk whose iterations
    are all masked)."""
    if ch.slot_map is not None:
        return np.asarray(ch.slot_map, dtype=np.int64)
    return np.arange(ch.num_chunks, dtype=np.int64)


def restore_chunk_order(ch) -> np.ndarray | None:
    """Slot index of every *real* chunk, in global chunk order — the
    inverse of ``slot_map`` used to reassemble outputs.  ``None`` for
    the cyclic deal (a plain reshape already restores order)."""
    if ch.slot_map is None:
        return None
    inv = np.empty(ch.real_chunks, dtype=np.int64)
    for s, j in enumerate(ch.slot_map):
        if j < ch.real_chunks:
            inv[j] = s
    return inv


def window_rows(ch, halo: tuple[int, int], nrows: int) -> np.ndarray:
    """Static (jit-level) row indices of every chunk's read window:
    ``(num_chunks, width)``, clipped in-bounds (out-of-range rows are
    only ever consumed by masked padding lanes).  Rows come out in
    *slot* order so the trailing ``(n_loc, P, ...)`` reshape always
    places a device's slabs on the device axis, weighted or not."""
    b_min, _ = halo
    width = window_extent(ch.chunk, halo)
    rows = (slot_chunk_ids(ch)[:, None] * ch.chunk + b_min
            + np.arange(width)[None, :])
    return np.clip(rows, 0, max(0, nrows - 1))


def device_window_rows(ch, halo: tuple[int, int], device_index,
                       nrows: int):
    """Traced (in-shard_map) row indices of THIS device's chunk windows:
    ``(local_chunks, width)`` — the fused analogue of
    :func:`window_rows` for slicing a replicated buffer locally."""
    b_min, _ = halo
    width = window_extent(ch.chunk, halo)
    base = (jnp.arange(ch.local_chunks, dtype=jnp.int32)[:, None]
            * ch.num_devices + device_index) * ch.chunk
    rows = base + b_min + jnp.arange(width, dtype=jnp.int32)[None, :]
    return jnp.clip(rows, 0, max(0, nrows - 1))


# ---------------------------------------------------------------------------
# Slab slicing — jit-level staging (chunk-cyclic pad/reshape)
# ---------------------------------------------------------------------------


def pad_reshape(x, ch):
    """(T, *rest) -> (n_loc, P, c, *rest) chunk-cyclic (or, with a
    weighted plan, slot-ordered) layout."""
    pad = ch.padded_trip - x.shape[0]
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
    if ch.slot_map is not None:
        chunks = x.reshape((ch.num_chunks, ch.chunk) + x.shape[1:])
        x = chunks[slot_chunk_ids(ch)].reshape(
            (ch.padded_trip,) + x.shape[1:])
    return x.reshape((ch.local_chunks, ch.num_devices, ch.chunk) + x.shape[1:])


def halo_slabs(x, ch, halo: tuple[int, int]):
    """(N, *rest) -> (n_loc, P, c + halo_width, *rest): each chunk's slab
    carries its read window ``[j*c + b_min, (j+1)*c - 1 + b_max]`` — the
    stencil halo exchange (rows duplicated at chunk edges)."""
    width = window_extent(ch.chunk, halo)
    rows = window_rows(ch, halo, x.shape[0])
    slab = x[rows]                                   # (K', width, *rest)
    return slab.reshape((ch.local_chunks, ch.num_devices, width)
                        + x.shape[1:])


def halo_slabs2(x, chs, halos):
    """(N0, N1, *rest) -> (n_i, P_i, w_i, n_j, P_j, w_j, *rest): the
    rank-2 staging — each (chunk_i, chunk_j) pair's slab carries its 2-D
    read window (per-axis halo rows/columns duplicated at chunk edges)."""
    ch_i, ch_j = chs
    halo_i, halo_j = halos
    rows_i = window_rows(ch_i, halo_i, x.shape[0])   # (K_i, w_i)
    rows_j = window_rows(ch_j, halo_j, x.shape[1])   # (K_j, w_j)
    slab = x[rows_i[:, :, None, None], rows_j[None, None, :, :]]
    return slab.reshape(
        (ch_i.local_chunks, ch_i.num_devices, rows_i.shape[1],
         ch_j.local_chunks, ch_j.num_devices, rows_j.shape[1])
        + x.shape[2:])


def unpad_flat(slabs, ch, t: int):
    """(n_loc, P, c, *rest) -> (T, *rest).  With a weighted plan the
    slabs sit in slot order; the inverse slot gather puts the real
    chunks back in global order before the flatten."""
    inv = restore_chunk_order(ch)
    if inv is None:
        flat = slabs.reshape((ch.padded_trip,) + slabs.shape[3:])
        return flat[:t]
    chunks = slabs.reshape((ch.num_chunks, ch.chunk) + slabs.shape[3:])
    flat = chunks[inv].reshape((len(inv) * ch.chunk,) + slabs.shape[3:])
    return flat[:t]


def unpad_flat2(slabs, chs, trips):
    """(n_i, P_i, c_i, n_j, P_j, c_j, *rest) -> (T_i, T_j, *rest)."""
    ch_i, ch_j = chs
    t_i, t_j = trips
    inv_i = restore_chunk_order(ch_i)
    inv_j = restore_chunk_order(ch_j)
    if inv_i is None and inv_j is None:
        flat = slabs.reshape((ch_i.padded_trip, ch_j.padded_trip)
                             + slabs.shape[6:])
        return flat[:t_i, :t_j]
    idx_i = inv_i if inv_i is not None else np.arange(ch_i.num_chunks)
    idx_j = inv_j if inv_j is not None else np.arange(ch_j.num_chunks)
    chunks = slabs.reshape(
        (ch_i.num_chunks, ch_i.chunk, ch_j.num_chunks, ch_j.chunk)
        + slabs.shape[6:])
    chunks = jnp.take(jnp.take(chunks, idx_i, axis=0), idx_j, axis=2)
    flat = chunks.reshape((len(idx_i) * ch_i.chunk,
                           len(idx_j) * ch_j.chunk) + slabs.shape[6:])
    return flat[:t_i, :t_j]


# ---------------------------------------------------------------------------
# Slab slicing — in-shard_map local windows (pure local indexing of a
# replicated buffer; the fused analogue of the staging above)
# ---------------------------------------------------------------------------


def local_slabs(x, ch, halo: tuple[int, int], device_index):
    """Slice THIS device's chunk slabs out of a replicated buffer:
    ``(n_loc, width, *rest)`` — same window geometry as
    :func:`halo_slabs`, computed per device inside the shard_map."""
    rows = device_window_rows(ch, halo, device_index, x.shape[0])
    return jnp.take(x, rows, axis=0)


def local_slabs2(x, chs, halos, device_indices):
    """Rank-2 :func:`local_slabs`: ``(n_i, w_i, n_j, w_j, *rest)``."""
    ch_i, ch_j = chs
    halo_i, halo_j = halos
    d_i, d_j = device_indices
    rows_i = device_window_rows(ch_i, halo_i, d_i, x.shape[0])
    rows_j = device_window_rows(ch_j, halo_j, d_j, x.shape[1])
    out = jnp.take(x, rows_i, axis=0)                # (n_i, w_i, N1, *rest)
    return jnp.take(out, rows_j, axis=2)             # (n_i, w_i, n_j, w_j, *)


# ---------------------------------------------------------------------------
# Tile derivation — Pallas lowering geometry (consumed by
# repro.core.pallas_lower; lives here because the chunk-cyclic layout
# above is the single owner of iteration-space geometry)
# ---------------------------------------------------------------------------


# Minimum second-to-minor tile extent per element width (the TPU packing
# rule: 8 sublanes of 32-bit lanes, narrower dtypes pack 2x/4x deeper).
_SUBLANE_BY_ITEMSIZE = {8: 8, 4: 8, 2: 16, 1: 32}

# Lanes per kernel tile are capped so one tile's window + values stay
# comfortably inside VMEM whatever the chunk size.
MAX_TILE_LANES = 256


def sublane_for(dtype) -> int:
    """Minimum tile granularity (second-to-minor extent) for ``dtype``."""
    return _SUBLANE_BY_ITEMSIZE.get(np.dtype(dtype).itemsize, 8)


@dataclasses.dataclass(frozen=True)
class AxisTiles:
    """Tiling of one axis's chunk lanes for the Pallas backend.

    A chunk's ``chunk`` lanes are covered by ``n_tiles`` tiles of
    ``tile`` lanes each; the last tile's ``masked_lanes`` trailing lanes
    are padding (their iteration numbers clamp to the final in-bounds
    iteration and the produced garbage is sliced off after the kernel,
    exactly like the chunk-cyclic trip padding).
    """

    chunk: int
    tile: int
    n_tiles: int
    padded: int

    @property
    def masked_lanes(self) -> int:
        return self.padded - self.chunk

    def cover(self) -> list[tuple[int, int]]:
        """``(start_lane, valid_lanes)`` per tile — a partition of
        ``[0, chunk)`` with no overlap and no gap."""
        return [(ti * self.tile, min(self.tile, self.chunk - ti * self.tile))
                for ti in range(self.n_tiles)]


def derive_axis_tiles(chunk: int, dtype,
                      max_tile: int = MAX_TILE_LANES) -> AxisTiles:
    """Tile one axis's chunk: ``tile`` is the chunk rounded up to the
    dtype's sublane multiple, capped at ``max_tile``; remainder lanes of
    the last tile are masked."""
    sub = sublane_for(dtype)
    tile = min(max(int(chunk), 1), int(max_tile))
    tile = -(-tile // sub) * sub
    n_tiles = max(1, -(-int(chunk) // tile))
    return AxisTiles(chunk=int(chunk), tile=tile, n_tiles=n_tiles,
                     padded=n_tiles * tile)


# ---------------------------------------------------------------------------
# Env substitution: sliced-read service from the local slab
# ---------------------------------------------------------------------------


class SubstitutionFailed(Exception):
    pass


class ShiftedWindow:
    """Stands in for a shared buffer whose accesses are ``x[i]`` /
    ``x[i, j]``-style unit-stride reads on the leading axes; serves them
    from the local chunk window instead.

    ``offsets[d]`` is the global position held by window row 0 of axis
    ``d``: reading ``x[a, b]`` returns
    ``window[a - offsets[0], b - offsets[1]]``.  Axes beyond
    ``len(offsets)`` pass through untouched (whole-axis slices).
    """

    def __init__(self, window, offsets: tuple, virtual_shape, dtype):
        self._win = window
        self._offsets = tuple(offsets)
        self.shape = tuple(virtual_shape)
        self.dtype = dtype
        self.ndim = len(self.shape)

    def __getitem__(self, idx):
        idx = idx if isinstance(idx, tuple) else (idx,)
        r = len(self._offsets)
        if len(idx) < r:
            raise SubstitutionFailed(
                f"sliced-read substitution needs {r} leading indices, "
                f"got {len(idx)}")
        out = self._win
        for d, (ix, off) in enumerate(zip(idx[:r], self._offsets)):
            out = jax.lax.dynamic_index_in_dim(
                out, jnp.asarray(ix - off, jnp.int32), 0, keepdims=False)
        rest = tuple(idx[r:])
        return out[rest] if rest else out

    def __len__(self):
        return self.shape[0]

    def _no(self, *a, **k):  # pragma: no cover - guard path
        raise SubstitutionFailed(
            "sliced-read substitution saw a non-getitem use; this buffer "
            "should have been classified as a whole-array read"
        )

    __add__ = __radd__ = __mul__ = __rmul__ = __sub__ = __rsub__ = _no
    __truediv__ = __rtruediv__ = __matmul__ = __rmatmul__ = _no
    __neg__ = __pow__ = __array__ = _no
