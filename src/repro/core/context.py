"""Context Analysis (paper §3.1.1).

OMP2MPI walks the Mercurium AST to classify every shared variable used in
an OpenMP block as IN (read, never written), OUT (written, consumed after
the block) or INOUT (both), and works out *where* the parallel iterator
appears in each array access (the "linear first-dimension" rule of
§3.1.3).  The JAX analogue walks the **jaxpr** of the loop body:

* reads are recovered from how each ``env`` buffer's invar is consumed —
  an invar whose every use is a ``dynamic_slice`` whose leading start
  index is an *affine* function of the iterator is a sliced read
  (``x[a*i+b]``); any other use makes it a whole-array read;
* writes are the declared :class:`~repro.core.pragma.At`/``Put``/``Red``
  updates; ``At`` indices are checked for affinity by symbolic affine
  propagation through the jaxpr (add/sub/mul/neg/convert chains seeded at
  the iterator invar).

The affine tracker also understands the negative-index wrap pattern jnp
emits for ``x[i]`` (``select_n(i < 0, i, i + dim)``): assuming a
non-negative iteration space it resolves to the raw affine index.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Mapping

import jax
import jax.numpy as jnp
from jax.extend import core as jcore

from repro.core import pragma
from repro.core.loop import LoopInfo, LoopNotCanonical
from repro.core.nest import LoopNest, NestAffine


# ---------------------------------------------------------------------------
# Affine expressions over the loop iterator
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Affine:
    """``a * i + b`` with static integer coefficients."""

    a: int
    b: int

    def __add__(self, other: "Affine") -> "Affine":
        return Affine(self.a + other.a, self.b + other.b)

    def __sub__(self, other: "Affine") -> "Affine":
        return Affine(self.a - other.a, self.b - other.b)

    def scale(self, k: int) -> "Affine":
        return Affine(self.a * k, self.b * k)

    @property
    def is_const(self) -> bool:
        return self.a == 0

    def __repr__(self) -> str:
        if self.a == 0:
            return str(self.b)
        s = "i" if self.a == 1 else f"{self.a}*i"
        return s if self.b == 0 else f"{s}{self.b:+d}"


def _literal_int(x: Any) -> int | None:
    try:
        v = int(x)
    except (TypeError, ValueError):
        return None
    if jnp.ndim(x) != 0:
        return None
    return v


def _literal_affine(x: Any) -> Affine | None:
    v = _literal_int(x)
    return None if v is None else Affine(0, v)


class _AffineEnv:
    """Symbolic affine propagation over jaxpr equations.

    Works over any affine representation supporting ``+``/``-``/
    ``scale``/``is_const``/``.b`` — :class:`Affine` for a single
    iterator, :class:`~repro.core.nest.NestAffine` for a loop nest.
    ``seeds`` maps iterator invars to their affine seeds; ``const``
    builds a constant of the same representation.
    """

    def __init__(self, seeds: Mapping[Any, Any],
                 const: Any = None) -> None:
        self._map: dict[Any, Any] = dict(seeds)
        self._const = const or (lambda v: Affine(0, v))
        self._producer: dict[Any, Any] = {}

    def lookup(self, atom):
        if isinstance(atom, jcore.Literal):
            v = _literal_int(atom.val)
            return None if v is None else self._const(v)
        return self._map.get(atom)

    def process(self, eqn) -> None:
        prim = eqn.primitive.name
        outs = eqn.outvars
        for ov in outs:
            self._producer[ov] = eqn
        if len(outs) != 1:
            return
        out = outs[0]
        # Only scalar integer-ish values can be loop indices.
        if getattr(out.aval, "shape", None) not in ((),):
            return
        ins = [self.lookup(v) for v in eqn.invars]
        res: Affine | None = None
        if prim == "add" and None not in ins:
            res = ins[0] + ins[1]
        elif prim == "sub" and None not in ins:
            res = ins[0] - ins[1]
        elif prim == "mul" and None not in ins:
            if ins[0].is_const:
                res = ins[1].scale(ins[0].b)
            elif ins[1].is_const:
                res = ins[0].scale(ins[1].b)
        elif prim == "neg" and ins[0] is not None:
            res = ins[0].scale(-1)
        elif prim in ("convert_element_type", "copy", "squeeze", "stop_gradient"):
            res = ins[0]
        elif prim == "max" and None not in ins:
            # clamp(i, 0) pattern: max(i, 0) with nonneg iteration space.
            if ins[0].is_const and ins[0].b == 0:
                res = ins[1]
            elif ins[1].is_const and ins[1].b == 0:
                res = ins[0]
        elif prim == "select_n" and len(eqn.invars) == 3:
            res = self._wrap_pattern(eqn)
        if res is not None:
            self._map[out] = res

    def _wrap_pattern(self, eqn) -> Affine | None:
        """Resolve ``select_n(v < 0, v, v + dim)`` → affine(v)."""
        pred, case_f, case_t = eqn.invars
        pred_eqn = self._producer.get(pred)
        if pred_eqn is None or pred_eqn.primitive.name != "lt":
            return None
        lhs, rhs = pred_eqn.invars
        rhs_aff = self.lookup(rhs)
        if rhs_aff is None or not rhs_aff.is_const or rhs_aff.b != 0:
            return None
        # The non-negative branch is the lt's lhs; pick whichever case is it.
        for case in (case_f, case_t):
            if case is lhs:
                return self.lookup(case)
        # jnp sometimes converts dtype between lt and select; fall back to
        # the case whose affine matches lhs's affine exactly.
        lhs_aff = self.lookup(lhs)
        if lhs_aff is None:
            return None
        for case in (case_f, case_t):
            if self.lookup(case) == lhs_aff:
                return lhs_aff
        return None


# ---------------------------------------------------------------------------
# Classification results
# ---------------------------------------------------------------------------


class ReadKind(enum.Enum):
    NONE = "none"
    SLICED = "sliced"    # every use is x[a*i+b] on the leading dim
    STENCIL = "stencil"  # several unit-stride maps x[i+b0..i+bk] (halo)
    WHOLE = "whole"


class WriteKind(enum.Enum):
    NONE = "none"
    AT = "at"
    PUT = "put"
    RED = "red"


class VarClass(enum.Enum):
    UNUSED = "unused"
    IN = "in"
    OUT = "out"
    INOUT = "inout"
    REDUCTION = "reduction"


@dataclasses.dataclass
class ReadInfo:
    kind: ReadKind
    affine: Affine | None = None          # leading-dim index map for SLICED
    affines: list | None = None           # all maps for STENCIL reads
    # rank-2 nests: number of leading buffer axes read through unit
    # slices, and the distinct per-axis NestAffine index tuples
    slice_ndim: int = 0
    accesses: tuple | None = None


@dataclasses.dataclass
class WriteInfo:
    kind: WriteKind
    affine: Affine | None = None          # index map for AT (None: non-affine)
    value_shape: tuple[int, ...] = ()
    value_dtype: Any = None
    reduction_op: str | None = None
    # rank-2 nests: per-buffer-axis NestAffine maps of the At index
    # tuple (entries None where non-affine)
    affines2: tuple | None = None


@dataclasses.dataclass
class VarInfo:
    name: str
    read: ReadInfo
    write: WriteInfo
    klass: VarClass
    shape: tuple[int, ...] = ()
    dtype: Any = None


@dataclasses.dataclass
class ContextInfo:
    """Output of the Context Analysis stage for one parallel block."""

    vars: dict[str, VarInfo]
    env_keys: list[str]
    update_keys: list[str]

    def by_class(self, klass: VarClass) -> list[str]:
        return [k for k, v in self.vars.items() if v.klass == klass]


# ---------------------------------------------------------------------------
# Analysis driver
# ---------------------------------------------------------------------------


def _aval_of(x: Any) -> jax.ShapeDtypeStruct:
    if isinstance(x, jax.ShapeDtypeStruct):
        return x
    arr = jnp.asarray(x)
    return jax.ShapeDtypeStruct(arr.shape, arr.dtype)


def analyze_context(program: pragma.ParallelFor, env: Mapping[str, Any],
                    loop: LoopInfo | LoopNest) -> ContextInfo:
    """Run the Context Analysis stage: trace the body once with an abstract
    iterator per nest axis, then classify every env buffer from the jaxpr."""
    if isinstance(loop, LoopNest):
        if loop.rank == 2:
            return _analyze_context2(program, env, loop)
        loop = loop.axes[0]
    env_keys = list(env.keys())
    env_avals = {k: _aval_of(v) for k, v in env.items()}

    def traced(i, env_arrays):
        return program.body(i, env_arrays)

    i_aval = jax.ShapeDtypeStruct((), jnp.int32)
    closed, out_shape = jax.make_jaxpr(traced, return_shape=True)(i_aval, env_avals)
    jaxpr = closed.jaxpr

    # --- map env keys to invars -------------------------------------------
    # Dicts flatten in sorted-key order; each env value must be one array.
    env_leaves, _ = jax.tree_util.tree_flatten(env_avals)
    n_env = len(env_leaves)
    sorted_keys = sorted(env_avals.keys())
    if n_env != len(sorted_keys):
        raise LoopNotCanonical("env values must be single arrays (no nested pytrees)")
    if len(jaxpr.invars) != 1 + n_env:
        raise LoopNotCanonical(
            "body must take (i, env) with env a flat dict of arrays; got "
            f"{len(jaxpr.invars)} invars for {n_env} env leaves"
        )
    iter_var = jaxpr.invars[0]
    var_of_key = {k: jaxpr.invars[1 + pos] for pos, k in enumerate(sorted_keys)}
    key_of_var = {id(v): k for k, v in var_of_key.items()}

    # --- affine propagation + read usage scan ------------------------------
    aff = _AffineEnv({iter_var: Affine(1, 0)})
    # read bookkeeping: key -> list of (eqn, affine-or-None) slice uses,
    # plus a flag for non-slice uses.
    sliced_uses: dict[str, list[Affine | None]] = {k: [] for k in env_keys}
    whole_use: dict[str, bool] = {k: False for k in env_keys}

    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        for pos, iv in enumerate(eqn.invars):
            key = key_of_var.get(id(iv))
            if key is None:
                continue
            if prim == "dynamic_slice" and pos == 0:
                idx_atoms = eqn.invars[1:]
                sizes = eqn.params["slice_sizes"]
                shape = env_avals[key].shape
                lead = aff.lookup(idx_atoms[0]) if idx_atoms else None
                rest_ok = all(
                    (a := aff.lookup(at)) is not None and a.is_const
                    for at in idx_atoms[1:]
                )
                if (
                    lead is not None
                    and sizes
                    and sizes[0] == 1
                    and rest_ok
                    and len(shape) == len(sizes)
                ):
                    sliced_uses[key].append(lead)
                else:
                    whole_use[key] = True
            else:
                whole_use[key] = True
        aff.process(eqn)

    # --- write classification from the returned update structure -----------
    flat_shapes, out_tree = jax.tree_util.tree_flatten(out_shape)
    positions = jax.tree_util.tree_unflatten(out_tree, list(range(len(flat_shapes))))
    outvars = jaxpr.outvars
    if not isinstance(positions, dict):
        raise LoopNotCanonical("body must return a dict of omp updates")

    writes: dict[str, WriteInfo] = {}
    for key, upd in positions.items():
        if isinstance(upd, pragma.At):
            idx_pos, val_pos = upd.idx, upd.value
            idx_atom = outvars[idx_pos]
            write_aff = (
                _literal_affine(idx_atom.val)
                if isinstance(idx_atom, jcore.Literal)
                else aff.lookup(idx_atom)
            )
            vshape = flat_shapes[val_pos]
            writes[key] = WriteInfo(
                WriteKind.AT,
                affine=write_aff,
                value_shape=tuple(vshape.shape),
                value_dtype=vshape.dtype,
            )
        elif isinstance(upd, pragma.Put):
            vshape = flat_shapes[upd.value]
            writes[key] = WriteInfo(
                WriteKind.PUT,
                value_shape=tuple(vshape.shape),
                value_dtype=vshape.dtype,
            )
        elif isinstance(upd, pragma.Red):
            if key not in program.reduction:
                raise LoopNotCanonical(
                    f"omp.red() for {key!r} without a reduction clause "
                    "(paper: reductions must be declared with reduction(op: var))"
                )
            vshape = flat_shapes[upd.value]
            writes[key] = WriteInfo(
                WriteKind.RED,
                value_shape=tuple(vshape.shape),
                value_dtype=vshape.dtype,
                reduction_op=program.reduction[key],
            )
        else:
            raise LoopNotCanonical(
                f"update for {key!r} must be omp.at/omp.put/omp.red, got "
                f"{type(upd).__name__}"
            )

    for key in program.reduction:
        if key in writes and writes[key].kind != WriteKind.RED:
            raise LoopNotCanonical(
                f"{key!r} is declared as a reduction but written with "
                f"{writes[key].kind.value}"
            )

    # --- assemble per-variable classification ------------------------------
    infos: dict[str, VarInfo] = {}
    all_keys = list(env_keys) + [k for k in writes if k not in env_avals]
    for key in all_keys:
        if key in env_avals:
            shape, dtype = env_avals[key].shape, env_avals[key].dtype
        else:
            # Reduction outputs may be fresh (not pre-existing in env).
            w = writes[key]
            shape, dtype = w.value_shape, w.value_dtype
        if key in env_avals and whole_use[key]:
            read = ReadInfo(ReadKind.WHOLE)
        elif key in env_avals and sliced_uses[key]:
            affs = sliced_uses[key]
            if any(a is None for a in affs):
                read = ReadInfo(ReadKind.WHOLE)
            elif len({(a.a, a.b) for a in affs}) == 1:
                read = ReadInfo(ReadKind.SLICED, affs[0])
            elif all(a.a == affs[0].a for a in affs):
                # several unit-stride maps (x[i-1], x[i], x[i+1]):
                # a stencil — distributable with a halo exchange
                uniq = sorted({(a.a, a.b) for a in affs},
                              key=lambda t: t[1])
                read = ReadInfo(ReadKind.STENCIL, affs[0],
                                [Affine(a, b) for a, b in uniq])
            else:
                read = ReadInfo(ReadKind.WHOLE)
        else:
            read = ReadInfo(ReadKind.NONE)

        write = writes.get(key, WriteInfo(WriteKind.NONE))
        if write.kind == WriteKind.RED:
            klass = VarClass.REDUCTION
        elif write.kind == WriteKind.NONE:
            klass = VarClass.IN if read.kind != ReadKind.NONE else VarClass.UNUSED
        elif read.kind == ReadKind.NONE:
            klass = VarClass.OUT
        else:
            klass = VarClass.INOUT
        infos[key] = VarInfo(
            name=key, read=read, write=write, klass=klass,
            shape=tuple(shape), dtype=dtype,
        )

    return ContextInfo(vars=infos, env_keys=env_keys, update_keys=list(writes))


# ---------------------------------------------------------------------------
# Rank-2 nest driver (``collapse=2``)
# ---------------------------------------------------------------------------


def _access_prefix(starts, sizes, shape) -> int:
    """Largest sliced prefix r in {1, 2} this dynamic_slice supports:
    axes d < r are unit slices with affine starts; axes d >= r are
    whole-axis slices (const-0 start) or const unit slices.  0 = neither.
    """
    def suffix_ok(d0: int) -> bool:
        for d in range(d0, len(shape)):
            a = starts[d]
            if a is None:
                return False
            if sizes[d] == shape[d] and a.is_const and a.b == 0:
                continue
            if sizes[d] == 1 and a.is_const:
                continue
            return False
        return True

    for r in (2, 1):
        if len(shape) < r or len(sizes) != len(shape):
            continue
        if all(sizes[d] == 1 and starts[d] is not None for d in range(r)) \
                and suffix_ok(r):
            return r
    return 0


def _analyze_context2(program: pragma.ParallelFor, env: Mapping[str, Any],
                      nest: LoopNest) -> ContextInfo:
    """Context Analysis over a rank-2 nest: the body is traced as
    ``body(i, j, env)`` and every index is tracked as a
    :class:`~repro.core.nest.NestAffine` over both iterators."""
    env_keys = list(env.keys())
    env_avals = {k: _aval_of(v) for k, v in env.items()}

    def traced(i, j, env_arrays):
        return program.body(i, j, env_arrays)

    it_aval = jax.ShapeDtypeStruct((), jnp.int32)
    closed, out_shape = jax.make_jaxpr(traced, return_shape=True)(
        it_aval, it_aval, env_avals)
    jaxpr = closed.jaxpr

    env_leaves, _ = jax.tree_util.tree_flatten(env_avals)
    n_env = len(env_leaves)
    sorted_keys = sorted(env_avals.keys())
    if n_env != len(sorted_keys):
        raise LoopNotCanonical("env values must be single arrays (no nested pytrees)")
    if len(jaxpr.invars) != 2 + n_env:
        raise LoopNotCanonical(
            "collapse=2 body must take (i, j, env) with env a flat dict of "
            f"arrays; got {len(jaxpr.invars)} invars for {n_env} env leaves"
        )
    iter_i, iter_j = jaxpr.invars[0], jaxpr.invars[1]
    var_of_key = {k: jaxpr.invars[2 + pos] for pos, k in enumerate(sorted_keys)}
    key_of_var = {id(v): k for k, v in var_of_key.items()}

    aff = _AffineEnv(
        {iter_i: NestAffine((1, 0), 0), iter_j: NestAffine((0, 1), 0)},
        const=lambda v: NestAffine((0, 0), v))
    # key -> list of (starts-affine-tuple, prefix r) slice uses
    slice_uses: dict[str, list[tuple[tuple, int]]] = {k: [] for k in env_keys}
    whole_use: dict[str, bool] = {k: False for k in env_keys}

    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        for pos, iv in enumerate(eqn.invars):
            key = key_of_var.get(id(iv))
            if key is None:
                continue
            if prim == "dynamic_slice" and pos == 0:
                idx_atoms = eqn.invars[1:]
                sizes = eqn.params["slice_sizes"]
                shape = env_avals[key].shape
                starts = tuple(aff.lookup(at) for at in idx_atoms)
                r = _access_prefix(starts, tuple(sizes), tuple(shape))
                if r:
                    slice_uses[key].append((starts[:r], r))
                else:
                    whole_use[key] = True
            else:
                whole_use[key] = True
        aff.process(eqn)

    # --- write classification ---------------------------------------------
    flat_shapes, out_tree = jax.tree_util.tree_flatten(out_shape)
    positions = jax.tree_util.tree_unflatten(out_tree, list(range(len(flat_shapes))))
    outvars = jaxpr.outvars
    if not isinstance(positions, dict):
        raise LoopNotCanonical("body must return a dict of omp updates")

    def _out_affine(pos: int):
        atom = outvars[pos]
        if isinstance(atom, jcore.Literal):
            v = _literal_int(atom.val)
            return None if v is None else NestAffine((0, 0), v)
        return aff.lookup(atom)

    writes: dict[str, WriteInfo] = {}
    for key, upd in positions.items():
        if isinstance(upd, pragma.At):
            idx = upd.idx if isinstance(upd.idx, tuple) else (upd.idx,)
            if len(idx) != 2:
                raise LoopNotCanonical(
                    f"{key!r}: a collapse=2 write needs omp.at((i, j), v) "
                    f"with a 2-tuple index, got {len(idx)} indices"
                )
            affines2 = tuple(_out_affine(p) for p in idx)
            vshape = flat_shapes[upd.value]
            writes[key] = WriteInfo(
                WriteKind.AT,
                affines2=affines2,
                value_shape=tuple(vshape.shape),
                value_dtype=vshape.dtype,
            )
        elif isinstance(upd, pragma.Put):
            raise LoopNotCanonical(
                f"{key!r}: omp.put is not supported inside a collapse=2 "
                "nest (paper §3.1.3: the block is kept as OpenMP)"
            )
        elif isinstance(upd, pragma.Red):
            if key not in program.reduction:
                raise LoopNotCanonical(
                    f"omp.red() for {key!r} without a reduction clause "
                    "(paper: reductions must be declared with reduction(op: var))"
                )
            vshape = flat_shapes[upd.value]
            writes[key] = WriteInfo(
                WriteKind.RED,
                value_shape=tuple(vshape.shape),
                value_dtype=vshape.dtype,
                reduction_op=program.reduction[key],
            )
        else:
            raise LoopNotCanonical(
                f"update for {key!r} must be omp.at/omp.red in a collapse=2 "
                f"nest, got {type(upd).__name__}"
            )

    for key in program.reduction:
        if key in writes and writes[key].kind != WriteKind.RED:
            raise LoopNotCanonical(
                f"{key!r} is declared as a reduction but written with "
                f"{writes[key].kind.value}"
            )

    # --- assemble per-variable classification ------------------------------
    infos: dict[str, VarInfo] = {}
    all_keys = list(env_keys) + [k for k in writes if k not in env_avals]
    for key in all_keys:
        if key in env_avals:
            shape, dtype = env_avals[key].shape, env_avals[key].dtype
        else:
            w = writes[key]
            shape, dtype = w.value_shape, w.value_dtype
        if key in env_avals and whole_use[key]:
            read = ReadInfo(ReadKind.WHOLE)
        elif key in env_avals and slice_uses[key]:
            uses = slice_uses[key]
            r = min(u[1] for u in uses)
            maps: list[tuple] = []
            seen: set = set()
            degenerate = False
            for starts, _ in uses:
                t = starts[:r]
                # axes beyond the shared prefix must be serveable from a
                # window sharded on the prefix only: const indices
                if any(a is None or not a.is_const for a in starts[r:]):
                    degenerate = True
                    break
                sig = tuple((a.coeffs, a.b) for a in t)
                if sig not in seen:
                    seen.add(sig)
                    maps.append(t)
            if degenerate:
                read = ReadInfo(ReadKind.WHOLE)
            else:
                kind = ReadKind.SLICED if len(maps) == 1 else ReadKind.STENCIL
                read = ReadInfo(kind, slice_ndim=r, accesses=tuple(maps))
        else:
            read = ReadInfo(ReadKind.NONE)

        write = writes.get(key, WriteInfo(WriteKind.NONE))
        if write.kind == WriteKind.RED:
            klass = VarClass.REDUCTION
        elif write.kind == WriteKind.NONE:
            klass = VarClass.IN if read.kind != ReadKind.NONE else VarClass.UNUSED
        elif read.kind == ReadKind.NONE:
            klass = VarClass.OUT
        else:
            klass = VarClass.INOUT
        infos[key] = VarInfo(
            name=key, read=read, write=write, klass=klass,
            shape=tuple(shape), dtype=dtype,
        )

    return ContextInfo(vars=infos, env_keys=env_keys, update_keys=list(writes))
