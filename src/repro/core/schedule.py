"""Workload Distribution — chunking math (paper §3.1.3).

The paper splits the iteration space into chunks and deals them to worker
ranks.  ``schedule(dynamic)`` (the default) over-decomposes by 10x —
``partSize = N / (size-1) / 10`` (Table 2, line 4) — so slow workers get
fewer chunks; ``schedule(static)`` deals one contiguous block per rank in
round-robin; ``guided`` starts large and shrinks.

TPU SPMD adaptation (DESIGN.md §2): there is no demand-driven dispatch, so
every schedule becomes a *deterministic chunk→device assignment*:

* static (no chunk): one contiguous block per device,
* static (chunk=c) / dynamic / guided: cyclic assignment — chunk ``j``
  lands on device ``j % P`` so each device sees a representative sample of
  the iteration space (same load-balancing effect the 10x split buys).

Cyclic assignment of equal-size chunks has a crucial structural property:
the global iteration space padded to ``K' * c`` (K' a multiple of P)
reshapes to ``(K'/P, P, c)`` whose *middle axis is the device axis* — so a
"chunk-distributed write" is just an array sharded on that axis, and the
whole master/worker exchange of the paper becomes layout, not messages.
"""
from __future__ import annotations

import dataclasses

from repro.core import pragma
from repro.core.loop import LoopInfo


@dataclasses.dataclass(frozen=True)
class ChunkPlan:
    """Deterministic chunk→device assignment for one parallel block.

    Iteration ``k`` (in ``[0, trip_count)``) lives in chunk ``k // chunk``;
    chunk ``j`` is executed by device ``j % num_devices`` as its local
    chunk number ``j // num_devices``.

    With a per-device ``weights`` vector (straggler mitigation,
    runtime/straggler.py) the cyclic deal is replaced by a proportional
    one: ``owners[j]`` names the device that executes real chunk ``j``,
    and ``slot_map[q * P + d]`` records which global chunk device ``d``
    runs as its local chunk ``q`` (the *slot* layout that the staging
    reshape ``(n_loc, P, c)`` realises).  Slots a device does not fill
    hold a sentinel chunk index ``>= ceil(trip/chunk)`` whose
    iterations all fall beyond ``trip_count`` and are masked out like
    ordinary padding.  ``owners``/``slot_map`` are ``None`` for the
    plain cyclic deal.
    """

    trip_count: int
    num_devices: int
    chunk: int                 # c — iterations per chunk
    num_chunks: int            # K' — padded to a multiple of num_devices
    local_chunks: int          # n_loc = K' / P
    padded_trip: int           # K' * c >= trip_count
    owners: tuple[int, ...] | None = None     # device owning real chunk j
    slot_map: tuple[int, ...] | None = None   # slot q*P+d -> global chunk
    weights: tuple[float, ...] | None = None  # per-device speed weights

    @property
    def padding(self) -> int:
        return self.padded_trip - self.trip_count

    @property
    def real_chunks(self) -> int:
        """Chunks that hold at least one real iteration."""
        return max(1, -(-self.trip_count // self.chunk))

    def owner_of_iteration(self, k: int) -> int:
        j = k // self.chunk
        if self.owners is not None:
            return self.owners[j]
        return j % self.num_devices

    def owner_of_last_iteration(self) -> int:
        if self.trip_count == 0:
            return 0
        return self.owner_of_iteration(self.trip_count - 1)

    def global_chunk(self, device: int, local: int) -> int:
        if self.slot_map is not None:
            return self.slot_map[local * self.num_devices + device]
        return local * self.num_devices + device


def paper_chunk_size(trip_count: int, ranks: int, *,
                     master_excluded: bool = False) -> int:
    """The paper's Table 2 line 4: ``partSize = N / (size-1) / 10``.

    ``master_excluded=True`` reproduces the MPI formula exactly (rank 0
    does not compute); the SPMD variant uses all P devices.
    """
    workers = max(1, ranks - 1 if master_excluded else ranks)
    return max(1, trip_count // workers // 10)


def guided_chunk_size(trip_count: int, ranks: int) -> int:
    """Flattened guided schedule: first-round guided chunk N/(2P)."""
    return max(1, trip_count // max(1, 2 * ranks))


def make_nest_chunk_plans(nest, schedules, num_devices,
                          weights=None) -> tuple[ChunkPlan, ...]:
    """Per-axis chunk plans for a loop nest: axis ``d`` of the iteration
    space is dealt over ``num_devices[d]`` mesh ranks with its own
    schedule clause — the ``collapse(2)`` generalisation of the paper's
    single ``partSize`` split (each axis keeps the Table 2 chunking math
    against its own trip count and rank count).  ``weights`` is an
    optional per-axis sequence of per-device weight vectors (``None``
    entries keep the cyclic deal on that axis)."""
    if not (len(nest.axes) == len(schedules) == len(num_devices)):
        raise ValueError(
            f"nest rank {len(nest.axes)} needs matching schedules "
            f"({len(schedules)}) and device counts ({len(num_devices)})")
    if weights is None:
        weights = (None,) * len(nest.axes)
    if len(weights) != len(nest.axes):
        raise ValueError(
            f"nest rank {len(nest.axes)} needs one weight vector per "
            f"axis, got {len(weights)}")
    return tuple(
        make_chunk_plan(loop_d, sched_d, int(p_d), weights=w_d)
        for loop_d, sched_d, p_d, w_d
        in zip(nest.axes, schedules, num_devices, weights))


def make_chunk_plan(
    loop: LoopInfo,
    schedule: pragma.Schedule,
    num_devices: int,
    *,
    paper_master_excluded: bool = False,
    weights=None,
) -> ChunkPlan:
    t = loop.trip_count
    p = max(1, num_devices)
    if schedule.chunk is not None:
        c = schedule.chunk
    elif schedule.kind == pragma.STATIC:
        c = max(1, -(-t // p))  # one block per device
    elif schedule.kind == pragma.DYNAMIC:
        c = paper_chunk_size(t, p, master_excluded=paper_master_excluded)
    elif schedule.kind == pragma.GUIDED:
        c = guided_chunk_size(t, p)
    else:  # pragma: no cover - Schedule validates kinds
        raise ValueError(schedule.kind)
    c = max(1, min(c, max(1, t)))
    k = max(1, -(-t // c))          # chunks needed
    if weights is None:
        k_pad = -(-k // p) * p      # padded to multiple of P
        return ChunkPlan(
            trip_count=t,
            num_devices=p,
            chunk=c,
            num_chunks=k_pad,
            local_chunks=k_pad // p,
            padded_trip=k_pad * c,
        )
    # Straggler-weighted deal: rebalance_chunks apportions the k real
    # chunks proportionally to per-device speed; the slot layout pads
    # every device to the *maximum* quota so the (n_loc, P, c) staging
    # reshape keeps its shape-uniformity (SPMD devices share one
    # program), with sentinel chunks filling unowned slots.
    from repro.runtime.straggler import rebalance_chunks

    w = tuple(float(x) for x in weights)
    if len(w) != p:
        raise ValueError(
            f"weights length {len(w)} != num_devices {p}")
    owners = rebalance_chunks(k, list(w))
    quota = [0] * p
    for d in owners:
        quota[d] += 1
    n_loc = max(1, max(quota))
    num_slots = n_loc * p
    sentinel = k                   # first padding chunk (< num_slots
    per_dev: list[list[int]] = [[] for _ in range(p)]  # whenever used)
    for j, d in enumerate(owners):
        per_dev[d].append(j)
    slot_map: list[int] = []
    for q in range(n_loc):
        for d in range(p):
            slot_map.append(per_dev[d][q] if q < quota[d] else sentinel)
    return ChunkPlan(
        trip_count=t,
        num_devices=p,
        chunk=c,
        num_chunks=num_slots,
        local_chunks=n_loc,
        padded_trip=num_slots * c,
        owners=tuple(owners),
        slot_map=tuple(slot_map),
        weights=w,
    )
