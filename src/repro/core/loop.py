"""Loop Analysis (paper §3.1.2).

OMP2MPI recovers the canonical semantics of the annotated ``for`` loop —
induction variable, initial value, bound, stride and comparison — and
*rejects* loops it cannot canonicalise (non-linear induction, compound
conditions), leaving them as OpenMP blocks.  Here the loop is already
declared as ``range(start, stop, step)`` on the :class:`ParallelFor`
program, so this stage (a) validates/normalises those bounds and (b)
computes the iteration space used by the scheduler.
"""
from __future__ import annotations

import dataclasses


class LoopNotCanonical(Exception):
    """Raised when the loop cannot be transformed (paper: the block is
    kept as an OpenMP block and executed on the shared-memory node)."""


@dataclasses.dataclass(frozen=True)
class LoopInfo:
    """Canonicalised loop: iteration k in [0, trip_count) maps to
    ``i = start + k * step``."""

    start: int
    stop: int
    step: int
    trip_count: int

    def iteration_to_index(self, k: int):
        return self.start + k * self.step


def analyze_loop(start: int, stop: int, step: int) -> LoopInfo:
    """Validate and canonicalise the loop bounds.

    Mirrors the paper's checks: the induction must advance by a non-zero
    static stride and the bound must be a single comparison.  Zero strides
    or non-integer bounds are exactly the "complex non-linear increments"
    the paper refuses to transform.
    """
    for name, v in (("start", start), ("stop", stop), ("step", step)):
        if not isinstance(v, int):
            raise LoopNotCanonical(
                f"loop {name} must be a static int, got {type(v).__name__} "
                "(paper §3.1.2: non-canonical loops are kept as OpenMP blocks)"
            )
    if step == 0:
        raise LoopNotCanonical("loop step must be non-zero")
    if step > 0:
        trip = max(0, -(-(stop - start) // step))
    else:
        trip = max(0, -(-(start - stop) // (-step)))
    return LoopInfo(start=start, stop=stop, step=step, trip_count=trip)
