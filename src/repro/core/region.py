"""Whole-program OMP→MPI transformation with inter-loop residency planning.

OMP2MPI transforms each ``parallel for`` in isolation: every block stages
its IN buffers out of rank 0's shared memory and returns every OUT slab
back to it (paper Fig. 1b).  For a *chain* of blocks that means a
gather→rebroadcast round trip between consecutive loops even when the
next loop immediately re-distributes the same array the same way — the
communication bottleneck follow-up systems (OMP2HMPP, MPI-rical) attack
by reasoning across statement boundaries.

This module transforms a :class:`~repro.core.pragma.ParallelRegion` as a
whole:

* :func:`plan_region` — the **inter-loop residency planner**.  It walks
  the stage sequence, tracking the layout of every environment buffer
  (``replicated`` or chunk-cyclic ``slab``), and matches each loop's OUT
  layout (from its :class:`~repro.core.plan.DistPlan`) against the next
  loop's IN requirement:

  - compatible layouts → the buffer **stays resident** in its slab; the
    gather→rebroadcast round trip is elided entirely;
  - incompatible layouts → a single minimal resharding collective (an
    ``all_gather``) materialises the buffer, replacing the staged
    master round trip;
  - serial glue stages run redundantly on every rank over replicated
    buffers (only their declared reads are materialised).

* :class:`DistributedRegion` — the executor
  (:func:`repro.core.api.compile` is the entry point; the historical
  :func:`region_to_mpi` remains as a deprecation shim).
  ``Lowering.FUSED`` fuses the whole region into **one** ``shard_map``
  so resident buffers never leave their device; ``MASTER_WORKER`` (and
  per-loop ``COLLECTIVE``) keep the paper's per-loop staging as the
  measurable baseline (EXPERIMENTS.md §Perf-C).

Boundary lowering is delegated to the cost-modeled communication
planner (:mod:`repro.core.comm`): each slab→consumer handoff becomes
the cheapest of ``resident`` / ``halo`` (neighbor ``ppermute`` ring
shifts) / ``all_gather`` / ``replicate``, recorded as a
:class:`~repro.core.comm.BoundaryComm` on the plan.  ``comm="gather"``
disables the halo strategy — the PR 1 baseline, kept measurable
(EXPERIMENTS.md §Perf-D).

Residency compatibility (the layout-matching rule): loop A's write slab
holds row ``base + j*c + r`` at (chunk ``j``, lane ``r``); loop B can
consume it in place iff both loops share the chunk geometry
``(c, P, n_loc, padded)``, cover the same trip count, and B's per-
iteration read map equals A's write map (``x[k + base]`` both sides —
identity or aligned unit-stride).  Strided, stencil and whole-array reads
fall back to the resharding collective.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core import comm as comm_mod
from repro.core import nest as nest_mod
from repro.core import pragma, reduction as red_mod
from repro.core import transform as tf
from repro.core.context import _aval_of
from repro.core.comm import (  # noqa: F401 (re-export)
    BoundaryComm,
    SlabLayout,
    SlabLayout2,
)
from repro.core.loop import LoopNotCanonical
from repro.core.plan import DistPlan, make_plan
from repro.core.tensor_plan import slab_spec

REPLICATED = "repl"

_SLABS = (SlabLayout, SlabLayout2)


@dataclasses.dataclass
class StageExec:
    """One stage of the fused execution schedule."""

    name: str
    kind: str                          # "loop" | "serial"
    stage: Any                         # ParallelFor | SerialStage
    plan: DistPlan | None
    gathers: tuple[str, ...]           # keys resharded (materialised) first
    feeds: dict[str, str]              # sharded-in key -> "resident"|"slice"
    serial_writes: tuple[str, ...] = ()


@dataclasses.dataclass
class RegionPlan:
    """Output of the inter-loop residency planner."""

    name: str
    axis: str
    num_devices: int
    stages: list[StageExec]
    env_keys: list[str]                # region input keys
    touched_keys: list[str]            # keys (re)written by some stage
    final_layout: dict[str, Any]       # touched key -> REPLICATED | SlabLayout
    n_elided: int                      # resident handoffs (round trips saved)
    n_reshards: int                    # minimal collectives inserted
    log: list[str]                     # human-readable transition journal
    comms: list[BoundaryComm] = dataclasses.field(default_factory=list)
    n_halo: int = 0                    # boundaries lowered to ppermute shifts
    comm_mode: str = "auto"
    rank: int = 1                      # nest rank shared by every loop
    # the schedule_comm artifact (repro.core.comm_schedule.CommSchedule):
    # aggregation groups + fused combines + launch accounting, attached
    # after planning by the compile pipeline (or lazily by the executor)
    comm_sched: Any = None

    @property
    def loop_plans(self) -> list[DistPlan]:
        return [s.plan for s in self.stages if s.plan is not None]

    @property
    def planned_wire_bytes(self) -> int:
        """Modeled wire bytes of the chosen boundary ops."""
        return sum(bc.cost.wire_bytes for bc in self.comms)

    @property
    def gather_wire_bytes(self) -> int:
        """Modeled wire bytes under the PR 1 rule (residency kept, every
        non-resident boundary lowered to the gather)."""
        total = 0
        for bc in self.comms:
            if bc.op == comm_mod.RESIDENT:
                continue
            alts = [c for op, c in bc.alternatives.items()
                    if op in (comm_mod.ALL_GATHER, comm_mod.REPLICATE)]
            total += alts[0].wire_bytes if alts else bc.cost.wire_bytes
        return total


# ---------------------------------------------------------------------------
# The residency planner
# ---------------------------------------------------------------------------


def _boundary_replicated(stage_name, key, st, aval, comm, chunks=None):
    """Plan a forced-replication boundary for either slab rank."""
    if isinstance(st, SlabLayout2):
        return comm_mod.plan_boundary2(
            stage=stage_name, key=key, layout=st, chunks_axes=None,
            trips=(0, 0), aval=aval, in_strategy="none", halo_axes=None,
            shard_ndim=0, needs_replicated=True, mode=comm)
    return comm_mod.plan_boundary(
        stage=stage_name, key=key, layout=st, chunks=chunks, trip=0,
        aval=aval, in_strategy="none", halo=None, needs_replicated=True,
        mode=comm)


def plan_region(
    region: pragma.ParallelRegion,
    env: Mapping[str, Any],
    num_devices: int | tuple,
    *,
    axis: str | tuple = "data",
    comm: str = "auto",
    schedule: pragma.Schedule | None = None,
) -> RegionPlan:
    """Match each loop's OUT layout against the next loop's IN needs,
    lowering each slab boundary through the cost-modeled communication
    planner (``comm="auto"``; ``comm="gather"`` pins the PR 1 all-gather
    baseline).  Rank-2 regions (every loop ``collapse=2``) plan over a
    2-D mesh: ``axis`` and ``num_devices`` are then 2-tuples."""
    if comm not in comm_mod.COMM_MODES:
        raise ValueError(
            f"unknown comm mode {comm!r}; expected {comm_mod.COMM_MODES}")
    rank = region.rank
    if (rank == 2) != isinstance(axis, tuple):
        raise LoopNotCanonical(
            f"region rank {rank} does not match mesh axis clause {axis!r} "
            "(collapse=2 regions need a 2-tuple of mesh axes)")
    env_shapes = {k: _aval_of(v) for k, v in env.items()}
    state: dict[str, Any] = {k: REPLICATED for k in env_shapes}
    touched: set[str] = set()
    stages: list[StageExec] = []
    n_elided = n_reshards = n_halo = 0
    log: list[str] = []
    comms: list[BoundaryComm] = []

    for stage in region.stages:
        if isinstance(stage, pragma.SerialStage):
            reads = (stage.reads if stage.reads is not None
                     else tuple(env_shapes))
            gathers = tuple(
                k for k in reads if isinstance(state.get(k), _SLABS))
            out_sh = jax.eval_shape(stage.fn, env_shapes)
            if not isinstance(out_sh, dict):
                raise LoopNotCanonical(
                    f"serial stage {stage.name!r} must return a dict of "
                    "whole-array updates"
                )
            for k in gathers:
                n_reshards += 1
                comms.append(_boundary_replicated(
                    stage.name, k, state[k], env_shapes[k], comm))
                log.append(f"{stage.name}: reshard {k!r} "
                           f"(~{comm_mod.full_bytes(env_shapes[k])} B all-gather; "
                           "serial glue reads it)")
                state[k] = REPLICATED
            for k, v in out_sh.items():
                env_shapes[k] = jax.ShapeDtypeStruct(v.shape, v.dtype)
                state[k] = REPLICATED
                touched.add(k)
            stages.append(StageExec(
                name=stage.name, kind="serial", stage=stage, plan=None,
                gathers=gathers, feeds={}, serial_writes=tuple(out_sh)))
            continue

        plan = make_plan(stage, env_shapes, num_devices, axis=axis,
                         lowering="collective", shard_inputs=True,
                         schedule=schedule)
        t = plan.nest.total_trip
        if t == 0:
            # Zero-trip loop: the executor only folds reduction
            # identities (mirroring single-block ``_execute``); no other
            # buffer moves, so no layout changes either.
            gathers0: list[str] = []
            for key, dec in plan.vars.items():
                if dec.out_strategy != "reduce":
                    continue
                if isinstance(state.get(key), _SLABS):
                    gathers0.append(key)
                    n_reshards += 1
                    comms.append(_boundary_replicated(
                        stage.name, key, state[key], env_shapes[key], comm,
                        chunks=plan.chunks))
                    log.append(
                        f"{stage.name}: reshard {key!r} "
                        f"(~{comm_mod.full_bytes(env_shapes[key])} B all-gather; "
                        "zero-trip reduction folds the prior value)")
                state[key] = REPLICATED
                touched.add(key)
                if key not in env_shapes:
                    info = plan.context.vars[key]
                    env_shapes[key] = jax.ShapeDtypeStruct(
                        info.write.value_shape, info.write.value_dtype)
            stages.append(StageExec(
                name=stage.name, kind="loop", stage=stage, plan=plan,
                gathers=tuple(gathers0), feeds={}))
            continue
        if plan.rank == 2:
            se, n_e, n_h, n_r = _plan_loop_stage2(
                stage, plan, state, touched, env_shapes, comms, log, comm)
            n_elided += n_e
            n_halo += n_h
            n_reshards += n_r
            stages.append(se)
            continue
        gathers: list[str] = []
        feeds: dict[str, str] = {}
        for key, dec in plan.vars.items():
            st = state.get(key, REPLICATED)
            is_slab = isinstance(st, SlabLayout)
            write_b = dec.write_map.b if dec.write_map is not None else None

            # Out-merges that consume the pre-stage value need it
            # replicated — except a partial write replacing a slab of the
            # identical interval, whose prior chains through.
            interval_same = (is_slab and dec.out_strategy == "partial"
                             and st.base == write_b and st.cover == t)
            prior_repl = (
                dec.out_strategy == "scatter"
                or (dec.out_strategy == "partial" and not interval_same)
                or (dec.out_strategy == "reduce" and key in state)
            )

            consumes = dec.in_strategy in ("shard", "shard_halo", "replicate")
            if is_slab and (prior_repl or consumes):
                bc = comm_mod.plan_boundary(
                    stage=stage.name, key=key, layout=st, chunks=plan.chunks,
                    trip=t, aval=env_shapes[key],
                    in_strategy=dec.in_strategy, halo=dec.halo,
                    needs_replicated=(prior_repl
                                      or dec.in_strategy == "replicate"),
                    mode=comm)
                comms.append(bc)
                if bc.op == comm_mod.RESIDENT:
                    feeds[key] = "resident"
                    n_elided += 1
                    log.append(
                        f"{stage.name}: {key!r} stays RESIDENT "
                        f"(elides ~{2 * comm_mod.full_bytes(env_shapes[key])} B "
                        "gather+redistribute round trip)")
                elif bc.op == comm_mod.HALO:
                    feeds[key] = "halo"
                    n_halo += 1
                    g = bc.alternatives[comm_mod.ALL_GATHER].wire_bytes
                    log.append(
                        f"{stage.name}: {key!r} HALO-EXCHANGED "
                        f"(shift {bc.shift}, {bc.cost.hops} ppermute hop(s), "
                        f"~{bc.cost.wire_bytes} B on the wire vs ~{g} B "
                        "all-gather)")
                else:
                    gathers.append(key)
                    n_reshards += 1
                    state[key] = REPLICATED
                    log.append(
                        f"{stage.name}: reshard {key!r} "
                        f"(~{comm_mod.full_bytes(env_shapes[key])} B all-gather; "
                        f"{bc.reason})")
                    if dec.in_strategy in ("shard", "shard_halo"):
                        feeds[key] = "slice"
            elif dec.in_strategy in ("shard", "shard_halo"):
                feeds[key] = "slice"

            if dec.out_strategy == "identity":
                state[key] = SlabLayout.of(plan, base=0, has_prior=False)
                touched.add(key)
            elif dec.out_strategy == "partial":
                state[key] = SlabLayout.of(plan, base=write_b, has_prior=True)
                touched.add(key)
            elif dec.out_strategy in ("scatter", "put", "reduce"):
                state[key] = REPLICATED
                touched.add(key)
                if key not in env_shapes:     # fresh reduction output
                    info = plan.context.vars[key]
                    env_shapes[key] = jax.ShapeDtypeStruct(
                        info.write.value_shape, info.write.value_dtype)

        stages.append(StageExec(
            name=stage.name, kind="loop", stage=stage, plan=plan,
            gathers=tuple(gathers), feeds=feeds))

    final_layout = {k: state[k] for k in sorted(touched)}
    return RegionPlan(
        name=region.name, axis=axis, num_devices=num_devices,
        stages=stages, env_keys=list(env.keys()),
        touched_keys=sorted(touched), final_layout=final_layout,
        n_elided=n_elided, n_reshards=n_reshards, log=log,
        comms=comms, n_halo=n_halo, comm_mode=comm, rank=rank,
    )


def _plan_loop_stage2(stage, plan, state, touched, env_shapes, comms, log,
                      comm):
    """Residency planning for one rank-2 loop stage: the 2-D analogue of
    the rank-1 key loop in :func:`plan_region` (per-axis bases/covers,
    boundaries through :func:`repro.core.comm.plan_boundary2`)."""
    trips = plan.nest.trip_counts
    n_elided = n_halo = n_reshards = 0
    gathers: list[str] = []
    feeds: dict[str, str] = {}
    for key, dec in plan.vars.items():
        st = state.get(key, REPLICATED)
        is_slab = isinstance(st, SlabLayout2)
        write_bases = (tuple(m.b for m in dec.write_maps)
                       if dec.write_maps is not None else None)

        # Out-merges that consume the pre-stage value need it replicated
        # — except a partial write replacing a slab of the identical
        # rectangle, whose prior chains through.
        interval_same = (is_slab and dec.out_strategy == "partial"
                         and st.bases == write_bases and st.covers == trips)
        prior_repl = (
            (dec.out_strategy == "partial" and not interval_same)
            or (dec.out_strategy == "reduce" and key in state)
        )

        consumes = dec.in_strategy in ("shard_halo", "replicate")
        if is_slab and (prior_repl or consumes):
            bc = comm_mod.plan_boundary2(
                stage=stage.name, key=key, layout=st,
                chunks_axes=plan.chunks_axes, trips=trips,
                aval=env_shapes[key], in_strategy=dec.in_strategy,
                halo_axes=dec.halo_axes, shard_ndim=dec.shard_ndim,
                needs_replicated=(prior_repl
                                  or dec.in_strategy == "replicate"),
                mode=comm)
            comms.append(bc)
            if bc.op == comm_mod.RESIDENT:
                feeds[key] = "resident"
                n_elided += 1
                log.append(
                    f"{stage.name}: {key!r} stays RESIDENT "
                    f"(elides ~{2 * comm_mod.full_bytes(env_shapes[key])} B "
                    "gather+redistribute round trip)")
            elif bc.op == comm_mod.HALO:
                feeds[key] = "halo"
                n_halo += 1
                g = bc.alternatives[comm_mod.ALL_GATHER].wire_bytes
                log.append(
                    f"{stage.name}: {key!r} HALO-EXCHANGED 2-D "
                    f"(shifts {bc.shift}, {bc.cost.hops} ppermute hop(s), "
                    f"~{bc.cost.wire_bytes} B on the wire vs ~{g} B "
                    "all-gather)")
            else:
                gathers.append(key)
                n_reshards += 1
                state[key] = REPLICATED
                log.append(
                    f"{stage.name}: reshard {key!r} "
                    f"(~{comm_mod.full_bytes(env_shapes[key])} B all-gather; "
                    f"{bc.reason})")
                if dec.in_strategy == "shard_halo":
                    feeds[key] = "slice"
        elif dec.in_strategy == "shard_halo":
            feeds[key] = "slice"

        if dec.out_strategy == "identity":
            state[key] = SlabLayout2.of(plan, bases=(0, 0), has_prior=False)
            touched.add(key)
        elif dec.out_strategy == "partial":
            state[key] = SlabLayout2.of(plan, bases=write_bases,
                                        has_prior=True)
            touched.add(key)
        elif dec.out_strategy == "reduce":
            state[key] = REPLICATED
            touched.add(key)
            if key not in env_shapes:     # fresh reduction output
                info = plan.context.vars[key]
                env_shapes[key] = jax.ShapeDtypeStruct(
                    info.write.value_shape, info.write.value_dtype)

    se = StageExec(name=stage.name, kind="loop", stage=stage, plan=plan,
                   gathers=tuple(gathers), feeds=feeds)
    return se, n_elided, n_halo, n_reshards


# ---------------------------------------------------------------------------
# Distributed region program
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DistributedRegion:
    """The generated whole-program "MPI" code for a parallel region."""

    region: pragma.ParallelRegion
    mesh: Mesh
    plan: RegionPlan | None
    axis: str = "data"
    lowering: str = "collective"
    fuse: bool = True
    shard_inputs: bool = False          # per-loop fallback path only
    unroll_chunks: bool = False
    paper_master_excluded: bool | None = None
    comm: str = "auto"                  # boundary planner mode
    comm_schedule: str = "aggregate"    # schedule_comm mode
    schedule_override: pragma.Schedule | None = None
    stage_plans: tuple | None = None    # staged path: per-loop (name, plan)
    use_pallas: bool = False            # Lowering.PALLAS: tiled kernels
    pallas_interpret: bool | None = None
    chunk_weights: tuple | None = None  # straggler-weighted (staged only)

    def __call__(self, env: Mapping[str, Any]) -> dict[str, Any]:
        from repro.core import comm_schedule as cs_mod

        env = {k: jnp.asarray(v) for k, v in env.items()}
        if self.lowering != "collective" or not self.fuse:
            return self._run_staged(env)
        if self.plan is None:
            self.plan = plan_region(
                self.region, env, tf.mesh_axis_sizes(self.mesh, self.axis),
                axis=self.axis, comm=self.comm,
                schedule=self.schedule_override)
        if self.plan.comm_sched is None:
            self.plan.comm_sched = cs_mod.build_comm_schedule(
                self.plan, mode=self.comm_schedule)
        return _execute_region(self, env)

    def _run_staged(self, env: dict) -> dict:
        """Paper-faithful baseline: each loop transformed in isolation
        (data returns to replicated form between stages).  When the
        compile pipeline pre-planned the stages (``stage_plans``), those
        exact plans execute — no re-planning per call."""
        out = dict(env)
        plans = iter(self.stage_plans) if self.stage_plans is not None \
            else None
        for stage in self.region.stages:
            if isinstance(stage, pragma.SerialStage):
                out = stage(out)
                continue
            plan = None
            if plans is not None:
                _, plan = next(plans)
            out = tf.DistributedProgram(
                program=stage, mesh=self.mesh, plan=plan, axis=self.axis,
                lowering=self.lowering, shard_inputs=self.shard_inputs,
                unroll_chunks=self.unroll_chunks,
                paper_master_excluded=self.paper_master_excluded,
                schedule_override=self.schedule_override,
                comm_schedule=self.comm_schedule,
                chunk_weights=self.chunk_weights,
            )(out)
        return out

    def report(self) -> str:
        from repro.core import report as report_mod

        if self.plan is None:
            raise ValueError(
                "call the region (or pass env_like to region_to_mpi) to "
                "build the residency plan before asking for a report")
        return report_mod.render_region(self.plan)


def region_to_mpi(
    region: pragma.ParallelRegion,
    mesh: Mesh,
    *,
    axis: str | tuple | None = None,
    lowering: str = "collective",
    fuse: bool = True,
    shard_inputs: bool = False,
    unroll_chunks: bool = False,
    env_like: Mapping[str, Any] | None = None,
    paper_master_excluded: bool | None = None,
    comm: str = "auto",
):
    """Deprecated: use ``omp.compile(region, mesh, omp.Options(...))``.

    Thin shim: translates the legacy kwargs to
    :class:`~repro.core.api.Options` — ``fuse=True`` +
    ``lowering="collective"`` becomes ``Lowering.FUSED``,
    ``fuse=False`` becomes ``Lowering.COLLECTIVE`` — and returns the
    :class:`~repro.core.api.Compiled` artifact (callable like the
    ``DistributedRegion`` it used to return, with ``.plan`` /
    ``.report()`` intact).
    """
    import warnings

    from repro.core import api

    warnings.warn(
        "omp.region_to_mpi() is deprecated; use omp.compile(region, mesh, "
        "omp.Options(lowering=..., comm=...)) instead",
        DeprecationWarning, stacklevel=2)
    if isinstance(region, pragma.ParallelFor):
        region = pragma.ParallelRegion((region,))
    if lowering == "master_worker":
        low = api.Lowering.MASTER_WORKER
    elif lowering != "collective":
        raise api.CompileError(f"unknown lowering {lowering!r}")
    elif fuse:
        low = api.Lowering.FUSED
    else:
        low = api.Lowering.COLLECTIVE
    options = api.Options(
        axis=axis,
        lowering=low,
        comm=comm,
        shard=(api.ShardPolicy.SLICE if shard_inputs
               else api.ShardPolicy.REPLICATE),
        unroll_chunks=unroll_chunks,
        paper_master_excluded=paper_master_excluded,
    )
    return api.compile(region, mesh, options, env_like=env_like)


# ---------------------------------------------------------------------------
# Fused execution (one shard_map for the whole region)
# ---------------------------------------------------------------------------


def _execute_region(dr: DistributedRegion, env: dict) -> dict:
    from repro.core import comm_schedule as cs_mod

    if dr.plan.rank == 2:
        return _execute_region2(dr, env)
    tf._maybe_fault("region")
    rp = dr.plan
    mesh, axis = dr.mesh, rp.axis
    env_dtypes = {k: v.dtype for k, v in env.items()}
    sched = rp.comm_sched
    aggregate = sched is not None and sched.mode == "aggregate"
    if dr.use_pallas:
        from repro.core import pallas_lower as plx

        pallas_interp = plx.resolve_interpret(dr.pallas_interpret, mesh)
        span_of = {s[0]: s for s in plx.compute_region_spans(rp)}

    # exit layout is static — build specs up front
    slab_out = {k: lay for k, lay in rp.final_layout.items()
                if isinstance(lay, SlabLayout)}
    repl_out = [k for k, lay in rp.final_layout.items() if lay == REPLICATED]
    prior_out = [k for k, lay in slab_out.items() if lay.has_prior]

    def device_fn(env_all):
        d = jax.lax.axis_index(axis)
        st: dict[str, tuple] = {k: ("repl", v) for k, v in env_all.items()}
        span_results: dict[int, tuple] = {}

        def run_span(si, env_in, slab_stacks):
            """Fuse the span starting at stage ``si`` into one pallas
            kernel; later stages' external feeds come from the current
            ``st`` (spans never cross an exchange, so those entries are
            stable until each stage's merge runs)."""
            specs = []
            written: set = set()
            for sj in span_of[si]:
                sse = rp.stages[sj]
                sp_plan = sse.plan
                if sj == si:
                    ext, repl, fwd = dict(slab_stacks), dict(env_in), set()
                else:
                    ext, repl, fwd = {}, {}, set()
                    for key in sp_plan.context.env_keys:
                        dec = sp_plan.vars[key]
                        if dec.in_strategy in ("shard", "shard_halo"):
                            if sse.feeds[key] == "resident":
                                if key in written:
                                    fwd.add(key)    # in-VMEM hand-off
                                else:
                                    ext[key] = st[key][1]
                            else:               # "slice"
                                halo = (dec.halo if dec.halo is not None
                                        else (0, 0))
                                ext[key] = nest_mod.local_slabs(
                                    st[key][1], sp_plan.chunks, halo, d)
                        elif dec.in_strategy == "replicate":
                            repl[key] = st[key][1]
                specs.append(plx.SpanStage(
                    name=sse.name, plan=sp_plan, program=sse.stage,
                    ext_windows=ext, env_repl=repl,
                    forwarded=frozenset(fwd)))
                written |= plx._written_keys(sp_plan)
            for sj, res in zip(span_of[si],
                               plx.execute_span(specs, (d,),
                                                pallas_interp)):
                span_results[sj] = res
        # hoisted exchanges: (consumer stage idx, key) -> read window,
        # issued right after the producing stage (the prefetch)
        prefetched: dict[tuple[int, str], Any] = {}

        def issue_prefetch(after_idx):
            for grp in sched.groups_after(after_idx):
                items = []
                for ev in grp.events:
                    _, stacks, sbase, scover, sprior, sdtype = st[ev.key]
                    items.append(cs_mod.HaloItem(
                        stacks=stacks, chunks=ev.chunks, shifts=ev.shifts,
                        prior=sprior, bases=(sbase,), covers=(scover,),
                        dtype=sdtype))
                wins = cs_mod.aggregated_halo_exchange(
                    items, axis=axis, num_devices=grp.events[0].num_devices[0],
                    device_index=d)
                for ev, win in zip(grp.events, wins):
                    prefetched[(ev.consumer_idx, ev.key)] = win

        def materialize(key):
            tag = st[key][0]
            if tag == "repl":
                return st[key][1]
            _, stacks, base, cover, prior, dtype = st[key]
            g = jax.lax.all_gather(stacks, axis, axis=1, tiled=False)
            flat = g.reshape((-1,) + g.shape[3:])[:cover].astype(dtype)
            if prior is None:
                full = flat
            else:
                full = jax.lax.dynamic_update_slice_in_dim(
                    prior, flat, base, 0)
            st[key] = ("repl", full)
            return full

        for si, se in enumerate(rp.stages):
            for k in se.gathers:
                materialize(k)

            if se.kind == "serial":
                env_full = {k: e[1] for k, e in st.items() if e[0] == "repl"}
                upd = se.stage.fn(env_full)
                for k, v in upd.items():
                    st[k] = ("repl", jnp.asarray(v))
                continue

            plan = se.plan
            t = plan.loop.trip_count
            if t == 0:
                for key, dec in plan.vars.items():
                    if dec.out_strategy == "reduce":
                        rop = red_mod.get_reduction(dec.reduction_op)
                        info = plan.context.vars[key]
                        val = red_mod.identity_like(
                            rop, jnp.zeros(info.write.value_shape,
                                           info.write.value_dtype))
                        if key in st:
                            val = rop.pairwise(materialize(key), val)
                        st[key] = ("repl", val)
                continue

            env_in: dict[str, Any] = {}
            slab_stacks: dict[str, Any] = {}
            for key in plan.context.env_keys:
                dec = plan.vars[key]
                if dec.in_strategy in ("shard", "shard_halo"):
                    feed = se.feeds[key]
                    if feed == "resident":
                        slab_stacks[key] = st[key][1]
                    elif feed == "halo":
                        if aggregate:
                            # the scheduler issued this exchange right
                            # after its producer (prefetched window)
                            slab_stacks[key] = prefetched.pop((si, key))
                        else:
                            # neighbor ppermute ring shifts: the planned
                            # point-to-point boundary exchange (§3.1.4)
                            _, stacks, sbase, scover, sprior, sdtype = st[key]
                            h = dec.halo if dec.halo is not None else (0, 0)
                            slab_stacks[key] = comm_mod.halo_exchange(
                                stacks, axis=axis,
                                num_devices=plan.chunks.num_devices,
                                device_index=d, chunk=plan.chunks.chunk,
                                delta_min=h[0] - sbase,
                                delta_max=h[1] - sbase,
                                prior=sprior, base=sbase, cover=scover,
                                dtype=sdtype)
                    else:
                        halo = dec.halo if dec.halo is not None else (0, 0)
                        slab_stacks[key] = nest_mod.local_slabs(
                            st[key][1], plan.chunks, halo, d)
                elif dec.in_strategy == "replicate":
                    env_in[key] = st[key][1]

            if not dr.use_pallas:
                carry, ys = tf._run_local_chunks(
                    plan, se.stage, env_in, slab_stacks, d,
                    dr.unroll_chunks)
            else:
                if si not in span_results:
                    run_span(si, env_in, slab_stacks)
                carry, ys = span_results.pop(si)

            # Cross-device combines of this stage's merges: issued
            # per-key inline, or deferred into fused flat collectives
            # (one launch per (collective, dtype) group) when scheduled.
            pending: dict[tuple[str, str], tuple[str, Any]] = {}
            for key, dec in plan.vars.items():
                info = plan.context.vars[key]
                if dec.out_strategy == "identity":
                    st[key] = ("slab", ys[key], 0, t, None, info.dtype)
                elif dec.out_strategy == "partial":
                    b = dec.write_map.b
                    prev = st.get(key)
                    if (prev is not None and prev[0] == "slab"
                            and prev[2] == b and prev[3] == t):
                        prior = prev[4]     # same interval: chain the prior
                    else:
                        prior = st[key][1]  # replicated (planner enforced)
                    st[key] = ("slab", ys[key], b, t, prior, info.dtype)
                elif dec.out_strategy == "scatter":
                    buf, mask = carry[key]
                    if aggregate:
                        pending[(key, "buf")] = ("psum", buf)
                        pending[(key, "mask")] = \
                            ("psum", mask.astype(jnp.int32))
                        continue
                    summed = jax.lax.psum(buf, axis)
                    m = jax.lax.psum(mask.astype(jnp.int32), axis)
                    prior = st[key][1]
                    vmask = (m > 0).reshape((-1,) + (1,) * (summed.ndim - 1))
                    st[key] = ("repl", jnp.where(
                        vmask, summed.astype(prior.dtype), prior))
                elif dec.out_strategy == "put":
                    j_star = (t - 1) // plan.chunks.chunk
                    owner = j_star % plan.chunks.num_devices
                    val = jnp.where(d == owner, carry[key],
                                    jnp.zeros_like(carry[key]))
                    if aggregate:
                        pending[(key, "put")] = ("psum", val)
                        continue
                    st[key] = ("repl", jax.lax.psum(val, axis))
                elif dec.out_strategy == "reduce":
                    rop = red_mod.get_reduction(dec.reduction_op)
                    if aggregate and rop.collective in ("psum", "pmax",
                                                        "pmin"):
                        pending[(key, "red")] = (rop.collective, carry[key])
                        continue
                    val = red_mod.cross_device_combine(rop, carry[key], axis)
                    if key in st:
                        val = rop.pairwise(st[key][1], val)
                    st[key] = ("repl", val)

            if pending:
                combined = cs_mod.fused_collectives(pending, axis)
                for key, dec in plan.vars.items():
                    if dec.out_strategy == "scatter":
                        summed = combined[(key, "buf")]
                        m = combined[(key, "mask")]
                        prior = st[key][1]
                        vmask = (m > 0).reshape(
                            (-1,) + (1,) * (summed.ndim - 1))
                        st[key] = ("repl", jnp.where(
                            vmask, summed.astype(prior.dtype), prior))
                    elif dec.out_strategy == "put":
                        st[key] = ("repl", combined[(key, "put")])
                    elif dec.out_strategy == "reduce" \
                            and (key, "red") in combined:
                        rop = red_mod.get_reduction(dec.reduction_op)
                        val = combined[(key, "red")]
                        if key in st:
                            val = rop.pairwise(st[key][1], val)
                        st[key] = ("repl", val)

            if aggregate:
                issue_prefetch(si)

        outs_repl = {k: st[k][1] for k in repl_out}
        outs_slab = {k: st[k][1][:, None] for k in slab_out}
        outs_prior = {k: st[k][4] for k in prior_out}
        return outs_repl, outs_slab, outs_prior

    in_specs = ({k: P() for k in env},)
    out_specs = (
        {k: P() for k in repl_out},
        {k: slab_spec(axis) for k in slab_out},
        {k: P() for k in prior_out},
    )
    if not rp.touched_keys:
        return dict(env)

    outs_repl, outs_slab, outs_prior = shard_map(
        device_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
    )(env)

    # --- reassembly at the jit level (layout, not messages) ---------------
    result = dict(env)
    for key in repl_out:
        result[key] = outs_repl[key]
    for key, lay in slab_out.items():
        g = outs_slab[key]                       # (n_loc, P, c, *rest)
        flat = g.reshape((-1,) + g.shape[3:])[:lay.cover]
        flat = flat.astype(env_dtypes.get(key, flat.dtype))
        if lay.has_prior:
            result[key] = jax.lax.dynamic_update_slice_in_dim(
                outs_prior[key], flat, lay.base, 0)
        else:
            result[key] = flat
    return result


def _execute_region2(dr: DistributedRegion, env: dict) -> dict:
    """Fused execution of a rank-2 region: ONE shard_map over the 2-D
    mesh; slabs stay resident as ``(n_i, c_i, n_j, c_j, *rest)`` stacks,
    halo boundaries run as row+column ``ppermute`` rings."""
    from repro.core import comm_schedule as cs_mod

    tf._maybe_fault("region2")

    rp = dr.plan
    mesh = dr.mesh
    ax_i, ax_j = rp.axis
    env_dtypes = {k: v.dtype for k, v in env.items()}
    sched = rp.comm_sched
    aggregate = sched is not None and sched.mode == "aggregate"
    if dr.use_pallas:
        from repro.core import pallas_lower as plx

        pallas_interp = plx.resolve_interpret(dr.pallas_interpret, mesh)
        span_of = {s[0]: s for s in plx.compute_region_spans(rp)}

    slab_out = {k: lay for k, lay in rp.final_layout.items()
                if isinstance(lay, SlabLayout2)}
    repl_out = [k for k, lay in rp.final_layout.items() if lay == REPLICATED]
    prior_out = [k for k, lay in slab_out.items() if lay.has_prior]

    def device_fn(env_all):
        d_i = jax.lax.axis_index(ax_i)
        d_j = jax.lax.axis_index(ax_j)
        st: dict[str, tuple] = {k: ("repl", v) for k, v in env_all.items()}
        prefetched: dict[tuple[int, str], Any] = {}
        span_results: dict[int, tuple] = {}

        def run_span(si, env_in, slab_stacks):
            specs = []
            written: set = set()
            for sj in span_of[si]:
                sse = rp.stages[sj]
                sp_plan = sse.plan
                sch_i, sch_j = sp_plan.chunks_axes
                if sj == si:
                    ext, repl, fwd = dict(slab_stacks), dict(env_in), set()
                else:
                    ext, repl, fwd = {}, {}, set()
                    for key in sp_plan.context.env_keys:
                        dec = sp_plan.vars[key]
                        if dec.in_strategy in ("shard", "shard_halo"):
                            if sse.feeds[key] == "resident":
                                if key in written:
                                    fwd.add(key)    # in-VMEM hand-off
                                else:
                                    ext[key] = st[key][1]
                            else:               # "slice"
                                halos = (dec.halo_axes
                                         if dec.halo_axes is not None
                                         else ((0, 0), (0, 0)))
                                x = st[key][1]
                                if dec.shard_ndim == 2:
                                    ext[key] = nest_mod.local_slabs2(
                                        x, (sch_i, sch_j), halos,
                                        (d_i, d_j))
                                else:
                                    ext[key] = nest_mod.local_slabs(
                                        x, sch_i, halos[0], d_i)
                        elif dec.in_strategy == "replicate":
                            repl[key] = st[key][1]
                specs.append(plx.SpanStage(
                    name=sse.name, plan=sp_plan, program=sse.stage,
                    ext_windows=ext, env_repl=repl,
                    forwarded=frozenset(fwd)))
                written |= plx._written_keys(sp_plan)
            for sj, res in zip(span_of[si],
                               plx.execute_span(specs, (d_i, d_j),
                                                pallas_interp)):
                span_results[sj] = res

        def issue_prefetch(after_idx):
            for grp in sched.groups_after(after_idx):
                items = []
                for ev in grp.events:
                    _, stacks, bases, covers, sprior, sdtype = st[ev.key]
                    items.append(cs_mod.HaloItem(
                        stacks=stacks, chunks=ev.chunks, shifts=ev.shifts,
                        prior=sprior, bases=bases, covers=covers,
                        dtype=sdtype))
                wins = cs_mod.aggregated_halo_exchange2(
                    items, axes=(ax_i, ax_j),
                    num_devices=grp.events[0].num_devices,
                    device_indices=(d_i, d_j))
                for ev, win in zip(grp.events, wins):
                    prefetched[(ev.consumer_idx, ev.key)] = win

        def materialize(key):
            tag = st[key][0]
            if tag == "repl":
                return st[key][1]
            _, stacks, bases, covers, prior, dtype = st[key]
            g = jax.lax.all_gather(stacks, ax_i, axis=1, tiled=False)
            g = jax.lax.all_gather(g, ax_j, axis=4, tiled=False)
            flat = g.reshape(
                (g.shape[0] * g.shape[1] * g.shape[2],
                 g.shape[3] * g.shape[4] * g.shape[5]) + g.shape[6:])
            flat = flat[:covers[0], :covers[1]].astype(dtype)
            if prior is None:
                full = flat
            else:
                full = jax.lax.dynamic_update_slice(
                    prior, flat, bases + (0,) * (flat.ndim - 2))
            st[key] = ("repl", full)
            return full

        for si, se in enumerate(rp.stages):
            for k in se.gathers:
                materialize(k)

            if se.kind == "serial":
                env_full = {k: e[1] for k, e in st.items() if e[0] == "repl"}
                upd = se.stage.fn(env_full)
                for k, v in upd.items():
                    st[k] = ("repl", jnp.asarray(v))
                continue

            plan = se.plan
            ch_i, ch_j = plan.chunks_axes
            trips = plan.nest.trip_counts
            if plan.nest.total_trip == 0:
                for key, dec in plan.vars.items():
                    if dec.out_strategy == "reduce":
                        rop = red_mod.get_reduction(dec.reduction_op)
                        info = plan.context.vars[key]
                        val = red_mod.identity_like(
                            rop, jnp.zeros(info.write.value_shape,
                                           info.write.value_dtype))
                        if key in st:
                            val = rop.pairwise(materialize(key), val)
                        st[key] = ("repl", val)
                continue

            env_in: dict[str, Any] = {}
            slab_stacks: dict[str, Any] = {}
            for key in plan.context.env_keys:
                dec = plan.vars[key]
                if dec.in_strategy == "shard_halo":
                    feed = se.feeds[key]
                    if feed == "resident":
                        slab_stacks[key] = st[key][1]
                    elif feed == "halo":
                        if aggregate:
                            slab_stacks[key] = prefetched.pop((si, key))
                            continue
                        _, stacks, bases, covers, prior, dtype = st[key]
                        halos = dec.halo_axes
                        slab_stacks[key] = comm_mod.halo_exchange2(
                            stacks, axes=(ax_i, ax_j),
                            num_devices=(ch_i.num_devices, ch_j.num_devices),
                            device_indices=(d_i, d_j),
                            chunks=(ch_i.chunk, ch_j.chunk),
                            deltas=tuple(
                                (h[0] - b, h[1] - b)
                                for h, b in zip(halos, bases)),
                            prior=prior, bases=bases, covers=covers,
                            dtype=dtype)
                    else:
                        halos = (dec.halo_axes if dec.halo_axes is not None
                                 else ((0, 0), (0, 0)))
                        x = st[key][1]
                        if dec.shard_ndim == 2:
                            slab_stacks[key] = nest_mod.local_slabs2(
                                x, (ch_i, ch_j), halos, (d_i, d_j))
                        else:
                            slab_stacks[key] = nest_mod.local_slabs(
                                x, ch_i, halos[0], d_i)
                elif dec.in_strategy == "replicate":
                    env_in[key] = st[key][1]

            if not dr.use_pallas:
                carry, ys = tf._run_local_chunks2(
                    plan, se.stage, env_in, slab_stacks, (d_i, d_j),
                    dr.unroll_chunks)
            else:
                if si not in span_results:
                    run_span(si, env_in, slab_stacks)
                carry, ys = span_results.pop(si)

            reduce_items: dict[str, tuple] = {}
            for key, dec in plan.vars.items():
                info = plan.context.vars[key]
                if dec.out_strategy == "identity":
                    st[key] = ("slab2", ys[key], (0, 0), trips, None,
                               info.dtype)
                elif dec.out_strategy == "partial":
                    bases = tuple(m.b for m in dec.write_maps)
                    prev = st.get(key)
                    if (prev is not None and prev[0] == "slab2"
                            and prev[2] == bases and prev[3] == trips):
                        prior = prev[4]     # same rectangle: chain the prior
                    else:
                        prior = st[key][1]  # replicated (planner enforced)
                    st[key] = ("slab2", ys[key], bases, trips, prior,
                               info.dtype)
                elif dec.out_strategy == "reduce":
                    rop = red_mod.get_reduction(dec.reduction_op)
                    if aggregate:
                        reduce_items[key] = (rop, carry[key])
                        continue
                    val = red_mod.cross_device_combine(
                        rop, carry[key], (ax_i, ax_j))
                    if key in st:
                        val = rop.pairwise(st[key][1], val)
                    st[key] = ("repl", val)

            if reduce_items:
                combined = cs_mod.fused_cross_device_combine(
                    reduce_items, (ax_i, ax_j))
                for key, val in combined.items():
                    rop = reduce_items[key][0]
                    if key in st:
                        val = rop.pairwise(st[key][1], val)
                    st[key] = ("repl", val)

            if aggregate:
                issue_prefetch(si)

        outs_repl = {k: st[k][1] for k in repl_out}
        outs_slab = {k: st[k][1][:, None, :, :, None] for k in slab_out}
        outs_prior = {k: st[k][4] for k in prior_out}
        return outs_repl, outs_slab, outs_prior

    in_specs = ({k: P() for k in env},)
    out_specs = (
        {k: P() for k in repl_out},
        {k: slab_spec((ax_i, ax_j)) for k in slab_out},
        {k: P() for k in prior_out},
    )
    if not rp.touched_keys:
        return dict(env)

    outs_repl, outs_slab, outs_prior = shard_map(
        device_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
    )(env)

    # --- reassembly at the jit level (layout, not messages) ---------------
    result = dict(env)
    for key in repl_out:
        result[key] = outs_repl[key]
    for key, lay in slab_out.items():
        g = outs_slab[key]               # (n_i, P_i, c_i, n_j, P_j, c_j, *)
        flat = g.reshape(
            (g.shape[0] * g.shape[1] * g.shape[2],
             g.shape[3] * g.shape[4] * g.shape[5]) + g.shape[6:])
        flat = flat[:lay.covers[0], :lay.covers[1]]
        flat = flat.astype(env_dtypes.get(key, flat.dtype))
        if lay.has_prior:
            result[key] = jax.lax.dynamic_update_slice(
                outs_prior[key], flat, lay.bases + (0,) * (flat.ndim - 2))
        else:
            result[key] = flat
    return result
