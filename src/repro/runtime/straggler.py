"""Straggler detection and mitigation.

In lock-step SPMD a slow host stalls every step (the collective waits).
Mitigations available without breaking SPMD semantics:

1. *detect* — per-step wall-time EWMA + spike counting (this module);
2. *re-balance* — the paper's own answer: dynamic-schedule
   over-decomposition.  The pragma engine's cyclic chunking
   (core/schedule.py) already deals 10x more chunks than devices, so a
   persistently slow device can be given fewer chunks by regenerating
   the chunk plan with a ``weights`` vector (``rebalance_chunks``);
3. *escalate* — report the host for eviction (elastic re-mesh,
   runtime/elastic.py) once it exceeds the spike budget.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass
class StragglerMonitor:
    ewma_alpha: float = 0.2
    spike_factor: float = 2.0
    spike_budget: int = 5

    _ewma: float | None = None
    spikes: int = 0
    steps: int = 0

    def observe(self, step_time_s: float) -> str:
        """Returns "ok" | "spike" | "evict"."""
        self.steps += 1
        if self._ewma is None:
            self._ewma = step_time_s
            return "ok"
        status = "ok"
        if step_time_s > self.spike_factor * self._ewma:
            self.spikes += 1
            status = "spike"
            if self.spikes >= self.spike_budget:
                status = "evict"
        else:
            self.spikes = max(0, self.spikes - 1)
        self._ewma = ((1 - self.ewma_alpha) * self._ewma
                      + self.ewma_alpha * step_time_s)
        return status

    @property
    def ewma(self) -> float:
        return self._ewma or 0.0


def rebalance_chunks(num_chunks: int, weights: list[float]) -> list[int]:
    """Deal ``num_chunks`` cyclic chunks proportionally to per-device
    speed ``weights`` (higher = faster = more chunks).  Returns the
    device owner of each chunk — the straggler-aware replacement for
    ``chunk j -> device j % P``.

    Quotas are assigned by largest-remainder apportionment, which
    always sums exactly to ``num_chunks`` and so terminates for every
    input — including ``num_chunks < len(weights)``, where the slowest
    devices simply receive zero chunks.  When there are at least as
    many chunks as devices, every device receives at least one chunk
    (SPMD lock-step means an idle device still pays for the step; a
    zero quota would only waste its slot).
    """
    if num_chunks < 1:
        raise ValueError(f"num_chunks must be >= 1, got {num_chunks}")
    p = len(weights)
    if p == 0:
        raise ValueError("weights must be non-empty")
    for w in weights:
        if not math.isfinite(w) or w <= 0:
            raise ValueError(
                f"weights must be finite and > 0, got {list(weights)}")
    total = float(sum(weights))
    ideal = [num_chunks * w / total for w in weights]
    quota = [int(f) for f in (math.floor(x) for x in ideal)]
    if num_chunks >= p:
        quota = [max(1, q) for q in quota]
    # Largest-remainder repair: hand out (or claw back) the rounding
    # drift one chunk at a time, fastest-first, never below the floor.
    floor = 1 if num_chunks >= p else 0
    drift = num_chunks - sum(quota)
    order = sorted(range(p), key=lambda i: (-(ideal[i] - quota[i]), i))
    i = 0
    while drift > 0:
        quota[order[i % p]] += 1
        drift -= 1
        i += 1
    order = sorted(range(p), key=lambda i: (ideal[i] - quota[i], i))
    i = 0
    while drift < 0:
        d = order[i % p]
        if quota[d] > floor:
            quota[d] -= 1
            drift += 1
        i += 1
    owners: list[int] = []
    remaining = quota[:]
    dev = 0
    for _ in range(num_chunks):
        while remaining[dev % p] == 0:
            dev += 1
        owners.append(dev % p)
        remaining[dev % p] -= 1
        dev += 1
    return owners
