"""Distributed runtime: fault tolerance, straggler mitigation, elasticity."""
from repro.runtime.fault_tolerance import FaultTolerantLoop  # noqa: F401
from repro.runtime.straggler import StragglerMonitor, rebalance_chunks  # noqa: F401
from repro.runtime.elastic import plan_elastic_remesh, reshard_tree  # noqa: F401
from repro.runtime.fault_injection import (  # noqa: F401
    DeviceLossError, FaultPlan, FaultSpec, Injector, inject)
from repro.runtime.resilient import (  # noqa: F401
    CorruptOutputError, ResilientExecutor, RetryPolicy)
