"""Distributed runtime: fault tolerance, straggler mitigation, elasticity."""
from repro.runtime.fault_tolerance import FaultTolerantLoop  # noqa: F401
from repro.runtime.straggler import StragglerMonitor  # noqa: F401
from repro.runtime.elastic import plan_elastic_remesh, reshard_tree  # noqa: F401
