"""Elastic scaling: re-mesh planning and checkpoint resharding.

When the fleet grows or shrinks (node joins / eviction), the job
restarts with a new device count.  Two invariants make this cheap:

* params are saved *unsharded per host shard* by the checkpointer, so a
  restore under a different mesh just re-places the same arrays with the
  new NamedShardings (GSPMD reshards on first use);
* the data pipeline is keyed by (seed, step, shard), so shard
  re-numbering is a pure function of the new topology.

``plan_elastic_remesh`` picks the nearest valid (data, model) factoring
for the new chip count; ``reshard_tree`` re-places a restored tree under
the new mesh.
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    old_shape: tuple[int, ...]
    new_shape: tuple[int, ...]
    axes: tuple[str, ...]
    note: str


def plan_elastic_remesh(n_devices: int, *, model_parallel: int,
                        axes=("data", "model")) -> RemeshPlan:
    """Keep model-parallel degree fixed (it is tied to the weight layout
    budget), flex the data axis; shrink TP only if chips < TP."""
    tp = model_parallel
    note = ""
    while n_devices % tp != 0 or n_devices < tp:
        tp //= 2
        note = f"model axis shrunk to {tp} (chip count {n_devices})"
        if tp == 0:
            raise ValueError("no valid mesh factoring")
    dp = n_devices // tp
    return RemeshPlan(old_shape=(-1, model_parallel),
                      new_shape=(dp, tp), axes=tuple(axes), note=note)


def reshard_tree(tree, specs, mesh: Mesh):
    """Re-place every leaf under the new mesh (GSPMD moves the bytes)."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        tree, specs)
