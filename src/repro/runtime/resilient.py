"""Fault-tolerant wrapper around :class:`~repro.core.api.Compiled`.

The generated MPI programs of the paper assume a healthy, fixed-size
communicator; a single lost rank kills the job.  The
:class:`ResilientExecutor` closes that gap at the runtime layer:

* **retry** — per-call retry with exponential backoff and seeded
  jitter absorbs transient faults (spurious device errors, injected
  delays, one-off NaN outputs when validation is on);
* **validation** — optional NaN/Inf screening of every inexact output
  leaf turns silent corruption into a retryable failure;
* **degraded-mesh recovery** — when a call fails persistently, the
  executor plans the nearest valid factoring for one fewer device
  (:func:`~repro.runtime.elastic.plan_elastic_remesh`), builds the
  shrunk mesh from the surviving devices, recompiles the *same*
  program through a single-flighted
  :class:`~repro.serving.compile_service.CompileService` (hitting the
  structural and AOT caches when warm), re-places the inputs under the
  new mesh (:func:`~repro.runtime.elastic.reshard_tree`) and re-runs.

Recovery is sticky: after a successful degraded run the executor keeps
serving from the shrunk mesh (the lost device is presumed gone) until
:meth:`ResilientExecutor.reset`.

Chunk-cyclic layouts make the recompile semantically a no-op for
element-wise and stencil outputs (bit-identical); reductions regroup
their per-device partial folds, so reduce keys match to float
tolerance — pinned by the differential tests in
``tests/test_resilient.py``.
"""
from __future__ import annotations

import dataclasses
import math
import random
import time
from typing import Any, Callable, Mapping

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec

from repro.runtime.elastic import plan_elastic_remesh, reshard_tree
from repro.runtime.fault_injection import DeviceLossError


class CorruptOutputError(RuntimeError):
    """Output validation found non-finite values."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """How hard to try before declaring the mesh degraded.

    ``backoff_s`` is the first sleep; each further retry multiplies it
    by ``backoff_factor`` and adds uniform jitter in ``[0, jitter_s)``
    drawn from ``random.Random(seed)`` — deterministic, so a CI replay
    sleeps the same schedule."""

    max_retries: int = 2
    backoff_s: float = 0.0
    backoff_factor: float = 2.0
    jitter_s: float = 0.0
    seed: int = 0
    validate_outputs: bool = True

    def __post_init__(self) -> None:
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.backoff_s < 0 or self.jitter_s < 0:
            raise ValueError("backoff_s and jitter_s must be >= 0")
        if self.backoff_factor < 1.0:
            raise ValueError(
                f"backoff_factor must be >= 1, got {self.backoff_factor}")


class ResilientExecutor:
    """Wrap a :class:`~repro.core.api.Compiled` with retry, output
    validation and degraded-mesh recovery.

    ``on_recover`` (optional) is called with the
    :class:`~repro.runtime.elastic.RemeshPlan` when recovery engages.
    ``stats`` counts ``calls`` / ``retries`` / ``validation_failures``
    / ``recoveries``.
    """

    def __init__(self, compiled, *, policy: RetryPolicy | None = None,
                 on_recover: Callable[..., None] | None = None) -> None:
        self.compiled = compiled
        self.policy = policy if policy is not None else RetryPolicy()
        self._rng = random.Random(self.policy.seed)
        self._on_recover = on_recover
        self.stats = {"calls": 0, "retries": 0, "validation_failures": 0,
                      "recoveries": 0}
        self.remesh_plan = None
        self._degraded: Any = None       # (CompileService, Mesh) once set

    # ------------------------------------------------------------- api --
    def run(self, env: Mapping[str, Any]) -> dict:
        self.stats["calls"] += 1
        if self._degraded is not None:
            return self._run_degraded(env)
        pol = self.policy
        delay = pol.backoff_s
        last: BaseException | None = None
        for attempt in range(pol.max_retries + 1):
            try:
                out = self.compiled.run(env)
                if pol.validate_outputs:
                    self._validate(out)
                return out
            except Exception as e:           # noqa: BLE001 — retry scope
                last = e
                if attempt < pol.max_retries:
                    self.stats["retries"] += 1
                    sleep = delay
                    if pol.jitter_s:
                        sleep += self._rng.uniform(0.0, pol.jitter_s)
                    if sleep > 0:
                        time.sleep(sleep)
                    delay *= pol.backoff_factor
        return self._recover(env, last)

    __call__ = run

    @property
    def degraded(self) -> bool:
        return self._degraded is not None

    def reset(self) -> None:
        """Forget the degraded mesh (e.g. the fleet healed): the next
        call goes back to the original compiled artifact."""
        if self._degraded is not None:
            self._degraded[0].shutdown()
        self._degraded = None
        self.remesh_plan = None

    # ------------------------------------------------------ validation --
    def _validate(self, out: Mapping[str, Any]) -> None:
        import jax.numpy as jnp

        bad = [k for k, v in out.items()
               if jnp.issubdtype(jnp.asarray(v).dtype, jnp.inexact)
               and not bool(jnp.all(jnp.isfinite(v)))]
        if bad:
            self.stats["validation_failures"] += 1
            raise CorruptOutputError(
                f"non-finite values in output keys {bad}")

    # -------------------------------------------------------- recovery --
    def _recover(self, env, cause: BaseException | None) -> dict:
        """Persistent failure: drop one device, recompile on the
        shrunk mesh, re-place inputs, re-run."""
        mesh = self.compiled.mesh
        devices = list(np.asarray(mesh.devices).flat)
        lost = 0
        if isinstance(cause, DeviceLossError):
            # keep the surviving devices, not blindly the suffix
            import re
            m = re.search(r"rank (\d+)", str(cause))
            if m:
                lost = min(int(m.group(1)), len(devices) - 1)
        survivors = devices[:lost] + devices[lost + 1:]
        if not survivors:                # single-device mesh: nothing to drop
            survivors = devices
        n_alive = len(survivors)

        old_shape = tuple(np.asarray(mesh.devices).shape)
        mp = old_shape[1] if len(old_shape) > 1 else 1
        plan = plan_elastic_remesh(n_alive, model_parallel=mp,
                                   axes=mesh.axis_names)
        self.remesh_plan = plan
        new_shape = (plan.new_shape if len(old_shape) > 1
                     else (plan.new_shape[0] * plan.new_shape[1],))
        n_new = math.prod(new_shape)
        new_mesh = Mesh(np.asarray(survivors[:n_new]).reshape(new_shape),
                        mesh.axis_names)

        options = self.compiled.options
        if options.chunk_weights is not None:
            # weights are per-device of the *old* mesh — drop them
            options = dataclasses.replace(options, chunk_weights=None)

        from repro.serving.compile_service import CompileService
        service = CompileService(new_mesh, options=options)
        out = service.run(self.compiled.program, self._replace_env(env, new_mesh))
        # only now (recovery succeeded) commit to the degraded mesh
        self._degraded = (service, new_mesh, options)
        self.stats["recoveries"] += 1
        if self._on_recover is not None:
            self._on_recover(plan)
        if self.policy.validate_outputs:
            self._validate(out)
        return out

    def _run_degraded(self, env) -> dict:
        service, new_mesh, options = self._degraded
        return service.run(self.compiled.program,
                           self._replace_env(env, new_mesh), options)

    @staticmethod
    def _replace_env(env, new_mesh) -> dict:
        """Re-place every input leaf replicated under the new mesh —
        the elastic invariant: a restore under a different mesh is a
        re-placement, not a reshape."""
        env = dict(env)
        specs = {k: PartitionSpec() for k in env}
        return reshard_tree(env, specs, new_mesh)
