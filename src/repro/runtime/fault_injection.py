"""Deterministic fault injection for the distributed executors.

The paper's pipeline stops at code generation — it never asks what
happens when a generated rank *dies* mid-run.  This module makes that
question testable: a :class:`FaultPlan` is a seeded, reproducible
script of faults (device loss, output corruption, artificial delay)
pinned to exact call indices and hook sites, and :func:`inject`
installs it into the hook points the executors already carry
(``repro.core.api._fault_hook`` / ``repro.core.transform._fault_hook``).

Hook sites (fired per :meth:`Compiled.run <repro.core.api.Compiled.run>`
call):

* ``"run"``        — entry of ``Compiled.run`` (also advances the call
  counter),
* ``"run_exit"``   — exit of ``Compiled.run``; the hook's return value
  replaces the output dict, which is how ``"nan"`` corruption lands,
* ``"collective"`` / ``"collective2"`` — entry of the rank-1 / rank-2
  chunk-cyclic collective executors,
* ``"region"`` / ``"region2"``         — entry of the rank-1 / rank-2
  fused region executors.

Executor-site faults fire on the interpreted (non-AOT-restored) path;
the entry/exit sites fire always.  Injection is process-local and
scoped: :func:`inject` is a context manager that restores the previous
hooks on exit, so a crashed test cannot leak faults into the next one.
"""
from __future__ import annotations

import contextlib
import dataclasses
import random
import time
from typing import Iterator

KINDS = ("device_loss", "nan", "delay")
SITES = ("run", "collective", "collective2", "region", "region2")


class DeviceLossError(RuntimeError):
    """An injected (or detected) loss of a device mid-execution."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scripted fault: at ``Compiled.run`` call number ``call``
    (0-based), at hook ``site``, do ``kind``.

    ``rank`` is bookkeeping — which device is deemed to have failed —
    consumed by recovery logic, not by the injector.  ``"nan"`` faults
    always land at ``run_exit`` of their call (output corruption has no
    executor-interior analogue), so they require ``site == "run"``.
    """

    call: int
    kind: str = "device_loss"
    site: str = "run"
    rank: int = 0
    delay_s: float = 0.0

    def __post_init__(self) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"kind must be one of {KINDS}, got {self.kind!r}")
        if self.site not in SITES:
            raise ValueError(f"site must be one of {SITES}, got {self.site!r}")
        if self.call < 0:
            raise ValueError(f"call must be >= 0, got {self.call}")
        if self.kind == "nan" and self.site != "run":
            raise ValueError(
                "kind='nan' corrupts outputs at run_exit; site must be 'run'")
        if self.kind == "delay" and self.delay_s < 0:
            raise ValueError(f"delay_s must be >= 0, got {self.delay_s}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable script of :class:`FaultSpec`\\ s.  Two plans built
    from the same seed are identical, so a failure seen in CI replays
    bit-for-bit locally."""

    specs: tuple[FaultSpec, ...] = ()

    @classmethod
    def seeded(cls, seed: int, *, calls: int, rate: float = 0.25,
               kinds=KINDS, sites=("run",), n_ranks: int = 1,
               delay_s: float = 0.005) -> "FaultPlan":
        """Draw a reproducible plan: each of ``calls`` run() calls
        faults with probability ``rate``; kind/site/rank drawn from the
        given pools with ``random.Random(seed)``."""
        rng = random.Random(seed)
        specs = []
        for call in range(calls):
            if rng.random() >= rate:
                continue
            kind = rng.choice(tuple(kinds))
            site = "run" if kind == "nan" else rng.choice(tuple(sites))
            specs.append(FaultSpec(
                call=call, kind=kind, site=site,
                rank=rng.randrange(max(1, n_ranks)),
                delay_s=delay_s if kind == "delay" else 0.0))
        return cls(specs=tuple(specs))

    def at_call(self, call: int) -> tuple[FaultSpec, ...]:
        return tuple(s for s in self.specs if s.call == call)


def _poison(out):
    """Corrupt every inexact leaf of an output env with one NaN —
    the signature of a silently-misbehaving device."""
    import jax.numpy as jnp

    def bad(x):
        x = jnp.asarray(x)
        if not jnp.issubdtype(x.dtype, jnp.inexact):
            return x
        if x.ndim == 0:
            return jnp.asarray(float("nan"), dtype=x.dtype)
        return x.at[(0,) * x.ndim].set(float("nan"))

    return {k: bad(v) for k, v in dict(out).items()}


class Injector:
    """The installed hook: counts ``Compiled.run`` calls and fires the
    plan's matching specs.  ``fired`` records ``(call, spec)`` in order
    — tests assert the script executed exactly as written."""

    def __init__(self, plan: FaultPlan) -> None:
        self.plan = plan
        self.calls = 0                  # completed "run" entries seen
        self.fired: list[tuple[int, FaultSpec]] = []

    def call_count(self) -> int:
        return self.calls

    def __call__(self, site: str, out=None):
        if site == "run":
            self.calls += 1
        cur = self.calls - 1
        if cur < 0:           # executor fired outside any run() (warmup)
            return out if site == "run_exit" else None
        for spec in self.plan.at_call(cur):
            if site == "run_exit":
                if spec.kind == "nan":
                    self.fired.append((cur, spec))
                    out = _poison(out)
                continue
            if spec.site != site:
                continue
            if spec.kind == "delay":
                self.fired.append((cur, spec))
                time.sleep(spec.delay_s)
            elif spec.kind == "device_loss":
                self.fired.append((cur, spec))
                raise DeviceLossError(
                    f"injected device loss: rank {spec.rank} at call "
                    f"{cur} (site {site!r})")
        return out if site == "run_exit" else None


@contextlib.contextmanager
def inject(plan: FaultPlan) -> Iterator[Injector]:
    """Install ``plan`` into the executor hook points for the duration
    of the ``with`` block; previous hooks are restored on exit."""
    from repro.core import api, transform

    inj = Injector(plan)
    prev_api, prev_tf = api._fault_hook, transform._fault_hook
    api._fault_hook = inj
    transform._fault_hook = inj
    try:
        yield inj
    finally:
        api._fault_hook = prev_api
        transform._fault_hook = prev_tf
