"""Fault tolerance: checkpoint/restart orchestration.

On a 1000+-node fleet the failure model is: a worker dies (hardware,
preemption), the SPMD step collectively fails on every host, the job
restarts, and training must resume bit-exactly from the last checkpoint.
The pieces here:

* :class:`FaultTolerantLoop` — wraps the train step: periodic async
  checkpoints, exception-driven restore (retry budget with exponential
  backoff), deterministic data replay (the data pipeline is keyed by
  (seed, step, shard), so resuming at step N regenerates exactly the
  batches the lost run would have seen);
* injectable ``failure_hook`` used by the test-suite to simulate device
  loss at a chosen step and assert recovery equivalence.

The *distributed-agreement* part (all hosts restarting on the same step)
falls out of checkpoint atomicity: a step directory either exists with a
manifest on every host or is ignored.
"""
from __future__ import annotations

import logging
import time
from typing import Any, Callable

from repro.checkpoint import Checkpointer

log = logging.getLogger(__name__)


class StepFailure(RuntimeError):
    """Raised by the failure hook / caught from the backend."""


class FaultTolerantLoop:
    def __init__(
        self,
        *,
        step_fn: Callable[[Any, int], Any],       # state, step -> state
        checkpointer: Checkpointer,
        checkpoint_every: int = 50,
        max_retries: int = 3,
        backoff_s: float = 0.1,
        failure_hook: Callable[[int], None] | None = None,
        on_restore: Callable[[Any], Any] | None = None,
    ) -> None:
        self.step_fn = step_fn
        self.ckpt = checkpointer
        self.checkpoint_every = checkpoint_every
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.failure_hook = failure_hook
        self.on_restore = on_restore
        self.retries_used = 0
        self.restores = 0
        # The retry budget is *per incident*: once the loop makes real
        # progress past the failing step after a restore, the counter
        # rearms so a long run survives any number of isolated transient
        # failures.  Replayed steps before the failure point do NOT
        # rearm — a step that fails deterministically still exhausts.
        self._reset_pending = False
        self._failed_step: int | None = None

    def run(self, state: Any, *, start_step: int, num_steps: int) -> Any:
        step = start_step
        end = start_step + num_steps
        initial = state
        while step < end:
            try:
                if self.failure_hook is not None:
                    self.failure_hook(step)
                state = self.step_fn(state, step)
                if self._reset_pending and step >= self._failed_step:
                    self.retries_used = 0
                    self._reset_pending = False
                step += 1
                if step % self.checkpoint_every == 0:
                    self.ckpt.save_async(step, state)
            except Exception as e:  # noqa: BLE001 - the restart boundary
                self.retries_used += 1
                self._failed_step = step
                if self.retries_used > self.max_retries:
                    raise RuntimeError(
                        f"retry budget exhausted at step {step}") from e
                log.warning("step %d failed (%s); restoring", step, e)
                time.sleep(self.backoff_s * (2 ** (self.retries_used - 1)))
                # Drain any in-flight async save first: without this the
                # restore can race the background writer, miss the newest
                # checkpoint, and silently restart further back.
                self.ckpt.wait()
                restored = self.ckpt.restore_latest(state)
                if restored is None:
                    # no checkpoint yet: restart from the initial state
                    step = start_step
                    state = initial
                else:
                    step, state = restored
                if self.on_restore is not None:
                    state = self.on_restore(state)
                self.restores += 1
                self._reset_pending = True
        self.ckpt.wait()
        return state
