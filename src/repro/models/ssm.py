"""Mamba-2 SSD (state-space duality) block. [arXiv:2405.21060]

The sequence loop of an SSM is a *loop-carried dependence* — the exact
case OMP2MPI's Loop Analysis rejects (DESIGN.md §Arch-applicability).
SSD's chunked reformulation restores parallelism: intra-chunk work is a
dense parallel loop (distributable), and only the O(S/Q) chunk-state
recurrence remains sequential (an associative ``recurrent`` clause,
lowered to ``lax.scan``).  That is the faithful adaptation of the paper's
technique to this family.

Layout: x (B,S,D); heads h = d_inner/head_dim; shared single-group B/C of
width d_state.  The Pallas kernel in repro.kernels/ssd_scan.py implements
the intra-chunk part with VMEM tiling; this module is its jnp oracle twin
and the default lowering path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import tensor_plan as tp
from repro.models.layers import make_param, zeros_param


def init_ssm(key, d_model: int, ssm_cfg):
    din = ssm_cfg.d_inner(d_model)
    nh = ssm_cfg.n_heads(d_model)
    ds = ssm_cfg.d_state
    cw = ssm_cfg.d_conv
    ks = jax.random.split(key, 8)
    t = {
        "w_z": make_param(ks[0], (d_model, din), (tp.D_MODEL, tp.D_INNER)),
        "w_x": make_param(ks[1], (d_model, din), (tp.D_MODEL, tp.D_INNER)),
        "w_B": make_param(ks[2], (d_model, ds), (tp.D_MODEL, tp.D_STATE)),
        "w_C": make_param(ks[3], (d_model, ds), (tp.D_MODEL, tp.D_STATE)),
        "w_dt": make_param(ks[4], (d_model, nh), (tp.D_MODEL, tp.HEADS)),
        "conv_x": make_param(ks[5], (cw, din), (tp.CONV, tp.D_INNER), 0.5),
        "conv_B": make_param(ks[6], (cw, ds), (tp.CONV, tp.D_STATE), 0.5),
        "conv_C": make_param(ks[7], (cw, ds), (tp.CONV, tp.D_STATE), 0.5),
        # A in (-16, -1): stable decay; dt_bias ~ softplus^-1(0.01..0.1)
        "A_log": (jnp.log(jnp.linspace(1.0, 16.0, nh)), (tp.HEADS,)),
        "D": (jnp.ones((nh,)), (tp.HEADS,)),
        "dt_bias": (jnp.full((nh,), -4.6), (tp.HEADS,)),
    }
    return t


def _causal_conv(u, w, state=None):
    """Depthwise causal conv. u: (B,S,C), w: (cw,C).

    ``state`` ((B, cw-1, C)) prepends history for decode/continuation;
    returns (y, new_state)."""
    cw = w.shape[0]
    if state is None:
        state = jnp.zeros((u.shape[0], cw - 1, u.shape[2]), u.dtype)
    full = jnp.concatenate([state, u], axis=1)           # (B, S+cw-1, C)
    y = jnp.zeros_like(u)
    for k in range(cw):
        y = y + full[:, k:k + u.shape[1]] * w[k]
    new_state = full[:, full.shape[1] - (cw - 1):]
    return y, new_state


def _segsum_decay(a):
    """a: (..., Q, h) cumulative dA. Returns exp(a_i - a_j) masked i>=j:
    (..., Q, Q, h)."""
    q = a.shape[-2]
    seg = a[..., :, None, :] - a[..., None, :, :]
    mask = (jnp.arange(q)[:, None] >= jnp.arange(q)[None, :])[..., None]
    return jnp.where(mask, jnp.exp(seg), 0.0)


def ssd_chunked(xh, dt, A, Bc, Cc, D, *, chunk: int, h0=None):
    """Chunked SSD scan.

    xh: (B,S,h,p); dt: (B,S,h) (post-softplus); A: (h,) negative;
    Bc, Cc: (B,S,s); D: (h,). Returns (y (B,S,h,p), h_final (B,h,p,s)).
    """
    b, s, h, p = xh.shape
    ds = Bc.shape[-1]
    q = chunk
    nc = -(-s // q)
    pad = nc * q - s
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
    f32 = jnp.float32
    xc = xh.reshape(b, nc, q, h, p).astype(f32)
    dtc = dt.reshape(b, nc, q, h).astype(f32)
    Bcc = Bc.reshape(b, nc, q, ds).astype(f32)
    Ccc = Cc.reshape(b, nc, q, ds).astype(f32)

    dA = dtc * A                                         # (b,nc,q,h) <= 0
    a = jnp.cumsum(dA, axis=2)
    decay = _segsum_decay(a)                             # (b,nc,q,q,h)
    cb = jnp.einsum("bcqs,bcks->bcqk", Ccc, Bcc)
    scores = cb[..., None] * decay * dtc[:, :, None]     # (b,nc,q,k,h)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", scores, xc)

    # per-chunk state contribution and total decay
    a_last = a[:, :, -1]                                 # (b,nc,h)
    w = jnp.exp(a_last[:, :, None] - a) * dtc            # (b,nc,q,h)
    h_chunk = jnp.einsum("bckh,bcks,bckhp->bchps", w, Bcc, xc)
    t_chunk = jnp.exp(a_last)                            # (b,nc,h)

    if h0 is None:
        h0 = jnp.zeros((b, h, p, ds), f32)

    def step(hprev, blk):
        hc, tc = blk                                     # (b,h,p,s), (b,h)
        hnew = hprev * tc[:, :, None, None] + hc
        return hnew, hprev

    h_final, h_prevs = jax.lax.scan(
        step, h0.astype(f32),
        (h_chunk.swapaxes(0, 1), t_chunk.swapaxes(0, 1)))
    h_prevs = h_prevs.swapaxes(0, 1)                     # (b,nc,h,p,s)

    y_inter = jnp.einsum("bcqs,bchps->bcqhp", Ccc, h_prevs) \
        * jnp.exp(a)[..., None]
    y = (y_intra + y_inter + xc * D[:, None]).reshape(b, nc * q, h, p)
    return y[:, :s].astype(xh.dtype), h_final


def ssm_apply(p, x, ssm_cfg, *, cache=None):
    """Full SSD block. x: (B,S,D) -> (y (B,S,D), new_cache).

    ``cache``: {"h": (B,h,p,s), "conv": (B,cw-1,din+2ds)} for decode /
    chunked prefill continuation; None for fresh sequences.
    """
    b, s, d = x.shape
    dtype = x.dtype
    din = p["w_x"].shape[1]
    nh = p["w_dt"].shape[1]
    hd = din // nh
    ds = p["w_B"].shape[1]

    z = jnp.einsum("bsd,dk->bsk", x, p["w_z"].astype(dtype))
    xin = jnp.einsum("bsd,dk->bsk", x, p["w_x"].astype(dtype))
    Bin = jnp.einsum("bsd,dk->bsk", x, p["w_B"].astype(dtype))
    Cin = jnp.einsum("bsd,dk->bsk", x, p["w_C"].astype(dtype))
    dt = jnp.einsum("bsd,dk->bsk", x, p["w_dt"].astype(dtype)) \
        + p["dt_bias"].astype(dtype)

    conv_w = jnp.concatenate(
        [p["conv_x"], p["conv_B"], p["conv_C"]], axis=1).astype(dtype)
    u = jnp.concatenate([xin, Bin, Cin], axis=2)
    conv_state = None if cache is None else cache["conv"]
    u, new_conv = _causal_conv(u, conv_w, conv_state)
    u = jax.nn.silu(u)
    xin, Bin, Cin = jnp.split(u, [din, din + ds], axis=2)

    dt = jax.nn.softplus(dt.astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xin.reshape(b, s, nh, hd)
    h0 = None if cache is None else cache["h"]

    if s == 1 and cache is not None:
        # decode: one recurrence step, no chunking
        dA = jnp.exp(dt[:, 0] * A)                       # (b,h)
        upd = jnp.einsum("bh,bs,bhp->bhps", dt[:, 0],
                         Bin[:, 0].astype(jnp.float32),
                         xh[:, 0].astype(jnp.float32))
        h_new = h0 * dA[:, :, None, None] + upd
        y = jnp.einsum("bs,bhps->bhp", Cin[:, 0].astype(jnp.float32), h_new)
        y = y + xh[:, 0].astype(jnp.float32) * p["D"][:, None]
        y = y[:, None].astype(dtype)                     # (b,1,h,p)
        h_final = h_new
    else:
        y, h_final = ssd_chunked(
            xh, dt, A, Bin, Cin, p["D"].astype(jnp.float32),
            chunk=ssm_cfg.chunk, h0=h0)

    y = y.reshape(b, s, din) * jax.nn.silu(z)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(dtype))
    new_cache = {"h": h_final, "conv": new_conv}
    return out, new_cache


def init_ssm_block(key, d_model: int, ssm_cfg):
    t = init_ssm(key, d_model, ssm_cfg)
    din = ssm_cfg.d_inner(d_model)
    k_out = jax.random.fold_in(key, 99)
    t["out_proj"] = make_param(k_out, (din, d_model),
                               (tp.D_INNER, tp.D_MODEL))
    return t


def init_ssm_cache(batch: int, d_model: int, ssm_cfg, dtype=jnp.bfloat16):
    din = ssm_cfg.d_inner(d_model)
    nh = ssm_cfg.n_heads(d_model)
    return {
        "h": jnp.zeros((batch, nh, ssm_cfg.head_dim, ssm_cfg.d_state),
                       jnp.float32),
        "conv": jnp.zeros((batch, ssm_cfg.d_conv - 1,
                           din + 2 * ssm_cfg.d_state), dtype),
    }
