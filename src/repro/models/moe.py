"""Mixture-of-Experts FFN with capacity-based token dispatch.

MoE dispatch is the model-stack incarnation of the paper's
``schedule(dynamic)``: tokens are loop iterations, experts are workers,
and the capacity factor plays the role of the 10x over-decomposition —
bounding imbalance when the router's "schedule" is skewed.  Dispatch is
performed *per group* (a group = one data-parallel shard's tokens), so
the gather/scatter stays local to the shard and only the expert GEMMs
touch the expert-sharded (model-axis) weights — the same
shard-the-written-slices / replicate-the-read-buffers split the pragma
planner derives for explicit loops.

Supports: top-k routing (renormalised gates), shared experts with a
sigmoid gate (qwen2-moe), a dense FFN residual (arctic), and a
load-balance auxiliary loss (Switch-style).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import tensor_plan as tp
from repro.models.layers import init_mlp, make_param, mlp_apply


def init_moe(key, d_model: int, moe_cfg):
    ks = jax.random.split(key, 8)
    e, fe = moe_cfg.e_alloc, moe_cfg.d_expert
    t = {
        "router": make_param(ks[0], (d_model, moe_cfg.n_experts),
                             (tp.D_MODEL, tp.EXPERTS)),
        "w_gate": make_param(ks[1], (e, d_model, fe),
                             (tp.EXPERTS, tp.D_MODEL, tp.D_EXPERT)),
        "w_up": make_param(ks[2], (e, d_model, fe),
                           (tp.EXPERTS, tp.D_MODEL, tp.D_EXPERT)),
        "w_down": make_param(ks[3], (e, fe, d_model),
                             (tp.EXPERTS, tp.D_EXPERT, tp.D_MODEL)),
    }
    if moe_cfg.n_shared:
        t["shared"] = init_mlp(ks[4], d_model, moe_cfg.shared_d_ff,
                               gated=True)
        t["shared_gate"] = make_param(ks[5], (d_model, 1),
                                      (tp.D_MODEL, None))
    if moe_cfg.dense_residual_d_ff:
        t["dense"] = init_mlp(ks[6], d_model, moe_cfg.dense_residual_d_ff,
                              gated=True)
    return t


def _dispatch_one_group(x, logits, top_k: int, capacity: int):
    """x: (T,D), logits: (T,E) -> (y (T,D) contribution, aux metrics)."""
    t, d = x.shape
    e = logits.shape[-1]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)    # (T,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    sel = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)   # (T,k,E)
    mask = jnp.sum(sel, axis=1)                          # (T,E) 0/1
    # position of each token within its expert's capacity buffer
    pos = jnp.cumsum(mask, axis=0) * mask - 1            # (T,E)
    keep = jnp.logical_and(pos >= 0, pos < capacity)

    # scatter token ids into (E, C) dispatch table
    tok_ids = jnp.broadcast_to(jnp.arange(t)[:, None], (t, e))
    flat_e = jnp.broadcast_to(jnp.arange(e)[None, :], (t, e))
    pos_safe = jnp.where(keep, pos, capacity)            # OOB -> dropped
    table = jnp.full((e, capacity), t, jnp.int32)        # t == invalid
    table = table.at[flat_e.reshape(-1), pos_safe.reshape(-1)].set(
        tok_ids.reshape(-1), mode="drop")
    slot_valid = table < t                               # (E,C)
    table_safe = jnp.minimum(table, t - 1)

    gathered = x[table_safe] * slot_valid[..., None].astype(x.dtype)

    # combine weights per slot
    w_tok = (probs * mask * keep).astype(jnp.float32)    # (T,E) gate per pair
    w_tok = w_tok / jnp.maximum(
        jnp.sum(w_tok, axis=-1, keepdims=True), 1e-9)
    w_slot = w_tok[table_safe, jnp.arange(e)[:, None]] \
        * slot_valid.astype(jnp.float32)                 # (E,C)

    # load-balance aux (Switch): E * mean_e(frac_tokens_e * mean_prob_e)
    frac = jnp.mean(mask.astype(jnp.float32), axis=0)
    mean_p = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac * mean_p)
    dropped = 1.0 - jnp.sum(keep) / jnp.maximum(jnp.sum(mask), 1)
    return gathered, table_safe, slot_valid, w_slot, aux, dropped


def moe_apply(p, x, moe_cfg, *, groups: int = 1):
    """x: (B,S,D) -> (y, aux_loss). ``groups`` = DP shards: dispatch is
    local to each group (see module docstring)."""
    b, s, d = x.shape
    g = max(1, min(groups, b)) if b % max(1, min(groups, b)) == 0 else 1
    xt = x.reshape(g, (b // g) * s, d)
    tokens = xt.shape[1]
    e, k = moe_cfg.n_experts, moe_cfg.top_k
    capacity = max(1, int(k * tokens * moe_cfg.capacity_factor / e))

    logits = jnp.einsum("gtd,de->gte", xt, p["router"].astype(x.dtype))
    if moe_cfg.e_alloc > e:
        # padded (never-routed) experts unlock EP sharding (§Perf-E)
        pad = jnp.full(logits.shape[:-1] + (moe_cfg.e_alloc - e,), -1e9,
                       logits.dtype)
        logits = jnp.concatenate([logits, pad], axis=-1)

    def per_group(xg, lg):
        gathered, table, valid, w_slot, aux, dropped = _dispatch_one_group(
            xg, lg, k, capacity)
        h_gate = jnp.einsum("ecd,edf->ecf", gathered,
                            p["w_gate"].astype(x.dtype))
        h_up = jnp.einsum("ecd,edf->ecf", gathered,
                          p["w_up"].astype(x.dtype))
        h = jax.nn.silu(h_gate) * h_up
        out = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))
        out = out * w_slot[..., None].astype(x.dtype)
        y = jnp.zeros_like(xg)
        y = y.at[table.reshape(-1)].add(
            out.reshape(-1, d), mode="drop")
        return y, aux, dropped

    y, aux, dropped = jax.vmap(per_group)(xt, logits)
    y = y.reshape(b, s, d)
    aux_loss = jnp.mean(aux)

    if "shared" in p:
        gate = jax.nn.sigmoid(
            jnp.einsum("bsd,do->bso", x, p["shared_gate"].astype(x.dtype)))
        y = y + gate * mlp_apply(p["shared"], x, gated=True)
    if "dense" in p:
        y = y + mlp_apply(p["dense"], x, gated=True)
    return y, aux_loss
