"""Transformer / SSD / MoE blocks and the layer-interleave structure.

Heterogeneous stacks (jamba 1:7 attn:mamba, gemma3 5:1 local:global,
jamba MoE every 2nd layer) are expressed as a repeating *period* of block
kinds; the model scans over periods (stacked params) and unrolls the
remainder.  A block kind is the string "<mixer>:<flavour>:<ffn>" —
e.g. "attn:global:dense", "attn:local:moe", "ssm::none".
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import tensor_plan as tp
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    apply_rope,
    attention,
    init_mlp,
    make_param,
    mlp_apply,
    rms_norm,
    zeros_param,
)


# ---------------------------------------------------------------------------
# Period structure
# ---------------------------------------------------------------------------


def kind_of_layer(cfg, idx: int) -> str:
    mixer = cfg.layer_kind(idx)                 # "attn" | "ssm"
    flavour = cfg.attn_kind(idx) if mixer == "attn" else ""
    if cfg.d_ff == 0 and cfg.moe is None:
        ffn = "none"                            # mamba2: SSD block only
    else:
        ffn = cfg.ffn_kind(idx)                 # "dense" | "moe"
    return f"{mixer}:{flavour}:{ffn}"


def period_structure(cfg):
    """Returns (period_len, slot_kinds, n_periods, tail_kinds)."""
    p = 1
    if cfg.attn_layer_period:
        p = math.lcm(p, cfg.attn_layer_period)
    if cfg.local_global_period:
        p = math.lcm(p, cfg.local_global_period)
    if cfg.moe is not None:
        p = math.lcm(p, cfg.moe.period)
    p = min(p, cfg.n_layers)
    n_periods = cfg.n_layers // p
    tail_start = n_periods * p
    slot_kinds = [kind_of_layer(cfg, i) for i in range(p)]
    tail_kinds = [kind_of_layer(cfg, i) for i in range(tail_start,
                                                       cfg.n_layers)]
    # kinds must repeat exactly across periods for stacking to be valid
    for layer in range(tail_start):
        assert kind_of_layer(cfg, layer) == slot_kinds[layer % p], (
            cfg.name, layer)
    return p, slot_kinds, n_periods, tail_kinds


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_attn(key, cfg, *, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 5)
    t = {
        "norm": zeros_param((d,), (tp.D_MODEL,)),
        "wq": make_param(ks[0], (d, h, hd), (tp.D_MODEL, tp.HEADS,
                                             tp.HEAD_DIM)),
        "wk": make_param(ks[1], (d, kv, hd), (tp.D_MODEL, tp.KV_HEADS,
                                              tp.HEAD_DIM)),
        "wv": make_param(ks[2], (d, kv, hd), (tp.D_MODEL, tp.KV_HEADS,
                                              tp.HEAD_DIM)),
        "wo": make_param(ks[3], (h, hd, d), (tp.HEADS, tp.HEAD_DIM,
                                             tp.D_MODEL)),
    }
    if cfg.qkv_bias and not cross:
        t["bq"] = zeros_param((h, hd), (tp.HEADS, tp.HEAD_DIM))
        t["bk"] = zeros_param((kv, hd), (tp.KV_HEADS, tp.HEAD_DIM))
        t["bv"] = zeros_param((kv, hd), (tp.KV_HEADS, tp.HEAD_DIM))
    return t


def init_ffn(key, cfg, kind_ffn: str):
    if kind_ffn == "none":
        return None
    t = {"norm": zeros_param((cfg.d_model,), (tp.D_MODEL,))}
    if kind_ffn == "moe":
        t.update(moe_mod.init_moe(key, cfg.d_model, cfg.moe))
    else:
        t.update(init_mlp(key, cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp))
    return t


def init_block(key, cfg, kind: str, *, with_cross: bool = False):
    mixer, flavour, ffn = kind.split(":")
    k1, k2, k3, k4 = jax.random.split(key, 4)
    t: dict = {}
    if mixer == "attn":
        t["attn"] = init_attn(k1, cfg)
    else:
        t["ssm"] = {"norm": zeros_param((cfg.d_model,), (tp.D_MODEL,)),
                    **ssm_mod.init_ssm_block(k1, cfg.d_model, cfg.ssm)}
    if with_cross:
        t["cross"] = init_attn(k4, cfg, cross=True)
        t["cross"]["norm"] = zeros_param((cfg.d_model,), (tp.D_MODEL,))
    f = init_ffn(k2, cfg, ffn)
    if f is not None:
        t["ffn"] = f
    return t


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------


INVALID_POS = jnp.iinfo(jnp.int32).max


def attn_cache_len(cfg, kind: str, cache_len: int) -> int:
    _, flavour, _ = kind.split(":")
    if flavour == "local" and cfg.sliding_window:
        return min(cfg.sliding_window, cache_len)
    return cache_len


def init_block_cache(cfg, kind: str, batch: int, cache_len: int,
                     dtype=jnp.bfloat16):
    mixer, flavour, _ = kind.split(":")
    if mixer == "ssm":
        return ssm_mod.init_ssm_cache(batch, cfg.d_model, cfg.ssm, dtype)
    length = attn_cache_len(cfg, kind, cache_len)
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, length, kv, hd), dtype),
        "v": jnp.zeros((batch, length, kv, hd), dtype),
        "pos": jnp.full((batch, length), INVALID_POS, jnp.int32),
    }


def block_cache_axes(cfg, kind: str):
    """Logical axes of the cache pytree (for sharding)."""
    mixer, _, _ = kind.split(":")
    if mixer == "ssm":
        return {"h": (tp.BATCH, tp.HEADS, tp.HEAD_DIM, tp.D_STATE),
                "conv": (tp.BATCH, None, tp.D_INNER)}
    return {"k": (tp.BATCH, tp.SEQ_KV, tp.KV_HEADS, tp.HEAD_DIM),
            "v": (tp.BATCH, tp.SEQ_KV, tp.KV_HEADS, tp.HEAD_DIM),
            "pos": (tp.BATCH, tp.SEQ_KV)}


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------


def _project_qkv(p, x, cfg, *, rope_positions=None):
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dtype))
    if "bq" in p:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    if rope_positions is not None:
        q = apply_rope(q, rope_positions, cfg.rope_theta)
        k = apply_rope(k, rope_positions, cfg.rope_theta)
    return q, k, v


def attn_apply(p, x, cfg, kind: str, *, positions, cache=None,
               decode_pos=None, impl="auto", attn_mode="causal"):
    """Self-attention sub-block. Returns (out, new_cache)."""
    _, flavour, _ = kind.split(":")
    window = cfg.sliding_window if flavour == "local" else None
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q, k, v = _project_qkv(p, h, cfg, rope_positions=positions)

    if cache is None:
        out = attention(q, k, v, kind=attn_mode, window=window,
                        q_positions=positions, k_positions=positions,
                        impl=impl)
        new_cache = None
    elif decode_pos is None:
        # prefill: write KV at ring slots (pos % length) so a later
        # decode step's slot arithmetic stays consistent
        length = cache["k"].shape[1]
        s = k.shape[1]
        take = min(s, length)
        slots = positions[:, s - take:] % length          # (B, take)
        bidx = jnp.arange(x.shape[0])[:, None]
        new_cache = {
            "k": cache["k"].at[bidx, slots].set(k[:, s - take:].astype(
                cache["k"].dtype)),
            "v": cache["v"].at[bidx, slots].set(v[:, s - take:].astype(
                cache["v"].dtype)),
            "pos": cache["pos"].at[bidx, slots].set(positions[:, s - take:]),
        }
        out = attention(q, k, v, kind="causal", window=window,
                        q_positions=positions, k_positions=positions,
                        impl=impl)
    else:
        # decode: write this token's KV at its ring slot and attend to all
        length = cache["k"].shape[1]
        slot = decode_pos % length                        # (B,)
        bidx = jnp.arange(x.shape[0])
        new_cache = {
            "k": cache["k"].at[bidx, slot].set(k[:, 0].astype(
                cache["k"].dtype)),
            "v": cache["v"].at[bidx, slot].set(v[:, 0].astype(
                cache["v"].dtype)),
            "pos": cache["pos"].at[bidx, slot].set(decode_pos),
        }
        out = attention(q, new_cache["k"].astype(q.dtype),
                        new_cache["v"].astype(q.dtype), kind="causal",
                        window=window, q_positions=positions,
                        k_positions=new_cache["pos"], impl="full")
    dtype = x.dtype
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dtype))
    return out, new_cache


def cross_attn_apply(p, x, enc_kv, cfg, *, impl="auto"):
    """Cross-attention against precomputed encoder K/V."""
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(dtype))
    k, v = enc_kv
    out = attention(q, k.astype(dtype), v.astype(dtype), kind="bidir",
                    impl=impl)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dtype))


def ffn_apply(p, x, cfg, kind: str, *, groups=1):
    """Returns (out, aux_loss)."""
    _, _, ffn = kind.split(":")
    if ffn == "none" or "ffn" not in p:
        return jnp.zeros_like(x), jnp.float32(0)
    fp = p["ffn"]
    h = rms_norm(x, fp["norm"], cfg.norm_eps)
    if ffn == "moe":
        y, aux = moe_mod.moe_apply(fp, h, cfg.moe, groups=groups)
        return y, aux
    return mlp_apply(fp, h, gated=cfg.gated_mlp), jnp.float32(0)


def block_apply(p, x, cfg, kind: str, *, positions, cache=None,
                decode_pos=None, impl="auto", groups=1, enc_kv=None,
                attn_mode="causal"):
    """One full block: mixer + (optional cross) + FFN, residual-wired.

    Returns (x, new_cache, aux_loss)."""
    mixer, _, ffn = kind.split(":")
    aux = jnp.float32(0)
    if mixer == "attn":
        out, new_cache = attn_apply(p["attn"], x, cfg, kind,
                                    positions=positions, cache=cache,
                                    decode_pos=decode_pos, impl=impl,
                                    attn_mode=attn_mode)
        x = x + out
    else:
        h = rms_norm(x, p["ssm"]["norm"], cfg.norm_eps)
        sp = {k: v for k, v in p["ssm"].items() if k != "norm"}
        out, new_cache = ssm_mod.ssm_apply(sp, h, cfg.ssm, cache=cache)
        x = x + out
    if enc_kv is not None and "cross" in p:
        x = x + cross_attn_apply(p["cross"], x, enc_kv, cfg, impl=impl)
    if ffn != "none":
        out, aux = ffn_apply(p, x, cfg, kind, groups=groups)
        x = x + out
    return x, new_cache, aux
