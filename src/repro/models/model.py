"""Full models: decoder-only LM (all LM-family archs) and enc-dec
(whisper).  Layers are scanned over *periods* (stacked params) with the
remainder unrolled — this keeps the HLO small for 80-layer models while
preserving heterogeneous interleaves (DESIGN.md §3).

API (used by launch/ and serving/):

* ``model.init(rng) -> (params, axes)`` — axes are logical-axis twins
  consumed by the tensor planner.
* ``model.loss_fn(params, batch, ...) -> (loss, metrics)``
* ``model.init_cache(batch, cache_len, dtype) -> (cache, cache_axes)``
* ``model.prefill(params, batch, cache) -> (logits, cache)``
* ``model.decode_step(params, cache, tokens, pos) -> (logits, cache)``
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import tensor_plan as tp
from repro.models import blocks as blk
from repro.models.layers import (
    chunked_cross_entropy,
    make_param,
    rms_norm,
    split_tree,
    zeros_param,
)


def _stack_trees(trees):
    """Stack (arr, axes) trees over a new leading LAYERS axis."""
    is_leaf = lambda x: (isinstance(x, tuple) and len(x) == 2
                         and hasattr(x[0], "shape"))
    return jax.tree_util.tree_map(
        lambda *leaves: (jnp.stack([l[0] for l in leaves]),
                         (tp.LAYERS,) + leaves[0][1]),
        *trees, is_leaf=is_leaf)


def _stack_caches(caches):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *caches)


def _closest_divisor(n: int) -> int:
    """Divisor of n closest to sqrt(n) (for two-level remat scans)."""
    best, target = 1, n ** 0.5
    for d in range(1, n + 1):
        if n % d == 0 and abs(d - target) < abs(best - target):
            best = d
    return best


class DecoderLM:
    def __init__(self, cfg):
        self.cfg = cfg
        (self.period, self.slot_kinds, self.n_periods,
         self.tail_kinds) = blk.period_structure(cfg)

    # ------------------------------------------------------------- init --
    def init(self, rng):
        cfg = self.cfg
        tree: dict = {
            "embed": make_param(jax.random.fold_in(rng, 0),
                                (cfg.vocab_size, cfg.d_model),
                                (tp.VOCAB, tp.D_MODEL), scale=0.02),
            "final_norm": zeros_param((cfg.d_model,), (tp.D_MODEL,)),
        }
        if not cfg.tie_embeddings:
            tree["head"] = make_param(jax.random.fold_in(rng, 1),
                                      (cfg.d_model, cfg.vocab_size),
                                      (tp.D_MODEL, tp.VOCAB), scale=0.02)
        slots = {}
        for s, kind in enumerate(self.slot_kinds):
            per = [blk.init_block(
                jax.random.fold_in(rng, 100 + per_i * self.period + s),
                cfg, kind) for per_i in range(self.n_periods)]
            slots[f"slot{s}"] = _stack_trees(per)
        tree["slots"] = slots
        tail = {}
        base = self.n_periods * self.period
        for i, kind in enumerate(self.tail_kinds):
            tail[f"tail{i}"] = blk.init_block(
                jax.random.fold_in(rng, 100 + base + i), cfg, kind)
        if tail:
            tree["tail"] = tail
        return split_tree(tree)

    # ----------------------------------------------------------- helpers --
    def _head_matrix(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["head"]

    def _embed(self, params, batch):
        if "embeds" in batch:
            return batch["embeds"]
        return jnp.take(params["embed"], batch["tokens"], axis=0)

    # ----------------------------------------------------------- forward --
    def forward(self, params, batch, *, positions=None, impl="auto",
                groups=1, remat=False, compute_dtype=jnp.bfloat16,
                shard_fn=None):
        """Full-sequence forward (training). Returns (hidden, aux).

        ``shard_fn`` pins activation sharding (batch-sharded) inside the
        layer scan; without it GSPMD may propagate a feature-sharded,
        batch-replicated layout from ZeRO-sharded params."""
        cfg = self.cfg
        sf = shard_fn or (lambda t: t)
        x = sf(self._embed(params, batch).astype(compute_dtype))
        b, s, _ = x.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32),
                                         (b, s))

        def period_body(carry, xs):
            x, aux = carry
            for sidx, kind in enumerate(self.slot_kinds):
                x, _, a = blk.block_apply(
                    xs[f"slot{sidx}"], x, cfg, kind, positions=positions,
                    impl=impl, groups=groups)
                x = sf(x)
                aux = aux + a
            return (x, aux), None

        carry0 = (x, jnp.float32(0))
        if remat and self.n_periods >= 8:
            # sqrt-remat: two-level scan saves O(sqrt(L)) activations
            # instead of O(L) (and dodges XLA hoisting a full-stack f32
            # convert of the saved carries — see EXPERIMENTS.md §Dry-run)
            n_seg = _closest_divisor(self.n_periods)
            per_seg = self.n_periods // n_seg
            slots_seg = jax.tree_util.tree_map(
                lambda t: t.reshape((n_seg, per_seg) + t.shape[1:]),
                params["slots"])

            def seg_body(carry, seg_xs):
                carry, _ = jax.lax.scan(jax.checkpoint(period_body),
                                        carry, seg_xs)
                return carry, None

            (x, aux), _ = jax.lax.scan(jax.checkpoint(seg_body), carry0,
                                       slots_seg)
        else:
            body = jax.checkpoint(period_body) if remat else period_body
            (x, aux), _ = jax.lax.scan(body, carry0, params["slots"])
        for i, kind in enumerate(self.tail_kinds):
            def tail_fn(p, xx, kind=kind):
                out, _, a = blk.block_apply(p, xx, cfg, kind,
                                            positions=positions, impl=impl,
                                            groups=groups)
                return out, a
            if remat:
                tail_fn = jax.checkpoint(tail_fn)
            x, a = tail_fn(params["tail"][f"tail{i}"], x)
            aux = aux + a
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, aux

    def loss_fn(self, params, batch, *, impl="auto", groups=1, remat=False,
                compute_dtype=jnp.bfloat16, aux_weight=0.01,
                shard_fn=None):
        """Next-token CE (+ MoE aux). batch: tokens/embeds + labels."""
        x, aux = self.forward(params, batch, impl=impl, groups=groups,
                              remat=remat, compute_dtype=compute_dtype,
                              shard_fn=shard_fn)
        labels = batch["labels"]
        mask = batch.get("mask")
        loss = chunked_cross_entropy(
            x[:, :-1], self._head_matrix(params), labels[:, 1:],
            mask=None if mask is None else mask[:, 1:])
        total = loss + aux_weight * aux
        return total, {"ce": loss, "aux": aux}

    # ------------------------------------------------------------- cache --
    def init_cache(self, batch: int, cache_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        caches = {}
        for s, kind in enumerate(self.slot_kinds):
            per = [blk.init_block_cache(cfg, kind, batch, cache_len, dtype)
                   for _ in range(self.n_periods)]
            caches[f"slot{s}"] = _stack_caches(per)
        for i, kind in enumerate(self.tail_kinds):
            caches[f"tail{i}"] = blk.init_block_cache(
                cfg, kind, batch, cache_len, dtype)
        return caches

    def cache_axes(self):
        """Logical-axes twin pytree of init_cache's output."""
        cfg = self.cfg
        is_axes = lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)
        axes = {}
        for s, kind in enumerate(self.slot_kinds):
            ax = blk.block_cache_axes(cfg, kind)
            axes[f"slot{s}"] = jax.tree_util.tree_map(
                lambda a: (None,) + a, ax, is_leaf=is_axes)
        for i, kind in enumerate(self.tail_kinds):
            axes[f"tail{i}"] = blk.block_cache_axes(cfg, kind)
        return axes

    def _with_cache(self, params, x, caches, positions, decode_pos,
                    impl, groups, shard_fn=None):
        cfg = self.cfg
        sf = shard_fn or (lambda t: t)

        def period_body(carry, xs):
            x, aux = carry
            slot_params, slot_caches = xs
            new_caches = {}
            for sidx, kind in enumerate(self.slot_kinds):
                x, nc, a = blk.block_apply(
                    slot_params[f"slot{sidx}"], x, cfg, kind,
                    positions=positions, cache=slot_caches[f"slot{sidx}"],
                    decode_pos=decode_pos, impl=impl, groups=groups)
                x = sf(x)
                new_caches[f"slot{sidx}"] = nc
                aux = aux + a
            return (x, aux), new_caches

        slot_caches = {k: v for k, v in caches.items()
                       if k.startswith("slot")}
        (x, aux), new_slot_caches = jax.lax.scan(
            period_body, (x, jnp.float32(0)),
            (params["slots"], slot_caches))
        new_caches = dict(new_slot_caches)
        for i, kind in enumerate(self.tail_kinds):
            x, nc, a = blk.block_apply(
                params["tail"][f"tail{i}"], x, cfg, kind,
                positions=positions, cache=caches[f"tail{i}"],
                decode_pos=decode_pos, impl=impl, groups=groups)
            new_caches[f"tail{i}"] = nc
            aux = aux + a
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, new_caches

    def prefill(self, params, batch, caches, *, impl="auto", groups=1,
                compute_dtype=jnp.bfloat16, shard_fn=None):
        """Process a prompt, fill caches, return last-token logits."""
        sf = shard_fn or (lambda t: t)
        x = sf(self._embed(params, batch).astype(compute_dtype))
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x, new_caches = self._with_cache(params, x, caches, positions,
                                         None, impl, groups,
                                         shard_fn=shard_fn)
        logits = jnp.einsum("bd,dv->bv", x[:, -1].astype(jnp.float32),
                            self._head_matrix(params).astype(jnp.float32))
        return logits, new_caches

    def decode_step(self, params, caches, tokens, pos, *, impl="auto",
                    groups=1, compute_dtype=jnp.bfloat16, shard_fn=None):
        """One decode step. tokens: (B,), pos: (B,) current positions."""
        x = jnp.take(params["embed"], tokens[:, None],
                     axis=0).astype(compute_dtype)
        positions = pos[:, None]
        x, new_caches = self._with_cache(params, x, caches, positions,
                                         pos, impl, groups,
                                         shard_fn=shard_fn)
        logits = jnp.einsum("bd,dv->bv", x[:, 0].astype(jnp.float32),
                            self._head_matrix(params).astype(jnp.float32))
        return logits, new_caches


class EncDecLM:
    """Whisper-style encoder-decoder; the audio frontend is a stub —
    encoder inputs are precomputed (B, frames, d_model) embeddings."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.n_enc = cfg.encoder.n_layers
        self.n_dec = cfg.n_layers

    def init(self, rng):
        cfg = self.cfg
        tree: dict = {
            "embed": make_param(jax.random.fold_in(rng, 0),
                                (cfg.vocab_size, cfg.d_model),
                                (tp.VOCAB, tp.D_MODEL)),
            "head": make_param(jax.random.fold_in(rng, 1),
                               (cfg.d_model, cfg.vocab_size),
                               (tp.D_MODEL, tp.VOCAB)),
            "enc_final_norm": zeros_param((cfg.d_model,), (tp.D_MODEL,)),
            "final_norm": zeros_param((cfg.d_model,), (tp.D_MODEL,)),
        }
        enc = [blk.init_block(jax.random.fold_in(rng, 100 + i), cfg,
                              "attn:global:dense")
               for i in range(self.n_enc)]
        dec = [blk.init_block(jax.random.fold_in(rng, 500 + i), cfg,
                              "attn:global:dense", with_cross=True)
               for i in range(self.n_dec)]
        tree["encoder"] = _stack_trees(enc)
        tree["decoder"] = _stack_trees(dec)
        return split_tree(tree)

    def encode(self, params, frames, *, impl="auto",
               compute_dtype=jnp.bfloat16, remat=False):
        cfg = self.cfg
        x = frames.astype(compute_dtype)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

        def body(x, xs):
            x, _, _ = blk.block_apply(xs, x, cfg, "attn:global:dense",
                                      positions=positions, impl=impl,
                                      attn_mode="bidir")
            return x, None

        if remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["encoder"])
        return rms_norm(x, params["enc_final_norm"], cfg.norm_eps)

    def _cross_kv(self, params, enc_h):
        """Precompute per-decoder-layer cross K/V: (L, B, F, KV, hd)."""
        cfg = self.cfg
        dtype = enc_h.dtype

        def per_layer(cp):
            k = jnp.einsum("bsd,dhk->bshk", enc_h,
                           cp["wk"].astype(dtype))
            v = jnp.einsum("bsd,dhk->bshk", enc_h,
                           cp["wv"].astype(dtype))
            return k, v

        return jax.vmap(per_layer)(params["decoder"]["cross"])

    def _decoder(self, params, x, positions, decode_pos, caches, enc_kv,
                 impl, shard_fn=None):
        cfg = self.cfg
        sf = shard_fn or (lambda t: t)

        def body(carry, xs):
            x = carry
            layer_params, layer_cache, (ck, cv) = xs
            x, nc, _ = blk.block_apply(
                layer_params, x, cfg, "attn:global:dense",
                positions=positions, cache=layer_cache,
                decode_pos=decode_pos, impl=impl, enc_kv=(ck, cv))
            return sf(x), nc

        x, new_caches = jax.lax.scan(
            body, x, (params["decoder"], caches, enc_kv))
        return rms_norm(x, params["final_norm"], cfg.norm_eps), new_caches

    def loss_fn(self, params, batch, *, impl="auto", groups=1, remat=False,
                compute_dtype=jnp.bfloat16, aux_weight=0.0,
                shard_fn=None):
        cfg = self.cfg
        sf = shard_fn or (lambda t: t)
        enc_h = sf(self.encode(params, batch["frames"], impl=impl,
                               compute_dtype=compute_dtype, remat=remat))
        enc_kv = self._cross_kv(params, enc_h)
        tokens = batch["tokens"]
        x = jnp.take(params["embed"], tokens, axis=0).astype(compute_dtype)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

        def body(carry, xs):
            x = carry
            layer_params, (ck, cv) = xs
            fn = lambda lp, xx: sf(blk.block_apply(
                lp, xx, cfg, "attn:global:dense", positions=positions,
                impl=impl, enc_kv=(ck, cv))[0])
            if remat:
                fn = jax.checkpoint(fn)
            return fn(layer_params, x), None

        x, _ = jax.lax.scan(body, x, (params["decoder"], enc_kv))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        loss = chunked_cross_entropy(
            x[:, :-1], params["head"], batch["labels"][:, 1:],
            mask=None if batch.get("mask") is None
            else batch["mask"][:, 1:])
        return loss, {"ce": loss, "aux": jnp.float32(0)}

    def init_cache(self, batch: int, cache_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        per = [blk.init_block_cache(cfg, "attn:global:dense", batch,
                                    cache_len, dtype)
               for _ in range(self.n_dec)]
        caches = {"self": _stack_caches(per)}
        f = cfg.encoder.n_frames
        caches["cross_k"] = jnp.zeros(
            (self.n_dec, batch, f, cfg.n_kv_heads, cfg.head_dim), dtype)
        caches["cross_v"] = jnp.zeros_like(caches["cross_k"])
        return caches

    def cache_axes(self):
        cfg = self.cfg
        ax = blk.block_cache_axes(cfg, "attn:global:dense")
        lift = lambda a: (None,) + a
        axes = {"self": jax.tree_util.tree_map(
            lift, ax, is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))}
        cross_ax = (None, tp.BATCH, tp.FRAMES, tp.KV_HEADS, tp.HEAD_DIM)
        axes["cross_k"] = cross_ax
        axes["cross_v"] = cross_ax
        return axes

    def prefill(self, params, batch, caches, *, impl="auto", groups=1,
                compute_dtype=jnp.bfloat16, shard_fn=None):
        """Encode frames, precompute cross KV, prefill decoder prompt."""
        sf = shard_fn or (lambda t: t)
        enc_h = sf(self.encode(params, batch["frames"], impl=impl,
                               compute_dtype=compute_dtype))
        ck, cv = self._cross_kv(params, enc_h)
        tokens = batch["tokens"]
        x = sf(jnp.take(params["embed"], tokens,
                        axis=0).astype(compute_dtype))
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x, new_self = self._decoder(params, x, positions, None,
                                    caches["self"], (ck, cv), impl,
                                    shard_fn=shard_fn)
        logits = jnp.einsum("bd,dv->bv", x[:, -1].astype(jnp.float32),
                            params["head"].astype(jnp.float32))
        new_caches = {"self": new_self,
                      "cross_k": ck.astype(caches["cross_k"].dtype),
                      "cross_v": cv.astype(caches["cross_v"].dtype)}
        return logits, new_caches

    def decode_step(self, params, caches, tokens, pos, *, impl="auto",
                    groups=1, compute_dtype=jnp.bfloat16, shard_fn=None):
        x = jnp.take(params["embed"], tokens[:, None],
                     axis=0).astype(compute_dtype)
        positions = pos[:, None]
        enc_kv = (caches["cross_k"].astype(compute_dtype),
                  caches["cross_v"].astype(compute_dtype))
        x, new_self = self._decoder(params, x, positions, pos,
                                    caches["self"], enc_kv, impl,
                                    shard_fn=shard_fn)
        logits = jnp.einsum("bd,dv->bv", x[:, 0].astype(jnp.float32),
                            params["head"].astype(jnp.float32))
        new_caches = dict(caches)
        new_caches["self"] = new_self
        return logits, new_caches


def build_model(cfg):
    if cfg.family == "encdec":
        return EncDecLM(cfg)
    return DecoderLM(cfg)
