"""Model zoo: layers, SSM (Mamba-2 SSD), MoE, blocks and full LMs.

All modules are plain functions over parameter pytrees; every parameter
is paired with a tuple of logical axis names consumed by
:mod:`repro.core.tensor_plan` (the paper's IN/OUT/INOUT derivation,
generalised to tensors).
"""
from repro.models.model import build_model  # noqa: F401
