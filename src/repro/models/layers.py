"""Shared layers: norms, RoPE, attention (3 execution paths), MLP, loss.

Attention paths:

* ``full``    — materialised scores; smoke tests & small shapes.
* ``chunked`` — online-softmax over KV blocks (flash-attention recurrence
  in pure jnp, lax.scan over KV): O(S * block) memory, used by the big
  prefill/train shapes.  The Pallas kernel in ``repro.kernels`` is the
  TPU-native version of exactly this recurrence; this is its oracle twin.
* ``decode``  — single-query attention against a KV cache.

Every function takes/returns plain arrays; parameter trees are built by
the block constructors in :mod:`repro.models.blocks`.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import tensor_plan as tp


# ---------------------------------------------------------------------------
# Param helpers: params and their logical axes travel together
# ---------------------------------------------------------------------------


def make_param(key, shape, axes, scale=0.02, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * scale, tuple(axes)


def zeros_param(shape, axes, dtype=jnp.float32):
    return jnp.zeros(shape, dtype), tuple(axes)


def split_tree(tree):
    """{(arr, axes)} pytree -> (params, axes) twin pytrees."""
    is_leaf = lambda x: (isinstance(x, tuple) and len(x) == 2
                         and hasattr(x[0], "shape"))
    params = jax.tree_util.tree_map(lambda x: x[0], tree, is_leaf=is_leaf)
    axes = jax.tree_util.tree_map(lambda x: x[1], tree, is_leaf=is_leaf)
    return params, axes


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, weight, eps=1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def layer_norm(x, weight, bias, eps=1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight + bias).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta):
    """x: (B, S, N, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = rope_freqs(hd, theta)                        # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:2 * half]
    rot1 = x1 * cos - x2 * sin
    rot2 = x2 * cos + x1 * sin
    out = jnp.concatenate([rot1, rot2], axis=-1)
    if hd != 2 * half:  # odd head_dim tail passes through
        out = jnp.concatenate([out, x[..., 2 * half:]], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


NEG_INF = -1e30


def _mask_bias(kind, window, q_pos, k_pos):
    """(..., Sq, Sk) additive bias from positions."""
    dq = q_pos[..., :, None]
    dk = k_pos[..., None, :]
    if kind == "bidir":
        allowed = jnp.ones(jnp.broadcast_shapes(dq.shape, dk.shape), bool)
    else:  # causal
        allowed = dk <= dq
    if window is not None:
        allowed = jnp.logical_and(allowed, dk > dq - window)
    return jnp.where(allowed, 0.0, NEG_INF)


def attention_full(q, k, v, *, kind="causal", window=None,
                   q_positions=None, k_positions=None):
    """Materialised-scores attention with GQA.

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd).  positions: (B, S).
    """
    b, sq, h, hd = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(sq), (b, sq))
    if k_positions is None:
        k_positions = jnp.broadcast_to(jnp.arange(sk), (b, sk))
    qg = q.reshape(b, sq, kv, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(hd)
    bias = _mask_bias(kind, window, q_positions, k_positions)  # (B,Sq,Sk)
    scores = scores + bias[:, None, None]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def attention_chunked(q, k, v, *, kind="causal", window=None,
                      q_positions=None, k_positions=None, block_kv=1024,
                      block_q=2048):
    """Online-softmax (flash recurrence): Q blocks x KV blocks.

    Memory O(block_q * block_kv) score tiles instead of O(Sq * Sk) — both
    loop dims are blocked (a 56-head unsharded arch at 32k would
    otherwise materialise 15 GB tiles, EXPERIMENTS.md §Dry-run).  The
    Pallas kernel in repro.kernels/flash_attention.py is the TPU-native
    twin of this recurrence.
    """
    b, sq, h, hd = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(sq), (b, sq))
    if k_positions is None:
        k_positions = jnp.broadcast_to(jnp.arange(sk), (b, sk))
    nb = -(-sk // block_kv)
    pad = nb * block_kv - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_positions = jnp.pad(k_positions, ((0, 0), (0, pad)),
                              constant_values=jnp.iinfo(jnp.int32).max)
    nq = -(-sq // block_q)
    qpad = nq * block_q - sq
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, qpad)),
                              constant_values=jnp.iinfo(jnp.int32).max - 1)

    kb = k.reshape(b, nb, block_kv, kv, hd).swapaxes(0, 1)
    vb = v.reshape(b, nb, block_kv, kv, hd).swapaxes(0, 1)
    pb = k_positions.reshape(b, nb, block_kv).swapaxes(0, 1)

    def one_q_block(args):
        qblk, qpos = args                                  # (b,bq,h,hd)
        qg = (qblk.reshape(b, block_q, kv, g, hd).astype(jnp.float32)
              / jnp.sqrt(hd))
        m0 = jnp.full((b, kv, g, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, block_q), jnp.float32)
        acc0 = jnp.zeros((b, block_q, kv, g, hd), jnp.float32)

        def step(carry, blk):
            m, l, acc = carry
            kblk, vblk, posb = blk
            s = jnp.einsum("bqkgd,bskd->bkgqs", qg,
                           kblk.astype(jnp.float32))
            bias = _mask_bias(kind, window, qpos, posb)
            s = s + bias[:, None, None]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            m_safe = jnp.where(m_new == NEG_INF, 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where((s <= NEG_INF / 2), 0.0, p)
            corr = jnp.exp(jnp.where(m == NEG_INF, NEG_INF, m - m_safe))
            corr = jnp.where(m == NEG_INF, 0.0, corr)
            l = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bqkgd", p,
                            vblk.astype(jnp.float32))
            acc = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
            return (m_new, l, acc), None

        # checkpoint: backward recomputes the score tile per block
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(step),
                                      (m0, l0, acc0), (kb, vb, pb))
        l = jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return (acc / l).reshape(b, block_q, h, hd)

    if nq == 1:
        out = one_q_block((q, q_positions))
    else:
        qs = q.reshape(b, nq, block_q, h, hd).swapaxes(0, 1)
        qp = q_positions.reshape(b, nq, block_q).swapaxes(0, 1)
        out = jax.lax.map(one_q_block, (qs, qp))           # (nq,b,bq,h,hd)
        out = out.swapaxes(0, 1).reshape(b, nq * block_q, h, hd)
    return out[:, :sq].astype(q.dtype)


def attention_decode(q, k_cache, v_cache, *, window=None,
                     q_positions=None, k_positions=None):
    """Single-token decode attention over a (possibly padded) KV cache.

    q: (B, 1, H, hd); caches: (B, S, KV, hd); k_positions: (B, S) with
    unfilled slots marked by a huge position (masked out).
    """
    return attention_full(
        q, k_cache, v_cache, kind="causal", window=window,
        q_positions=q_positions, k_positions=k_positions)


def attention(q, k, v, *, kind="causal", window=None, q_positions=None,
              k_positions=None, impl="auto", block_kv=1024):
    if impl == "auto":
        # blocked path whenever the full score tile would be large
        # (cross-attention with long queries counts too)
        impl = ("chunked" if q.shape[1] * k.shape[1] > 2048 * 2048
                else "full")
    if impl == "full":
        return attention_full(q, k, v, kind=kind, window=window,
                              q_positions=q_positions,
                              k_positions=k_positions)
    if impl == "chunked":
        return attention_chunked(q, k, v, kind=kind, window=window,
                                 q_positions=q_positions,
                                 k_positions=k_positions,
                                 block_kv=block_kv)
    if impl == "pallas":
        from repro.kernels import ops as kops

        return kops.flash_attention(q, k, v, kind=kind, window=window,
                                    q_positions=q_positions,
                                    k_positions=k_positions)
    raise ValueError(f"unknown attention impl {impl!r}")


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_apply(p, x, *, gated: bool):
    """SwiGLU (gated) or GELU MLP. x: (..., D)."""
    dtype = x.dtype
    if gated:
        gate = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(dtype))
        up = jnp.einsum("...d,df->...f", x, p["w_up"].astype(dtype))
        h = jax.nn.silu(gate) * up
    else:
        h = jnp.einsum("...d,df->...f", x, p["w_up"].astype(dtype))
        h = jax.nn.gelu(h)
    return jnp.einsum("...f,fd->...d", h, p["w_down"].astype(dtype))


def init_mlp(key, d_model, d_ff, *, gated: bool):
    ks = jax.random.split(key, 3)
    t = {}
    if gated:
        t["w_gate"] = make_param(ks[0], (d_model, d_ff),
                                 (tp.D_MODEL, tp.D_FF))
    t["w_up"] = make_param(ks[1], (d_model, d_ff), (tp.D_MODEL, tp.D_FF))
    t["w_down"] = make_param(ks[2], (d_ff, d_model), (tp.D_FF, tp.D_MODEL))
    return t


# ---------------------------------------------------------------------------
# Loss: chunked cross-entropy (never materialises (B,S,V) logits)
# ---------------------------------------------------------------------------


def chunked_cross_entropy(x, w_head, labels, *, mask=None, chunk=512):
    """Mean CE over tokens. x: (B,S,D), w_head: (D,V), labels: (B,S)."""
    b, s, d = x.shape
    nb = -(-s // chunk)
    pad = nb * chunk - s
    if mask is None:
        mask = jnp.ones((b, s), bool)
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    xb = x.reshape(b, nb, chunk, d).swapaxes(0, 1)
    lb = labels.reshape(b, nb, chunk).swapaxes(0, 1)
    mb = mask.reshape(b, nb, chunk).swapaxes(0, 1)

    v = w_head.shape[-1]

    def step(carry, blk):
        tot, cnt = carry
        xc, lc, mc = blk
        logits = jnp.einsum("bsd,dv->bsv", xc.astype(jnp.float32),
                            w_head.astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        # label logit via one-hot contraction: partitions cleanly when the
        # vocab dim is model-sharded (take_along_axis would all-gather)
        onehot = jax.nn.one_hot(lc, v, dtype=logits.dtype)
        ll = jnp.einsum("bsv,bsv->bs", logits, onehot)
        tot = tot + jnp.sum(jnp.where(mc, lse - ll, 0.0))
        cnt = cnt + jnp.sum(mc)
        return (tot, cnt), None

    # checkpoint the chunk step: backward recomputes the (B, chunk, V)
    # logits instead of saving them per scan step (vocab 262k would OOM)
    (tot, cnt), _ = jax.lax.scan(jax.checkpoint(step),
                                 (jnp.float32(0), jnp.float32(0)),
                                 (xb, lb, mb))
    return tot / jnp.maximum(cnt, 1.0)
