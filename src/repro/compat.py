"""JAX version compatibility shims.

The repo targets the modern JAX surface (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh(..., axis_types=...)``,
``jax.sharding.AxisType``) but must also run on the 0.4.x line where

* ``shard_map`` lives in ``jax.experimental.shard_map`` and the
  replication-check kwarg is ``check_rep``,
* ``jax.make_mesh`` exists but does not accept ``axis_types``,
* ``jax.sharding.AxisType`` does not exist.

Everything in the repo goes through these two helpers instead of calling
the moving targets directly.  No behaviour difference is intended: the
meshes are always fully "auto" (GSPMD-managed) and the shard_map
replication checker is always disabled (the master/worker lowering is
deliberately rank-divergent).
"""
from __future__ import annotations

from typing import Any, Sequence

import jax

try:  # modern JAX
    from jax.sharding import AxisType as _AxisType
except ImportError:  # 0.4.x
    _AxisType = None

AxisType = _AxisType


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` with explicit Auto axis types where supported."""
    if _AxisType is not None:
        return jax.make_mesh(
            tuple(axis_shapes), tuple(axis_names),
            axis_types=(_AxisType.Auto,) * len(tuple(axis_names)),
        )
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs) -> Any:
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )

else:
    from jax.experimental.shard_map import shard_map as _shard_map_04x

    def shard_map(f, *, mesh, in_specs, out_specs) -> Any:
        return _shard_map_04x(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False,
        )
