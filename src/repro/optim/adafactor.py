"""Adafactor (Shazeer & Stern, 2018): factored second moment.

For a (r, c) matrix the second-moment estimate is stored as a rank-1
outer product of row/col statistics — O(r + c) instead of O(r*c) — which
is what lets the 398B/480B train cells hold optimizer state in 16 GB
chips.  >=2D params factor over the two largest dims; 1D params keep a
full second moment.  Momentum is optional bf16 (off by default, as in
T5X large-model recipes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _factored_dims(shape):
    """Sorted indices of the two largest dims (factored), None for <2D."""
    if len(shape) < 2:
        return None
    order = sorted(range(len(shape)), key=lambda i: shape[i])
    return tuple(sorted(order[-2:]))


def adafactor_init(params, *, momentum: bool = False):
    def per_param(p):
        dims = _factored_dims(p.shape)
        if dims is None:
            st = {"v": jnp.zeros(p.shape, jnp.float32)}
        else:
            d0, d1 = dims                       # d0 < d1
            row_shape = tuple(s for i, s in enumerate(p.shape) if i != d1)
            col_shape = tuple(s for i, s in enumerate(p.shape) if i != d0)
            st = {"vr": jnp.zeros(row_shape, jnp.float32),
                  "vc": jnp.zeros(col_shape, jnp.float32)}
        if momentum:
            st["m"] = jnp.zeros(p.shape, jnp.bfloat16)
        return st

    is_leaf = lambda x: hasattr(x, "shape") and hasattr(x, "dtype")
    return {
        "per_param": jax.tree_util.tree_map(per_param, params,
                                            is_leaf=is_leaf),
        "count": jnp.zeros((), jnp.int32),
    }


def adafactor_update(grads, state, params, *, lr, decay=0.8, eps=1e-30,
                     clip_threshold=1.0, weight_decay=0.0,
                     momentum_beta=0.9, stream_leading: int = 0):
    """``stream_leading`` (opt-in, 0=off): >=3D params with a leading dim
    >= this value update via ``lax.map`` over that dim.  Hypothesised to
    shrink the f32 working set to one layer slice; MEASURED WORSE on
    arctic-480b (+10 GB — the map's input/output stacks stay fully live
    and lose the elementwise buffer reuse; EXPERIMENTS.md §Perf-G), so it
    is off by default.  Per-slice math is exact either way (the factored
    dims are never the leading stack dim)."""
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    beta2 = 1.0 - c ** (-decay)

    def upd_block(g, st, p, dims):
        """One (possibly sliced) block; dims are the factored axes."""
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if dims is None:
            v = beta2 * st["v"] + (1 - beta2) * g2
            new_st = {"v": v}
            update = g * jax.lax.rsqrt(v)
        else:
            d0, d1 = dims                       # d0 < d1
            vr = beta2 * st["vr"] + (1 - beta2) * jnp.mean(g2, axis=d1)
            vc = beta2 * st["vc"] + (1 - beta2) * jnp.mean(g2, axis=d0)
            new_st = {"vr": vr, "vc": vc}
            # update = g / (sqrt(vr / mean_d0(vr)) (x) sqrt(vc))
            denom = jnp.mean(vr, axis=d0, keepdims=True)
            row_factor = jax.lax.rsqrt(
                jnp.maximum(vr / jnp.maximum(denom, eps), eps))
            col_factor = jax.lax.rsqrt(jnp.maximum(vc, eps))
            update = (g * jnp.expand_dims(row_factor, d1)
                      * jnp.expand_dims(col_factor, d0))
        # update clipping (RMS <= clip_threshold)
        rms = jnp.sqrt(jnp.mean(update * update) + 1e-30)
        update = update / jnp.maximum(1.0, rms / clip_threshold)
        if "m" in st:
            m = momentum_beta * st["m"].astype(jnp.float32) \
                + (1 - momentum_beta) * update
            new_st["m"] = m.astype(jnp.bfloat16)
            update = m
        new_p = p.astype(jnp.float32) - lr * update \
            - lr * weight_decay * p.astype(jnp.float32)
        return new_p.astype(p.dtype), new_st

    def upd(g, st, p):
        dims = _factored_dims(p.shape)
        stream = (stream_leading and p.ndim >= 3 and dims is not None
                  and 0 not in dims and p.shape[0] >= stream_leading)
        if not stream:
            return upd_block(g, st, p, dims)
        sliced_dims = tuple(d - 1 for d in dims)

        def one(slices):
            gl, vrl, vcl, pl = slices
            stl = {"vr": vrl, "vc": vcl}
            if "m" in st:
                stl["m"] = slices[4]
            return upd_block(gl, stl, pl, sliced_dims)

        args = (g, st["vr"], st["vc"], p)
        if "m" in st:
            args = args + (st["m"],)
        new_p, new_st = jax.lax.map(one, args)
        return new_p, new_st

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_s = tdef.flatten_up_to(state["per_param"])
    out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_s = tdef.unflatten([o[1] for o in out])
    return new_p, {"per_param": new_s, "count": count}
