"""Optimizers and gradient machinery (no external deps).

* AdamW — default for <=20B configs.
* Adafactor — factored second moment; the only way the 398B/480B train
  cells fit 16 GB/chip (DESIGN.md §5).
* global-norm clipping, cosine-with-warmup schedule,
* gradient accumulation (microbatching),
* int8 error-feedback gradient compression for the DP all-reduce
  (beyond-paper distributed-optimization trick; EXPERIMENTS.md §Perf).
"""
from repro.optim.adamw import adamw_init, adamw_update  # noqa: F401
from repro.optim.adafactor import adafactor_init, adafactor_update  # noqa: F401
from repro.optim.api import (  # noqa: F401
    Optimizer,
    make_optimizer,
)
from repro.optim.grad import (  # noqa: F401
    clip_by_global_norm,
    compress_int8,
    compressed_allreduce_tree,
    decompress_int8,
    global_norm,
)
from repro.optim.schedule import cosine_warmup  # noqa: F401
