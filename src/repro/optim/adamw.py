"""AdamW with decoupled weight decay. State: (m, v, count)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, state, params, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * (g * g)
        step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        step = step + weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * step
        return new_p.astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}
