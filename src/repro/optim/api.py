"""Uniform optimizer facade used by the train step builder."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.optim.adafactor import _factored_dims, adafactor_init, adafactor_update
from repro.optim.adamw import adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], Any]
    update: Callable[..., tuple]      # (grads, state, params, lr=) -> (p, s)


def make_optimizer(name: str, *, weight_decay: float = 0.1) -> Optimizer:
    if name == "adamw":
        return Optimizer(
            "adamw", adamw_init,
            lambda g, s, p, lr: adamw_update(
                g, s, p, lr=lr, weight_decay=weight_decay))
    if name == "adafactor":
        return Optimizer(
            "adafactor", adafactor_init,
            lambda g, s, p, lr: adafactor_update(
                g, s, p, lr=lr, weight_decay=0.0))
    raise ValueError(f"unknown optimizer {name!r}")


def opt_state_axes(name: str, params_shapes, params_axes):
    """Logical-axes tree matching the optimizer state structure, so the
    tensor planner can shard optimizer state exactly like its params
    (ZeRO-1/2 falls out of the same rules)."""
    is_shape = lambda x: hasattr(x, "shape")
    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    if name == "adamw":
        return {
            "m": params_axes,
            "v": params_axes,
            "count": (),
        }
    if name == "adafactor":
        def per_param(shape_struct, axes):
            dims = _factored_dims(shape_struct.shape)
            if dims is None:
                return {"v": axes}
            d0, d1 = dims
            vr_axes = tuple(a for i, a in enumerate(axes) if i != d1)
            vc_axes = tuple(a for i, a in enumerate(axes) if i != d0)
            return {"vr": vr_axes, "vc": vc_axes}

        flat_s, tdef = jax.tree_util.tree_flatten(params_shapes,
                                                  is_leaf=is_shape)
        flat_a = tdef.flatten_up_to(params_axes)
        per = tdef.unflatten([per_param(s, a)
                              for s, a in zip(flat_s, flat_a)])
        return {"per_param": per, "count": ()}
    raise ValueError(f"unknown optimizer {name!r}")
