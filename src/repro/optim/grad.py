"""Gradient utilities: clipping, accumulation support and int8
error-feedback compression for the DP all-reduce.

The compression trick (1-bit/8-bit SGD lineage, Seide et al. 2014): each
worker quantises its gradient shard to int8 with a per-tensor scale,
keeps the quantisation error as feedback added to the next step's
gradient, and the all-reduce moves 4x fewer bytes.  On the roofline this
divides the DP-gradient collective term by ~4 at the cost of two cheap
elementwise passes — measured in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


def compress_int8(g, error):
    """Quantise g+error to int8 with per-tensor scale.

    Returns (q, scale, new_error)."""
    gf = g.astype(jnp.float32) + error
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gf - deq


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def tree_compress_int8(grads, errors):
    """Apply error-feedback int8 compression leaf-wise.

    Returns (q_tree, scale_tree, new_error_tree)."""
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(errors)
    out = [compress_int8(g, e) for g, e in zip(flat_g, flat_e)]
    qs = tdef.unflatten([o[0] for o in out])
    scales = tdef.unflatten([o[1] for o in out])
    errs = tdef.unflatten([o[2] for o in out])
    return qs, scales, errs


def tree_decompress_int8(qs, scales):
    return jax.tree_util.tree_map(decompress_int8, qs, scales)


def init_error_feedback(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


# ---------------------------------------------------------------------------
# Compressed all-reduce (shard_map collective)
# ---------------------------------------------------------------------------


def _compressed_allreduce_leaf(g, err, axis, p):
    """Two-hop int8 mean over ``axis``: quantise -> all_to_all int8 slices
    -> local segment mean -> re-quantise -> all_gather int8.

    Wire ~ S/4 + S/4 int8 bytes vs ~2S fp32 for a ring all-reduce: ~4x.
    Error feedback makes the long-run average exact."""
    shape = g.shape
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    k = -(-n // p)
    pad = p * k - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
        err_f = jnp.pad(err.reshape(-1), (0, pad))
    else:
        err_f = err.reshape(-1)

    q, scale, new_err = compress_int8(flat, err_f)
    slices = q.reshape(p, k)
    recv = jax.lax.all_to_all(slices[:, None], axis, split_axis=0,
                              concat_axis=0)[:, 0]          # (P, k) int8
    scales = jax.lax.all_gather(scale, axis)                 # (P,)
    seg_mean = jnp.sum(recv.astype(jnp.float32)
                       * scales[:, None], axis=0) / p        # (k,)

    q2, scale2, _ = compress_int8(seg_mean, jnp.zeros_like(seg_mean))
    all_q2 = jax.lax.all_gather(q2, axis)                    # (P, k) int8
    all_s2 = jax.lax.all_gather(scale2, axis)                # (P,)
    full = (all_q2.astype(jnp.float32)
            * all_s2[:, None]).reshape(-1)
    if pad:
        full = full[:n]
        new_err = new_err[:n]
    return full.reshape(shape).astype(g.dtype), new_err.reshape(shape)


def compressed_allreduce_tree(grads, errors, *, axis: str, num_devices: int):
    """int8 error-feedback gradient mean across ``axis``.

    Call INSIDE a shard_map region whose per-device gradients differ
    (explicit-DP steps); returns (mean tree, new error tree).  Wire cost
    ~4x below a float all-reduce (EXPERIMENTS.md §Perf)."""
    out = jax.tree_util.tree_map(
        lambda g, e: _compressed_allreduce_leaf(g, e, axis, num_devices),
        grads, errors)
    is_pair = lambda x: isinstance(x, tuple) and len(x) == 2 \
        and hasattr(x[0], "shape")
    means = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=is_pair)
    errs = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=is_pair)
    return means, errs
