"""Public facade for the OMP2MPI engine: ``from repro import omp``.

Mirrors the OpenMP surface the paper consumes:

* ``@omp.parallel_for(stop=N, schedule=omp.dynamic(), reduction={...})``
  annotates a loop body — the ``#pragma omp parallel for target mpi``.
* calling the resulting program runs the *shared-memory* semantics
  (the original OpenMP program);
* ``omp.compile(program, mesh, omp.Options(...))`` performs the
  source-to-source transformation through the staged pass pipeline
  (``analyze → schedule → plan → plan_comm → lower``) and returns the
  distributed ("MPI") program as a :class:`~repro.core.api.Compiled`
  artifact.  It accepts a single ``ParallelFor`` block or a whole
  ``ParallelRegion``.

``omp.to_mpi`` / ``omp.region_to_mpi`` are deprecated shims over
``omp.compile`` and emit ``DeprecationWarning``.
"""
from repro.core.api import (  # noqa: F401
    CommMode,
    Compiled,
    CompileError,
    Lowering,
    Options,
    PassRecord,
    ShardPolicy,
    clear_compile_cache,
    compile,
    compile_cache_stats,
    disable_persistent_cache,
    enable_persistent_cache,
)
from repro.core.aot_store import AOTStore  # noqa: F401
from repro.core.context import (  # noqa: F401
    Affine,
    ContextInfo,
    ReadKind,
    VarClass,
    WriteKind,
    analyze_context,
)
from repro.core.comm import (  # noqa: F401
    ALPHA_LAUNCH_BYTES,
    BoundaryComm,
    CommCost,
    halo_exchange,
    halo_exchange2,
    modeled_cost_bytes,
    plan_boundary,
    plan_boundary2,
    plan_comm,
)
from repro.core.comm_schedule import (  # noqa: F401
    CommEvent,
    CommGroup,
    CommSchedule,
    build_comm_schedule,
)
from repro.core.loop import LoopInfo, LoopNotCanonical, analyze_loop  # noqa: F401
from repro.core.nest import LoopNest, NestAffine, ShiftedWindow  # noqa: F401
from repro.core.plan import DistPlan, KAffine, make_plan  # noqa: F401
from repro.core.pragma import (  # noqa: F401
    DYNAMIC,
    GUIDED,
    STATIC,
    At,
    ParallelFor,
    ParallelRegion,
    Put,
    Red,
    Schedule,
    SerialStage,
    at,
    dynamic,
    guided,
    parallel_for,
    put,
    red,
    region,
    serial,
    static,
)
from repro.core.pallas_lower import (  # noqa: F401
    KernelPlan,
    KernelSpan,
)
from repro.core.region import (  # noqa: F401
    DistributedRegion,
    RegionPlan,
    SlabLayout,
    SlabLayout2,
    plan_region,
    region_to_mpi,
)
from repro.core.schedule import (  # noqa: F401
    ChunkPlan,
    guided_chunk_size,
    make_chunk_plan,
    make_nest_chunk_plans,
    paper_chunk_size,
)
from repro.core.transform import (  # noqa: F401
    DistributedProgram,
    run_reference,
    to_mpi,
)
