"""Batched serving: slot-based continuous batching over prefill/decode."""
from repro.serving.engine import Request, ServeEngine  # noqa: F401
