"""Serving: slot-based continuous batching over prefill/decode, plus
the concurrent compile-and-run service over ``omp.compile``."""
from repro.serving.compile_service import (  # noqa: F401
    CompileService,
    ServiceStats,
)
from repro.serving.engine import Request, ServeEngine  # noqa: F401
