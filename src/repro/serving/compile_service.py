"""Concurrent compile-and-run service over ``omp.compile``.

The paper's master/worker split, one level up: the service is the
master — it owns admission, scheduling and the compile cache — and the
compiled SPMD executables are the workers.  Many independent client
programs (the MPI-rical / MPIrigen load shape: a sustained stream of
translation requests) submit concurrently; the service

* serves **warm keys lock-free** — a structurally-seen program is a
  plain dict probe straight into the cached :class:`~repro.core.api.Compiled`
  artifact (which itself holds the AOT executable when the persistent
  store is on),
* **single-flights cold compiles** — N clients racing the same new
  structural key produce exactly ONE compile; the rest park on an event
  and reuse the winner's artifact (pinned in
  ``tests/test_compile_service.py``),
* runs distinct cold keys concurrently on a thread pool (planning is
  pure Python; XLA compiles release the GIL),
* wires the seed :mod:`repro.runtime.straggler` /
  :mod:`repro.runtime.elastic` hooks: per-request wall time feeds a
  :class:`~repro.runtime.straggler.StragglerMonitor`; when the spike
  budget is exhausted the service escalates — first (given operator
  ``device_weights``) it recompiles with a straggler-weighted chunk
  schedule (``Options.chunk_weights``, counted in ``rebalances``);
  only if the straggler persists does it plan a degraded-mesh restart
  (:func:`~repro.runtime.elastic.plan_elastic_remesh`) and surface it
  via :meth:`CompileService.health` / the ``on_evict`` callback.

``benchmarks/serving_load.py`` drives this under a many-client load
generator (EXPERIMENTS §Perf-I).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Mapping

from repro.core import api as api_mod
from repro.runtime.elastic import RemeshPlan, plan_elastic_remesh
from repro.runtime.straggler import StragglerMonitor, rebalance_chunks


class _Flight:
    """One in-progress cold compile; followers park on the event."""

    __slots__ = ("event", "compiled", "error")

    def __init__(self) -> None:
        self.event = threading.Event()
        self.compiled: Any = None
        self.error: BaseException | None = None


class ServiceStats:
    """Request counters.  Bumps on the lock-free warm path use the
    GIL-atomic counter from the compile cache (a bare ``+= 1`` is a
    read-modify-write that loses counts under threads — the same bug
    family as the engine's dropped results)."""

    _FIELDS = ("requests", "warm_hits", "cold_compiles", "coalesced",
               "errors", "rebalances", "evictions")

    def __init__(self) -> None:
        for f in self._FIELDS:
            setattr(self, "_" + f, api_mod._Counter())
        self.run_seconds = 0.0    # guarded by the monitor lock

    def inc(self, field: str) -> None:
        getattr(self, "_" + field).inc()

    def __getattr__(self, name: str):
        if name in self._FIELDS:
            return getattr(self, "_" + name).value
        raise AttributeError(name)

    def as_dict(self) -> dict:
        d = {f: getattr(self, f) for f in self._FIELDS}
        d["run_seconds"] = self.run_seconds
        d["compile_cache"] = api_mod.compile_cache_stats()
        return d


class CompileService:
    """Admit, compile (deduplicated), and run client programs.

    Thread-safety contract: the warm path touches only GIL-atomic
    operations (dict probe, counter bumps); ``_lock`` guards flight
    registration and the publish of a finished compile.  The compile
    itself — and the client's execution — run outside the lock.
    """

    def __init__(self, mesh, *, options=None, max_workers: int = 8,
                 persistent_dir: str | None = None,
                 monitor: StragglerMonitor | None = None,
                 on_evict: Callable[[RemeshPlan], None] | None = None,
                 model_parallel: int = 1,
                 device_weights=None) -> None:
        self.mesh = mesh
        self.options = options if options is not None else api_mod.Options()
        if persistent_dir is not None:
            api_mod.enable_persistent_cache(persistent_dir)
        self._compiled: dict[tuple, Any] = {}     # key -> Compiled (warm)
        self._inflight: dict[tuple, _Flight] = {}
        self._lock = threading.Lock()
        self._pool: ThreadPoolExecutor | None = None
        self._max_workers = max_workers
        self.stats = ServiceStats()
        self.monitor = monitor if monitor is not None else StragglerMonitor()
        self._monitor_lock = threading.Lock()
        self._on_evict = on_evict
        self._model_parallel = model_parallel
        self._device_weights = (tuple(device_weights)
                                if device_weights is not None else None)
        self._weighted_options: Any = None
        self.remesh_plan: RemeshPlan | None = None

    # ------------------------------------------------------------- keys --
    def _key(self, program, options) -> tuple:
        """The in-process structural identity — the same key the
        compile cache uses, so service dedup and cache residency agree."""
        return (api_mod._program_signature(program),
                api_mod._mesh_signature(self.mesh), options)

    # -------------------------------------------------------- admission --
    def run(self, program, env: Mapping[str, Any],
            options=None) -> dict:
        """Compile (or reuse) ``program`` and run it on ``env``.
        Blocking; safe to call from many client threads at once."""
        options = options if options is not None else self.options
        self.stats.inc("requests")
        compiled = self._get_compiled(program, env, options)
        t0 = time.perf_counter()
        try:
            out = compiled.run(env)
        except BaseException:
            self.stats.inc("errors")
            raise
        self._observe(time.perf_counter() - t0)
        return out

    def submit(self, program, env: Mapping[str, Any],
               options=None) -> Future:
        """Async variant of :meth:`run`: returns a Future resolving to
        the output environment."""
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=self._max_workers,
                thread_name_prefix="compile-service")
        return self._pool.submit(self.run, program, env, options)

    def warmup(self, programs, env_like: Mapping[str, Any],
               options=None) -> int:
        """Pre-compile ``programs`` (shapes only); returns how many
        cold compiles that took."""
        before = self.stats.cold_compiles
        for p in programs:
            self._get_compiled(p, env_like,
                               options if options is not None
                               else self.options)
        return self.stats.cold_compiles - before

    # ------------------------------------------------------ single-flight --
    def _get_compiled(self, program, env, options):
        key = self._key(program, options)
        compiled = self._compiled.get(key)       # warm: lock-free
        if compiled is not None:
            self.stats.inc("warm_hits")
            return compiled
        follower = False
        with self._lock:
            compiled = self._compiled.get(key)   # published while racing
            if compiled is not None:
                self.stats.inc("warm_hits")
                return compiled
            flight = self._inflight.get(key)
            if flight is None:
                flight = _Flight()
                self._inflight[key] = flight
            else:
                follower = True
        if follower:
            flight.event.wait()
            self.stats.inc("coalesced")
            if flight.error is not None:
                raise flight.error
            return flight.compiled
        try:
            compiled = api_mod.compile(program, self.mesh, options,
                                       env_like=env)
            compiled._ensure(env)                # plan + (AOT) build now
            self.stats.inc("cold_compiles")
            flight.compiled = compiled
            with self._lock:
                self._compiled[key] = compiled
        except BaseException as e:
            flight.error = e
            raise
        finally:
            with self._lock:
                self._inflight.pop(key, None)
            flight.event.set()
        return compiled

    # ------------------------------------------- degraded-mesh operation --
    def _observe(self, dt: float) -> None:
        with self._monitor_lock:
            self.stats.run_seconds += dt
            status = self.monitor.observe(dt)
            if status != "evict" or self.remesh_plan is not None:
                return
            if (self._device_weights is not None
                    and self._weighted_options is None):
                self._escalate_weighted()
            else:
                self._plan_degraded()

    def _escalate_weighted(self) -> None:
        """First escalation rung: keep every device but re-deal its
        chunk share to the operator-supplied ``device_weights`` — a
        recompile (through the cache) with a straggler-weighted
        schedule, cheaper than evicting the slow device outright.  The
        spike budget resets; if the straggler persists through the
        rebalanced schedule, the next exhaustion falls through to
        :meth:`_plan_degraded`."""
        self.stats.inc("rebalances")
        opts = self.options
        lowering = opts.lowering
        if lowering is not api_mod.Lowering.COLLECTIVE:
            # weighted schedules live in the collective chunk executor
            lowering = api_mod.Lowering.COLLECTIVE
        self._weighted_options = dataclasses.replace(
            opts, lowering=lowering, chunk_weights=self._device_weights)
        self.options = self._weighted_options
        self.monitor.spikes = 0

    def _plan_degraded(self) -> None:
        """The elastic escalation path: a persistent straggler means
        running degraded — plan the nearest valid mesh for one fewer
        device (floor 1) so the restart is a lookup, not a scramble."""
        self.stats.inc("evictions")
        n = max(1, self.mesh.devices.size - 1)
        self.remesh_plan = plan_elastic_remesh(
            n, model_parallel=self._model_parallel)
        if self._on_evict is not None:
            self._on_evict(self.remesh_plan)

    def suggest_rebalance(self, num_chunks: int,
                          weights: list[float]) -> list[int]:
        """Straggler mitigation short of eviction: re-deal the cyclic
        chunks proportionally to observed per-device speed (the
        paper's dynamic-schedule over-decomposition answer), via
        :func:`repro.runtime.straggler.rebalance_chunks`."""
        return rebalance_chunks(num_chunks, weights)

    def health(self) -> dict:
        """Liveness/degradation snapshot for an external supervisor."""
        return {
            "ewma_step_s": self.monitor.ewma,
            "spikes": self.monitor.spikes,
            "steps": self.monitor.steps,
            "degraded": self.remesh_plan is not None,
            "rebalanced": self._weighted_options is not None,
            "device_weights": self._device_weights,
            "remesh_plan": (dataclasses.asdict(self.remesh_plan)
                            if self.remesh_plan is not None else None),
            "inflight": len(self._inflight),
            "resident_programs": len(self._compiled),
        }

    # ---------------------------------------------------------- lifecycle --
    def shutdown(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def __enter__(self) -> "CompileService":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
