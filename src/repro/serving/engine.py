"""Slot-based batched serving engine (continuous batching, vLLM-lite).

The engine owns a fixed decode batch of ``n_slots`` sequences sharing
one ring KV cache per layer.  Requests queue up; free slots are filled
by running a (single-sequence) prefill whose KV is scattered into the
slot; every engine tick runs one batched decode step for all live slots.
Greedy sampling (argmax) keeps the demo deterministic; temperature
sampling is a flag.

This is the serving analogue of the paper's master/worker split: the
host (master) owns admission/scheduling — the sequential remainder —
while the SPMD decode step is the distributed parallel block.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 32
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model, params, *, n_slots: int, cache_len: int,
                 eos_id: int | None = None, temperature: float = 0.0,
                 compute_dtype=jnp.float32, seed: int = 0) -> None:
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.temperature = temperature
        self.compute_dtype = compute_dtype
        self.caches = model.init_cache(n_slots, cache_len,
                                       dtype=compute_dtype)
        self.slot_req: list[Request | None] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int64)
        self.slot_last = np.zeros(n_slots, np.int64)
        self.queue: list[Request] = []
        self._finished: list[Request] = []
        self._rng = jax.random.PRNGKey(seed)
        self._decode = jax.jit(
            lambda p, c, t, q: model.decode_step(
                p, c, t, q, compute_dtype=compute_dtype))

    # --------------------------------------------------------- admission --
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self) -> None:
        for slot in self._free_slots():
            # A request may finish at prefill (max_new_tokens=1 or a
            # prefill EOS) — keep admitting into this slot until one
            # survives to decode, so no slot idles while work queues.
            while self.queue:
                req = self.queue.pop(0)
                self._prefill_into_slot(slot, req)
                if self.slot_req[slot] is not None:
                    break

    def _prefill_into_slot(self, slot: int, req: Request) -> None:
        """Run a single-sequence prefill and scatter its KV into ``slot``."""
        tokens = jnp.asarray(req.prompt, jnp.int32)[None]
        cache1 = self.model.init_cache(1, self.cache_len,
                                       dtype=self.compute_dtype)
        logits, cache1 = self.model.prefill(
            self.params, {"tokens": tokens}, cache1,
            compute_dtype=self.compute_dtype)
        self.caches = jax.tree_util.tree_map(
            lambda full, one: full.at[:, slot].set(one[:, 0])
            if full.ndim >= 2 and full.shape[1] == self.n_slots
            else full.at[slot].set(one[0]),
            self.caches, cache1)
        tok = int(jnp.argmax(logits[0]))
        req.output.append(tok)
        # Same completion check as tick(): a request whose budget (or
        # EOS) is already met at admission must not occupy a slot — it
        # would burn a decode tick in a dead slot and overrun
        # max_new_tokens by one.
        if self._is_done(req, tok):
            self._retire(req)
            return
        self.slot_req[slot] = req
        self.slot_pos[slot] = len(req.prompt)
        self.slot_last[slot] = tok

    def _is_done(self, req: Request, tok: int) -> bool:
        return (len(req.output) >= req.max_new_tokens
                or (self.eos_id is not None and tok == self.eos_id))

    def _retire(self, req: Request) -> None:
        req.done = True
        self._finished.append(req)

    def take_finished(self) -> list[Request]:
        """Pop every request that completed since the last call."""
        out, self._finished = self._finished, []
        return out

    # ------------------------------------------------------------- tick --
    def tick(self) -> int:
        """Admit + one batched decode step. Returns #live slots."""
        self._admit()
        live = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not live:
            return 0
        tokens = jnp.asarray(self.slot_last, jnp.int32)
        pos = jnp.asarray(self.slot_pos, jnp.int32)
        logits, self.caches = self._decode(self.params, self.caches,
                                           tokens, pos)
        if self.temperature > 0:
            self._rng, k = jax.random.split(self._rng)
            nxt = jax.random.categorical(k, logits / self.temperature,
                                         axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        nxt = np.asarray(nxt)
        for slot in live:
            req = self.slot_req[slot]
            tok = int(nxt[slot])
            req.output.append(tok)
            self.slot_pos[slot] += 1
            self.slot_last[slot] = tok
            if self._is_done(req, tok):
                self._retire(req)
                self.slot_req[slot] = None
        return len(live)

    def run_until_drained(self, max_ticks: int = 10_000) -> list[Request]:
        """Tick until queue and slots are empty; returns the completed
        requests in completion order (historically this dropped every
        result — the ``done`` list was never appended)."""
        done: list[Request] = []
        for _ in range(max_ticks):
            if not self.queue and all(r is None for r in self.slot_req):
                break
            self.tick()
            done.extend(self.take_finished())
        done.extend(self.take_finished())
        return done
