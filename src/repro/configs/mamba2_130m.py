"""mamba2-130m — SSD (state-space duality), attention-free.
[arXiv:2405.21060] 24L d_model=768 d_ff=0 vocab=50280 ssm_state=128."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=24,             # d_inner / head_dim = 1536 / 64
    n_kv_heads=24,
    d_ff=0,                 # attn-free, no separate FFN (SSD block only)
    vocab_size=50_280,
    head_dim=64,
    tie_embeddings=True,
    max_seq_len=1_048_576,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    sub_quadratic=True,
)
