"""Config dataclasses for models, meshes, shapes and training.

Every assigned architecture is expressed as a :class:`ModelConfig`; the
four input-shape sets of the brief are :data:`SHAPES`.  Configs are plain
frozen dataclasses — no framework magic — so the dry-run can enumerate
(arch x shape x mesh) cells cheaply.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden size
    n_shared: int = 0             # qwen2-moe: shared experts
    shared_d_ff: int = 0          # hidden size of the shared-expert FFN
    dense_residual_d_ff: int = 0  # arctic: dense FFN residual beside the MoE
    period: int = 1               # jamba: MoE every `period` layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # pad the expert dimension to unlock EP sharding when n_experts does
    # not divide the model axis (qwen2-moe: 60 -> 64; dummies never
    # routed; ~6% weight overhead). Beyond-paper opt, EXPERIMENTS.md §Perf-E.
    n_padded: int = 0

    @property
    def e_alloc(self) -> int:
        return max(self.n_padded, self.n_experts)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec models (whisper). The modality frontend
    (conv over mel frames) is a STUB: input_specs() provides precomputed
    frame embeddings, per the brief."""

    n_layers: int
    n_frames: int = 1500          # whisper: 30 s of audio at 50 Hz


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | ssm | moe | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    max_seq_len: int = 131_072
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    gated_mlp: bool = True        # SwiGLU (3 mats) vs plain GELU MLP (2)
    # attention pattern ----------------------------------------------------
    sliding_window: Optional[int] = None     # SWA width (h2o-danube, local)
    local_global_period: Optional[int] = None  # gemma3: 6 => 5 local + 1 global
    attn_layer_period: Optional[int] = None    # jamba: attn every k-th layer
    # sub-modules ----------------------------------------------------------
    ssm: Optional[SSMConfig] = None
    moe: Optional[MoEConfig] = None
    encoder: Optional[EncoderConfig] = None
    # modality stub: inputs are precomputed embeddings, not token ids
    embedding_stub: bool = False
    # whether full attention makes long_500k infeasible (DESIGN.md §4)
    sub_quadratic: bool = False

    # ----- derived -------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(1, self.n_kv_heads)

    def layer_kind(self, layer_idx: int) -> str:
        """Block type of layer ``layer_idx`` (the interleave patterns)."""
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid" and self.attn_layer_period:
            # jamba: 1 attention layer per `attn_layer_period` (the rest SSM)
            return ("attn" if layer_idx % self.attn_layer_period
                    == self.attn_layer_period - 1 else "ssm")
        return "attn"

    def attn_kind(self, layer_idx: int) -> str:
        """'global' | 'local' attention flavour for attention layers."""
        if self.local_global_period:
            return ("global" if layer_idx % self.local_global_period
                    == self.local_global_period - 1 else "local")
        if self.sliding_window:
            return "local"
        return "global"

    def ffn_kind(self, layer_idx: int) -> str:
        """'dense' | 'moe' for this layer's FFN."""
        if self.moe is None:
            return "dense"
        if layer_idx % self.moe.period == self.moe.period - 1:
            return "moe"
        return "dense"

    def param_count(self) -> int:
        """Total parameters (embeddings included once)."""
        return _param_count(self)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k + shared + dense)."""
        return _param_count(self, active_only=True)


def _ffn_params(cfg: ModelConfig, layer: int, active_only: bool) -> int:
    d = cfg.d_model
    n_mats = 3 if cfg.gated_mlp else 2
    if cfg.ffn_kind(layer) == "dense":
        return n_mats * d * cfg.d_ff
    moe = cfg.moe
    n_e = moe.top_k if active_only else moe.n_experts
    total = 3 * d * moe.d_expert * n_e
    if moe.n_shared:
        total += 3 * d * moe.shared_d_ff  # fused shared expert
    if moe.dense_residual_d_ff:
        total += 3 * d * moe.dense_residual_d_ff
    total += d * moe.n_experts  # router
    return total


def _attn_params(cfg: ModelConfig) -> int:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = d * h * hd + 2 * d * kv * hd + h * hd * d
    if cfg.qkv_bias:
        p += (h + 2 * kv) * hd
    return p


def _ssm_params(cfg: ModelConfig) -> int:
    s = cfg.ssm
    d = cfg.d_model
    din = s.d_inner(d)
    nh = s.n_heads(d)
    # in_proj: z, x, B, C, dt ; conv over (x,B,C); out_proj; A,D per head
    in_proj = d * (2 * din + 2 * s.d_state + nh)
    conv = s.d_conv * (din + 2 * s.d_state)
    out_proj = din * d
    return in_proj + conv + out_proj + 2 * nh


def _param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    total = cfg.vocab_size * cfg.d_model  # embedding
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * cfg.d_model
    for layer in range(cfg.n_layers):
        kind = cfg.layer_kind(layer)
        if kind == "attn":
            total += _attn_params(cfg) + 2 * cfg.d_model
        else:
            total += _ssm_params(cfg) + cfg.d_model
        total += _ffn_params(cfg, layer, active_only) + cfg.d_model
    total += cfg.d_model  # final norm
    if cfg.encoder is not None:
        n_mats = 3 if cfg.gated_mlp else 2
        for _ in range(cfg.encoder.n_layers):
            total += _attn_params(cfg) + 3 * cfg.d_model
            total += n_mats * cfg.d_model * cfg.d_ff + cfg.d_model
        # decoder cross-attention blocks
        total += cfg.n_layers * (_attn_params(cfg) + cfg.d_model)
    return total


# ---------------------------------------------------------------------------
# Input shapes (the brief's four shape sets)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Mesh / run configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False

    @property
    def shape(self) -> tuple[int, ...]:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self) -> tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    optimizer: str = "adamw"            # "adamw" | "adafactor"
    remat: bool = True
    zero3: bool = False                 # shard params over data axis (ZeRO-3)
    grad_compression: bool = False      # int8 error-feedback DP compression
    microbatch: int = 0                 # grad accumulation (0 = off)
    strategy: str = "dp_tp"             # "dp_tp" | "dp_only" (§Perf-B)
    seq_parallel: bool = False          # Megatron-SP activations (§Perf-C)
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    seed: int = 0


def recommended_train_config(model: ModelConfig) -> TrainConfig:
    """Big models need Adafactor + ZeRO-3 + remat to fit 16 GB/chip;
    >=200B additionally store params in bf16 (T5X-style, relies on the
    factored optimizer's update clipping for stability)."""
    n = model.param_count()
    big = n > 5_000_000_000
    return TrainConfig(
        optimizer="adafactor" if big else "adamw",
        zero3=big,
        remat=True,
        param_dtype="bfloat16" if n > 200_000_000_000 else "float32",
    )
