"""Architecture registry: ``get_config(arch)`` / ``list_archs()``.

The ten assigned architectures plus reduced "smoke" variants of each
(same family, tiny dims) used by the CPU test-suite.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (  # noqa: F401
    EncoderConfig,
    MeshConfig,
    ModelConfig,
    MoEConfig,
    SHAPES,
    ShapeConfig,
    SSMConfig,
    TrainConfig,
    recommended_train_config,
)

from repro.configs import (  # noqa: E402
    arctic_480b,
    gemma3_1b,
    h2o_danube3_4b,
    internvl2_76b,
    jamba_1_5_large_398b,
    mamba2_130m,
    qwen1_5_110b,
    qwen2_moe_a2_7b,
    starcoder2_7b,
    whisper_small,
)

_REGISTRY: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        mamba2_130m.CONFIG,
        internvl2_76b.CONFIG,
        starcoder2_7b.CONFIG,
        gemma3_1b.CONFIG,
        qwen1_5_110b.CONFIG,
        h2o_danube3_4b.CONFIG,
        whisper_small.CONFIG,
        jamba_1_5_large_398b.CONFIG,
        qwen2_moe_a2_7b.CONFIG,
        arctic_480b.CONFIG,
    ]
}


def list_archs() -> list[str]:
    return sorted(_REGISTRY)


def get_config(arch: str) -> ModelConfig:
    try:
        return _REGISTRY[arch]
    except KeyError:
        raise KeyError(
            f"unknown arch {arch!r}; available: {', '.join(list_archs())}"
        ) from None


def smoke_config(arch: str) -> ModelConfig:
    """A reduced config of the same family for CPU smoke tests: few
    layers, narrow width, tiny vocab — structure preserved (interleave
    patterns, MoE, enc-dec), sizes shrunk."""
    cfg = get_config(arch)
    changes: dict = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(4, max(1, cfg.n_kv_heads * 4 // cfg.n_heads)),
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        head_dim=32,
        max_seq_len=512,
    )
    if cfg.sliding_window:
        changes["sliding_window"] = 16
    if cfg.local_global_period:
        changes["local_global_period"] = 2
        changes["n_layers"] = 4
    if cfg.attn_layer_period:
        changes["attn_layer_period"] = 2
        changes["n_layers"] = 4
    if cfg.ssm is not None:
        changes["ssm"] = SSMConfig(
            d_state=16, d_conv=4, expand=2, head_dim=32, chunk=16)
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe,
            n_experts=min(8, cfg.moe.n_experts),
            top_k=min(2, cfg.moe.top_k),
            d_expert=64,
            shared_d_ff=64 if cfg.moe.n_shared else 0,
            n_shared=min(1, cfg.moe.n_shared),
            dense_residual_d_ff=64 if cfg.moe.dense_residual_d_ff else 0,
        )
    if cfg.encoder is not None:
        changes["encoder"] = EncoderConfig(n_layers=2, n_frames=32)
    return dataclasses.replace(cfg, name=f"{cfg.name}-smoke", **changes)
