"""arctic-480b — 128 experts top-2 + dense residual.
[hf:Snowflake/snowflake-arctic-base] 35L d_model=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000.

The largest memory cell: requires ZeRO-3 + Adafactor + full remat
(DESIGN.md §4)."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32_000,
    head_dim=128,
    max_seq_len=4096,
    moe=MoEConfig(
        n_experts=128, top_k=2, d_expert=4864,
        dense_residual_d_ff=4864,
    ),
    sub_quadratic=False,     # full attention -> long_500k skipped
)
