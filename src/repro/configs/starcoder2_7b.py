"""starcoder2-7b — GQA, RoPE. [arXiv:2402.19173; hf]
32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18_432,
    vocab_size=49_152,
    head_dim=128,
    rope_theta=100_000.0,
    max_seq_len=16_384,
    gated_mlp=False,         # starcoder2: plain GELU MLP (c_fc/c_proj)
    qkv_bias=True,

    sub_quadratic=False,     # full attention -> long_500k skipped
)
