"""jamba-1.5-large-398b — Mamba+attention 1:7 interleave + MoE.
[arXiv:2403.19887; hf] 72L d_model=8192 64H (GQA kv=8) d_ff=24576,
MoE 16e top-2 every other layer.

Hybrid (mostly SSM) -> long_500k RUNS."""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24_576,
    vocab_size=65_536,
    head_dim=128,
    max_seq_len=262_144,
    attn_layer_period=8,     # 1 attention : 7 mamba
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=128, chunk=256),
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=24_576, period=2),
    sub_quadratic=True,
)
