"""gemma3-1b — 5:1 local:global attention interleave, 128k context.
[hf:google/gemma-3-1b-pt] 26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144.

Predominantly-local attention (window 512, one global layer per 6) makes
long-context decode sub-quadratic in aggregate; long_500k RUNS for this
arch (DESIGN.md §4)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_ff=6912,
    vocab_size=262_144,
    head_dim=256,
    rope_theta=1_000_000.0,
    max_seq_len=131_072,
    tie_embeddings=True,
    sliding_window=512,
    local_global_period=6,   # 5 local : 1 global
    sub_quadratic=True,
)
