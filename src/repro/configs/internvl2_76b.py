"""internvl2-76b — InternViT + InternLM2 backbone. [arXiv:2404.16821]
80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.

VLM: the vision frontend is a STUB — input_specs() provides precomputed
patch embeddings mixed into the token stream (brief: "[vlm] entries
specify the transformer BACKBONE only")."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28_672,
    vocab_size=128_256,
    head_dim=128,
    max_seq_len=32_768,
    embedding_stub=True,     # patch embeddings arrive precomputed
    sub_quadratic=False,     # full attention -> long_500k skipped
)
