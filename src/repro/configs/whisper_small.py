"""whisper-small — encoder-decoder ASR backbone. [arXiv:2212.04356]
12L d_model=768 12H d_ff=3072 vocab=51865.

[audio]: the conv-over-mel frontend is a STUB — input_specs() provides
precomputed 1500-frame encoder embeddings.  Decode shapes lower the
decoder step with cross-attention KV from the encoder (max positions are
shape-parameterised so decode_32k is lowerable; the real model caps at
448 decoder positions)."""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,              # decoder layers
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51_865,
    head_dim=64,
    max_seq_len=32_768,
    gated_mlp=False,          # whisper: plain GELU MLP

    encoder=EncoderConfig(n_layers=12, n_frames=1500),
    embedding_stub=True,      # encoder inputs are precomputed frames
    sub_quadratic=False,      # full attention -> long_500k skipped
)
