"""qwen2-moe-a2.7b — 4 shared + 60 routed top-4.
[hf:Qwen/Qwen1.5-MoE-A2.7B] 24L d_model=2048 16H d_ff=1408(per expert)
vocab=151936."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151_936,
    head_dim=128,
    qkv_bias=True,
    max_seq_len=32_768,
    moe=MoEConfig(
        n_experts=60, top_k=4, d_expert=1408,
        n_shared=4, shared_d_ff=5632,
    ),
    sub_quadratic=False,     # full attention -> long_500k skipped
)
