"""Step builders: train_step / prefill_step / decode_step with shardings.

Every (arch x shape x mesh) dry-run cell lowers one of these.  The
shardings come from the tensor planner (repro.core.tensor_plan) — i.e.
from the paper's IN/OUT/INOUT derivation generalised to tensors — and
are attached as jax.ShapeDtypeStruct shardings for AOT lowering
(``input_specs``) or as in_shardings for live execution.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ModelConfig, ShapeConfig, TrainConfig
from repro.core import tensor_plan as tp
from repro.models import build_model
from repro.optim import clip_by_global_norm, cosine_warmup, make_optimizer
from repro.optim.api import opt_state_axes
from repro.optim.schedule import cosine_warmup as _cos


@dataclasses.dataclass
class CellSpec:
    """Everything needed to lower one dry-run cell."""

    model_cfg: ModelConfig
    shape: ShapeConfig
    mesh: Mesh
    plan: tp.TensorPlan
    step_fn: Any                   # the jittable python callable
    args: tuple                    # ShapeDtypeStructs with shardings
    donate: tuple = ()
    kind: str = "train"


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _tree_sds(shapes_tree, axes_tree, plan, mesh):
    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    return jax.tree_util.tree_map(
        lambda s, a: _sds(s.shape, s.dtype, mesh, plan.spec(s.shape, a)),
        shapes_tree, axes_tree, is_leaf=lambda x: hasattr(x, "shape"))


def _dp_degree(mesh: Mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def _batch_fields(cfg: ModelConfig, shape: ShapeConfig):
    """(field -> (shape, dtype)) for a training batch of this arch."""
    b, s = shape.global_batch, shape.seq_len
    fields = {"labels": ((b, s), jnp.int32)}
    if cfg.family == "encdec":
        fields["frames"] = ((b, cfg.encoder.n_frames, cfg.d_model),
                            jnp.float32)
        fields["tokens"] = ((b, s), jnp.int32)
    elif cfg.embedding_stub:
        fields["embeds"] = ((b, s, cfg.d_model), jnp.bfloat16)
    else:
        fields["tokens"] = ((b, s), jnp.int32)
    return fields


_BATCH_AXES = {
    "labels": (tp.BATCH, None),
    "tokens": (tp.BATCH, None),
    "frames": (tp.BATCH, None, None),
    "embeds": (tp.BATCH, None, None),
}


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------


def choose_microbatch(cfg: ModelConfig, shape: ShapeConfig,
                      dp: int, *, budget_gb: float = 3.0) -> int:
    """Split the per-device batch so rematerialised activations fit.

    Rough per-microbatch activation estimate: one (B_loc, S, d_model)
    residual per layer in bf16, x4 for block intermediates kept live
    during the rematerialised backward."""
    b_loc = max(1, shape.global_batch // dp)
    per_seq = shape.seq_len * cfg.d_model * 2 * 4 * cfg.n_layers
    micro = 1
    while (b_loc // micro > 1
           and b_loc % (micro * 2) == 0
           and b_loc // micro * per_seq > budget_gb * 2**30):
        micro *= 2
    return micro


def make_train_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    train_cfg: TrainConfig, *, attn_impl="auto") -> CellSpec:
    model = build_model(cfg)
    plan = tp.make_train_plan(mesh.axis_names, tuple(mesh.shape.values()),
                              zero3=train_cfg.zero3,
                              strategy=train_cfg.strategy, mesh=mesh)
    opt = make_optimizer(train_cfg.optimizer,
                         weight_decay=train_cfg.weight_decay)
    groups = _dp_degree(mesh)
    compute_dtype = jnp.bfloat16 if train_cfg.compute_dtype == "bfloat16" \
        else jnp.float32
    n_micro = train_cfg.microbatch or choose_microbatch(
        cfg, shape, _dp_degree(mesh))

    act_axes = ((tp.BATCH, tp.SEQ, None) if train_cfg.seq_parallel
                else (tp.BATCH, None, None))
    if train_cfg.seq_parallel:
        plan = dataclasses.replace(
            plan, rules={**plan.rules, tp.SEQ: ("model",)})

    def shard_act(x):
        return plan.constrain(x, act_axes)

    def loss_of(params, batch):
        return model.loss_fn(params, batch, impl=attn_impl, groups=groups,
                             remat=train_cfg.remat,
                             compute_dtype=compute_dtype,
                             shard_fn=shard_act)

    def train_step(params, opt_state, batch, step):
        if n_micro > 1:
            # gradient accumulation: the microbatch scan lives INSIDE the
            # differentiated function so the parameter cotangent is a
            # single in-place loop carry (an explicit `g_acc + g` outside
            # grad keeps two full gradient trees live — measured +7.3 GB
            # on arctic-480b, EXPERIMENTS.md §Dry-run).
            # strided split (B -> (B/n, n) -> (n, B/n)) so each device's
            # local rows split evenly across microbatches: no resharding
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape((x.shape[0] // n_micro, n_micro)
                                    + x.shape[1:]).swapaxes(0, 1),
                batch)

            def micro_loss(params, micro):
                def body(carry, mb):
                    l, m = loss_of(params, mb)
                    return (carry[0] + l, carry[1] + m["aux"]), None

                (tot, aux), _ = jax.lax.scan(
                    jax.checkpoint(body),
                    (jnp.float32(0), jnp.float32(0)), micro)
                return tot / n_micro, {"ce": tot / n_micro,
                                       "aux": aux / n_micro}

            (loss, metrics), grads = jax.value_and_grad(
                micro_loss, has_aux=True)(params, micro)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, train_cfg.grad_clip)
        lr = _cos(step, base_lr=train_cfg.learning_rate,
                  warmup_steps=train_cfg.warmup_steps,
                  total_steps=train_cfg.total_steps)
        params, opt_state = opt.update(grads, opt_state, params, lr)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return params, opt_state, metrics

    # abstract shapes + shardings
    p_shapes, p_axes = _param_shapes(model)
    if train_cfg.param_dtype == "bfloat16":
        # bf16 resident params (drivers cast after init; see train.py)
        p_shapes = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16),
            p_shapes)
    params_sds = _tree_sds(p_shapes, p_axes, plan, mesh)
    o_shapes = jax.eval_shape(opt.init, p_shapes)
    o_axes = opt_state_axes(train_cfg.optimizer, p_shapes, p_axes)
    opt_sds = _tree_sds(o_shapes, o_axes, plan, mesh)
    batch_sds = {
        k: _sds(sh, dt, mesh, plan.spec(sh, _BATCH_AXES[k]))
        for k, (sh, dt) in _batch_fields(cfg, shape).items()
    }
    step_sds = _sds((), jnp.int32, mesh, P())
    return CellSpec(cfg, shape, mesh, plan, train_step,
                    (params_sds, opt_sds, batch_sds, step_sds),
                    donate=(0, 1), kind="train")


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------


def _serve_plan(mesh, shape):
    shard_seq = shape.global_batch < _dp_degree(mesh)
    return tp.make_serve_plan(mesh.axis_names, tuple(mesh.shape.values()),
                              shard_seq=shard_seq, decode=shape.is_decode,
                              mesh=mesh)


def make_prefill_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                      *, attn_impl="auto") -> CellSpec:
    model = build_model(cfg)
    plan = _serve_plan(mesh, shape)
    b, s = shape.global_batch, shape.seq_len

    def shard_act(x):
        return plan.constrain(x, (tp.BATCH, None, None))

    groups = 1 if shape.global_batch < _dp_degree(mesh) \
        else _dp_degree(mesh)

    def prefill_step(params, batch, caches):
        return model.prefill(params, batch, caches, impl=attn_impl,
                             compute_dtype=jnp.bfloat16, groups=groups,
                             shard_fn=shard_act)

    p_shapes, p_axes = _param_shapes(model)
    p_shapes = _cast_tree(p_shapes, jnp.bfloat16)  # inference: bf16 params
    params_sds = _tree_sds(p_shapes, p_axes, plan, mesh)
    fields = _batch_fields(cfg, shape)
    fields.pop("labels")
    batch_sds = {
        k: _sds(sh, dt, mesh, plan.spec(sh, _BATCH_AXES[k]))
        for k, (sh, dt) in fields.items()
    }
    c_shapes, c_axes = _cache_shapes(model, b, s)
    cache_sds = _tree_sds(c_shapes, c_axes, plan, mesh)
    return CellSpec(cfg, shape, mesh, plan, prefill_step,
                    (params_sds, batch_sds, cache_sds),
                    donate=(2,), kind="prefill")


def _cache_shapes(model, batch, cache_len):
    shapes = jax.eval_shape(
        functools.partial(model.init_cache, batch, cache_len,
                          dtype=jnp.bfloat16))
    return shapes, model.cache_axes()


def _cast_tree(shapes_tree, dtype):
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), shapes_tree)


def _param_shapes(model):
    """(ShapeDtypeStruct tree, axes tree) without allocating params.

    The axes tree is static python (strings), so it is captured from the
    traced call rather than returned through eval_shape."""
    captured = {}

    def init_params_only(key):
        params, axes = model.init(key)
        captured["axes"] = axes
        return params

    shapes = jax.eval_shape(init_params_only, jax.random.PRNGKey(0))
    return shapes, captured["axes"]


def make_decode_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                     *, attn_impl="auto") -> CellSpec:
    model = build_model(cfg)
    plan = _serve_plan(mesh, shape)
    b, s = shape.global_batch, shape.seq_len

    def shard_act(x):
        return plan.constrain(x, (tp.BATCH, None, None))

    groups = 1 if shape.global_batch < _dp_degree(mesh) \
        else _dp_degree(mesh)

    def decode_step(params, caches, tokens, pos):
        return model.decode_step(params, caches, tokens, pos,
                                 impl=attn_impl, groups=groups,
                                 compute_dtype=jnp.bfloat16,
                                 shard_fn=shard_act)

    p_shapes, p_axes = _param_shapes(model)
    p_shapes = _cast_tree(p_shapes, jnp.bfloat16)  # inference: bf16 params
    params_sds = _tree_sds(p_shapes, p_axes, plan, mesh)
    c_shapes, c_axes = _cache_shapes(model, b, s)
    cache_sds = _tree_sds(c_shapes, c_axes, plan, mesh)
    tok_sds = _sds((b,), jnp.int32, mesh,
                   plan.spec((b,), (tp.BATCH,)))
    pos_sds = _sds((b,), jnp.int32, mesh,
                   plan.spec((b,), (tp.BATCH,)))
    return CellSpec(cfg, shape, mesh, plan, decode_step,
                    (params_sds, cache_sds, tok_sds, pos_sds),
                    donate=(1,), kind="decode")


def make_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
              train_cfg: TrainConfig | None = None, **kw) -> CellSpec:
    if shape.kind == "train":
        from repro.configs import recommended_train_config

        return make_train_cell(cfg, shape, mesh,
                               train_cfg or recommended_train_config(cfg),
                               **kw)
    if shape.kind == "prefill":
        return make_prefill_cell(cfg, shape, mesh, **kw)
    return make_decode_cell(cfg, shape, mesh, **kw)


def lower_cell(cell: CellSpec):
    """AOT-lower the cell (no device memory touched)."""
    jitted = jax.jit(cell.step_fn, donate_argnums=cell.donate)
    return jitted.lower(*cell.args)
