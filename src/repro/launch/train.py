"""End-to-end training driver.

Wires together every substrate: config registry, synthetic data pipeline,
plan-derived shardings, microbatched train step, fault-tolerant loop with
async checkpointing and straggler monitoring.

Examples:
  # train a ~100M smoke-size model for 300 steps on the local device
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
      --smoke --steps 300

  # multi-host production launch (per host; see launch/scripts/)
  python -m repro.launch.train --arch qwen1.5-110b --coordinator ...
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

log = logging.getLogger("repro.train")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-size)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="results/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--coordinator", default=None,
                    help="jax.distributed coordinator address (multi-host)")
    ap.add_argument("--num-hosts", type=int, default=1)
    ap.add_argument("--host-id", type=int, default=0)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    if args.coordinator:
        jax.distributed.initialize(args.coordinator, args.num_hosts,
                                   args.host_id)

    from repro.checkpoint import Checkpointer
    from repro.configs import (ShapeConfig, get_config,
                               recommended_train_config, smoke_config)
    from repro.core import tensor_plan as tp
    from repro.data import make_batch_iterator
    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import make_train_cell
    from repro.models import build_model
    from repro.optim import make_optimizer
    from repro.runtime import FaultTolerantLoop, StragglerMonitor

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    train_cfg = dataclasses.replace(
        recommended_train_config(cfg),
        learning_rate=args.lr, total_steps=args.steps,
        warmup_steps=max(1, args.steps // 20))
    mesh = make_local_mesh(args.model_parallel)
    shape = ShapeConfig("cli", args.seq_len, args.batch, "train")
    cell = make_train_cell(cfg, shape, mesh, train_cfg)

    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(train_cfg.seed))
    if train_cfg.param_dtype == "bfloat16":
        params = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.bfloat16), params)
    opt = make_optimizer(train_cfg.optimizer)
    opt_state = opt.init(params)

    step_j = jax.jit(cell.step_fn, donate_argnums=(0, 1))
    data = make_batch_iterator(
        vocab_size=cfg.vocab_size, batch=args.batch, seq_len=args.seq_len,
        seed=train_cfg.seed, shard=args.host_id,
        num_shards=args.num_hosts,
        embed_dim=cfg.d_model if cfg.embedding_stub
        and cfg.family != "encdec" else None,
        frames=cfg.encoder.n_frames if cfg.family == "encdec" else None,
    )
    if cfg.family == "encdec":
        # frames stub needs embed_dim; rebuild accordingly
        data = make_batch_iterator(
            vocab_size=cfg.vocab_size, batch=args.batch,
            seq_len=args.seq_len, seed=train_cfg.seed,
            shard=args.host_id, num_shards=args.num_hosts,
            embed_dim=cfg.d_model, frames=cfg.encoder.n_frames)

    ckpt = Checkpointer(args.ckpt_dir, host_id=args.host_id,
                        num_hosts=args.num_hosts)
    monitor = StragglerMonitor()
    metrics_hist: list[float] = []

    def step_fn(state, step):
        params, opt_state = state
        batch = None
        # deterministic replay: the iterator is keyed by step
        from repro.data.pipeline import SyntheticLM, batch_key

        batch = next(data)  # iterator advances monotonically; replay via
        # checkpoint restore handled by recreating the iterator (the
        # FaultTolerantLoop restores (params, opt), and data is re-keyed)
        t0 = time.time()
        params, opt_state, m = step_j(params, opt_state, batch,
                                      jnp.int32(step))
        status = monitor.observe(time.time() - t0)
        if status != "ok":
            log.warning("straggler status at step %d: %s", step, status)
        if step % args.log_every == 0:
            loss = float(m["loss"])
            metrics_hist.append(loss)
            log.info("step %5d loss %.4f ce %.4f gnorm %.2f lr %.2e",
                     step, loss, float(m["ce"]), float(m["grad_norm"]),
                     float(m["lr"]))
        return params, opt_state

    loop = FaultTolerantLoop(
        step_fn=step_fn, checkpointer=ckpt,
        checkpoint_every=args.ckpt_every)
    state = (params, opt_state)
    restored = ckpt.restore_latest(state)
    start = 0
    if restored is not None:
        start, state = restored
        log.info("resumed from step %d", start)
    state = loop.run(state, start_step=start,
                     num_steps=args.steps - start)
    ckpt.save(args.steps, state)
    if len(metrics_hist) >= 2:
        log.info("loss: first %.4f -> last %.4f", metrics_hist[0],
                 metrics_hist[-1])


if __name__ == "__main__":
    main()
