"""Production mesh definition.

``make_production_mesh()`` is a FUNCTION (not a module constant) so that
importing this module never touches jax device state — required because
the dry-run forces 512 virtual host devices while tests/benches must see
the single real device.
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (tests, examples, smoke runs)."""
    n = len(jax.devices())
    assert n % model_parallel == 0, (n, model_parallel)
    return make_mesh((n // model_parallel, model_parallel),
                     ("data", "model"))
