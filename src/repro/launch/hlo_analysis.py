"""Post-optimization HLO analysis: collective bytes + roofline terms.

``compiled.cost_analysis()`` reports FLOPs/bytes with every ``while``
(scan) body counted ONCE (verified on jax 0.8.2), and collective traffic
not at all.  This module parses the per-device SPMD HLO text:

* splits it into computations,
* builds the call graph (while bodies, conditionals, called computations),
* extracts each while loop's trip count from its condition computation
  (``compare(counter, constant), direction=LT`` pattern),
* sums collective bytes with per-op wire-cost models, multiplying ops
  inside loop bodies by the enclosing trip counts,
* converts to the three roofline terms with the v5e constants.

All sizes in the SPMD module are already per-device, so "bytes" here are
per-chip wire bytes; the collective term is bytes / link_bw.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# v5e-like hardware constants (per the brief)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Sum bytes over every array shape in an HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    bytes_wire: int            # per-device wire bytes (cost model applied)
    bytes_payload: int
    group_size: int
    computation: str
    multiplier: int = 1


@dataclasses.dataclass
class HloReport:
    collectives: list[CollectiveOp]
    trip_counts: dict[str, int]
    dot_flops: float = 0.0       # scan-corrected MXU flops (dots only)
    dot_bytes: float = 0.0       # scan-corrected dot operand+result bytes
    # CPU-backend artifact: FloatNormalization hoists bf16->f32 converts
    # of whole parameter stacks out of loops (no bf16 dot on CPU). A TPU
    # build keeps bf16 MXU dots, so these buffers don't exist there.
    f32_param_convert_bytes: float = 0.0

    @property
    def total_wire_bytes(self) -> float:
        return sum(c.bytes_wire * c.multiplier for c in self.collectives)

    def by_kind(self) -> dict[str, float]:
        out: dict[str, float] = defaultdict(float)
        for c in self.collectives:
            out[c.kind] += c.bytes_wire * c.multiplier
        return dict(out)


def _split_computations(hlo: str) -> dict[str, list[str]]:
    """Split module text into computations by column-0 indentation.

    Computation definitions start at column 0 (``%name (params...) ->``,
    possibly wrapped over several lines); instructions are indented; the
    closing ``}`` is at column 0.  Wrapped header lines land in the body
    but never match an instruction pattern, so they are harmless.
    """
    comps: dict[str, list[str]] = {}
    body: list[str] | None = None
    for line in hlo.splitlines():
        if not line.strip():
            continue
        if line.startswith("}"):
            body = None
            continue
        if not line.startswith(" "):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", line)
            if m:
                body = []
                comps[m.group(1)] = body
                continue
        if body is not None:
            s = line.strip()
            if s and not s.startswith("//"):
                body.append(s)
    return comps


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota-style [groups, size]
        return int(m.group(2))
    return default


def _wire_bytes(kind: str, payload: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * payload * (g - 1) / g
    if kind == "all-gather":
        return payload * (g - 1) / g          # payload = gathered result
    if kind == "reduce-scatter":
        return payload * (g - 1)              # payload = scattered result
    if kind == "all-to-all":
        return payload * (g - 1) / g
    if kind == "collective-permute":
        return float(payload)
    return float(payload)


def analyze_hlo(hlo: str, *, num_devices: int,
                default_trip: int = 1) -> HloReport:
    comps = _split_computations(hlo)

    # --- trip counts: map while-op body/condition computations ------------
    trip_of_body: dict[str, int] = {}
    for cname, lines in comps.items():
        for line in lines:
            if " while(" in line:
                mb = re.search(r"body=%?([\w\.\-]+)", line)
                mc = re.search(r"condition=%?([\w\.\-]+)", line)
                trip = default_trip
                if mc and mc.group(1) in comps:
                    consts = [int(x) for x in re.findall(
                        r"constant\((\d+)\)", "\n".join(comps[mc.group(1)]))]
                    if consts:
                        trip = max(consts)
                if mb:
                    trip_of_body[mb.group(1)] = max(trip, 1)

    # --- call-graph multipliers (nested whiles multiply) -------------------
    multiplier: dict[str, int] = defaultdict(lambda: 1)

    def propagate(name: str, mult: int, seen: frozenset):
        if name in seen or name not in comps:
            return
        multiplier[name] = max(multiplier[name], mult)
        for line in comps[name]:
            for ref in re.findall(
                    r"(?:body|condition|to_apply|calls)=%?([\w\.\-]+)", line):
                child_mult = mult * trip_of_body.get(ref, 1) \
                    if ref in trip_of_body else mult
                propagate(ref, child_mult, seen | {name})

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
        if entry:
            break
    roots = [entry] if entry and entry in comps else list(comps)
    for r in roots:
        propagate(r, 1, frozenset())

    # --- scan-corrected dot flops/bytes ------------------------------------
    # Operands carry no inline types in optimized HLO, so first build a
    # per-computation symbol table (%name -> type string).
    dot_flops = 0.0
    dot_bytes = 0.0
    def_re = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+"
                        r"([\w\-]+)\(")
    dot_args_re = re.compile(r"\sdot\(([^)]*)\)")
    lcd_re = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
    for cname, lines in comps.items():
        mult = multiplier.get(cname, 1)
        symtab: dict[str, str] = {}
        for line in lines:
            md = def_re.match(line)
            if md:
                symtab[md.group(1)] = md.group(2)

        def _operand_types(arg_str):
            """Type string per operand.  Older HLO printers inline the
            operand type (``dot(f32[8,16]{1,0} %a, ...)``); newer ones
            print bare names resolved through the symbol table.  Args
            are split on top-level commas only (shapes contain commas).
            """
            parts, depth, cur = [], 0, []
            for chx in arg_str:
                if chx in "[{(":
                    depth += 1
                elif chx in "]})":
                    depth -= 1
                if chx == "," and depth == 0:
                    parts.append("".join(cur))
                    cur = []
                else:
                    cur.append(chx)
            if cur:
                parts.append("".join(cur))
            types = []
            for p in parts:
                p = p.strip()
                if not p:
                    continue
                if " " in p:                      # inline "type %name"
                    types.append(p.rsplit(None, 1)[0])
                else:
                    types.append(symtab.get(p.lstrip("%"), ""))
            return types

        for line in lines:
            md = def_re.match(line)
            if md is None or md.group(3) != "dot":
                continue
            result_type = md.group(2)
            result_shapes = _SHAPE_RE.findall(result_type)
            ma = dot_args_re.search(line)
            if not result_shapes or ma is None:
                continue
            op_types = _operand_types(ma.group(1))
            lhs_type = op_types[0] if op_types else ""
            lhs_shapes = _SHAPE_RE.findall(lhs_type)
            if not lhs_shapes:
                continue
            res_dims = [int(d) for d in result_shapes[0][1].split(",") if d]
            lhs_dims = [int(d) for d in lhs_shapes[0][1].split(",") if d]
            mc = lcd_re.search(line)
            contract = 1
            if mc and mc.group(1):
                for idx in mc.group(1).split(","):
                    contract *= lhs_dims[int(idx)]
            res_n = 1
            for d in res_dims:
                res_n *= d
            dot_flops += 2.0 * res_n * contract * mult
            op_bytes = sum(_shape_bytes(t) for t in op_types)
            dot_bytes += (_shape_bytes(result_type) + op_bytes) * mult

    # --- CPU float-normalization artifact ----------------------------------
    # Only count hoisted converts in the ENTRY computation whose operand
    # is a true module parameter: those are weight stacks promoted to f32
    # because the CPU backend has no bf16 dot; they are live together at
    # the loop boundary (they feed the while tuple).
    f32_conv_bytes = 0.0
    conv_re = re.compile(
        r"=\s*(f32\[[0-9,]*\])[^ ]*\s+(?:fusion|convert)\((%?param[\w\.\-]*)\)")
    entry_name = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry_name = m.group(1)
            break
    if entry_name in comps:
        lines = comps[entry_name]
        symtab: dict[str, tuple[str, str]] = {}
        for line in lines:
            md = def_re.match(line)
            if md:
                symtab[md.group(1)] = (md.group(2), md.group(3))
        for line in lines:
            m = conv_re.search(line)
            if m is None:
                continue
            operand = m.group(2).lstrip("%")
            op_type, op_code = symtab.get(operand, ("", ""))
            if op_code != "parameter" or "bf16[" not in op_type:
                continue
            res_b = _shape_bytes(m.group(1))
            if res_b == 2 * _shape_bytes(op_type):
                f32_conv_bytes += res_b

    # --- collect collectives ----------------------------------------------
    ops: list[CollectiveOp] = []
    for cname, lines in comps.items():
        for line in lines:
            for kind in COLLECTIVES:
                token = f" {kind}("
                start_token = f"{kind}-start("
                if token in line or start_token in line:
                    # result type(s): text between '=' and the op name
                    m = re.search(r"=\s*(.*?)\s*" + kind, line)
                    if not m:
                        continue
                    payload = _shape_bytes(m.group(1))
                    g = _group_size(line, num_devices)
                    ops.append(CollectiveOp(
                        kind=kind,
                        bytes_wire=int(_wire_bytes(kind, payload, g)),
                        bytes_payload=payload,
                        group_size=g,
                        computation=cname,
                        multiplier=multiplier.get(cname, 1),
                    ))
                    break
    return HloReport(collectives=ops, trip_counts=trip_of_body,
                     dot_flops=dot_flops, dot_bytes=dot_bytes,
                     f32_param_convert_bytes=f32_conv_bytes)


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float
    hlo_bytes: float
    wire_bytes: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """compute_term / max(all terms): 1.0 = perfectly compute-bound."""
        t = self.bound_time_s
        return self.compute_s / t if t > 0 else 0.0


def roofline_terms(*, hlo_flops: float, hlo_bytes: float,
                   wire_bytes: float) -> RooflineTerms:
    """All inputs are PER-DEVICE quantities (SPMD module values)."""
    return RooflineTerms(
        compute_s=hlo_flops / PEAK_FLOPS,
        memory_s=hlo_bytes / HBM_BW,
        collective_s=wire_bytes / ICI_BW,
        hlo_flops=hlo_flops,
        hlo_bytes=hlo_bytes,
        wire_bytes=wire_bytes,
    )
