"""Serving driver: batched requests through the continuous-batching
engine.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
      --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

log = logging.getLogger("repro.serve")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    from repro.configs import get_config, smoke_config
    from repro.models import build_model
    from repro.serving import Request, ServeEngine

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.embedding_stub and cfg.family != "encdec":
        raise SystemExit(f"{cfg.name}: serving needs token inputs "
                         "(vlm stub arch serves via embeds API)")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(model, params, n_slots=args.slots,
                         cache_len=args.cache_len,
                         temperature=args.temperature,
                         compute_dtype=jnp.float32)
    rng = np.random.default_rng(args.seed)
    reqs = []
    for rid in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size,
                              size=rng.integers(3, 12)).tolist()
        req = Request(rid=rid, prompt=prompt, max_new_tokens=args.max_new)
        reqs.append(req)
        engine.submit(req)

    t0 = time.time()
    ticks = 0
    while any(not r.done for r in reqs):
        engine.tick()
        ticks += 1
        if ticks > 10_000:
            raise RuntimeError("engine did not drain")
    dt = time.time() - t0
    total_tokens = sum(len(r.output) for r in reqs)
    log.info("served %d requests, %d tokens in %.2fs (%.1f tok/s, "
             "%d ticks)", len(reqs), total_tokens, dt,
             total_tokens / max(dt, 1e-9), ticks)
    for r in reqs[:4]:
        log.info("req %d: prompt=%s -> %s", r.rid, r.prompt, r.output)


if __name__ == "__main__":
    main()
