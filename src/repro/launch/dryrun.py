import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count at first
init, and the production meshes need 512 placeholder host devices.

For every cell this script:

1. builds the step (train_step / prefill / decode) with plan-derived
   shardings (launch/steps.py),
2. ``jit(...).lower(**input ShapeDtypeStructs).compile()`` — no arrays
   are ever allocated,
3. records ``memory_analysis()`` (proves the cell fits 16 GB/chip),
   ``cost_analysis()`` (FLOPs/bytes, scan body counted once),
   and the scan-corrected HLO collective/dot statistics
   (launch/hlo_analysis.py),
4. writes one JSON per cell to --out (existing cells are skipped, so the
   sweep is resumable).

Usage:
  python -m repro.launch.dryrun --arch starcoder2-7b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import json
import time
import traceback


def cell_id(arch: str, shape: str, mesh_name: str) -> str:
    return f"{arch}__{shape}__{mesh_name}"


def should_skip(cfg, shape) -> str | None:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("long_500k skipped: pure full attention "
                "(DESIGN.md §4)")
    return None


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             *, force: bool = False) -> dict:
    import jax

    from repro.configs import SHAPES, get_config
    from repro.launch import hlo_analysis as ha
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import lower_cell, make_cell

    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cid = cell_id(arch, shape_name, mesh_name)
    path = os.path.join(out_dir, cid + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    record: dict = {
        "cell": cid, "arch": arch, "shape": shape_name,
        "mesh": mesh_name, "kind": shape.kind,
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    }
    skip = should_skip(cfg, shape)
    if skip:
        record["status"] = "skipped"
        record["reason"] = skip
        _write(path, record)
        return record

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_dev = mesh.size
        cell = make_cell(cfg, shape, mesh)
        lowered = lower_cell(cell)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        trip_default = max(1, cfg.n_layers)
        rep = ha.analyze_hlo(hlo, num_devices=n_dev,
                             default_trip=trip_default)

        record.update({
            "status": "ok",
            "devices": n_dev,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_per_device_gb": round(
                    (ma.argument_size_in_bytes + ma.output_size_in_bytes
                     + ma.temp_size_in_bytes
                     - ma.alias_size_in_bytes) / 2**30, 3),
                # minus the CPU-only f32 weight-convert buffers (the TPU
                # build keeps bf16 MXU dots): the deployable HBM estimate
                "peak_tpu_adjusted_gb": None,  # filled below
            },
            "cost_analysis": {
                "flops_scan_once": ca.get("flops", 0.0),
                "bytes_scan_once": ca.get("bytes accessed", 0.0),
            },
            "hlo": {
                "dot_flops": rep.dot_flops,
                "dot_bytes": rep.dot_bytes,
                "wire_bytes": rep.total_wire_bytes,
                "collective_bytes_by_kind": rep.by_kind(),
                "n_collectives": len(rep.collectives),
                "trip_counts": rep.trip_counts,
                "f32_param_convert_bytes": rep.f32_param_convert_bytes,
            },
        })
        record["memory"]["peak_tpu_adjusted_gb"] = round(
            record["memory"]["peak_per_device_gb"]
            - rep.f32_param_convert_bytes / 2**30, 3)
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    _write(path, record)
    return record


def _write(path: str, record: dict) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=2, default=float)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    from repro.configs import SHAPES, list_archs

    archs = args.arch or (list_archs() if args.all else [])
    shapes = args.shape or list(SHAPES)
    if not archs:
        ap.error("pass --arch <id> (repeatable) or --all")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                rec = run_cell(arch, shape, multi, args.out,
                               force=args.force)
                status = rec.get("status")
                extra = ""
                if status == "ok":
                    extra = (f" mem={rec['memory']['peak_per_device_gb']}GB"
                             f" compile={rec['compile_s']}s"
                             f" wire={rec['hlo']['wire_bytes']/2**30:.3f}GB")
                elif status == "error":
                    extra = " " + rec["error"][:120]
                print(f"[{status:>7s}] {rec['cell']}{extra}", flush=True)


if __name__ == "__main__":
    main()
