"""Pallas TPU kernels for the two compute hot-spots of the model stack:

* ``flash_attention`` — blocked online-softmax attention (GQA, causal,
  sliding-window), VMEM-tiled for the MXU;
* ``ssd_scan``       — Mamba-2 SSD chunked scan (intra-chunk dense work
  + sequential chunk-state recurrence in VMEM scratch).

``ops.py`` exposes jit-ready wrappers (interpret-mode on CPU, compiled on
TPU); ``ref.py`` holds the pure-jnp oracles the test-suite sweeps
against.
"""
