"""Blocked online-softmax attention (FlashAttention recurrence) in Pallas.

TPU-native layout (DESIGN.md hardware-adaptation note): instead of the
CUDA warp-level softmax of the GPU kernels, the recurrence is expressed
as MXU-shaped (block_q x block_k) matmuls over VMEM tiles; the running
max / denominator / accumulator live in VMEM scratch and persist across
the (sequential) innermost grid dimension, which walks KV blocks.

Grid: (B, H, n_q_blocks, n_kv_blocks) — the last dim is sequential on
TPU, so the scratch carries the online-softmax state for one (b, h, qi)
triple while ki sweeps.  GQA is expressed in the BlockSpec index maps
(`h // group` selects the KV head), so no KV replication is materialised.

Supports: causal (suffix-aligned when Sq != Sk), sliding window, bf16 or
f32 inputs with f32 accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                  causal: bool, window, block_q: int, block_k: int,
                  sq: int, sk: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    # positions of this tile (suffix alignment: query row r is position
    # r + sk - sq in key space)
    off = sk - sq
    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q) + off
    k_pos = ki * block_k + jax.lax.iota(jnp.int32, block_k)

    # tile interaction test (lets XLA skip dead tiles cheaply)
    q_lo = qi * block_q + off
    q_hi = q_lo + block_q - 1
    k_lo = ki * block_k
    needed = True
    if causal:
        needed = jnp.asarray(k_lo <= q_hi)
    if window is not None:
        needed = jnp.logical_and(
            needed, jnp.asarray(k_lo + block_k - 1 > q_lo - window))

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)              # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)          # (bq, bk)
        allowed = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            allowed = jnp.logical_and(
                allowed, k_pos[None, :] <= q_pos[:, None])
        if window is not None:
            allowed = jnp.logical_and(
                allowed, k_pos[None, :] > q_pos[:, None] - window)
        # mask padded keys
        allowed = jnp.logical_and(allowed, (k_pos < sk)[None, :])
        s = jnp.where(allowed, s, NEG_INF)

        m_prev = m_sc[...]                               # (bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.exp(s - m_safe[:, None])
        p = jnp.where(allowed, p, 0.0)
        corr = jnp.where(m_prev <= NEG_INF / 2, 0.0,
                         jnp.exp(m_prev - m_safe))
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)          # (bq, d)
        acc_sc[...] = acc_sc[...] * corr[:, None] + pv
        m_sc[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_sc[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_sc[...] / denom).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal=True, window=None,
                           block_q=128, block_k=128, interpret=False):
    """q: (B, H, Sq, hd); k, v: (B, KV, Sk, hd) -> (B, H, Sq, hd).

    Pads Sq/Sk up to block multiples internally; hd should be a multiple
    of 128 on real TPUs (any value works in interpret mode).
    """
    b, h, sq, hd = q.shape
    _, kv, sk, _ = k.shape
    assert h % kv == 0, (h, kv)
    group = h // kv
    scale = 1.0 / (hd ** 0.5)

    block_q = min(block_q, max(sq, 8))
    block_k = min(block_k, max(sk, 8))
    nq = -(-sq // block_q)
    nk = -(-sk // block_k)
    sq_p, sk_p = nq * block_q, nk * block_k
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    if sk_p != sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))

    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, block_q=block_q,
        block_k=block_k, sq=sq, sk=sk, scale=scale)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bb, hh, qi, ki, g=group: (bb, hh // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bb, hh, qi, ki, g=group: (bb, hh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq]
