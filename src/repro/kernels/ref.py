"""Pure-jnp oracles for the Pallas kernels.

These are *independent* implementations (naive recurrences / materialised
scores), deliberately structured differently from both the kernels and
the model-stack fast paths, so agreement is meaningful.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    """Materialised-scores attention.

    q: (B, H, Sq, hd); k, v: (B, KV, Sk, hd). GQA via H = KV * G.
    Query position i attends to key j iff (not causal or j <= i + off)
    and (window is None or j > i + off - window), with off = Sk - Sq
    (suffix alignment, matching the kernel).
    """
    b, h, sq, hd = q.shape
    _, kv, sk, _ = k.shape
    g = h // kv
    off = sk - sq
    qg = q.reshape(b, kv, g, sq, hd).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k.astype(jnp.float32))
    s = s / jnp.sqrt(hd)
    qi = jnp.arange(sq)[:, None] + off
    kj = jnp.arange(sk)[None, :]
    allowed = jnp.ones((sq, sk), bool)
    if causal:
        allowed &= kj <= qi
    if window is not None:
        allowed &= kj > qi - window
    s = jnp.where(allowed, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(b, h, sq, hd).astype(q.dtype)


def ssd_ref(x, dt, A, B, C, D):
    """Naive per-token SSD recurrence (the definitionally-correct oracle).

    x: (b, s, h, p); dt: (b, s, h) post-softplus; A: (h,) negative;
    B, C: (b, s, n); D: (h,).  Returns (y (b,s,h,p), h_final (b,h,p,n)).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    f32 = jnp.float32

    def step(hprev, inp):
        xt, dtt, Bt, Ct = inp                       # (b,h,p),(b,h),(b,n),(b,n)
        decay = jnp.exp(dtt * A)                    # (b,h)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dtt, Bt, xt)
        hnew = hprev * decay[..., None, None] + upd
        yt = jnp.einsum("bn,bhpn->bhp", Ct, hnew)
        return hnew, yt

    h0 = jnp.zeros((b, h, p, n), f32)
    hf, ys = jax.lax.scan(
        step, h0,
        (x.swapaxes(0, 1).astype(f32), dt.swapaxes(0, 1).astype(f32),
         B.swapaxes(0, 1).astype(f32), C.swapaxes(0, 1).astype(f32)))
    y = ys.swapaxes(0, 1) + x.astype(f32) * D[:, None]
    return y.astype(x.dtype), hf
