"""Mamba-2 SSD chunked scan in Pallas.

TPU-native decomposition (DESIGN.md): the chunk dimension is the
*sequential* innermost grid axis — the (p x n) chunk state lives in VMEM
scratch and is carried across chunk steps, while the intra-chunk work is
dense (Q x Q) MXU matmuls, exactly the state-space-duality split.  One
grid step handles one (batch, head, chunk) triple.

Inputs are pre-chunked by the wrapper: x (B, C, Q, H, P), dt (B, C, Q, H)
(post-softplus), A (H,), Bm/Cm (B, C, Q, N).  Output y excludes the D*x
skip (added by the wrapper; keeps the kernel state-only).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_sc, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_sc[...] = jnp.zeros_like(h_sc)

    x = x_ref[0, 0, :, 0].astype(jnp.float32)            # (Q, P)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)          # (Q,)
    a_h = a_ref[0].astype(jnp.float32)                   # scalar
    bm = b_ref[0, 0].astype(jnp.float32)                 # (Q, N)
    cm = c_ref[0, 0].astype(jnp.float32)                 # (Q, N)

    da = dt * a_h                                        # (Q,) <= 0
    acum = jnp.cumsum(da)                                # (Q,)
    # intra-chunk: scores(i,j) = (C_i . B_j) * exp(a_i - a_j) * dt_j, i>=j
    seg = acum[:, None] - acum[None, :]
    iq = jax.lax.iota(jnp.int32, chunk)
    causal = iq[:, None] >= iq[None, :]
    decay = jnp.where(causal, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    scores = cb * decay * dt[None, :]
    y_intra = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk: y_i += exp(a_i) * C_i . h_prev
    h_prev = h_sc[...]                                   # (N, P)
    y_inter = jax.lax.dot_general(cm, h_prev, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y = y_intra + y_inter * jnp.exp(acum)[:, None]
    y_ref[0, 0, :, 0] = y.astype(y_ref.dtype)

    # state update: h = h * exp(a_last) + sum_j exp(a_last - a_j) dt_j B_j x_j^T
    w = jnp.exp(acum[-1] - acum) * dt                    # (Q,)
    hb = jax.lax.dot_general(bm * w[:, None], x,
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (N, P)
    h_sc[...] = h_prev * jnp.exp(acum[-1]) + hb


def ssd_scan_kernel(x, dt, A, Bm, Cm, *, chunk=128, interpret=False):
    """x: (B,S,H,P), dt: (B,S,H), A: (H,), Bm/Cm: (B,S,N).

    Returns y (B,S,H,P) (without the D*x skip)."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    q = min(chunk, s)
    nc = -(-s // q)
    pad = nc * q - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h)
    bc = Bm.reshape(b, nc, q, n)
    cc = Cm.reshape(b, nc, q, n)

    kernel = functools.partial(_ssd_kernel, chunk=q)
    y = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, q, 1, p),
                         lambda bb, hh, ci: (bb, ci, 0, hh, 0)),
            pl.BlockSpec((1, 1, q, 1),
                         lambda bb, hh, ci: (bb, ci, 0, hh)),
            pl.BlockSpec((1,), lambda bb, hh, ci: (hh,)),
            pl.BlockSpec((1, 1, q, n), lambda bb, hh, ci: (bb, ci, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda bb, hh, ci: (bb, ci, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q, 1, p),
                               lambda bb, hh, ci: (bb, ci, 0, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nc, q, h, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xc, dtc, A, bc, cc)
    return y.reshape(b, nc * q, h, p)[:, :s]
