"""Jit-ready wrappers over the Pallas kernels.

On this CPU container the kernels execute in interpret mode (the kernel
body runs as traced jnp ops — correctness-identical); on a TPU backend
they compile through Mosaic.  The wrappers adapt the model stack's
(B, S, H, hd) layout to the kernels' (B, H, S, hd) MXU-friendly layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.ssd_scan import ssd_scan_kernel


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("kind", "window", "block_q",
                                             "block_k"))
def flash_attention(q, k, v, *, kind="causal", window=None,
                    q_positions=None, k_positions=None,
                    block_q=128, block_k=128):
    """Drop-in for repro.models.layers.attention(impl="pallas").

    q: (B, S, H, hd); k, v: (B, Sk, KV, hd) — model-stack layout.
    Positions must be the default contiguous layout (the kernel derives
    them; explicit position arrays fall back to suffix alignment).
    """
    qt = q.swapaxes(1, 2)
    kt = k.swapaxes(1, 2)
    vt = v.swapaxes(1, 2)
    out = flash_attention_kernel(
        qt, kt, vt, causal=(kind == "causal"), window=window,
        block_q=block_q, block_k=block_k, interpret=_use_interpret())
    return out.swapaxes(1, 2)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, Bm, Cm, D, *, chunk=128):
    """Drop-in SSD scan: adds the D*x skip the kernel omits.

    x: (B,S,H,P); dt post-softplus; A negative; Bm/Cm (B,S,N); D (H,).
    """
    y = ssd_scan_kernel(x, dt, A, Bm, Cm, chunk=chunk,
                        interpret=_use_interpret())
    return y + x * D[:, None].astype(x.dtype)
