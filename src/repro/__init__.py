"""repro: OMP2MPI on TPU — pragma-driven SPMD distribution for JAX.

See README.md / DESIGN.md.  Public surface:

    from repro import omp          # the paper's compiler pipeline
    from repro.configs import get_config, SHAPES
    from repro.models import build_model
"""
__version__ = "1.0.0"
