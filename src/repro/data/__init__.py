"""Deterministic synthetic data pipeline (the training substrate).

No external corpora are available offline, so the pipeline synthesises a
*learnable* token stream — a mixture of a Zipfian unigram floor and a
seeded first-order Markov chain — deterministically from (seed, step,
shard), which gives:

* reproducibility across restarts (fault tolerance needs bit-identical
  batches after resume),
* per-host sharding without communication (each host computes only its
  shard's slice, the paper's "sequential remainder on master" stays on
  the host),
* a non-trivial learning signal (loss drops well below the unigram
  entropy only if the model learns the transition structure).
"""
from repro.data.pipeline import SyntheticLM, make_batch_iterator  # noqa: F401
