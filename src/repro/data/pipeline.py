"""Synthetic LM data: seeded Zipf + Markov mixture, shard-deterministic."""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    """Deterministic synthetic token distribution.

    A first-order Markov chain over a reduced state space (``n_states``)
    lifted to the full vocab; transition structure is fixed by ``seed``.
    """

    vocab_size: int
    seed: int = 0
    n_states: int = 64
    markov_weight: float = 0.7

    def __post_init__(self) -> None:
        rng = np.random.default_rng(self.seed)
        s = self.n_states
        # sparse-ish row-stochastic transition matrix
        logits = rng.normal(size=(s, s)) * 2.0
        keep = rng.random((s, s)) < 0.25
        logits = np.where(keep, logits, -1e9)
        logits[:, 0] = 0.0  # ensure rows are connected
        self._trans = jnp.asarray(
            jax.nn.softmax(jnp.asarray(logits, jnp.float32), axis=-1))
        # Zipfian unigram over the full vocab
        ranks = np.arange(1, self.vocab_size + 1)
        z = 1.0 / ranks ** 1.1
        self._unigram = jnp.asarray(z / z.sum(), jnp.float32)
        # state -> vocab band mapping
        self._band = self.vocab_size // s

    def sample(self, key, batch: int, seq_len: int) -> jax.Array:
        """(batch, seq_len) int32 tokens."""
        k1, k2, k3 = jax.random.split(key, 3)
        s0 = jax.random.randint(k1, (batch,), 0, self.n_states)

        def step(state, k):
            nxt = jax.random.categorical(
                k, jnp.log(self._trans[state] + 1e-9))
            return nxt, nxt

        keys = jax.random.split(k2, seq_len)
        _, states = jax.lax.scan(step, s0, keys)         # (S, B)
        states = states.T                                # (B, S)
        # lift: mostly a deterministic token inside the state's band,
        # mixed with Zipf noise
        offs = jax.random.randint(k3, (batch, seq_len), 0,
                                  max(1, self._band))
        markov_tok = states * self._band + offs % max(1, self._band)
        zipf_tok = jax.random.categorical(
            k3, jnp.log(self._unigram + 1e-12),
            shape=(batch, seq_len))
        pick = jax.random.uniform(k1, (batch, seq_len)) < self.markov_weight
        return jnp.where(pick, markov_tok, zipf_tok).astype(jnp.int32) \
            % self.vocab_size


def batch_key(seed: int, step: int, shard: int) -> jax.Array:
    key = jax.random.PRNGKey(seed)
    key = jax.random.fold_in(key, step)
    return jax.random.fold_in(key, shard)


def make_batch_iterator(
    *,
    vocab_size: int,
    batch: int,
    seq_len: int,
    seed: int = 0,
    shard: int = 0,
    num_shards: int = 1,
    start_step: int = 0,
    embed_dim: int | None = None,
    frames: int | None = None,
) -> Iterator[dict]:
    """Yields batches for this host shard, deterministically per step.

    ``embed_dim`` switches to precomputed-embedding batches (VLM stub);
    ``frames`` adds encoder frames (whisper stub).
    """
    assert batch % num_shards == 0, (batch, num_shards)
    local = batch // num_shards
    dist = SyntheticLM(vocab_size, seed=seed)
    step = start_step
    while True:
        key = batch_key(seed, step, shard)
        tokens = dist.sample(key, local, seq_len)
        out = {"labels": tokens}
        if embed_dim is not None:
            ek = jax.random.fold_in(key, 1)
            out["embeds"] = jax.random.normal(
                ek, (local, seq_len, embed_dim), jnp.float32) * 0.1
        else:
            out["tokens"] = tokens
        if frames is not None and embed_dim is None:
            raise ValueError("frames requires embed_dim for the stub")
        if frames is not None:
            fk = jax.random.fold_in(key, 2)
            out["frames"] = jax.random.normal(
                fk, (local, frames, embed_dim), jnp.float32) * 0.1
            out["tokens"] = tokens
            del out["embeds"]
        yield out
        step += 1
