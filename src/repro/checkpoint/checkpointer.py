"""Checkpointing substrate (fault-tolerance backbone).

Design (multi-host-ready, no external deps):

* a checkpoint is a directory ``step_<N>/`` holding one ``.npz`` per
  host shard plus a ``manifest.json`` (tree structure, shapes, dtypes,
  step, host count);
* writes go to ``step_<N>.tmp/`` and are atomically renamed — a crash
  mid-save can never corrupt the latest good checkpoint;
* ``save_async`` hands the (host-local) arrays to a background thread so
  the train loop overlaps serialisation with the next steps (one
  outstanding save at a time, matching large-scale practice);
* ``restore_latest`` discovers the newest complete step — the restart
  path used by :mod:`repro.runtime.fault_tolerance`;
* ``keep`` bounds disk usage (older steps are GC'd after a successful
  save).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3,
                 host_id: int = 0, num_hosts: int = 1) -> None:
        self.directory = directory
        self.keep = keep
        self.host_id = host_id
        self.num_hosts = num_hosts
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ----------------------------------------------------------- saving --
    def save(self, step: int, tree: Any, *, extra: dict | None = None) -> str:
        self.wait()
        return self._save_now(step, tree, extra or {})

    def save_async(self, step: int, tree: Any,
                   *, extra: dict | None = None) -> None:
        """Snapshot to host memory, serialise in the background."""
        self.wait()
        names, leaves, _ = _flatten_with_paths(tree)
        host_leaves = [np.asarray(l) for l in leaves]

        def work():
            self._write(step, names, host_leaves, extra or {})

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _save_now(self, step: int, tree: Any, extra: dict) -> str:
        names, leaves, _ = _flatten_with_paths(tree)
        return self._write(step, names, [np.asarray(l) for l in leaves],
                           extra)

    def _write(self, step: int, names, leaves, extra: dict) -> str:
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + f".tmp{self.host_id}"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"shard_{self.host_id}.npz"),
                 **{n: l for n, l in zip(names, leaves)})
        manifest = {
            "step": step,
            "num_hosts": self.num_hosts,
            "names": names,
            "shapes": [list(l.shape) for l in leaves],
            "dtypes": [str(l.dtype) for l in leaves],
            "extra": extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.isdir(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.list_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------- restoring --
    def list_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith("tmp"):
                path = os.path.join(self.directory, name, "manifest.json")
                if os.path.exists(path):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def restore(self, step: int, like: Any) -> Any:
        """Restore into the structure of ``like`` (shape-checked)."""
        final = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(final, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(final, f"shard_{self.host_id}.npz"))
        names, leaves, treedef = _flatten_with_paths(like)
        assert names == manifest["names"], "checkpoint/model tree mismatch"
        restored = []
        for n, l in zip(names, leaves):
            arr = data[n]
            if tuple(arr.shape) != tuple(np.shape(l)):
                raise ValueError(
                    f"shape mismatch for {n}: ckpt {arr.shape} vs model "
                    f"{np.shape(l)} (elastic reshape requires "
                    "runtime.elastic.reshard)")
            restored.append(arr.astype(l.dtype) if hasattr(l, "dtype")
                            else arr)
        return jax.tree_util.tree_unflatten(treedef, restored)

    def restore_latest(self, like: Any) -> tuple[int, Any] | None:
        steps = self.list_steps()
        if not steps:
            return None
        step = steps[-1]
        return step, self.restore(step, like)
