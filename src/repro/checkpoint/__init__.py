"""Sharded checkpointing with async save and restart discovery."""
from repro.checkpoint.checkpointer import Checkpointer  # noqa: F401
