"""The docs baseline: required documents exist and their referenced
file paths resolve (same check CI's `docs` job runs)."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_docs_exist_and_paths_resolve():
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "check_docs.py")],
        capture_output=True, text=True, timeout=60,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "docs ok" in proc.stdout
