"""Deterministic fault injection: plans, hook sites, scoping."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import omp
from repro.compat import make_mesh
from repro.runtime.fault_injection import (
    DeviceLossError, FaultPlan, FaultSpec, inject)


def _case():
    n = 11

    @omp.parallel_for(stop=n, name="fi_map", schedule=omp.dynamic(2))
    def prog(i, env):
        return {"y": omp.at(i, env["x"][i] * 2.0 + 1.0)}

    env = {"x": jnp.arange(n, dtype=jnp.float32),
           "y": jnp.zeros(n, jnp.float32)}
    mesh = make_mesh((1,), ("data",))
    return omp.compile(prog, mesh, env_like=env), env, prog(env)


# ---------------------------------------------------------------- specs --


def test_spec_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultSpec(call=0, kind="meteor")
    with pytest.raises(ValueError, match="site"):
        FaultSpec(call=0, site="nowhere")
    with pytest.raises(ValueError, match="call"):
        FaultSpec(call=-1)
    with pytest.raises(ValueError, match="nan"):
        FaultSpec(call=0, kind="nan", site="collective")
    with pytest.raises(ValueError, match="delay_s"):
        FaultSpec(call=0, kind="delay", delay_s=-1.0)


def test_seeded_plan_is_deterministic():
    a = FaultPlan.seeded(42, calls=50, rate=0.3, n_ranks=8)
    b = FaultPlan.seeded(42, calls=50, rate=0.3, n_ranks=8)
    assert a == b and len(a.specs) > 0
    c = FaultPlan.seeded(43, calls=50, rate=0.3, n_ranks=8)
    assert a != c
    assert all(0 <= s.call < 50 and 0 <= s.rank < 8 for s in a.specs)


# ---------------------------------------------------------------- sites --


def test_device_loss_at_exact_call():
    compiled, env, ref = _case()
    plan = FaultPlan((FaultSpec(call=1, kind="device_loss", rank=0),))
    with inject(plan) as inj:
        out0 = compiled.run(env)                      # call 0: clean
        np.testing.assert_array_equal(np.asarray(out0["y"]),
                                      np.asarray(ref["y"]))
        with pytest.raises(DeviceLossError, match="rank 0 at call 1"):
            compiled.run(env)                         # call 1: dies
        out2 = compiled.run(env)                      # call 2: clean again
        np.testing.assert_array_equal(np.asarray(out2["y"]),
                                      np.asarray(ref["y"]))
        assert inj.call_count() == 3
        assert [c for c, _ in inj.fired] == [1]


def test_nan_corruption_poisons_outputs():
    compiled, env, ref = _case()
    plan = FaultPlan((FaultSpec(call=0, kind="nan"),))
    with inject(plan) as inj:
        out = compiled.run(env)
        assert not bool(jnp.all(jnp.isfinite(out["y"])))
        clean = compiled.run(env)
        np.testing.assert_array_equal(np.asarray(clean["y"]),
                                      np.asarray(ref["y"]))
    assert len(inj.fired) == 1


def test_delay_fault_sleeps():
    compiled, env, _ = _case()
    compiled.run(env)                                  # warm outside plan
    plan = FaultPlan((FaultSpec(call=0, kind="delay", delay_s=0.15),))
    with inject(plan):
        t0 = time.perf_counter()
        compiled.run(env)
        assert time.perf_counter() - t0 >= 0.15


def test_executor_site_fault_fires_in_collective():
    compiled, env, _ = _case()
    plan = FaultPlan((FaultSpec(call=0, kind="device_loss",
                                site="collective"),))
    with inject(plan) as inj:
        with pytest.raises(DeviceLossError, match="site 'collective'"):
            compiled.run(env)
    assert [s.site for _, s in inj.fired] == ["collective"]


# -------------------------------------------------------------- scoping --


def test_hooks_restored_after_context():
    from repro.core import api, transform

    compiled, env, ref = _case()
    plan = FaultPlan((FaultSpec(call=0, kind="device_loss"),))
    with pytest.raises(DeviceLossError):
        with inject(plan):
            compiled.run(env)
    assert api._fault_hook is None
    assert transform._fault_hook is None
    out = compiled.run(env)                            # no fault leaks
    np.testing.assert_array_equal(np.asarray(out["y"]),
                                  np.asarray(ref["y"]))


def test_empty_plan_is_a_noop():
    compiled, env, ref = _case()
    with inject(FaultPlan()) as inj:
        out = compiled.run(env)
    np.testing.assert_array_equal(np.asarray(out["y"]), np.asarray(ref["y"]))
    assert inj.fired == [] and inj.call_count() == 1
