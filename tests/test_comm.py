"""Unit tests for the cost-modeled communication planner (core/comm.py).

Planning is pure — no devices needed — so these tests exercise the
boundary cost model (including 8-rank geometries) on the single real
device; execution of the emitted halo exchanges is covered by the
differential harness and the 8-device region test.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

from repro import omp
from repro.core import comm
from repro.core.region import plan_region
from repro.core.report import render_region
from repro.core.schedule import ChunkPlan


def _layout(c=8, p=8, n_loc=2, base=0, cover=None, has_prior=False):
    padded = n_loc * p * c
    return comm.SlabLayout(
        chunk=c, num_devices=p, local_chunks=n_loc, padded_trip=padded,
        base=base, cover=padded if cover is None else cover,
        has_prior=has_prior)


def _chunks(lay: comm.SlabLayout) -> ChunkPlan:
    return ChunkPlan(
        trip_count=lay.cover, num_devices=lay.num_devices, chunk=lay.chunk,
        num_chunks=lay.local_chunks * lay.num_devices,
        local_chunks=lay.local_chunks, padded_trip=lay.padded_trip)


def _plan(lay, *, trip, n, in_strategy="shard_halo", halo=(0, 1),
          needs_replicated=False, mode="auto"):
    return comm.plan_boundary(
        stage="s", key="k", layout=lay, chunks=_chunks(lay), trip=trip,
        aval=jax.ShapeDtypeStruct((n,), jnp.float32),
        in_strategy=in_strategy, halo=halo,
        needs_replicated=needs_replicated, mode=mode)


# ---------------------------------------------------------------------------
# The iff rule: halo beats all-gather exactly when it moves fewer bytes
# ---------------------------------------------------------------------------


def test_halo_wins_iff_fewer_bytes():
    # 8 ranks, chunk 8: one halo row per chunk << gathering the slab
    lay = _layout(c=8, p=8, n_loc=2, has_prior=True)
    bc = _plan(lay, trip=lay.cover, n=lay.padded_trip + 1, halo=(0, 1))
    halo_w = bc.alternatives[comm.HALO].wire_bytes
    gather_w = bc.alternatives[comm.ALL_GATHER].wire_bytes
    assert halo_w < gather_w
    assert bc.op == comm.HALO
    assert bc.cost.hops == 1
    assert bc.shift == (0, 1)

    # 2 ranks, chunk 1: the one halo row IS the chunk — equal bytes, and
    # on a tie the gather wins (halo must be strictly cheaper)
    lay2 = _layout(c=1, p=2, n_loc=4, has_prior=True)
    bc2 = _plan(lay2, trip=lay2.cover, n=lay2.padded_trip + 1, halo=(0, 1))
    assert (bc2.alternatives[comm.HALO].wire_bytes
            == bc2.alternatives[comm.ALL_GATHER].wire_bytes)
    assert bc2.op == comm.ALL_GATHER

    # 2 ranks, chunk 4, 3-row halo: still strictly cheaper -> halo
    lay3 = _layout(c=4, p=2, n_loc=2, has_prior=True)
    bc3 = _plan(lay3, trip=lay3.cover, n=lay3.padded_trip + 3, halo=(0, 3))
    assert (bc3.alternatives[comm.HALO].wire_bytes
            < bc3.alternatives[comm.ALL_GATHER].wire_bytes)
    assert bc3.op == comm.HALO


def test_cost_model_bytes():
    lay = _layout(c=8, p=8, n_loc=2, has_prior=True)
    row = 4  # f32 scalar rows
    g = comm.gather_cost(lay, jax.ShapeDtypeStruct((128,), jnp.float32))
    assert g.wire_bytes == lay.padded_trip * row * (lay.num_devices - 1)
    h = comm.halo_cost(lay, jax.ShapeDtypeStruct((128,), jnp.float32),
                       -1, 2)
    num_chunks = lay.local_chunks * lay.num_devices
    assert h.wire_bytes == num_chunks * 3 * row
    assert h.payload_bytes == lay.local_chunks * 3 * row
    assert h.hops == 2
    # one-sided halo: a single ring shift
    h1 = comm.halo_cost(lay, jax.ShapeDtypeStruct((128,), jnp.float32),
                        0, 2)
    assert h1.hops == 1


# ---------------------------------------------------------------------------
# Degenerate halos and forced replication
# ---------------------------------------------------------------------------


def test_degenerate_halo_stays_resident():
    # (0, 0) halo over a base-0 slab covering the trip: nothing moves
    lay = _layout(c=8, p=8, n_loc=2)
    bc = _plan(lay, trip=lay.cover, n=lay.padded_trip, halo=(0, 0))
    assert bc.op == comm.RESIDENT
    assert bc.cost.wire_bytes == 0 and bc.cost.hops == 0

    # (base, base) degenerate window over a shifted slab: also resident
    lay2 = _layout(c=8, p=8, n_loc=2, base=2, cover=100, has_prior=True)
    bc2 = _plan(lay2, trip=100, n=128, halo=(2, 2))
    assert bc2.op == comm.RESIDENT

    # identity "shard" reads are the same degenerate window
    bc3 = _plan(lay, trip=lay.cover, n=lay.padded_trip,
                in_strategy="shard", halo=None)
    assert bc3.op == comm.RESIDENT


def test_replicated_consumers_never_plan_ppermute():
    lay = _layout(c=8, p=8, n_loc=2)
    # whole-array read
    bc = _plan(lay, trip=lay.cover, n=lay.padded_trip,
               in_strategy="replicate", halo=None)
    assert bc.op == comm.REPLICATE
    assert bc.cost.hops == 0
    assert comm.HALO not in bc.alternatives
    # out-merge prior (scatter/partial/reduce folds): forced even for a
    # chunk-sharded stencil read
    bc2 = _plan(lay, trip=lay.cover, n=lay.padded_trip, halo=(0, 1),
                needs_replicated=True)
    assert bc2.op == comm.REPLICATE
    assert comm.HALO not in bc2.alternatives


def test_halo_infeasibility_reasons():
    # halo wider than one chunk -> gather
    lay = _layout(c=2, p=4, n_loc=2, has_prior=True)
    bc = _plan(lay, trip=lay.cover, n=lay.padded_trip + 3, halo=(0, 3))
    assert bc.op == comm.ALL_GATHER
    assert "chunk" in bc.reason
    # reads below a shifted slab with no prior copy -> gather
    lay2 = _layout(c=8, p=8, n_loc=2, base=1, cover=100, has_prior=False)
    bc2 = _plan(lay2, trip=100, n=128, halo=(0, 2))
    assert bc2.op == comm.ALL_GATHER
    assert "prior" in bc2.reason
    # same window WITH a prior -> halo
    lay3 = _layout(c=8, p=8, n_loc=2, base=1, cover=100, has_prior=True)
    bc3 = _plan(lay3, trip=100, n=128, halo=(0, 2))
    assert bc3.op == comm.HALO
    assert bc3.shift == (-1, 1) and bc3.cost.hops == 2
    # geometry mismatch -> gather
    lay4 = _layout(c=4, p=8, n_loc=2)
    bc4 = comm.plan_boundary(
        stage="s", key="k", layout=lay4, chunks=_chunks(_layout(c=8, p=8)),
        trip=64, aval=jax.ShapeDtypeStruct((64,), jnp.float32),
        in_strategy="shard_halo", halo=(0, 1), needs_replicated=False)
    assert bc4.op == comm.ALL_GATHER
    assert "geometry" in bc4.reason


def test_gather_mode_pins_pr1_baseline():
    lay = _layout(c=8, p=8, n_loc=2, has_prior=True)
    bc = _plan(lay, trip=lay.cover, n=lay.padded_trip + 1, halo=(0, 1),
               mode="gather")
    assert bc.op == comm.ALL_GATHER
    # resident handoffs are part of the PR 1 rule and stay
    bc2 = _plan(lay, trip=lay.cover, n=lay.padded_trip, halo=(0, 0),
                mode="gather")
    assert bc2.op == comm.RESIDENT
    with pytest.raises(ValueError):
        _plan(lay, trip=lay.cover, n=lay.padded_trip, mode="bogus")


# ---------------------------------------------------------------------------
# Planner integration (pure planning at 8 ranks, no devices needed)
# ---------------------------------------------------------------------------


def _stencil_region(n=256, c=8):
    @omp.parallel_for(stop=n, schedule=omp.static(c), name="fill")
    def fill(i, env):
        return {"u": omp.at(i, env["a"][i] + 1.0)}

    @omp.parallel_for(start=1, stop=n - 1, schedule=omp.static(c),
                      name="smooth")
    def smooth(i, env):
        v = (env["u"][i - 1] + env["u"][i] + env["u"][i + 1]) / 3.0
        return {"w": omp.at(i, v)}

    env = {"a": jnp.arange(n, dtype=jnp.float32),
           "u": jnp.zeros(n, jnp.float32), "w": jnp.zeros(n, jnp.float32)}
    return omp.region(fill, smooth, name="stencil_chain"), env


def test_plan_comm_chooses_halo_for_stencil_boundary():
    reg, env = _stencil_region()
    comms = omp.plan_comm(reg, env, 8)
    assert [bc.op for bc in comms] == [comm.HALO]
    bc = comms[0]
    assert bc.key == "u" and bc.stage == "smooth"
    assert bc.cost.wire_bytes < bc.alternatives[comm.ALL_GATHER].wire_bytes
    # the PR 1 baseline mode falls back to the gather
    comms_g = omp.plan_comm(reg, env, 8, comm="gather")
    assert [bc.op for bc in comms_g] == [comm.ALL_GATHER]


def test_plan_comm_single_loop_has_no_boundaries():
    @omp.parallel_for(stop=16, name="solo")
    def solo(i, env):
        return {"y": omp.at(i, env["x"][i] * 2.0)}

    env = {"x": jnp.arange(16, dtype=jnp.float32), "y": jnp.zeros(16)}
    assert omp.plan_comm(solo, env, 8) == []


def test_whole_array_read_plans_replicate_not_halo():
    n = 64

    @omp.parallel_for(stop=n, name="w1")
    def w1(i, env):
        return {"tmp": omp.at(i, env["x"][i] * 3.0)}

    @omp.parallel_for(stop=n, name="w2")
    def w2(i, env):
        return {"y": omp.at(i, env["tmp"][i] + jnp.sum(env["tmp"]))}

    env = {"x": jnp.arange(n, dtype=jnp.float32), "tmp": jnp.zeros(n),
           "y": jnp.zeros(n)}
    comms = omp.plan_comm(omp.region(w1, w2, name="whole"), env, 8)
    assert [bc.op for bc in comms] == [comm.REPLICATE]
    assert all(comm.HALO not in bc.alternatives for bc in comms)


def test_region_plan_totals_and_report():
    reg, env = _stencil_region()
    rp = plan_region(reg, env, 8)
    assert rp.n_halo == 1 and rp.n_reshards == 0
    assert rp.planned_wire_bytes < rp.gather_wire_bytes
    text = render_region(rp)
    for needle in ("communication plan", "halo", "rejected", "ppermute",
                   "planned wire total"):
        assert needle in text, needle


def test_halo_execution_eight_devices(multidevice):
    """Real 8-device run of a 3-loop ping-pong stencil chain: the halo
    boundaries execute as collective-permutes, match the shared-memory
    reference, and move >=5x fewer wire bytes than the PR 1 all-gather
    rule (the acceptance bar of EXPERIMENTS.md §Perf-D)."""
    out = multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import omp
        from repro.compat import make_mesh
        from repro.launch import hlo_analysis as ha

        mesh = make_mesh((8,), ("data",))
        n, c = 512, 16

        def sweep(src, dst, name):
            @omp.parallel_for(start=1, stop=n - 1, schedule=omp.static(c),
                              name=name)
            def body(i, env):
                v = 0.25 * (env[src][i - 1] + 2.0 * env[src][i]
                            + env[src][i + 1])
                return {dst: omp.at(i, v)}
            return body

        reg = omp.region(sweep("a", "b", "s1"), sweep("b", "a", "s2"),
                         sweep("a", "b", "s3"), name="heat")
        env = {"a": jnp.sin(jnp.arange(n, dtype=jnp.float32)),
               "b": jnp.zeros(n, jnp.float32)}
        ref = reg(env)
        dist = omp.region_to_mpi(reg, mesh, env_like=env)
        got = dist(env)
        for k in ref:
            assert np.allclose(np.asarray(got[k]), np.asarray(ref[k]),
                               atol=1e-4), k
        assert dist.plan.n_halo == 2 and dist.plan.n_reshards == 0, \\
            dist.plan.log
        text = dist.report()
        assert "halo" in text and "ppermute" in text

        avals = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                 for k, v in env.items()}

        def kinds_of(prog):
            co = jax.jit(lambda e: prog(e)).lower(avals).compile()
            return ha.analyze_hlo(co.as_text(), num_devices=8).by_kind()

        kinds = kinds_of(dist)
        assert kinds.get("collective-permute", 0) > 0, kinds
        kinds_g = kinds_of(omp.region_to_mpi(reg, mesh, env_like=env,
                                             comm="gather"))
        boundary_gather = (kinds_g.get("all-gather", 0)
                           - kinds.get("all-gather", 0))
        boundary_halo = kinds["collective-permute"]
        assert boundary_gather >= 5 * boundary_halo, (kinds, kinds_g)
        print("OKHALO8", int(boundary_halo), int(boundary_gather))
    """)
    assert "OKHALO8" in out


def test_window_geometry_shared_between_paths():
    """The static (per-loop staging) and per-device (fused region) window
    row computations must agree for every device."""
    ch = ChunkPlan(trip_count=60, num_devices=4, chunk=4, num_chunks=16,
                   local_chunks=4, padded_trip=64)
    for halo in ((0, 0), (0, 2), (1, 1), (2, 3)):
        stat = comm.window_rows(ch, halo, 60)   # (num_chunks, width)
        width = comm.window_extent(ch.chunk, halo)
        assert stat.shape == (ch.num_chunks, width)
        for d in range(ch.num_devices):
            dev = np.asarray(comm.device_window_rows(ch, halo, d, 60))
            expect = stat.reshape(ch.local_chunks, ch.num_devices,
                                  width)[:, d]
            np.testing.assert_array_equal(dev, expect)


# ---------------------------------------------------------------------------
# Rank-2 (collapse=2) boundary planning and the 2x2-mesh acceptance pin
# ---------------------------------------------------------------------------


def _layout2(ci=8, cj=8, pi=2, pj=2, ni=2, nj=2, bases=(0, 0), covers=None,
             has_prior=False):
    axes = []
    for c, p, n, b, cv in zip((ci, cj), (pi, pj), (ni, nj), bases,
                              covers or (ni * pi * ci, nj * pj * cj)):
        axes.append(comm.AxisSlab(chunk=c, num_devices=p, local_chunks=n,
                                  padded_trip=n * p * c, base=b, cover=cv))
    return comm.SlabLayout2(tuple(axes), has_prior)


def _chunks2(lay):
    return tuple(
        ChunkPlan(trip_count=a.cover, num_devices=a.num_devices,
                  chunk=a.chunk, num_chunks=a.local_chunks * a.num_devices,
                  local_chunks=a.local_chunks, padded_trip=a.padded_trip)
        for a in lay.axes)


def _plan2(lay, *, trips, shape, in_strategy="shard_halo",
           halo_axes=((0, 1), (0, 1)), shard_ndim=2,
           needs_replicated=False, mode="auto"):
    return comm.plan_boundary2(
        stage="s2", key="k", layout=lay, chunks_axes=_chunks2(lay),
        trips=trips, aval=jax.ShapeDtypeStruct(shape, jnp.float32),
        in_strategy=in_strategy, halo_axes=halo_axes, shard_ndim=shard_ndim,
        needs_replicated=needs_replicated, mode=mode)


def test_plan_boundary2_halo_wins_iff_fewer_bytes():
    lay = _layout2(ci=8, cj=8, pi=2, pj=2, ni=2, nj=2, has_prior=True)
    n = lay.axes[0].padded_trip + 2
    m = lay.axes[1].padded_trip + 2
    bc = _plan2(lay, trips=lay.covers, shape=(n, m),
                halo_axes=((0, 2), (0, 2)))
    assert bc.op == comm.HALO
    halo_w = bc.alternatives[comm.HALO].wire_bytes
    gather_w = bc.alternatives[comm.ALL_GATHER].wire_bytes
    assert halo_w < gather_w
    # both axes shifted one-sided: one row hop + one column hop
    assert bc.cost.hops == 2
    assert bc.shift == ((0, 2), (0, 2))
    # chunk 1 per axis: the windows ARE whole neighbor chunks plus the
    # extended corners — more bytes than the gather, which wins
    lay2 = _layout2(ci=1, cj=1, pi=2, pj=2, ni=4, nj=4, has_prior=True)
    bc2 = _plan2(lay2, trips=lay2.covers,
                 shape=(lay2.axes[0].padded_trip + 1,
                        lay2.axes[1].padded_trip + 1),
                 halo_axes=((0, 1), (0, 1)))
    assert bc2.op == comm.ALL_GATHER


def test_halo_cost2_counts_rows_columns_and_corners():
    lay = _layout2(ci=4, cj=6, pi=2, pj=2, ni=2, nj=2, has_prior=True)
    aval = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    h = comm.halo_cost2(lay, aval, ((-1, 1), (-1, 2)))
    k_pairs = (2 * 2) * (2 * 2)          # K_i * K_j chunk pairs
    # row pass: (L_i+R_i) * c_j; column pass: (c_i+L_i+R_i) * (L_j+R_j)
    per_pair = (1 + 1) * 6 + (4 + 1 + 1) * (1 + 2)
    assert h.wire_bytes == k_pairs * per_pair * 4
    assert h.hops == 4
    g = comm.gather_cost2(lay, aval)
    assert g.wire_bytes == (lay.axes[0].padded_trip
                            * lay.axes[1].padded_trip * 4 * (2 * 2 - 1))


def test_plan_boundary2_resident_and_forced_replicate():
    lay = _layout2(ci=8, cj=8, pi=2, pj=2, ni=2, nj=2)
    trips = lay.covers
    shape = (lay.axes[0].padded_trip, lay.axes[1].padded_trip)
    bc = _plan2(lay, trips=trips, shape=shape, halo_axes=((0, 0), (0, 0)))
    assert bc.op == comm.RESIDENT
    assert bc.cost.wire_bytes == 0
    # whole-array consumer: gather forced, halo never offered
    bc2 = _plan2(lay, trips=trips, shape=shape, in_strategy="replicate",
                 halo_axes=None, shard_ndim=0)
    assert bc2.op == comm.REPLICATE
    assert comm.HALO not in bc2.alternatives
    # out-merge prior forces replication even for a stencil consumer
    bc3 = _plan2(lay, trips=trips, shape=shape, needs_replicated=True)
    assert bc3.op == comm.REPLICATE
    # a consumer sharding only the leading axis re-gathers a 2-D slab
    bc4 = _plan2(lay, trips=trips, shape=shape, halo_axes=((0, 1),),
                 shard_ndim=1)
    assert bc4.op == comm.ALL_GATHER
    assert "leading axis" in bc4.reason


def test_plan_boundary2_infeasibility_and_gather_mode():
    # halo wider than one chunk on axis j -> gather
    lay = _layout2(ci=8, cj=2, pi=2, pj=2, ni=2, nj=2, has_prior=True)
    bc = _plan2(lay, trips=lay.covers,
                shape=(lay.axes[0].padded_trip + 4,
                       lay.axes[1].padded_trip + 4),
                halo_axes=((0, 1), (0, 3)))
    assert bc.op == comm.ALL_GATHER
    assert "axis-1" in bc.reason and "chunk" in bc.reason
    # reads below a shifted slab with no prior -> gather; with -> halo
    lay_np = _layout2(ci=8, cj=8, pi=2, pj=2, bases=(1, 1),
                      covers=(20, 20), has_prior=False)
    bc2 = _plan2(lay_np, trips=(20, 20), shape=(24, 24),
                 halo_axes=((0, 2), (0, 2)))
    assert bc2.op == comm.ALL_GATHER and "prior" in bc2.reason
    lay_p = _layout2(ci=8, cj=8, pi=2, pj=2, bases=(1, 1),
                     covers=(20, 20), has_prior=True)
    bc3 = _plan2(lay_p, trips=(20, 20), shape=(24, 24),
                 halo_axes=((0, 2), (0, 2)))
    assert bc3.op == comm.HALO
    assert bc3.shift == ((-1, 1), (-1, 1))
    # mode="gather" pins the baseline
    bc4 = _plan2(lay_p, trips=(20, 20), shape=(24, 24),
                 halo_axes=((0, 2), (0, 2)), mode="gather")
    assert bc4.op == comm.ALL_GATHER


def _heat2d_region(n=128, m=96, c=8):
    from repro import omp as _omp

    def sweep(src, dst, name):
        @_omp.parallel_for(start=(1, 1), stop=(n - 1, m - 1), collapse=2,
                           schedule=_omp.static(c), name=name)
        def body(i, j, env):
            v = 0.25 * (env[src][i - 1, j] + env[src][i + 1, j]
                        + env[src][i, j - 1] + env[src][i, j + 1])
            return {dst: _omp.at((i, j), v)}
        return body

    reg = omp.region(sweep("a", "b", "s1"), sweep("b", "a", "s2"),
                     sweep("a", "b", "s3"), name="heat2d")
    env = {"a": jnp.sin(jnp.arange(n * m, dtype=jnp.float32)).reshape(n, m),
           "b": jnp.zeros((n, m), jnp.float32)}
    return reg, env


def test_heat2d_plan_halo_beats_gather_5x_on_2x2():
    """ISSUE 3 acceptance pin: the collapse=2 heat chain's 2-D halo plan
    moves >=5x fewer modeled wire bytes than the all-gather rule on a
    2x2 mesh (pure planning, no devices needed)."""
    reg, env = _heat2d_region()
    comms = omp.plan_comm(reg, env, (2, 2))
    halo_bcs = [bc for bc in comms if bc.op == comm.HALO]
    assert len(halo_bcs) == 2, [bc.op for bc in comms]
    for bc in halo_bcs:
        assert 5 * bc.cost.wire_bytes <= \
            bc.alternatives[comm.ALL_GATHER].wire_bytes
    rp = plan_region(reg, env, (2, 2), axis=("i", "j"))
    assert rp.n_halo == 2 and rp.n_reshards == 0
    assert 5 * rp.planned_wire_bytes <= rp.gather_wire_bytes
    # the PR 1 baseline mode falls back to gathers
    comms_g = omp.plan_comm(reg, env, (2, 2), comm="gather")
    assert all(bc.op == comm.ALL_GATHER for bc in comms_g)


def test_heat2d_executes_on_2x2_mesh(multidevice):
    """ISSUE 3 acceptance pin: a collapse=2 heat-equation program lowers
    through BOTH to_mpi and region_to_mpi(comm="auto") on a real 2x2
    mesh and matches the shared-memory reference; the fused lowering
    emits collective-permutes for the 2-D halo boundaries."""
    out = multidevice(f"""
        import sys
        sys.path.insert(0, {REPO!r})
        import jax, jax.numpy as jnp, numpy as np
        from repro import omp
        from repro.compat import make_mesh
        from repro.launch import hlo_analysis as ha
        from tests.test_comm import _heat2d_region

        mesh = make_mesh((2, 2), ("i", "j"))
        reg, env = _heat2d_region(n=48, m=32, c=8)
        ref = reg(env)

        dist = omp.region_to_mpi(reg, mesh, env_like=env, comm="auto")
        got = dist(env)
        for k in ref:
            assert np.allclose(np.asarray(got[k]), np.asarray(ref[k]),
                               atol=1e-4), k
        assert dist.plan.n_halo == 2 and dist.plan.n_reshards == 0, \\
            dist.plan.log
        assert 5 * dist.plan.planned_wire_bytes <= \\
            dist.plan.gather_wire_bytes
        text = dist.report()
        assert "HALO-EXCHANGED 2-D" in text

        avals = {{k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                  for k, v in env.items()}}
        co = jax.jit(lambda e: dist(e)).lower(avals).compile()
        kinds = ha.analyze_hlo(co.as_text(), num_devices=4).by_kind()
        assert kinds.get("collective-permute", 0) > 0, kinds

        # the single-block path: each sweep through to_mpi
        sweep1 = reg.loops[0]
        d1 = omp.to_mpi(sweep1, mesh, shard_inputs=True)
        got1 = d1(env)
        ref1 = omp.run_reference(sweep1, env)
        for k in ref1:
            assert np.allclose(np.asarray(got1[k]), np.asarray(ref1[k]),
                               atol=1e-4), k
        print("OKHEAT2D")
    """, n_devices=4)
    assert "OKHEAT2D" in out


# ---------------------------------------------------------------------------
# ISSUE 5: region-wide communication scheduling (schedule_comm pass)
# ---------------------------------------------------------------------------


def test_pack_unpack_roundtrip_mixed_dtypes():
    """Byte-level payload packing must round-trip mixed dtypes, shapes
    and bools exactly (the aggregation carrier)."""
    from repro.core import comm_schedule as cs

    rng = np.random.default_rng(0)
    arrs = [
        jnp.asarray(rng.normal(size=(2, 3)).astype(np.float32)),
        jnp.asarray(rng.integers(-5, 5, size=(4,)).astype(np.int32)),
        jnp.asarray(rng.integers(0, 2, size=(3, 2)).astype(bool)),
        jnp.asarray(rng.integers(-3, 3, size=(5,)).astype(np.int8)),
        jnp.asarray(rng.normal(size=(1, 2, 2)).astype(np.float16)),
    ]
    flat, specs = cs.pack_payloads(arrs)
    assert flat.dtype == jnp.uint8
    assert flat.shape[0] == sum(sp[3] for sp in specs)
    back = cs.unpack_payloads(flat, specs)
    for a, b in zip(arrs, back):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _multifield_region(n=256, c=8, fields=3, sweeps=3):
    """Ping-pong chain of ``sweeps`` 3-point stencils over ``fields``
    arrays at once: every boundary carries ``fields`` buffers across the
    same (axis, shift) ring — the aggregation target shape.

    Mirror of ``benchmarks/stencil_halo.py::make_multifield_chain``
    (which cannot be imported here: the script forces XLA_FLAGS /
    jax_platforms at import); keep the sweep bodies in sync."""
    a_names = tuple(f"a{k}" for k in range(fields))
    b_names = tuple(f"b{k}" for k in range(fields))

    def sweep(srcs, dsts, name):
        @omp.parallel_for(start=1, stop=n - 1, schedule=omp.static(c),
                          name=name)
        def body(i, env):
            return {d: omp.at(i, 0.25 * (env[s][i - 1] + 2.0 * env[s][i]
                                         + env[s][i + 1]))
                    for s, d in zip(srcs, dsts)}
        return body

    stages = []
    cur, nxt = a_names, b_names
    for k in range(sweeps):
        stages.append(sweep(cur, nxt, f"s{k + 1}"))
        cur, nxt = nxt, cur
    reg = omp.region(*stages, name="multifield")
    env = {k: jnp.sin((j + 1) * jnp.arange(n, dtype=jnp.float32) * 0.01)
           for j, k in enumerate(a_names)}
    env.update({k: jnp.zeros(n, jnp.float32) for k in b_names})
    return reg, env


def test_schedule_build_multifield_groups():
    """Pure planning at 8 ranks: same-boundary buffers group into one
    packed exchange per issue point; inline mode records the identical
    events with no grouping; the alpha launch model prices the saving."""
    from repro.core import comm_schedule as cs
    from repro.core.region import plan_region

    reg, env = _multifield_region(fields=3, sweeps=3)
    rp = plan_region(reg, env, 8)
    sched = cs.build_comm_schedule(rp, mode="aggregate")
    assert len(sched.events) == 6          # 2 boundaries x 3 fields
    assert len(sched.groups) == 2          # one per producing stage
    assert all(len(g.events) == 3 for g in sched.groups)
    assert sched.launches_inline == 12     # 6 events x 2 hops
    assert sched.launches_scheduled == 4   # 2 groups x (left + right)
    before, after = sched.modeled_cost_bytes()
    assert after < before
    assert after - sched.wire_bytes == 4 * comm.ALPHA_LAUNCH_BYTES

    inline = cs.build_comm_schedule(rp, mode="inline")
    assert inline.groups == ()
    assert [ev.key for ev in inline.events] == [ev.key for ev in
                                                sched.events]
    assert inline.launches_scheduled == inline.launches_inline == 12
    with pytest.raises(ValueError, match="schedule mode"):
        cs.build_comm_schedule(rp, mode="packed")


def test_schedule_hoists_exchange_to_earliest_stage_after_producer():
    """An exchange whose consumer sits two stages after its producer is
    issued right after the producer (prefetch overlapping the
    intervening stage's compute)."""
    from repro.core import comm_schedule as cs
    from repro.core.region import plan_region

    n, c = 128, 8

    @omp.parallel_for(stop=n, schedule=omp.static(c), name="mk_u")
    def mk_u(i, env):
        return {"u": omp.at(i, env["x"][i] * 2.0)}

    @omp.parallel_for(stop=n, schedule=omp.static(c), name="mk_w")
    def mk_w(i, env):
        return {"w": omp.at(i, env["y"][i] + 1.0)}

    @omp.parallel_for(start=1, stop=n - 1, schedule=omp.static(c),
                      name="use_u")
    def use_u(i, env):
        return {"z": omp.at(i, env["u"][i - 1] + env["u"][i + 1])}

    reg = omp.region(mk_u, mk_w, use_u, name="hoist")
    env = {"x": jnp.arange(n, dtype=jnp.float32),
           "y": jnp.ones(n, jnp.float32), "u": jnp.zeros(n, jnp.float32),
           "w": jnp.zeros(n, jnp.float32), "z": jnp.zeros(n, jnp.float32)}
    rp = plan_region(reg, env, 8)
    sched = cs.build_comm_schedule(rp, mode="aggregate")
    (ev,) = [e for e in sched.events if e.key == "u"]
    assert ev.producer_idx == 0 and ev.consumer_idx == 2
    assert ev.span == 1 and sched.n_hoisted == 1
    (grp,) = sched.groups
    assert grp.issue_idx == 0 and grp.issue_stage == "mk_u"


def test_multifield_aggregation_eight_devices(multidevice):
    """ISSUE 5 acceptance pin: on a multi-field stencil chain (3 arrays
    sharing every halo boundary, 5 sweeps) the aggregated schedule emits
    >=2x fewer collective ops in optimized HLO than the inline (PR 4)
    planner at wire bytes no worse than +5%, and its outputs are
    bit-identical to inline and equal to the shared-memory reference."""
    out = multidevice(f"""
        import sys
        sys.path.insert(0, {REPO!r})
        import jax, jax.numpy as jnp, numpy as np
        from repro import omp
        from repro.compat import make_mesh
        from repro.launch import hlo_analysis as ha
        from tests.test_comm import _multifield_region

        mesh = make_mesh((8,), ("data",))
        reg, env = _multifield_region(n=512, c=16, fields=3, sweeps=5)
        ref = reg(env)

        agg = omp.compile(reg, mesh, env_like=env,
                          comm_schedule="aggregate")
        inl = omp.compile(reg, mesh, env_like=env, comm_schedule="inline")
        got_a, got_i = agg(env), inl(env)
        for k in ref:
            assert np.allclose(np.asarray(got_a[k]), np.asarray(ref[k]),
                               atol=1e-4), k
            assert (np.asarray(got_a[k]) == np.asarray(got_i[k])).all(), k

        sched = agg.comm_schedule
        assert len(sched.events) == 12, sched      # 4 boundaries x 3
        assert sched.launches_inline == 24
        assert sched.launches_scheduled == 8

        avals = {{k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                  for k, v in env.items()}}

        def measure(prog):
            co = jax.jit(lambda e: prog(e)).lower(avals).compile()
            rep = ha.analyze_hlo(co.as_text(), num_devices=8)
            n_ops = sum(c.multiplier for c in rep.collectives)
            by = rep.by_kind()
            n_pp = sum(c.multiplier for c in rep.collectives
                       if c.kind == "collective-permute")
            return n_ops, n_pp, rep.total_wire_bytes, by

        ops_a, pp_a, wire_a, by_a = measure(agg)
        ops_i, pp_i, wire_i, by_i = measure(inl)
        # >=2x fewer collective launches overall, 3x on the boundary
        # ppermutes (exit materialisation is identical either way)
        assert ops_i >= 2 * ops_a, (ops_i, ops_a, by_i, by_a)
        assert pp_i >= 3 * pp_a > 0, (pp_i, pp_a)
        # packing concatenates, it never pads: wire bytes no worse +5%
        assert wire_a <= 1.05 * wire_i, (wire_a, wire_i)
        print("OKAGG8", int(ops_i), int(ops_a), int(pp_i), int(pp_a),
              int(wire_i), int(wire_a))
    """)
    assert "OKAGG8" in out


def test_aggregation_edge_cases_eight_devices(multidevice):
    """Aggregation edge cases on real devices: mixed-dtype packing,
    unequal halo widths on one boundary, and single-buffer boundaries
    (which must not regress to pack/unpack overhead — their HLO is
    identical to the inline planner's)."""
    out = multidevice(f"""
        import sys
        sys.path.insert(0, {REPO!r})
        import jax, jax.numpy as jnp, numpy as np
        from repro import omp
        from repro.compat import make_mesh
        from repro.launch import hlo_analysis as ha

        mesh = make_mesh((8,), ("data",))
        n, c = 256, 8

        def both(reg, env):
            ref = reg(env)
            agg = omp.compile(reg, mesh, env_like=env,
                              comm_schedule="aggregate")
            inl = omp.compile(reg, mesh, env_like=env,
                              comm_schedule="inline")
            got_a, got_i = agg(env), inl(env)
            for k in ref:
                assert np.allclose(np.asarray(got_a[k]),
                                   np.asarray(ref[k]), atol=1e-4), k
                assert (np.asarray(got_a[k])
                        == np.asarray(got_i[k])).all(), k
            return agg, inl

        # --- mixed dtypes: one f32 field + one i32 field per boundary --
        @omp.parallel_for(start=1, stop=n - 1, schedule=omp.static(c),
                          name="mx1")
        def mx1(i, env):
            return {{"u": omp.at(i, env["a"][i - 1] + env["a"][i + 1]),
                     "q": omp.at(i, env["b"][i - 1] + env["b"][i + 1])}}

        @omp.parallel_for(start=1, stop=n - 1, schedule=omp.static(c),
                          name="mx2")
        def mx2(i, env):
            q = env["q"][i - 1] + env["q"][i + 1]
            return {{"y": omp.at(i, env["u"][i - 1] + env["u"][i + 1]
                                 + q.astype(jnp.float32))}}

        env = {{"a": jnp.sin(jnp.arange(n, dtype=jnp.float32)),
                "b": jnp.arange(n, dtype=jnp.int32),
                "u": jnp.zeros(n, jnp.float32),
                "q": jnp.zeros(n, jnp.int32),
                "y": jnp.zeros(n, jnp.float32)}}
        agg, _ = both(omp.region(mx1, mx2, name="mixed"), env)
        sched = agg.comm_schedule
        (grp,) = sched.groups
        assert set(grp.keys) == {{"u", "q"}}
        assert grp.launches_packed == 2 and grp.launches_inline == 4

        # --- unequal halo widths on one boundary ----------------------
        @omp.parallel_for(start=2, stop=n - 2, schedule=omp.static(c),
                          name="uw1")
        def uw1(i, env):
            return {{"u": omp.at(i, env["a"][i] * 2.0),
                     "v": omp.at(i, env["a"][i] + 1.0)}}

        @omp.parallel_for(start=2, stop=n - 2, schedule=omp.static(c),
                          name="uw2")
        def uw2(i, env):
            return {{"y": omp.at(i, env["u"][i - 1] + env["u"][i + 1]
                                 + env["v"][i - 2] + env["v"][i + 2])}}

        env2 = {{"a": jnp.cos(jnp.arange(n, dtype=jnp.float32)),
                 "u": jnp.zeros(n, jnp.float32),
                 "v": jnp.ones(n, jnp.float32),
                 "y": jnp.zeros(n, jnp.float32)}}
        agg2, _ = both(omp.region(uw1, uw2, name="widths"), env2)
        (grp2,) = agg2.comm_schedule.groups
        shifts = {{ev.key: ev.shifts[0] for ev in grp2.events}}
        assert shifts == {{"u": (-1, 1), "v": (-2, 2)}}, shifts
        assert grp2.launches_packed == 2 and grp2.launches_inline == 4

        # --- single-buffer boundaries: no pack/unpack regression ------
        def sweep(src, dst, name):
            @omp.parallel_for(start=1, stop=n - 1, schedule=omp.static(c),
                              name=name)
            def body(i, env):
                return {{dst: omp.at(i, 0.25 * (env[src][i - 1]
                                     + 2.0 * env[src][i]
                                     + env[src][i + 1]))}}
            return body

        reg1 = omp.region(sweep("a", "b", "p1"), sweep("b", "a", "p2"),
                          sweep("a", "b", "p3"), name="pingpong")
        env3 = {{"a": jnp.sin(jnp.arange(n, dtype=jnp.float32)),
                 "b": jnp.zeros(n, jnp.float32)}}
        agg3, inl3 = both(reg1, env3)
        avals = {{k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                  for k, v in env3.items()}}

        def kinds(prog):
            co = jax.jit(lambda e: prog(e)).lower(avals).compile()
            return ha.analyze_hlo(co.as_text(), num_devices=8).by_kind()

        ka, ki = kinds(agg3), kinds(inl3)
        assert ka == ki, (ka, ki)   # lone boundaries delegate, byte-equal
        print("OKEDGE8")
    """)
    assert "OKEDGE8" in out


def test_heat2d_multifield_aggregate_2x2(multidevice):
    """2-D corner rides under aggregation: a two-field collapse=2 heat
    chain on a 2x2 mesh packs both fields' row and column ring passes
    (corners ride the packed second pass), matches the shared-memory
    reference, and is bit-identical to the inline schedule."""
    out = multidevice(f"""
        import sys
        sys.path.insert(0, {REPO!r})
        import jax, jax.numpy as jnp, numpy as np
        from repro import omp
        from repro.compat import make_mesh
        from repro.launch import hlo_analysis as ha

        mesh = make_mesh((2, 2), ("i", "j"))
        n, m, c = 48, 32, 8

        def sweep(srcs, dsts, name):
            @omp.parallel_for(start=(1, 1), stop=(n - 1, m - 1),
                              collapse=2, schedule=omp.static(c),
                              name=name)
            def body(i, j, env):
                out = {{}}
                for s, d in zip(srcs, dsts):
                    out[d] = omp.at((i, j), 0.25 * (
                        env[s][i - 1, j] + env[s][i + 1, j]
                        + env[s][i, j - 1] + env[s][i, j + 1]))
                return out
            return body

        reg = omp.region(sweep(("a", "b"), ("u", "v"), "h1"),
                         sweep(("u", "v"), ("a", "b"), "h2"),
                         name="heat2d_mf")
        base = jnp.sin(jnp.arange(n * m, dtype=jnp.float32)).reshape(n, m)
        env = {{"a": base, "b": base * 0.5,
                "u": jnp.zeros((n, m), jnp.float32),
                "v": jnp.zeros((n, m), jnp.float32)}}
        ref = reg(env)
        agg = omp.compile(reg, mesh, env_like=env,
                          comm_schedule="aggregate")
        inl = omp.compile(reg, mesh, env_like=env, comm_schedule="inline")
        got_a, got_i = agg(env), inl(env)
        for k in ref:
            assert np.allclose(np.asarray(got_a[k]), np.asarray(ref[k]),
                               atol=1e-4), k
            assert (np.asarray(got_a[k]) == np.asarray(got_i[k])).all(), k

        (grp,) = agg.comm_schedule.groups
        assert set(grp.keys) == {{"u", "v"}}
        # 2 fields x 4 hops inline -> 4 packed ring passes
        assert grp.launches_inline == 8 and grp.launches_packed == 4

        avals = {{k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                  for k, v in env.items()}}

        def pp(prog):
            co = jax.jit(lambda e: prog(e)).lower(avals).compile()
            rep = ha.analyze_hlo(co.as_text(), num_devices=4)
            return sum(c.multiplier for c in rep.collectives
                       if c.kind == "collective-permute")

        assert pp(inl) == 2 * pp(agg) > 0, (pp(inl), pp(agg))
        print("OKHEATMF")
    """, n_devices=4)
    assert "OKHEATMF" in out
