"""Per-arch smoke tests (brief requirement): instantiate a REDUCED config
of the same family and run one forward/train step on CPU asserting output
shapes + no NaNs.  Full configs are exercised only via the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, smoke_config
from repro.models import build_model

RNG = jax.random.PRNGKey(0)
B, S = 2, 64


def _batch(cfg, key):
    batch = {"labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder.n_frames, cfg.d_model), jnp.float32)
        batch["tokens"] = jax.random.randint(key, (B, S), 0,
                                             cfg.vocab_size)
    elif cfg.embedding_stub:
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model),
                                            jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0,
                                             cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_loss_and_step(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params, axes = model.init(RNG)
    # axes tree mirrors params tree
    assert (jax.tree_util.tree_structure(params)
            == jax.tree_util.tree_structure(
                jax.tree_util.tree_map(lambda a: 0, axes,
                                       is_leaf=lambda x: isinstance(x, tuple)
                                       and all(isinstance(e, (str, type(None)))
                                               for e in x))))
    batch = _batch(cfg, jax.random.fold_in(RNG, 1))
    loss, metrics = jax.jit(
        lambda p, b: model.loss_fn(p, b, remat=True, groups=2))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    # one SGD-flavoured step moves the loss (gradient is non-trivial)
    grads = jax.jit(jax.grad(
        lambda p: model.loss_fn(p, batch, groups=2)[0]))(params)
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in
                jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", [
    "mamba2-130m", "gemma3-1b", "h2o-danube-3-4b", "qwen1.5-110b",
    "whisper-small",
])
def test_decode_matches_forward(arch):
    """Prefill+decode logits == full-forward logits (exact caches)."""
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params, _ = model.init(RNG)
    s = 33
    tokens = jax.random.randint(jax.random.fold_in(RNG, 2), (B, s + 1), 0,
                                cfg.vocab_size)
    if cfg.family == "encdec":
        frames = jax.random.normal(
            RNG, (B, cfg.encoder.n_frames, cfg.d_model), jnp.float32)
        loss_batch = {"frames": frames, "tokens": tokens}
        enc_h = model.encode(params, frames, compute_dtype=jnp.float32)
        enc_kv = model._cross_kv(params, enc_h)
        x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(s + 1), (B, s + 1))
        x, _ = model._decoder(params, x, pos, None,
                              model.init_cache(B, 64, jnp.float32)["self"],
                              enc_kv, "auto")
        full_logits = x[:, s] @ params["head"]
        cache = model.init_cache(B, 64, dtype=jnp.float32)
        _, cache = model.prefill(params,
                                 {"frames": frames, "tokens": tokens[:, :s]},
                                 cache, compute_dtype=jnp.float32)
    else:
        x, _ = model.forward(params, {"tokens": tokens},
                             compute_dtype=jnp.float32)
        full_logits = x[:, s] @ model._head_matrix(params)
        cache = model.init_cache(B, 64, dtype=jnp.float32)
        _, cache = model.prefill(params, {"tokens": tokens[:, :s]}, cache,
                                 compute_dtype=jnp.float32)
    logits, _ = model.decode_step(params, cache, tokens[:, s],
                                  jnp.full((B,), s, jnp.int32),
                                  compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full_logits),
                               rtol=2e-4, atol=2e-4)


def test_moe_decode_matches_forward_at_high_capacity():
    """MoE archs agree exactly once capacity drops are eliminated."""
    for arch in ["qwen2-moe-a2.7b", "jamba-1.5-large-398b"]:
        cfg = smoke_config(arch)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        model = build_model(cfg)
        params, _ = model.init(RNG)
        s = 21
        tokens = jax.random.randint(jax.random.fold_in(RNG, 3),
                                    (B, s + 1), 0, cfg.vocab_size)
        x, _ = model.forward(params, {"tokens": tokens},
                             compute_dtype=jnp.float32)
        full_logits = x[:, s] @ model._head_matrix(params)
        cache = model.init_cache(B, 48, dtype=jnp.float32)
        _, cache = model.prefill(params, {"tokens": tokens[:, :s]}, cache,
                                 compute_dtype=jnp.float32)
        logits, _ = model.decode_step(params, cache, tokens[:, s],
                                      jnp.full((B,), s, jnp.int32),
                                      compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full_logits),
                                   rtol=2e-4, atol=2e-4)


def test_param_counts_match_brief():
    expect = {
        "mamba2-130m": 0.13e9, "gemma3-1b": 1.0e9,
        "h2o-danube-3-4b": 4.0e9, "starcoder2-7b": 7.4e9,
        "qwen1.5-110b": 111e9, "internvl2-76b": 70e9,
        "jamba-1.5-large-398b": 398e9, "qwen2-moe-a2.7b": 14.3e9,
        "arctic-480b": 477e9, "whisper-small": 0.25e9,
    }
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert abs(got - n) / n < 0.15, (arch, got, n)


def test_moe_active_params():
    assert abs(get_config("qwen2-moe-a2.7b").active_param_count()
               - 2.7e9) / 2.7e9 < 0.1
