"""Shared test fixtures.

NOTE: XLA_FLAGS / device-count forcing is deliberately NOT set here —
smoke tests and benches must see the single real device.  Multi-device
tests spawn subprocesses with their own XLA_FLAGS (see _subproc helper).
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_multidevice(code: str, n_devices: int = 8) -> str:
    """Run ``code`` in a fresh python with n virtual devices; returns
    stdout.  Raises on failure with combined output."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=560,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\n"
            f"STDERR:\n{proc.stderr[-3000:]}")
    return proc.stdout


@pytest.fixture(scope="session")
def multidevice():
    return run_multidevice
