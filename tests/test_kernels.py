"""Pallas kernel allclose sweeps against the pure-jnp oracles
(interpret mode on CPU; the same kernels compile via Mosaic on TPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize(
    "b,h,kv,sq,sk,hd,causal,window,dtype,tol",
    [
        (1, 4, 4, 64, 64, 32, True, None, np.float32, 2e-5),
        (2, 8, 2, 128, 128, 64, True, None, np.float32, 2e-5),
        (1, 4, 1, 96, 96, 64, True, 32, np.float32, 2e-5),     # GQA+window
        (2, 4, 4, 1, 160, 64, True, None, np.float32, 2e-5),   # decode
        (1, 2, 2, 64, 64, 128, False, None, np.float32, 2e-5), # bidir
        (1, 4, 2, 200, 200, 64, True, None, np.float16, 5e-2), # ragged+fp16
        (1, 2, 1, 48, 80, 32, True, 16, np.float32, 2e-5),     # suffix+win
    ],
)
def test_flash_attention_vs_oracle(b, h, kv, sq, sk, hd, causal, window,
                                   dtype, tol):
    q = RNG.normal(size=(b, sq, h, hd)).astype(dtype)
    k = RNG.normal(size=(b, sk, kv, hd)).astype(dtype)
    v = RNG.normal(size=(b, sk, kv, hd)).astype(dtype)
    out = ops.flash_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        kind="causal" if causal else "bidir", window=window,
        block_q=32, block_k=32)
    want = ref.flash_attention_ref(
        jnp.asarray(q).swapaxes(1, 2), jnp.asarray(k).swapaxes(1, 2),
        jnp.asarray(v).swapaxes(1, 2), causal=causal,
        window=window).swapaxes(1, 2)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - want.astype(jnp.float32))))
    assert err < tol, err


@pytest.mark.parametrize(
    "b,s,h,p,n,chunk,dtype,tol",
    [
        (1, 64, 2, 16, 8, 16, np.float32, 1e-3),
        (2, 100, 4, 32, 16, 32, np.float32, 1e-3),   # ragged chunks
        (1, 128, 3, 64, 128, 64, np.float32, 1e-3),
        (2, 48, 2, 32, 16, 16, np.float16, 1e-1),
        (1, 33, 1, 8, 4, 64, np.float32, 1e-3),      # chunk > seq
    ],
)
def test_ssd_scan_vs_oracle(b, s, h, p, n, chunk, dtype, tol):
    x = RNG.normal(size=(b, s, h, p)).astype(dtype)
    dt = np.abs(RNG.normal(size=(b, s, h))).astype(np.float32) * 0.1
    A = (-np.abs(RNG.normal(size=(h,))) - 0.1).astype(np.float32)
    Bm = RNG.normal(size=(b, s, n)).astype(dtype)
    Cm = RNG.normal(size=(b, s, n)).astype(dtype)
    D = RNG.normal(size=(h,)).astype(np.float32)
    y = ops.ssd_scan(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
                     jnp.asarray(Bm), jnp.asarray(Cm), jnp.asarray(D),
                     chunk=chunk)
    want, _ = ref.ssd_ref(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
                          jnp.asarray(Bm), jnp.asarray(Cm), jnp.asarray(D))
    err = float(jnp.max(jnp.abs(y.astype(jnp.float32)
                                - want.astype(jnp.float32))))
    assert err < tol, err


def test_model_ssd_chunked_matches_naive_recurrence():
    """Third implementation cross-check: the model stack's chunked SSD
    (models/ssm.py) against the naive oracle."""
    from repro.models.ssm import ssd_chunked

    b, s, h, p, n = 2, 70, 3, 16, 8
    x = jnp.asarray(RNG.normal(size=(b, s, h, p)).astype(np.float32))
    dt = jnp.asarray(np.abs(RNG.normal(size=(b, s, h))).astype(np.float32)
                     * 0.2)
    A = jnp.asarray((-np.abs(RNG.normal(size=(h,))) - 0.1)
                    .astype(np.float32))
    Bm = jnp.asarray(RNG.normal(size=(b, s, n)).astype(np.float32))
    Cm = jnp.asarray(RNG.normal(size=(b, s, n)).astype(np.float32))
    D = jnp.asarray(RNG.normal(size=(h,)).astype(np.float32))
    y, hf = ssd_chunked(x, dt, A, Bm, Cm, D, chunk=16)
    want, hf_want = ref.ssd_ref(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hf_want),
                               rtol=1e-4, atol=1e-4)


def test_flash_attention_matches_model_attention_path():
    """kernels.ops.flash_attention == models.layers.attention(chunked)."""
    from repro.models.layers import attention

    b, s, h, kv, hd = 2, 96, 8, 2, 64
    q = jnp.asarray(RNG.normal(size=(b, s, h, hd)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(b, s, kv, hd)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(b, s, kv, hd)).astype(np.float32))
    a = ops.flash_attention(q, k, v, kind="causal", block_q=32, block_k=32)
    c = attention(q, k, v, kind="causal", impl="chunked", block_kv=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                               rtol=2e-4, atol=2e-4)
