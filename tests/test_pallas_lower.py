"""Unit + property tests for the Pallas backend's tile derivation,
span planning, and KernelPlan reporting (``src/repro/core/pallas_lower.py``).

Correctness of the kernels themselves is pinned by the differential
wall in tests/test_differential.py (``check_case_pallas`` /
``check_case2_pallas``); this file pins the *geometry*: tile shapes,
slab coverage (property-based — no overlap, no gap, masked remainder
lanes only), fusion span boundaries, and the rendered report.
"""
import os

import numpy as np

from tests._hypothesis_compat import given, settings, strategies as st

os.environ.setdefault("JAX_PLATFORMS", "cpu")


# ---------------------------------------------------------------------------
# Tile derivation units
# ---------------------------------------------------------------------------


def test_derive_axis_tiles_small_chunk_pads_to_sublane():
    import jax.numpy as jnp

    from repro.core.nest import derive_axis_tiles

    tl = derive_axis_tiles(1, jnp.float32)
    assert (tl.chunk, tl.tile, tl.n_tiles, tl.padded) == (1, 8, 1, 8)
    assert tl.masked_lanes == 7


def test_derive_axis_tiles_rounds_up_to_sublane():
    import jax.numpy as jnp

    from repro.core.nest import derive_axis_tiles

    tl = derive_axis_tiles(17, jnp.float32)
    assert (tl.tile, tl.n_tiles, tl.padded) == (24, 1, 24)
    assert tl.masked_lanes == 7


def test_derive_axis_tiles_caps_tile_and_splits():
    import jax.numpy as jnp

    from repro.core.nest import derive_axis_tiles

    tl = derive_axis_tiles(300, jnp.float32)
    assert (tl.tile, tl.n_tiles, tl.padded) == (256, 2, 512)
    assert tl.masked_lanes == 212


def test_derive_axis_tiles_dtype_sublane():
    import jax.numpy as jnp

    from repro.core.nest import derive_axis_tiles, sublane_for

    assert sublane_for(jnp.float32) == 8
    assert sublane_for(jnp.bfloat16) == 16
    assert sublane_for(jnp.int8) == 32
    tl = derive_axis_tiles(20, jnp.bfloat16)
    assert tl.tile == 32 and tl.n_tiles == 1 and tl.masked_lanes == 12


# ---------------------------------------------------------------------------
# Property wall: tile geometry must cover the slab exactly —
# no overlap, no gap, masked remainder lanes only (satellite 2)
# ---------------------------------------------------------------------------


@settings(max_examples=60)
@given(chunk=st.integers(1, 700),
       dtype_name=st.sampled_from(["float32", "float64", "bfloat16",
                                   "int8", "int32"]))
def test_axis_tiles_cover_partitions_chunk(chunk, dtype_name):
    import jax.numpy as jnp

    from repro.core.nest import derive_axis_tiles, sublane_for

    dt = getattr(jnp, dtype_name)
    tl = derive_axis_tiles(chunk, dt)
    assert tl.tile % sublane_for(dt) == 0
    assert tl.padded == tl.n_tiles * tl.tile >= chunk
    assert 0 <= tl.masked_lanes < tl.tile
    seen = np.zeros(chunk, dtype=int)
    for start, valid in tl.cover():
        assert valid >= 1                    # no empty tiles
        seen[start:start + valid] += 1
    assert (seen == 1).all()                 # exact partition of [0, chunk)


@settings(max_examples=40)
@given(n=st.integers(0, 200), num_devices=st.sampled_from([1, 2, 3, 4, 8]),
       chunk_req=st.one_of(st.none(), st.integers(1, 16)),
       halo=st.integers(0, 3))
def test_chunk_plan_plus_tiles_cover_every_iteration(n, num_devices,
                                                     chunk_req, halo):
    """Composed coverage: chunk-cyclic dealing x tile cover must visit
    every global iteration exactly once; halo never shifts lane
    ownership (it only widens the read window)."""
    import jax.numpy as jnp

    from repro.core import pragma
    from repro.core.loop import analyze_loop
    from repro.core.nest import derive_axis_tiles
    from repro.core.schedule import make_chunk_plan

    loop = analyze_loop(0, n, 1)
    ch = make_chunk_plan(loop, pragma.static(chunk_req), num_devices)
    tl = derive_axis_tiles(ch.chunk, jnp.float32)
    seen = np.zeros(n, dtype=int)
    for d in range(ch.num_devices):
        for q in range(ch.local_chunks):
            j = q * ch.num_devices + d
            k0 = j * ch.chunk
            for start, valid in tl.cover():
                for lane in range(start, start + valid):
                    k = k0 + lane
                    if k < n:
                        seen[k] += 1
    assert (seen == 1).all()


@settings(max_examples=25)
@given(n_i=st.integers(1, 40), n_j=st.integers(1, 40),
       p_i=st.sampled_from([1, 2, 4]), p_j=st.sampled_from([1, 2]),
       c_i=st.one_of(st.none(), st.integers(1, 7)),
       c_j=st.one_of(st.none(), st.integers(1, 7)))
def test_chunk_plan_plus_tiles_cover_2d(n_i, n_j, p_i, p_j, c_i, c_j):
    """Rank-2: the cross product of two per-axis covers partitions the
    collapse(2) iteration space exactly."""
    import jax.numpy as jnp

    from repro.core import pragma
    from repro.core.loop import analyze_loop
    from repro.core.nest import derive_axis_tiles
    from repro.core.schedule import make_chunk_plan

    covers = []
    for n, p, c in ((n_i, p_i, c_i), (n_j, p_j, c_j)):
        ch = make_chunk_plan(analyze_loop(0, n, 1), pragma.static(c), p)
        tl = derive_axis_tiles(ch.chunk, jnp.float32)
        ks = []
        for d in range(ch.num_devices):
            for q in range(ch.local_chunks):
                k0 = (q * ch.num_devices + d) * ch.chunk
                for start, valid in tl.cover():
                    ks.extend(k0 + lane
                              for lane in range(start, start + valid)
                              if k0 + lane < n)
        covers.append(ks)
    seen = np.zeros((n_i, n_j), dtype=int)
    for ki in covers[0]:
        for kj in covers[1]:
            seen[ki, kj] += 1
    assert (seen == 1).all()


# ---------------------------------------------------------------------------
# Span planning + KernelPlan artifact
# ---------------------------------------------------------------------------


def _mesh1(k=1):
    import jax
    from jax.sharding import Mesh

    return Mesh(np.asarray(jax.devices()[:k]), ("data",))


def test_block_kernel_plan_single_span():
    from repro import omp

    @omp.parallel_for(stop=37, name="mapk")
    def prog(i, env):
        return {"y": omp.at(i, env["x"][i] * 2.0)}

    import jax.numpy as jnp

    env = {"x": jnp.arange(37, dtype=jnp.float32),
           "y": jnp.zeros(37, jnp.float32)}
    c = omp.compile(prog, _mesh1(), lowering="pallas", env_like=env)
    kp = c.kernel_plan
    assert isinstance(kp, omp.KernelPlan)
    assert kp.n_kernels == 1 and kp.n_loop_stages == 1
    assert kp.spans[0].stage_names == ("mapk",)
    assert kp.spans[0].rank == 1
    assert [p.name for p in c.passes].count("pallas") == 1


def test_kernel_plan_absent_without_pallas():
    from repro import omp

    @omp.parallel_for(stop=8, name="mapl")
    def prog(i, env):
        return {"y": omp.at(i, env["x"][i])}

    import jax.numpy as jnp

    env = {"x": jnp.arange(8, dtype=jnp.float32),
           "y": jnp.zeros(8, jnp.float32)}
    c = omp.compile(prog, _mesh1(), lowering="collective", env_like=env)
    assert c.kernel_plan is None
    assert "pallas" not in [p.name for p in c.passes]


def _chain_region(omp, jnp, n=21):
    @omp.parallel_for(stop=n, name="k1")
    def l1(i, env):
        return {"tmp": omp.at(i, env["x"][i] * 2.0)}

    @omp.parallel_for(stop=n, name="k2")
    def l2(i, env):
        return {"y": omp.at(i, env["tmp"][i] + 1.0)}

    @omp.parallel_for(stop=n, name="k3", reduction={"tot": "+"})
    def l3(i, env):
        return {"tot": omp.red(env["y"][i])}

    prog = omp.region(l1, l2, l3, name="chaink")
    env = {"x": jnp.arange(n, dtype=jnp.float32) * 0.1,
           "tmp": jnp.zeros(n, jnp.float32),
           "y": jnp.zeros(n, jnp.float32), "tot": jnp.float32(0.0)}
    return prog, env


def test_region_chain_fuses_into_one_span():
    """Resident hand-offs with identical geometry fuse: the 3-stage
    chain becomes ONE kernel with VMEM-forwarded intermediates."""
    import jax.numpy as jnp

    from repro import omp

    prog, env = _chain_region(omp, jnp)
    c = omp.compile(prog, _mesh1(), lowering="pallas", env_like=env)
    kp = c.kernel_plan
    assert kp.n_kernels == 1 and kp.max_fused == 3
    assert kp.spans[0].stage_names == ("k1", "k2", "k3")
    assert set(kp.spans[0].forwarded) == {"tmp", "y"}


def test_region_halo_exchange_breaks_spans():
    """A halo feed means an exchange sits between stages — the
    ping-pong sweeps must NOT fuse."""
    import jax.numpy as jnp

    from repro import omp

    n = 18

    def sweep(src, dst, name):
        @omp.parallel_for(start=1, stop=n - 1, name=name)
        def body(i, env):
            v = (env[src][i - 1] + env[src][i] + env[src][i + 1]) / 3.0
            return {dst: omp.at(i, v)}
        return body

    prog = omp.region(sweep("a", "b", "p1"), sweep("b", "a", "p2"),
                      name="pingk")
    env = {"a": jnp.sin(jnp.arange(n, dtype=jnp.float32)),
           "b": jnp.zeros(n, jnp.float32)}
    c = omp.compile(prog, _mesh1(), lowering="pallas", env_like=env)
    assert c.kernel_plan.n_kernels == 2
    assert c.kernel_plan.max_fused == 1


def test_region_serial_glue_breaks_spans():
    import jax.numpy as jnp

    from repro import omp

    @omp.parallel_for(stop=9, name="s1")
    def g1(i, env):
        return {"tmp": omp.at(i, env["x"][i] * env["x"][i])}

    glue = omp.serial(lambda env: {"bias": env["bias"] * 0.5},
                      reads=("bias",), name="halve")

    @omp.parallel_for(stop=9, name="s2")
    def g2(i, env):
        return {"y": omp.at(i, env["tmp"][i] + env["bias"][0])}

    prog = omp.region(g1, glue, g2, name="gluek")
    env = {"x": jnp.arange(9, dtype=jnp.float32),
           "tmp": jnp.zeros(9, jnp.float32),
           "y": jnp.zeros(9, jnp.float32),
           "bias": jnp.full((1,), 3.0, jnp.float32)}
    c = omp.compile(prog, _mesh1(), lowering="pallas", env_like=env)
    assert c.kernel_plan.n_kernels == 2
    assert all(len(s.stage_names) == 1 for s in c.kernel_plan.spans)


def test_kernel_plan_report_golden():
    """``Compiled.report()`` renders the tile geometry + fusion spans."""
    import jax.numpy as jnp

    from repro import omp

    prog, env = _chain_region(omp, jnp)
    c = omp.compile(prog, _mesh1(), lowering="pallas", env_like=env)
    rep = c.report()
    assert "pallas: exchange-free compute spans + chunk geometry" in rep
    assert "pallas kernels: 1 span(s) over 3 loop stage(s)" in rep
    assert "k1+k2+k3: grid=" in rep
    assert "vmem-forwarded: tmp, y" in rep
    # the one-span line carries the tile geometry verbatim
    span = c.kernel_plan.spans[0]
    assert span.describe() in rep


def test_resolve_interpret():
    from repro.core.pallas_lower import resolve_interpret

    mesh = _mesh1()
    assert resolve_interpret(None, mesh) is True      # CPU -> interpret
    assert resolve_interpret(True, mesh) is True
    assert resolve_interpret(False, mesh) is False


def test_pallas_smoke_matches_reference():
    """One end-to-end run (interpret) against the shared-memory
    reference — the full wall lives in tests/test_differential.py."""
    import jax.numpy as jnp

    from repro import omp

    prog, env = _chain_region(omp, jnp)
    ref = prog(env)
    got = omp.compile(prog, _mesh1(), lowering="pallas")(env)
    for k in ref:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(ref[k]),
                                   rtol=1e-5, atol=1e-5)
