"""MoE dispatch: capacity semantics, expert padding, shared/dense paths."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import MoEConfig
from repro.models.moe import init_moe, moe_apply

RNG = jax.random.PRNGKey(0)


def _cfg(**kw):
    base = dict(n_experts=6, top_k=2, d_expert=16, capacity_factor=8.0)
    base.update(kw)
    return MoEConfig(**base)


def _dense_reference(p, x, cfg):
    """Token-exact MoE (no capacity): run every expert densely, weight by
    renormalised top-k gates."""
    e = cfg.n_experts
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    vals, idx = jax.lax.top_k(probs, cfg.top_k)
    vals = vals / jnp.sum(vals, axis=-1, keepdims=True)
    w = jnp.zeros_like(probs)
    w = jnp.take_along_axis(w, idx, axis=-1)
    # scatter the renormalised gates back
    full_w = jnp.zeros_like(probs)
    for k in range(cfg.top_k):
        full_w = full_w + vals[..., k:k + 1] * jax.nn.one_hot(
            idx[..., k], probs.shape[-1])
    h = jnp.einsum("bsd,edf->bsef", x, p["w_gate"][:e].astype(x.dtype))
    u = jnp.einsum("bsd,edf->bsef", x, p["w_up"][:e].astype(x.dtype))
    o = jnp.einsum("bsef,efd->bsed", jax.nn.silu(h) * u,
                   p["w_down"][:e].astype(x.dtype))
    return jnp.einsum("bsed,bse->bsd", o, full_w[..., :e].astype(x.dtype))


def test_moe_matches_dense_reference_at_high_capacity():
    cfg = _cfg()
    p_tree = init_moe(RNG, 32, cfg)
    p = jax.tree_util.tree_map(lambda t: t[0], p_tree,
                               is_leaf=lambda t: isinstance(t, tuple)
                               and hasattr(t[0], "shape"))
    x = jax.random.normal(jax.random.fold_in(RNG, 1), (2, 24, 32))
    y, aux = moe_apply(p, x, cfg, groups=2)
    want = _dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_expert_padding_is_routing_invisible():
    """n_padded=8: outputs identical to the unpadded model when the
    first 6 experts share weights (dummies never routed)."""
    cfg6 = _cfg()
    cfg8 = _cfg(n_padded=8)
    tree = init_moe(RNG, 32, cfg8)
    p8 = jax.tree_util.tree_map(lambda t: t[0], tree,
                                is_leaf=lambda t: isinstance(t, tuple)
                                and hasattr(t[0], "shape"))
    p6 = dict(p8)
    for k in ("w_gate", "w_up", "w_down"):
        p6[k] = p8[k][:6]
    x = jax.random.normal(jax.random.fold_in(RNG, 2), (2, 16, 32))
    y8, _ = moe_apply(p8, x, cfg8, groups=1)
    y6, _ = moe_apply(p6, x, cfg6, groups=1)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y6),
                               rtol=1e-5, atol=1e-5)


def test_capacity_drops_bounded():
    """With cf=0.5 some tokens drop; output stays finite and the
    drop-less tokens match the high-capacity result."""
    cfg_lo = _cfg(capacity_factor=0.5)
    tree = init_moe(RNG, 32, cfg_lo)
    p = jax.tree_util.tree_map(lambda t: t[0], tree,
                               is_leaf=lambda t: isinstance(t, tuple)
                               and hasattr(t[0], "shape"))
    x = jax.random.normal(jax.random.fold_in(RNG, 3), (1, 64, 32))
    y, aux = moe_apply(p, x, cfg_lo, groups=1)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) > 0


def test_shared_and_dense_residual_paths():
    cfg = _cfg(n_shared=1, shared_d_ff=24, dense_residual_d_ff=24)
    tree = init_moe(RNG, 32, cfg)
    p = jax.tree_util.tree_map(lambda t: t[0], tree,
                               is_leaf=lambda t: isinstance(t, tuple)
                               and hasattr(t[0], "shape"))
    assert "shared" in p and "dense" in p
    x = jax.random.normal(jax.random.fold_in(RNG, 4), (2, 8, 32))
    y, _ = moe_apply(p, x, cfg, groups=1)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
