"""Differential test harness: random canonical OMP programs vs their
transformations, across 1/2/4-device meshes.

This is the regression net under the communication-planner refactor:
programs are drawn from the canonical-form families the paper recognises
(identity / aligned / strided affine writes, stencil reads with halo
offsets, reductions, ``put``, serial glue, multi-loop chains; schedules
``static``/``dynamic``/``guided`` with and without explicit chunk sizes,
including zero-trip and trip_count < num_devices draws) and every
lowering must reproduce the shared-memory reference
(:func:`repro.core.transform.run_reference`).  Every variant routes
through the one entry point ``omp.compile``:

* ``Lowering.COLLECTIVE``, with ``shard`` = replicate and slice, plus a
  schedule-override draw that forces exactly one chunk per device (the
  static fast path: no ``lax.scan``, no dynamic window gather),
* ``Lowering.MASTER_WORKER`` (the paper's staging; needs >= 2 ranks),
* ``Lowering.FUSED`` regions, both ``comm="auto"`` (cost-modeled halo
  ``ppermute`` boundaries) and ``comm="gather"`` (the PR 1 baseline),
  each under ``comm_schedule`` = ``aggregate`` (packed payloads, fused
  reductions, prefetched exchanges) *and* ``inline`` — the two schedule
  modes must be bit-identical — plus the per-loop
  ``Lowering.COLLECTIVE`` staged fallback.

Single-device examples run in-process through the (vendored) hypothesis
``given``; the 2/4-device sweep runs in one subprocess with forced
virtual devices (``conftest.run_multidevice``) and re-draws the same
seeded cases there.
"""
import os
import random

import numpy as np

from tests._hypothesis_compat import given, settings, strategies as st

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

FAMILIES = (
    "map", "stencil", "strided", "reduce", "put", "combo",
    "chain", "pingpong", "glue", "zerotrip",
)


def _schedule(rng):
    from repro import omp

    kind = rng.choice([omp.static, omp.dynamic, omp.guided])
    chunk = rng.choice([None, None, 1, 2, 3, 5])
    return kind(chunk)


def make_case(seed: int, family: str | None = None):
    """Build one random canonical program (or region) + env from a seed.

    Deterministic: the in-process and subprocess sweeps rebuild
    identical cases from the same seed.  ``family`` forces one program
    family (the multi-device sweep uses it to guarantee every family —
    in particular the halo-exercising stencil/pingpong ones — runs on
    every mesh size).
    """
    import jax.numpy as jnp

    from repro import omp

    rng = random.Random(seed)
    if family is None:
        family = rng.choice(FAMILIES)
    assert family in FAMILIES, family
    sched = _schedule(rng)
    fx = jnp.float32

    if family == "map":
        n = rng.randint(3, 24)
        start = rng.choice([0, 0, 1, 2])
        stop = rng.randint(start, n)          # may draw a zero-trip loop
        step = rng.choice([1, 1, 2])

        @omp.parallel_for(start=start, stop=stop, step=step, schedule=sched,
                          name=f"map{seed}")
        def prog(i, env):
            return {"y": omp.at(i, env["x"][i] * 2.0 + 1.0)}

        env = {"x": jnp.arange(n, dtype=fx) * 0.25, "y": -jnp.ones(n, fx)}

    elif family == "stencil":
        n = rng.randint(8, 24)
        w = rng.choice([1, 2])

        @omp.parallel_for(start=w, stop=n - w, schedule=sched,
                          name=f"stencil{seed}")
        def prog(i, env):
            v = (env["x"][i - w] + env["x"][i] + env["x"][i + w]) / 3.0
            return {"y": omp.at(i, v)}

        env = {"x": jnp.arange(n, dtype=fx) * 0.5, "y": -jnp.ones(n, fx)}

    elif family == "strided":
        t = rng.randint(1, 9)
        a = rng.choice([2, 3])
        b = rng.randint(0, 2)
        m = a * (t - 1) + b + 1

        @omp.parallel_for(stop=t, schedule=sched, name=f"strided{seed}")
        def prog(i, env):
            return {"z": omp.at(a * i + b, env["x"][i] + 3.0)}

        env = {"x": jnp.arange(max(t, 2), dtype=fx), "z": -jnp.ones(m, fx)}

    elif family == "reduce":
        n = rng.randint(1, 20)
        op = rng.choice(["+", "max", "min", "*"])
        fresh = rng.random() < 0.4

        @omp.parallel_for(stop=n, schedule=sched, reduction={"s": op},
                          name=f"reduce{seed}")
        def prog(i, env):
            return {"s": omp.red(env["x"][i])}

        # keep values near 1 so "*" stays well-conditioned
        env = {"x": 1.0 + 0.1 * jnp.sin(jnp.arange(n, dtype=fx))}
        if not fresh:
            env["s"] = fx(0.5)

    elif family == "put":
        t = rng.randint(1, 9)

        @omp.parallel_for(stop=t, schedule=sched, name=f"put{seed}")
        def prog(i, env):
            return {"w": omp.put(jnp.full((3,), 1.0, fx) * i)}

        env = {"x": jnp.arange(t, dtype=fx), "w": jnp.zeros(3, fx)}

    elif family == "combo":
        n = rng.randint(2, 16)

        @omp.parallel_for(stop=n, schedule=sched, reduction={"s": "+"},
                          name=f"combo{seed}")
        def prog(i, env):
            v = env["x"][i] * env["x"][i]
            return {"y": omp.at(i, v), "s": omp.red(v)}

        env = {"x": jnp.arange(n, dtype=fx) * 0.3, "y": jnp.zeros(n, fx),
               "s": fx(1.0)}

    elif family == "chain":
        n = rng.randint(4, 24)

        @omp.parallel_for(stop=n, schedule=sched, name=f"c1_{seed}")
        def l1(i, env):
            return {"tmp": omp.at(i, env["x"][i] * 2.0)}

        @omp.parallel_for(stop=n, schedule=sched, name=f"c2_{seed}")
        def l2(i, env):
            return {"y": omp.at(i, env["tmp"][i] + 1.0)}

        @omp.parallel_for(stop=n, schedule=sched, reduction={"tot": "+"},
                          name=f"c3_{seed}")
        def l3(i, env):
            return {"tot": omp.red(env["y"][i])}

        prog = omp.region(l1, l2, l3, name=f"chain{seed}")
        env = {"x": jnp.arange(n, dtype=fx) * 0.1, "tmp": jnp.zeros(n, fx),
               "y": jnp.zeros(n, fx), "tot": fx(0.0)}

    elif family == "pingpong":
        n = rng.randint(10, 28)

        def sweep(src, dst, name):
            @omp.parallel_for(start=1, stop=n - 1, schedule=sched, name=name)
            def body(i, env):
                v = 0.25 * (env[src][i - 1] + 2.0 * env[src][i]
                            + env[src][i + 1])
                return {dst: omp.at(i, v)}
            return body

        prog = omp.region(sweep("a", "b", f"s1_{seed}"),
                          sweep("b", "a", f"s2_{seed}"),
                          sweep("a", "b", f"s3_{seed}"),
                          name=f"pingpong{seed}")
        env = {"a": jnp.sin(jnp.arange(n, dtype=fx)),
               "b": jnp.zeros(n, fx)}

    elif family == "glue":
        n = rng.randint(4, 20)

        @omp.parallel_for(stop=n, schedule=sched, name=f"g1_{seed}")
        def g1(i, env):
            return {"tmp": omp.at(i, env["x"][i] * env["x"][i])}

        glue = omp.serial(lambda env: {"bias": env["bias"] * 0.5},
                          reads=("bias",), name=f"halve{seed}")

        @omp.parallel_for(stop=n, schedule=sched, name=f"g2_{seed}")
        def g2(i, env):
            return {"y": omp.at(i, env["tmp"][i] + env["bias"][0])}

        prog = omp.region(g1, glue, g2, name=f"glue{seed}")
        env = {"x": jnp.arange(n, dtype=fx) * 0.2, "tmp": jnp.zeros(n, fx),
               "y": jnp.zeros(n, fx), "bias": jnp.full((1,), 3.0, fx)}

    else:  # zerotrip
        n = rng.randint(3, 12)

        @omp.parallel_for(stop=0, schedule=sched, reduction={"s": "+"},
                          name=f"z0_{seed}")
        def z0(i, env):
            return {"y": omp.at(i, env["x"][i]), "s": omp.red(env["x"][i])}

        @omp.parallel_for(stop=n, schedule=sched, name=f"z1_{seed}")
        def z1(i, env):
            return {"y": omp.at(i, env["x"][i] + env["s"])}

        prog = omp.region(z0, z1, name=f"zerotrip{seed}")
        env = {"x": jnp.arange(n, dtype=fx), "y": jnp.zeros(n, fx),
               "s": fx(7.0)}

    return prog, env, family


def check_case(seed: int, mesh, family: str | None = None) -> str:
    """Every lowering of the drawn program must match the reference.

    Everything routes through ``omp.compile`` — the single entry point
    must handle every family × schedule × lowering × comm mode the
    legacy entry points covered (those survive only as shims; their
    equivalence is pinned in tests/test_api.py).
    """
    from repro import omp

    prog, env, family = make_case(seed, family)
    is_region = isinstance(prog, omp.ParallelRegion)
    ref = prog(env)
    p = mesh.shape["data"]

    variants = {}
    if is_region:
        variants["region_auto"] = omp.compile(prog, mesh, comm="auto")
        variants["region_inline"] = omp.compile(
            prog, mesh, comm="auto", comm_schedule="inline")
        variants["region_gather"] = omp.compile(prog, mesh, comm="gather")
        variants["region_staged"] = omp.compile(prog, mesh,
                                                lowering="collective")
        if p >= 2:
            variants["region_mw"] = omp.compile(
                prog, mesh, lowering="master_worker")
    else:
        variants["mpi"] = omp.compile(prog, mesh, lowering="collective")
        variants["mpi_sharded"] = omp.compile(
            prog, mesh, lowering="collective", shard="slice")
        t = len(range(prog.start, prog.stop, prog.step))
        if t > 0:
            # pin the one-chunk-per-device fast path (static slab body,
            # no scan): chunk = ceil(t / P) makes local_chunks == 1
            variants["mpi_onechunk"] = omp.compile(
                prog, mesh, lowering="collective", shard="slice",
                schedule=omp.static(-(-t // p)))
        if p >= 2:
            variants["mpi_mw"] = omp.compile(prog, mesh,
                                             lowering="master_worker")

    outs = {}
    for vname, dist in variants.items():
        got = dist(env)
        outs[vname] = got
        assert set(got) == set(ref), (
            f"seed={seed} {family}/{vname} P={p}: key set "
            f"{sorted(got)} != {sorted(ref)}")
        for k in ref:
            np.testing.assert_allclose(
                np.asarray(got[k]), np.asarray(ref[k]),
                rtol=1e-4, atol=1e-4,
                err_msg=f"seed={seed} {family}/{vname} P={p} key={k!r}")
    if "mpi_onechunk" in variants:
        assert variants["mpi_onechunk"].plan.chunks.local_chunks == 1
    if "region_inline" in outs:
        # the two schedule modes move identical bytes and must produce
        # bit-identical outputs
        for k in ref:
            np.testing.assert_array_equal(
                np.asarray(outs["region_auto"][k]),
                np.asarray(outs["region_inline"][k]),
                err_msg=f"seed={seed} {family} P={p} key={k!r}: "
                        "aggregate vs inline schedule diverged")
    return family


def run_sweep(seeds, device_counts) -> None:
    """Subprocess entry point: sweep seeds over real sub-meshes.

    Every family is forced once per mesh size (random seeds alone can
    miss the halo-exercising stencil/pingpong families), then the free
    seeds add schedule/shape variety on top.
    """
    import jax
    from jax.sharding import Mesh

    covered = set()
    for k in device_counts:
        mesh = Mesh(np.asarray(jax.devices()[:k]), ("data",))
        for j, fam in enumerate(FAMILIES):
            covered.add(check_case(1000 * k + j, mesh, family=fam))
        for seed in seeds:
            covered.add(check_case(seed, mesh))
    assert covered == set(FAMILIES), sorted(set(FAMILIES) - covered)
    print("families:", ",".join(sorted(covered)))


# ---------------------------------------------------------------------------
# Rank-2 (collapse=2) program families over 2-D meshes
# ---------------------------------------------------------------------------

FAMILIES2 = ("heat2d", "transpose2", "rowreduce2", "matmul2")


def make_case2(seed: int, family: str | None = None):
    """Build one random canonical ``collapse=2`` program (or region) +
    env from a seed: the 2-D families of the paper's benchmark suite
    (Jacobi/heat stencils, transposed feeds, reductions, matmul tiles).
    """
    import jax.numpy as jnp

    from repro import omp

    rng = random.Random(seed)
    if family is None:
        family = rng.choice(FAMILIES2)
    assert family in FAMILIES2, family
    sched = _schedule(rng)
    fx = jnp.float32

    if family == "heat2d":
        n = rng.randint(6, 14)
        m = rng.randint(6, 14)

        def sweep(src, dst, name):
            @omp.parallel_for(start=(1, 1), stop=(n - 1, m - 1), collapse=2,
                              schedule=sched, name=name)
            def body(i, j, env):
                v = 0.25 * (env[src][i - 1, j] + env[src][i + 1, j]
                            + env[src][i, j - 1] + env[src][i, j + 1])
                return {dst: omp.at((i, j), v)}
            return body

        prog = omp.region(sweep("a", "b", f"h1_{seed}"),
                          sweep("b", "a", f"h2_{seed}"),
                          name=f"heat2d{seed}")
        env = {"a": jnp.sin(jnp.arange(n * m, dtype=fx)).reshape(n, m),
               "b": jnp.zeros((n, m), fx)}

    elif family == "transpose2":
        n = rng.randint(4, 10)

        @omp.parallel_for(stop=(n, n), collapse=2, schedule=sched,
                          name=f"t1_{seed}")
        def t1(i, j, env):
            return {"t": omp.at((i, j), env["x"][i, j] * 2.0)}

        @omp.parallel_for(stop=(n, n), collapse=2, schedule=sched,
                          name=f"t2_{seed}")
        def t2(i, j, env):
            return {"y": omp.at((i, j), env["t"][j, i] + 1.0)}

        prog = omp.region(t1, t2, name=f"transpose2_{seed}")
        env = {"x": jnp.arange(n * n, dtype=fx).reshape(n, n) * 0.1,
               "t": jnp.zeros((n, n), fx), "y": jnp.zeros((n, n), fx)}

    elif family == "rowreduce2":
        n = rng.randint(3, 10)
        m = rng.randint(3, 10)
        op = rng.choice(["+", "max", "min", "*"])
        fresh = rng.random() < 0.4

        @omp.parallel_for(stop=(n, m), collapse=2, schedule=sched,
                          reduction={"s": op}, name=f"rr_{seed}")
        def prog(i, j, env):
            return {"s": omp.red(env["x"][i, j])}

        env = {"x": 1.0 + 0.1 * jnp.sin(
            jnp.arange(n * m, dtype=fx)).reshape(n, m)}
        if not fresh:
            env["s"] = fx(0.5)

    else:  # matmul2
        n = rng.randint(3, 9)
        m = rng.randint(3, 9)
        kk = rng.randint(2, 6)

        @omp.parallel_for(stop=(n, m), collapse=2, schedule=sched,
                          name=f"mm_{seed}")
        def prog(i, j, env):
            return {"C": omp.at((i, j),
                                jnp.dot(env["A"][i], env["B"][:, j]))}

        env = {"A": jnp.arange(n * kk, dtype=fx).reshape(n, kk) * 0.05,
               "B": jnp.arange(kk * m, dtype=fx).reshape(kk, m) * 0.03,
               "C": -jnp.ones((n, m), fx)}

    return prog, env, family


def check_case2(seed: int, mesh, family: str | None = None) -> str:
    """Every rank-2 lowering of the drawn program must match the
    shared-memory reference on the given 2-D mesh."""
    from repro import omp

    prog, env, family = make_case2(seed, family)
    is_region = isinstance(prog, omp.ParallelRegion)
    ref = prog(env)
    shape = (mesh.shape["i"], mesh.shape["j"])

    variants = {}
    if is_region:
        variants["region2_auto"] = omp.compile(prog, mesh, comm="auto")
        variants["region2_inline"] = omp.compile(
            prog, mesh, comm="auto", comm_schedule="inline")
        variants["region2_gather"] = omp.compile(prog, mesh, comm="gather")
    else:
        variants["mpi2"] = omp.compile(prog, mesh, lowering="collective")
        variants["mpi2_sharded"] = omp.compile(
            prog, mesh, lowering="collective", shard="slice")
        variants["region2_auto"] = omp.compile(
            omp.ParallelRegion((prog,)), mesh)

    outs = {}
    for vname, dist in variants.items():
        got = dist(env)
        outs[vname] = got
        assert set(got) == set(ref), (
            f"seed={seed} {family}/{vname} mesh={shape}: key set "
            f"{sorted(got)} != {sorted(ref)}")
        for k in ref:
            np.testing.assert_allclose(
                np.asarray(got[k]), np.asarray(ref[k]),
                rtol=1e-4, atol=1e-4,
                err_msg=f"seed={seed} {family}/{vname} mesh={shape} key={k!r}")
    if "region2_inline" in outs:
        for k in ref:
            np.testing.assert_array_equal(
                np.asarray(outs["region2_auto"][k]),
                np.asarray(outs["region2_inline"][k]),
                err_msg=f"seed={seed} {family} mesh={shape} key={k!r}: "
                        "aggregate vs inline schedule diverged")
    return family


def run_sweep2(mesh_shapes) -> None:
    """Subprocess entry point: every 2-D family on every mesh shape."""
    from repro.compat import make_mesh

    covered = set()
    for si, shape in enumerate(mesh_shapes):
        mesh = make_mesh(shape, ("i", "j"))
        for fj, fam in enumerate(FAMILIES2):
            covered.add(check_case2(7000 + 100 * si + fj, mesh, family=fam))
    assert covered == set(FAMILIES2), sorted(set(FAMILIES2) - covered)
    print("families2:", ",".join(sorted(covered)))


@settings(max_examples=4)
@given(seed=st.integers(0, 2**31 - 1))
def test_differential_2d_single_device(seed):
    """1x1 meshes in-process: the rank-2 transformation must be a
    semantic no-op for every drawn collapse=2 program."""
    from repro.compat import make_mesh

    mesh = make_mesh((1, 1), ("i", "j"))
    check_case2(seed, mesh)


def test_differential_2d_multidevice(multidevice):
    """2x1 / 2x2 / 4x2 meshes (8 virtual devices, one subprocess): every
    rank-2 lowering of every family matches the reference."""
    out = multidevice(f"""
        import sys
        sys.path.insert(0, {REPO!r})
        from tests.test_differential import run_sweep2
        run_sweep2(((2, 1), (2, 2), (4, 2)))
        print("OKDIFF2")
    """, n_devices=8)
    assert "OKDIFF2" in out
    families_line = [l for l in out.splitlines()
                     if l.startswith("families2:")][0]
    for fam in FAMILIES2:
        assert fam in families_line, fam


@settings(max_examples=10)
@given(seed=st.integers(0, 2**31 - 1))
def test_differential_single_device(seed):
    """1-device meshes: the transformation must be a semantic no-op for
    every drawn program."""
    import jax
    from jax.sharding import Mesh

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    check_case(seed, mesh)


def test_differential_multidevice(multidevice):
    """2- and 4-device meshes (4 virtual devices, one subprocess):
    every lowering of every drawn case matches the reference."""
    out = multidevice(f"""
        import sys
        sys.path.insert(0, {REPO!r})
        from tests.test_differential import FAMILIES, run_sweep
        run_sweep(seeds=range(4), device_counts=(2, 4))
        print("OKDIFF")
    """, n_devices=4)
    assert "OKDIFF" in out
    families_line = [l for l in out.splitlines()
                     if l.startswith("families:")][0]
    for fam in FAMILIES:
        assert fam in families_line, fam


# ---------------------------------------------------------------------------
# Lowering.PALLAS: the tiled shard-local kernel backend must match BOTH
# the shared-memory reference and the lax lowering of the same draw
# ---------------------------------------------------------------------------


def check_case_pallas(seed: int, mesh, family: str | None = None) -> str:
    """Differential wall for the Pallas backend (interpret on CPU):
    same drawn program, three executions — shared-memory reference, the
    lax lowering (collective / fused region), and ``lowering=pallas`` —
    and the pallas output must match both."""
    from repro import omp

    prog, env, family = make_case(seed, family)
    is_region = isinstance(prog, omp.ParallelRegion)
    ref = prog(env)
    p = mesh.shape["data"]
    if is_region:
        lax_c = omp.compile(prog, mesh, comm="auto")
    else:
        lax_c = omp.compile(prog, mesh, lowering="collective")
    pal_c = omp.compile(prog, mesh, lowering="pallas")
    lax_out = lax_c(env)
    pal_out = pal_c(env)
    assert set(pal_out) == set(ref), (
        f"seed={seed} {family}/pallas P={p}: key set "
        f"{sorted(pal_out)} != {sorted(ref)}")
    for k in ref:
        np.testing.assert_allclose(
            np.asarray(pal_out[k]), np.asarray(ref[k]),
            rtol=1e-4, atol=1e-4,
            err_msg=f"seed={seed} {family}/pallas-vs-ref P={p} key={k!r}")
        np.testing.assert_allclose(
            np.asarray(pal_out[k]), np.asarray(lax_out[k]),
            rtol=1e-4, atol=1e-4,
            err_msg=f"seed={seed} {family}/pallas-vs-lax P={p} key={k!r}")
    return family


def check_case2_pallas(seed: int, mesh, family: str | None = None) -> str:
    """Rank-2 pallas differential: collapse=2 families on 2-D meshes."""
    from repro import omp

    prog, env, family = make_case2(seed, family)
    is_region = isinstance(prog, omp.ParallelRegion)
    ref = prog(env)
    shape = (mesh.shape["i"], mesh.shape["j"])
    if is_region:
        lax_c = omp.compile(prog, mesh, comm="auto")
    else:
        lax_c = omp.compile(prog, mesh, lowering="collective")
    pal_c = omp.compile(prog, mesh, lowering="pallas")
    lax_out = lax_c(env)
    pal_out = pal_c(env)
    assert set(pal_out) == set(ref), (
        f"seed={seed} {family}/pallas mesh={shape}: key set "
        f"{sorted(pal_out)} != {sorted(ref)}")
    for k in ref:
        np.testing.assert_allclose(
            np.asarray(pal_out[k]), np.asarray(ref[k]),
            rtol=1e-4, atol=1e-4,
            err_msg=f"seed={seed} {family}/pallas-vs-ref "
                    f"mesh={shape} key={k!r}")
        np.testing.assert_allclose(
            np.asarray(pal_out[k]), np.asarray(lax_out[k]),
            rtol=1e-4, atol=1e-4,
            err_msg=f"seed={seed} {family}/pallas-vs-lax "
                    f"mesh={shape} key={k!r}")
    return family


def test_differential_pallas_every_family():
    """Every rank-1 family through the pallas backend, in-process on a
    1-device mesh (interpret mode)."""
    import jax
    from jax.sharding import Mesh

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    for j, fam in enumerate(FAMILIES):
        check_case_pallas(500 + j, mesh, family=fam)


def test_differential_pallas2_every_family():
    """Every rank-2 family through the pallas backend on a 1x1 mesh."""
    from repro.compat import make_mesh

    mesh = make_mesh((1, 1), ("i", "j"))
    for j, fam in enumerate(FAMILIES2):
        check_case2_pallas(600 + j, mesh, family=fam)


@settings(max_examples=4)
@given(seed=st.integers(0, 2**31 - 1))
def test_differential_pallas_single_device(seed):
    """Random draws through the pallas backend (any family)."""
    import jax
    from jax.sharding import Mesh

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    check_case_pallas(seed, mesh)


def run_sweep_pallas() -> None:
    """Subprocess entry point: every family through the pallas backend
    on real multi-device meshes (rank-1 on 4 ranks, rank-2 on 2x2)."""
    import jax
    from jax.sharding import Mesh

    from repro.compat import make_mesh

    covered = set()
    mesh = Mesh(np.asarray(jax.devices()[:4]), ("data",))
    for j, fam in enumerate(FAMILIES):
        covered.add(check_case_pallas(4000 + j, mesh, family=fam))
    mesh2 = make_mesh((2, 2), ("i", "j"))
    for j, fam in enumerate(FAMILIES2):
        covered.add(check_case2_pallas(4100 + j, mesh2, family=fam))
    assert covered == set(FAMILIES) | set(FAMILIES2), sorted(covered)
    print("families_pallas:", ",".join(sorted(covered)))


def test_differential_pallas_multidevice(multidevice):
    """Pallas backend on real multi-device meshes (8 virtual devices,
    one subprocess): every family, both ranks, vs reference AND lax."""
    out = multidevice(f"""
        import sys
        sys.path.insert(0, {REPO!r})
        from tests.test_differential import run_sweep_pallas
        run_sweep_pallas()
        print("OKPALLAS")
    """, n_devices=8)
    assert "OKPALLAS" in out
    families_line = [l for l in out.splitlines()
                     if l.startswith("families_pallas:")][0]
    for fam in FAMILIES + FAMILIES2:
        assert fam in families_line, fam
