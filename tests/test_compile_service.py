"""CompileService: single-flight dedup, concurrency, straggler hooks.

Pins the ISSUE acceptance criterion: N client threads submitting
overlapping programs get bit-identical results to serial execution,
with exactly ONE cold compile per structural key.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import omp
from repro.compat import make_mesh
from repro.runtime.straggler import StragglerMonitor
from repro.serving import CompileService


def mesh1():
    return make_mesh((len(jax.devices()),), ("data",))


def _block(tag, n=16):
    scale = float(sum(ord(ch) for ch in str(tag)))

    @omp.parallel_for(stop=n, name=f"svc{tag}")
    def block(i, env):
        return {"y": omp.at(i, env["x"][i] * scale + 1.0)}

    env = {"x": jnp.arange(n, dtype=jnp.float32),
           "y": jnp.zeros(n, jnp.float32)}
    return block, env


def test_serial_smoke_and_stats():
    omp.clear_compile_cache()
    svc = CompileService(mesh1())
    blk, env = _block("a")
    out1 = svc.run(blk, env)
    out2 = svc.run(blk, env)
    np.testing.assert_array_equal(np.asarray(out1["y"]),
                                  np.asarray(blk(env)["y"]))
    np.testing.assert_array_equal(np.asarray(out1["y"]),
                                  np.asarray(out2["y"]))
    assert svc.stats.requests == 2
    assert svc.stats.cold_compiles == 1
    assert svc.stats.warm_hits == 1
    d = svc.stats.as_dict()
    assert d["requests"] == 2 and "compile_cache" in d


def test_single_flight_exactly_one_cold_compile_per_key():
    """The acceptance criterion: many racing clients, overlapping keys,
    bit-identical to serial, exactly one compile per structural key."""
    omp.clear_compile_cache()
    programs = [_block(t) for t in ("p0", "p1", "p2")]
    serial = [np.asarray(blk(env)["y"]) for blk, env in programs]

    svc = CompileService(mesh1())
    n_threads = 12
    results = [[None] * len(programs) for _ in range(n_threads)]
    errors = []
    barrier = threading.Barrier(n_threads)

    def client(tid):
        try:
            barrier.wait()
            for j, (blk, env) in enumerate(programs):
                results[tid][j] = np.asarray(svc.run(blk, env)["y"])
        except Exception as e:            # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=client, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    for tid in range(n_threads):
        for j in range(len(programs)):
            np.testing.assert_array_equal(results[tid][j], serial[j])
    # exactly one cold compile per structural key, all others coalesced
    assert svc.stats.cold_compiles == len(programs)
    assert svc.stats.requests == n_threads * len(programs)
    assert (svc.stats.warm_hits + svc.stats.coalesced
            == n_threads * len(programs) - len(programs))
    # the underlying compile cache saw exactly one miss per key too
    cstats = omp.compile_cache_stats()
    assert cstats["misses"] == len(programs)


def test_compile_error_propagates_to_all_followers():
    omp.clear_compile_cache()
    svc = CompileService(mesh1())

    @omp.parallel_for(stop=16, name="svcbad")
    def bad(i, env):
        return {"y": omp.at(i, env["missing_key"][i])}

    env = {"x": jnp.arange(16, dtype=jnp.float32),
           "y": jnp.zeros(16, jnp.float32)}
    n_threads = 4
    errors = []
    barrier = threading.Barrier(n_threads)

    def client():
        barrier.wait()
        try:
            svc.run(bad, env)
        except Exception as e:
            errors.append(e)

    threads = [threading.Thread(target=client) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    # every client observed the failure; nothing was published warm
    assert len(errors) == n_threads
    assert svc._compiled == {} and svc._inflight == {}


def test_submit_returns_future():
    omp.clear_compile_cache()
    blk, env = _block("fut")
    with CompileService(mesh1(), max_workers=2) as svc:
        futs = [svc.submit(blk, env) for _ in range(4)]
        outs = [f.result(timeout=60) for f in futs]
    want = np.asarray(blk(env)["y"])
    for out in outs:
        np.testing.assert_array_equal(np.asarray(out["y"]), want)
    assert svc.stats.cold_compiles == 1


def test_warmup_counts_cold_compiles():
    omp.clear_compile_cache()
    svc = CompileService(mesh1())
    pairs = [_block(t) for t in ("w0", "w1")]
    env_like = pairs[0][1]
    assert svc.warmup([blk for blk, _ in pairs], env_like) == 2
    # a second warmup is free
    assert svc.warmup([blk for blk, _ in pairs], env_like) == 0


def test_straggler_evict_plans_degraded_remesh():
    """A persistent slow device trips the monitor's spike budget and the
    service pre-plans the degraded mesh + fires on_evict exactly once."""
    omp.clear_compile_cache()
    plans = []
    svc = CompileService(
        mesh1(),
        monitor=StragglerMonitor(spike_factor=2.0, spike_budget=3),
        on_evict=plans.append)
    blk, env = _block("slow")
    svc.run(blk, env)                       # warm the key
    # feed a stable baseline, then a sustained spike
    for _ in range(20):
        svc._observe(0.010)
    assert svc.remesh_plan is None
    for _ in range(10):
        svc._observe(0.200)
    assert svc.remesh_plan is not None
    assert svc.stats.evictions == 1 and plans == [svc.remesh_plan]
    h = svc.health()
    assert h["degraded"] is True
    want_n = max(1, mesh1().devices.size - 1)
    assert int(np.prod(h["remesh_plan"]["new_shape"])) <= want_n


def test_straggler_escalation_rebalances_before_evicting():
    """With operator-supplied device_weights the first spike-budget
    exhaustion recompiles with a straggler-weighted schedule (same
    device count, re-dealt chunks) and resets the budget; only a
    *second* exhaustion falls through to the degraded-mesh plan."""
    omp.clear_compile_cache()
    plans = []
    n_dev = mesh1().devices.size
    svc = CompileService(
        mesh1(),
        monitor=StragglerMonitor(spike_factor=2.0, spike_budget=3),
        on_evict=plans.append,
        device_weights=[2.0] + [1.0] * (n_dev - 1))
    blk, env = _block("esc")
    ref = blk(env)
    out = svc.run(blk, env)
    np.testing.assert_array_equal(np.asarray(out["y"]), np.asarray(ref["y"]))
    for _ in range(20):
        svc._observe(0.010)
    for _ in range(10):
        svc._observe(0.200)
    # first exhaustion: weighted recompile, not eviction
    assert svc.stats.rebalances == 1 and svc.stats.evictions == 0
    assert svc.remesh_plan is None and plans == []
    h = svc.health()
    assert h["rebalanced"] is True and h["degraded"] is False
    assert svc.options.chunk_weights is not None
    # the weighted options still serve correct results (new structural
    # key -> one more cold compile, then warm)
    out2 = svc.run(blk, env)
    np.testing.assert_array_equal(np.asarray(out2["y"]), np.asarray(ref["y"]))
    # straggler persists through the rebalanced schedule (spikes big
    # enough to clear the EWMA adapted during round one): now evict
    for _ in range(10):
        svc._observe(2.0)
    assert svc.stats.evictions == 1 and svc.remesh_plan is not None
    assert plans == [svc.remesh_plan]


def test_no_device_weights_goes_straight_to_degraded():
    omp.clear_compile_cache()
    svc = CompileService(
        mesh1(),
        monitor=StragglerMonitor(spike_factor=2.0, spike_budget=3))
    for _ in range(20):
        svc._observe(0.010)
    for _ in range(10):
        svc._observe(0.200)
    assert svc.stats.rebalances == 0 and svc.stats.evictions == 1
    assert svc.remesh_plan is not None


def test_suggest_rebalance_prefers_fast_devices():
    svc = CompileService(mesh1())
    owners = svc.suggest_rebalance(8, [1.0, 3.0])
    assert len(owners) == 8
    assert owners.count(1) > owners.count(0)
