"""Resilient execution: retry, output validation, degraded-mesh
recovery — and the differential harness pinning that a recovered run
reproduces the healthy one for every program family.

The recovery invariant: chunk-cyclic layouts make the device count an
implementation detail, so recompiling the same program on the shrunk
mesh is semantically a no-op.  Non-reduce outputs bit-match the healthy
run; reductions regroup their per-device partial folds and match to
float tolerance.
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro import omp
from repro.compat import make_mesh
from repro.runtime.fault_injection import DeviceLossError, FaultPlan, FaultSpec, inject
from repro.runtime.resilient import (
    CorruptOutputError, ResilientExecutor, RetryPolicy)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _case(n_dev=1):
    n = 13

    @omp.parallel_for(stop=n, name="rex_map", schedule=omp.dynamic(3))
    def prog(i, env):
        return {"y": omp.at(i, env["x"][i] * 3.0 - 1.0)}

    env = {"x": jnp.arange(n, dtype=jnp.float32),
           "y": jnp.zeros(n, jnp.float32)}
    mesh = make_mesh((n_dev,), ("data",))
    return omp.compile(prog, mesh, env_like=env), env, prog(env)


def test_policy_validation():
    with pytest.raises(ValueError):
        RetryPolicy(max_retries=-1)
    with pytest.raises(ValueError):
        RetryPolicy(backoff_s=-0.1)
    with pytest.raises(ValueError):
        RetryPolicy(backoff_factor=0.5)


def test_retry_absorbs_transient_faults():
    compiled, env, ref = _case()
    plan = FaultPlan((FaultSpec(call=0), FaultSpec(call=1)))
    rex = ResilientExecutor(compiled, policy=RetryPolicy(max_retries=2))
    with inject(plan):
        out = rex.run(env)
    np.testing.assert_array_equal(np.asarray(out["y"]), np.asarray(ref["y"]))
    assert rex.stats["retries"] == 2
    assert not rex.degraded


def test_validation_flags_nan_outputs():
    compiled, env, _ = _case()
    rex = ResilientExecutor(compiled, policy=RetryPolicy(
        max_retries=1, validate_outputs=True))
    plan = FaultPlan(tuple(FaultSpec(call=k, kind="nan") for k in range(9)))
    with inject(plan):
        # every attempt (incl. single-device "recovery") returns NaN —
        # the executor must surface the corruption, not the poison
        with pytest.raises(CorruptOutputError):
            rex.run(env)
    assert rex.stats["validation_failures"] >= 2


def test_validation_off_passes_poison_through():
    compiled, env, _ = _case()
    rex = ResilientExecutor(compiled, policy=RetryPolicy(
        max_retries=0, validate_outputs=False))
    plan = FaultPlan((FaultSpec(call=0, kind="nan"),))
    with inject(plan):
        out = rex.run(env)
    assert not bool(jnp.all(jnp.isfinite(out["y"])))
    assert rex.stats["validation_failures"] == 0


def test_backoff_schedule_is_deterministic():
    pol = RetryPolicy(max_retries=3, backoff_s=0.01, jitter_s=0.005, seed=7)
    compiled, env, _ = _case()
    a = ResilientExecutor(compiled, policy=pol)
    b = ResilientExecutor(compiled, policy=pol)
    assert [a._rng.uniform(0, 1) for _ in range(4)] \
        == [b._rng.uniform(0, 1) for _ in range(4)]


def run_recovery_sweep() -> None:
    """Subprocess entry (8 virtual devices): for every rank-1 and
    rank-2 family — injected persistent device loss, degraded-mesh
    recompile, recovered output vs healthy vs reference."""
    from tests.test_differential import FAMILIES, FAMILIES2, make_case, make_case2

    def red_keys(prog):
        stages = getattr(prog, "stages", None)
        loops = prog.loops if stages is not None else (prog,)
        keys = set()
        for lp in loops:
            keys |= set(getattr(lp, "reduction", {}) or {})
        return keys

    def check(prog, env, mesh, tag):
        ref = prog(env)
        compiled = omp.compile(prog, mesh, env_like=env)
        healthy = compiled.run(env)
        plan = FaultPlan(tuple(
            FaultSpec(call=k, kind="device_loss", rank=2) for k in range(3)))
        rex = ResilientExecutor(compiled, policy=RetryPolicy(max_retries=2))
        with inject(plan):
            recovered = rex.run(env)
        assert rex.degraded and rex.stats["recoveries"] == 1, (tag, rex.stats)
        reds = red_keys(prog)
        for k in ref:
            h, r, g = (np.asarray(healthy[k]), np.asarray(recovered[k]),
                       np.asarray(ref[k]))
            if k in reds:
                np.testing.assert_allclose(r, g, rtol=1e-5, atol=1e-6,
                                           err_msg=f"{tag} key={k!r}")
            else:
                np.testing.assert_array_equal(r, g,
                                              err_msg=f"{tag} key={k!r}")
                np.testing.assert_array_equal(r, h,
                                              err_msg=f"{tag} key={k!r}")

    mesh = make_mesh((8,), ("data",))
    for fi, fam in enumerate(FAMILIES):
        prog, env, fam = make_case(9100 + fi, family=fam)
        check(prog, env, mesh, f"r1:{fam}")
    print("recovered1:", ",".join(FAMILIES))

    mesh2 = make_mesh((4, 2), ("i", "j"))
    for fj, fam in enumerate(FAMILIES2):
        prog, env, fam = make_case2(9200 + fj, family=fam)
        check(prog, env, mesh2, f"r2:{fam}")
    print("recovered2:", ",".join(FAMILIES2))
    print("OKRECOVERY")


def test_degraded_recovery_differential(multidevice):
    """8 -> 7 devices (rank-1) and (4,2) -> 7 (rank-2): every family
    recovers onto the shrunk mesh and reproduces the healthy run."""
    out = multidevice(f"""
        import sys
        sys.path.insert(0, {REPO!r})
        from tests.test_resilient import run_recovery_sweep
        run_recovery_sweep()
    """, n_devices=8)
    assert "OKRECOVERY" in out
    assert "recovered1:" in out and "recovered2:" in out


def run_sticky_degraded() -> None:
    compiled, env, ref = _case(n_dev=8)
    healthy = compiled.run(env)
    np.testing.assert_array_equal(np.asarray(healthy["y"]),
                                  np.asarray(ref["y"]))
    seen = []
    rex = ResilientExecutor(
        compiled, policy=RetryPolicy(max_retries=1),
        on_recover=lambda plan: seen.append(plan))
    plan = FaultPlan(tuple(FaultSpec(call=k) for k in range(2)))
    with inject(plan):
        out = rex.run(env)
    np.testing.assert_array_equal(np.asarray(out["y"]), np.asarray(ref["y"]))
    assert rex.degraded and len(seen) == 1
    assert seen[0].new_shape[0] * seen[0].new_shape[1] == 7
    out2 = rex.run(env)                 # serves from the shrunk mesh
    np.testing.assert_array_equal(np.asarray(out2["y"]), np.asarray(ref["y"]))
    rex.reset()
    assert not rex.degraded
    out3 = rex.run(env)                 # healed: original artifact again
    np.testing.assert_array_equal(np.asarray(out3["y"]), np.asarray(ref["y"]))
    print("OKSTICKY")


def test_sticky_degraded_and_reset(multidevice):
    out = multidevice(f"""
        import sys
        sys.path.insert(0, {REPO!r})
        from tests.test_resilient import run_sticky_degraded
        run_sticky_degraded()
    """, n_devices=8)
    assert "OKSTICKY" in out
