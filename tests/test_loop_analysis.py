"""Loop Analysis (paper §3.1.2): canonicalisation + rejection rules."""
import pytest

from repro.core.loop import LoopNotCanonical, analyze_loop


@pytest.mark.parametrize("start,stop,step,trip", [
    (0, 10, 1, 10),
    (0, 10, 3, 4),
    (3, 40, 2, 19),
    (10, 0, -1, 10),
    (10, 0, -3, 4),
    (5, 5, 1, 0),
    (7, 3, 2, 0),          # empty forward
    (0, 1, 100, 1),
])
def test_trip_counts(start, stop, step, trip):
    info = analyze_loop(start, stop, step)
    assert info.trip_count == trip
    # iteration_to_index covers exactly the python range
    assert [info.iteration_to_index(k) for k in range(trip)] == \
        list(range(start, stop, step))


def test_zero_step_rejected():
    with pytest.raises(LoopNotCanonical):
        analyze_loop(0, 10, 0)


@pytest.mark.parametrize("bad", [(0.5, 10, 1), (0, "n", 1), (0, 10, None)])
def test_non_static_bounds_rejected(bad):
    with pytest.raises(LoopNotCanonical):
        analyze_loop(*bad)
