"""Data pipeline: determinism, shard independence, restart replay."""
import jax.numpy as jnp
import numpy as np

from repro.data import SyntheticLM, make_batch_iterator


def test_deterministic_across_iterators():
    it1 = make_batch_iterator(vocab_size=128, batch=8, seq_len=16, seed=3)
    it2 = make_batch_iterator(vocab_size=128, batch=8, seq_len=16, seed=3)
    for _ in range(3):
        a, b = next(it1), next(it2)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))


def test_restart_replays_from_step():
    it = make_batch_iterator(vocab_size=128, batch=8, seq_len=16, seed=3)
    batches = [next(it) for _ in range(5)]
    it_resume = make_batch_iterator(vocab_size=128, batch=8, seq_len=16,
                                    seed=3, start_step=3)
    np.testing.assert_array_equal(np.asarray(batches[3]["tokens"]),
                                  np.asarray(next(it_resume)["tokens"]))


def test_shards_differ_and_partition_batch():
    its = [make_batch_iterator(vocab_size=128, batch=8, seq_len=16,
                               seed=0, shard=s, num_shards=4)
           for s in range(4)]
    batches = [next(it) for it in its]
    assert all(b["tokens"].shape == (2, 16) for b in batches)
    flat = [np.asarray(b["tokens"]) for b in batches]
    assert not np.array_equal(flat[0], flat[1])


def test_tokens_in_vocab_and_learnable_structure():
    dist = SyntheticLM(vocab_size=256, seed=0)
    import jax
    toks = dist.sample(jax.random.PRNGKey(0), 8, 256)
    assert int(jnp.min(toks)) >= 0 and int(jnp.max(toks)) < 256
    # markov structure: conditional entropy < unigram entropy
    t = np.asarray(toks).reshape(-1)
    # coarse states (band mapping)
    s = t // dist._band
    uni = np.bincount(s, minlength=dist.n_states) + 1e-9
    uni = uni / uni.sum()
    h_uni = -(uni * np.log(uni)).sum()
    pair = np.zeros((dist.n_states, dist.n_states)) + 1e-9
    st = np.asarray(s)
    for a, b in zip(st[:-1], st[1:]):
        pair[a, b] += 1
    cond = pair / pair.sum(1, keepdims=True)
    h_cond = -(pair / pair.sum() * np.log(cond)).sum()
    assert h_cond < h_uni - 0.1, (h_cond, h_uni)


def test_embeds_mode():
    it = make_batch_iterator(vocab_size=128, batch=4, seq_len=8, seed=1,
                             embed_dim=32)
    b = next(it)
    assert b["embeds"].shape == (4, 8, 32)
    assert "tokens" not in b
