"""HLO analyzer: dot-flops exactness, collective accounting, trip counts."""
import jax
import jax.numpy as jnp

from repro.launch import hlo_analysis as ha


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_dot_flops_exact_on_matmul():
    m, k, n = 128, 256, 64
    co = _compile(lambda a, b: a @ b,
                  jax.ShapeDtypeStruct((m, k), jnp.float32),
                  jax.ShapeDtypeStruct((k, n), jnp.float32))
    rep = ha.analyze_hlo(co.as_text(), num_devices=1)
    assert rep.dot_flops == 2 * m * k * n


def test_scan_multiplies_dot_flops():
    m = 64
    length = 7

    def scanned(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=length)
        return y

    co = _compile(scanned, jax.ShapeDtypeStruct((m, m), jnp.float32))
    rep = ha.analyze_hlo(co.as_text(), num_devices=1)
    # cost_analysis counts the body once; our parser multiplies by 7
    assert rep.dot_flops == length * 2 * m * m * m
    assert length in rep.trip_counts.values()


def test_collective_bytes_detected(multidevice):
    out = multidevice("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.launch import hlo_analysis as ha

        mesh = make_mesh((8,), ("d",))

        def f(x):
            return jax.lax.psum(x, "d")

        sm = shard_map(f, mesh=mesh, in_specs=P("d"), out_specs=P())
        co = jax.jit(sm).lower(
            jax.ShapeDtypeStruct((1024,), jnp.float32)).compile()
        rep = ha.analyze_hlo(co.as_text(), num_devices=8)
        kinds = rep.by_kind()
        assert "all-reduce" in kinds, kinds
        # per-device payload 128 floats = 512B; wire = 2*S*(g-1)/g
        expect = 2 * 512 * 7 / 8
        assert abs(kinds["all-reduce"] - expect) < 1e-6, kinds
        print("OKCOLL")
    """)
    assert "OKCOLL" in out


def test_roofline_terms_math():
    t = ha.roofline_terms(hlo_flops=197e12, hlo_bytes=819e9,
                          wire_bytes=50e9)
    assert abs(t.compute_s - 1.0) < 1e-9
    assert abs(t.memory_s - 1.0) < 1e-9
    assert abs(t.collective_s - 1.0) < 1e-9
    assert t.roofline_fraction == 1.0
    t2 = ha.roofline_terms(hlo_flops=197e12, hlo_bytes=819e9 * 2,
                           wire_bytes=0)
    assert t2.dominant == "memory"
    assert abs(t2.roofline_fraction - 0.5) < 1e-9
