"""Checkpointing: roundtrip, async, atomicity, GC, restart discovery."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer


def _state(v=0.0):
    return {"params": {"w": jnp.full((4, 4), v), "b": jnp.zeros(4)},
            "step": jnp.int32(v)}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    st = _state(3.0)
    ck.save(10, st)
    got = ck.restore(10, _state())
    np.testing.assert_allclose(np.asarray(got["params"]["w"]),
                               np.asarray(st["params"]["w"]))
    assert ck.list_steps() == [10]


def test_async_save_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path))
    for s in (5, 10, 15):
        ck.save_async(s, _state(float(s)))
    ck.wait()
    step, got = ck.restore_latest(_state())
    assert step == 15
    assert float(got["params"]["w"][0, 0]) == 15.0


def test_gc_keeps_last_k(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in range(5):
        ck.save(s, _state(float(s)))
    assert ck.list_steps() == [3, 4]


def test_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        ck.restore(1, {"w": jnp.zeros((8,))})


def test_no_partial_checkpoint_visible(tmp_path):
    """Atomicity: a tmp dir never counts as a checkpoint."""
    ck = Checkpointer(str(tmp_path))
    os.makedirs(os.path.join(str(tmp_path), "step_00000007.tmp0"))
    assert ck.list_steps() == []
    assert ck.restore_latest(_state()) is None
