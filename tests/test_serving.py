"""Serving engine: continuous batching correctness."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import build_model
from repro.serving import Request, ServeEngine


def _setup():
    cfg = smoke_config("gemma3-1b")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _greedy_reference(model, params, prompt, n_new):
    """Single-sequence greedy decode via prefill + decode_step."""
    cache = model.init_cache(1, 128, dtype=jnp.float32)
    logits, cache = model.prefill(
        params, {"tokens": jnp.asarray(prompt, jnp.int32)[None]}, cache,
        compute_dtype=jnp.float32)
    out = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([out[-1]], jnp.int32),
            jnp.asarray([pos], jnp.int32), compute_dtype=jnp.float32)
        out.append(int(jnp.argmax(logits[0])))
        pos += 1
    return out


def test_engine_matches_reference_greedy():
    cfg, model, params = _setup()
    prompts = [[1, 2, 3, 4], [7, 8, 9], [5, 6, 5, 6, 5]]
    n_new = 6
    engine = ServeEngine(model, params, n_slots=2, cache_len=128,
                         compute_dtype=jnp.float32)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=n_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained()
    for r in reqs:
        assert r.done
        want = _greedy_reference(model, params, r.prompt, n_new)
        assert r.output == want, (r.rid, r.output, want)


def test_continuous_batching_reuses_slots():
    cfg, model, params = _setup()
    engine = ServeEngine(model, params, n_slots=2, cache_len=64,
                         compute_dtype=jnp.float32)
    reqs = [Request(rid=i, prompt=[i + 1, i + 2], max_new_tokens=3)
            for i in range(5)]
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained()
    assert all(r.done for r in reqs)
    assert all(len(r.output) == 3 for r in reqs)
