"""Serving engine: continuous batching correctness."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import build_model
from repro.serving import Request, ServeEngine


_SETUP = None


def _setup():
    global _SETUP
    if _SETUP is None:
        cfg = smoke_config("gemma3-1b")
        model = build_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0))
        _SETUP = (cfg, model, params)
    return _SETUP


def _greedy_reference(model, params, prompt, n_new):
    """Single-sequence greedy decode via prefill + decode_step."""
    cache = model.init_cache(1, 128, dtype=jnp.float32)
    logits, cache = model.prefill(
        params, {"tokens": jnp.asarray(prompt, jnp.int32)[None]}, cache,
        compute_dtype=jnp.float32)
    out = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([out[-1]], jnp.int32),
            jnp.asarray([pos], jnp.int32), compute_dtype=jnp.float32)
        out.append(int(jnp.argmax(logits[0])))
        pos += 1
    return out


def test_engine_matches_reference_greedy():
    cfg, model, params = _setup()
    prompts = [[1, 2, 3, 4], [7, 8, 9], [5, 6, 5, 6, 5]]
    n_new = 6
    engine = ServeEngine(model, params, n_slots=2, cache_len=128,
                         compute_dtype=jnp.float32)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=n_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained()
    for r in reqs:
        assert r.done
        want = _greedy_reference(model, params, r.prompt, n_new)
        assert r.output == want, (r.rid, r.output, want)


def test_run_until_drained_returns_completed_requests():
    """Regression: the ``done`` list was never appended — callers
    always got ``[]`` back even though every request finished."""
    cfg, model, params = _setup()
    engine = ServeEngine(model, params, n_slots=2, cache_len=64,
                         compute_dtype=jnp.float32)
    reqs = [Request(rid=i, prompt=[i + 1, i + 2], max_new_tokens=2)
            for i in range(3)]
    for r in reqs:
        engine.submit(r)
    done = engine.run_until_drained()
    assert sorted(r.rid for r in done) == [0, 1, 2]
    assert all(r.done for r in done)
    # completion order, not submission order, and no duplicates
    assert len(done) == len(set(id(r) for r in done)) == 3
    # a second drain has nothing left to return
    assert engine.run_until_drained() == []


def test_prefill_completion_gap_max_new_tokens_one():
    """Regression: a request satisfied at prefill (max_new_tokens=1)
    was never marked done at admission — it burned a decode tick in a
    dead slot and overran its token budget by one."""
    cfg, model, params = _setup()
    engine = ServeEngine(model, params, n_slots=2, cache_len=64,
                         compute_dtype=jnp.float32)
    reqs = [Request(rid=i, prompt=[1 + i, 2 + i, 3 + i], max_new_tokens=1)
            for i in range(3)]
    for r in reqs:
        engine.submit(r)
    done = engine.run_until_drained()
    assert sorted(r.rid for r in done) == [0, 1, 2]
    for r in reqs:
        assert r.done
        assert len(r.output) == 1, (r.rid, r.output)
        want = _greedy_reference(model, params, r.prompt, 1)
        assert r.output == want


def test_prefill_eos_completes_at_admission():
    """A prompt whose prefill token IS eos_id must complete without
    occupying a slot (the tick() done-check, applied at admission)."""
    cfg, model, params = _setup()
    prompt = [5, 6, 7]
    first = _greedy_reference(model, params, prompt, 1)[0]
    engine = ServeEngine(model, params, n_slots=1, cache_len=64,
                         eos_id=first, compute_dtype=jnp.float32)
    eos_req = Request(rid=0, prompt=prompt, max_new_tokens=8)
    engine.submit(eos_req)
    engine._admit()
    assert eos_req.done and eos_req.output == [first]
    # the slot stayed free for the next request
    assert engine.slot_req == [None]
    assert engine.take_finished() == [eos_req]


def test_continuous_batching_reuses_slots():
    cfg, model, params = _setup()
    engine = ServeEngine(model, params, n_slots=2, cache_len=64,
                         compute_dtype=jnp.float32)
    reqs = [Request(rid=i, prompt=[i + 1, i + 2], max_new_tokens=3)
            for i in range(5)]
    for r in reqs:
        engine.submit(r)
    engine.run_until_drained()
    assert all(r.done for r in reqs)
    assert all(len(r.output) == 3 for r in reqs)
