"""Optimizers, clipping, schedules and gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    compress_int8,
    cosine_warmup,
    decompress_int8,
    global_norm,
    make_optimizer,
)
from repro.optim.api import opt_state_axes
from repro.optim.grad import (
    init_error_feedback,
    tree_compress_int8,
    tree_decompress_int8,
)


def _quad_problem():
    """min 0.5*||x - t||^2: gradient = x - t."""
    t = {"a": jnp.asarray([1.0, -2.0, 3.0]),
         "b": jnp.ones((4, 5)) * 0.5}
    x = jax.tree_util.tree_map(jnp.zeros_like, t)
    return x, t


def test_adamw_converges_on_quadratic():
    x, t = _quad_problem()
    state = adamw_init(x)
    for _ in range(200):
        g = jax.tree_util.tree_map(lambda a, b: a - b, x, t)
        x, state = adamw_update(g, state, x, lr=0.05, weight_decay=0.0)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree_util.tree_leaves(x),
                              jax.tree_util.tree_leaves(t)))
    assert err < 0.05, err


def test_adafactor_converges_on_quadratic():
    x, t = _quad_problem()
    state = adafactor_init(x)
    for _ in range(300):
        g = jax.tree_util.tree_map(lambda a, b: a - b, x, t)
        x, state = adafactor_update(g, state, x, lr=0.05)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree_util.tree_leaves(x),
                              jax.tree_util.tree_leaves(t)))
    assert err < 0.1, err


def test_adafactor_state_is_factored():
    p = {"w": jnp.zeros((64, 128)), "b": jnp.zeros((64,))}
    st = adafactor_init(p)
    assert st["per_param"]["w"]["vr"].shape == (64,)
    assert st["per_param"]["w"]["vc"].shape == (128,)
    assert st["per_param"]["b"]["v"].shape == (64,)
    # memory: factored state is O(r+c), not O(r*c)
    n = sum(x.size for x in jax.tree_util.tree_leaves(st["per_param"]))
    assert n == 64 + 128 + 64


def test_opt_state_axes_structure_matches_init():
    p = {"w": jnp.zeros((8, 16)), "b": jnp.zeros((8,))}
    shapes = jax.eval_shape(lambda: p)
    for name in ("adamw", "adafactor"):
        opt = make_optimizer(name)
        st = jax.eval_shape(opt.init, shapes)
        axes = opt_state_axes(name, shapes,
                              {"w": ("d_ff", "d_model"), "b": ("d_ff",)})
        # same tree structure (ignoring leaf types)
        s1 = jax.tree_util.tree_structure(
            jax.tree_util.tree_map(lambda _: 0, st))
        s2 = jax.tree_util.tree_structure(
            jax.tree_util.tree_map(
                lambda _: 0, axes,
                is_leaf=lambda x: isinstance(x, tuple)))
        assert s1 == s2, name


def test_clip_by_global_norm():
    tree = {"a": jnp.asarray([3.0, 4.0])}          # norm 5
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    # below threshold: untouched
    clipped2, _ = clip_by_global_norm(tree, 10.0)
    np.testing.assert_allclose(np.asarray(clipped2["a"]),
                               np.asarray(tree["a"]))


def test_cosine_warmup_shape():
    lr0 = cosine_warmup(0, base_lr=1e-3, warmup_steps=10, total_steps=100)
    lr_w = cosine_warmup(10, base_lr=1e-3, warmup_steps=10, total_steps=100)
    lr_end = cosine_warmup(100, base_lr=1e-3, warmup_steps=10,
                           total_steps=100)
    assert float(lr0) == 0.0
    assert abs(float(lr_w) - 1e-3) < 1e-9
    assert float(lr_end) < 2e-4


def test_int8_compression_roundtrip_and_error_feedback():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(128,)).astype(np.float32))
    q, scale, err = compress_int8(g, jnp.zeros_like(g))
    deq = decompress_int8(q, scale)
    # quantisation error bounded by scale/2 per element
    assert float(jnp.max(jnp.abs(deq - g))) <= float(scale) * 0.5 + 1e-7
    # error feedback: accumulated error corrects over repeated steps
    total_true = jnp.zeros_like(g)
    total_sent = jnp.zeros_like(g)
    e = jnp.zeros_like(g)
    for _ in range(50):
        total_true = total_true + g
        q, s, e = compress_int8(g, e)
        total_sent = total_sent + decompress_int8(q, s)
    rel = float(jnp.linalg.norm(total_sent - total_true)
                / jnp.linalg.norm(total_true))
    assert rel < 0.01, rel


def test_tree_compression():
    tree = {"a": jnp.asarray([1.0, -1.0]), "b": jnp.ones((3, 3))}
    errs = init_error_feedback(tree)
    qs, scales, errs = tree_compress_int8(tree, errs)
    deq = tree_decompress_int8(qs, scales)
    for k in tree:
        np.testing.assert_allclose(np.asarray(deq[k]),
                                   np.asarray(tree[k]), atol=0.02)


def test_compressed_allreduce_matches_mean(multidevice):
    out = multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.optim import compressed_allreduce_tree
        from repro.optim.grad import init_error_feedback

        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(8, 333)).astype(np.float32))

        def step(x_local):
            g = {"w": x_local[0] * 2.0, "b": x_local[0][:5] - 1.0}
            e = init_error_feedback(g)
            mean, _ = compressed_allreduce_tree(
                g, e, axis="data", num_devices=8)
            return mean["w"][None], mean["b"][None]

        w, b = jax.jit(shard_map(
            step, mesh=mesh, in_specs=P("data"),
            out_specs=(P("data"), P("data"))))(x)
        want_w = np.mean(np.asarray(x) * 2.0, axis=0)
        want_b = np.mean(np.asarray(x)[:, :5] - 1.0, axis=0)
        scale = np.abs(want_w).max()
        for d in range(8):
            assert np.allclose(np.asarray(w)[d], want_w,
                               atol=0.03 * scale), d
            assert np.allclose(np.asarray(b)[d], want_b, atol=0.05), d
        # HLO moves int8, not fp32: wire must be ~4x below 2*S*(P-1)/P
        from repro.launch import hlo_analysis as ha
        co = jax.jit(shard_map(
            step, mesh=mesh, in_specs=P("data"),
            out_specs=(P("data"), P("data")))).lower(
            jax.ShapeDtypeStruct((8, 333), jnp.float32)).compile()
        rep = ha.analyze_hlo(co.as_text(), num_devices=8)
        fp32_allreduce = 2 * (333 + 5) * 4 * 7 / 8
        assert rep.total_wire_bytes < fp32_allreduce, (
            rep.total_wire_bytes, fp32_allreduce, rep.by_kind())
        print("OKCOMP", rep.total_wire_bytes, fp32_allreduce)
    """)
    assert "OKCOMP" in out


def test_adafactor_streamed_matches_unstreamed():
    """lax.map-streamed update (stacked >=3D params) must be numerically
    identical to the block update."""
    rng = np.random.default_rng(3)
    p = {"stack": jnp.asarray(rng.normal(size=(12, 6, 10))
                              .astype(np.float32)),
         "mat": jnp.asarray(rng.normal(size=(6, 10)).astype(np.float32))}
    g = jax.tree_util.tree_map(
        lambda t: jnp.asarray(rng.normal(size=t.shape)
                              .astype(np.float32)), p)
    s1 = adafactor_init(p)
    s2 = adafactor_init(p)
    p1, s1 = adafactor_update(g, s1, p, lr=0.1, stream_leading=8)
    p2, s2 = adafactor_update(g, s2, p, lr=0.1, stream_leading=0)
    for k in p:
        np.testing.assert_allclose(np.asarray(p1[k]), np.asarray(p2[k]),
                                   rtol=1e-6, atol=1e-6)
