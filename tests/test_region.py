"""ParallelRegion: whole-program transformation with inter-loop residency.

The oracle is always the per-stage shared-memory reference executed in
sequence (``region(env)``); the fused ``region_to_mpi`` must match it on
every chain shape: compatible-layout elision, forced reshards
(whole-array / stencil reads), partial-cover aligned chains, serial
glue, reduction-carrying chains, and both staged baselines.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import omp
from repro.compat import make_mesh


def mesh1():
    return make_mesh((1,), ("data",))


def _close(a, b, tol=1e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=tol, atol=tol)


def _chain3(n=48):
    @omp.parallel_for(stop=n, name="l1")
    def l1(i, env):
        return {"tmp": omp.at(i, env["x"][i] * 2.0)}

    @omp.parallel_for(stop=n, name="l2")
    def l2(i, env):
        return {"y": omp.at(i, env["tmp"][i] + 1.0)}

    @omp.parallel_for(stop=n, reduction={"tot": "+"}, name="l3")
    def l3(i, env):
        return {"tot": omp.red(env["y"][i])}

    env = {"x": jnp.arange(n, dtype=jnp.float32), "tmp": jnp.zeros(n),
           "y": jnp.zeros(n), "tot": jnp.float32(0)}
    return omp.region(l1, l2, l3, name="chain3"), env


def test_region_reference_matches_sequential_loops():
    reg, env = _chain3()
    seq = env
    for stage in reg.stages:
        seq = stage(seq)
    ref = reg(env)
    for k in ref:
        _close(ref[k], seq[k])


def test_region_elides_compatible_layouts():
    reg, env = _chain3()
    ref = reg(env)
    dist = omp.region_to_mpi(reg, mesh1(), env_like=env)
    out = dist(env)
    for k in ref:
        _close(out[k], ref[k])
    # tmp: l1 identity-out -> l2 identity-in; y: l2 -> l3 reduce loop
    assert dist.plan.n_elided == 2, dist.plan.log
    assert dist.plan.n_reshards == 0, dist.plan.log
    feeds = {s.name: s.feeds for s in dist.plan.stages}
    assert feeds["l2"]["tmp"] == "resident"
    assert feeds["l3"]["y"] == "resident"


def test_region_forced_reshard_whole_read():
    n = 40

    @omp.parallel_for(stop=n, name="w1")
    def w1(i, env):
        return {"tmp": omp.at(i, env["x"][i] * 3.0)}

    @omp.parallel_for(stop=n, name="w2")
    def w2(i, env):
        # whole-array read of tmp: the slab cannot be consumed in place
        return {"y": omp.at(i, env["tmp"][i] + jnp.sum(env["tmp"]))}

    reg = omp.region(w1, w2, name="whole_read")
    env = {"x": jnp.arange(n, dtype=jnp.float32), "tmp": jnp.zeros(n),
           "y": jnp.zeros(n)}
    ref = reg(env)
    dist = omp.region_to_mpi(reg, mesh1(), env_like=env)
    out = dist(env)
    for k in ref:
        _close(out[k], ref[k], tol=1e-4)
    assert dist.plan.n_reshards == 1, dist.plan.log
    assert dist.plan.n_elided == 0, dist.plan.log


def test_region_forced_reshard_stencil_read():
    n = 33

    @omp.parallel_for(stop=n, name="s1")
    def s1(i, env):
        return {"u": omp.at(i, env["x"][i] + 1.0)}

    @omp.parallel_for(start=1, stop=n - 1, name="s2")
    def s2(i, env):
        v = (env["u"][i - 1] + env["u"][i] + env["u"][i + 1]) / 3.0
        return {"y": omp.at(i, v)}

    reg = omp.region(s1, s2, name="stencil_chain")
    env = {"x": jnp.arange(n, dtype=jnp.float32), "u": jnp.zeros(n),
           "y": jnp.zeros(n)}
    ref = reg(env)
    dist = omp.region_to_mpi(reg, mesh1(), env_like=env)
    out = dist(env)
    for k in ref:
        _close(out[k], ref[k])
    # different trip counts + stencil window -> one minimal reshard
    assert dist.plan.n_reshards == 1, dist.plan.log


def test_region_partial_cover_aligned_chain():
    """Interior writes u[i+1] chained into aligned reads u[i+1] stay
    resident (the generalised unit-stride residency rule)."""
    m = 41

    @omp.parallel_for(stop=m - 2, name="p1")
    def p1(i, env):
        return {"u": omp.at(i + 1, env["a"][i + 1] * 3.0)}

    @omp.parallel_for(stop=m - 2, name="p2")
    def p2(i, env):
        return {"v": omp.at(i + 1, env["u"][i + 1] - 1.0)}

    reg = omp.region(p1, p2, name="partial_chain")
    env = {"a": jnp.arange(m, dtype=jnp.float32),
           "u": -jnp.ones(m, jnp.float32), "v": -jnp.ones(m, jnp.float32)}
    ref = reg(env)
    dist = omp.region_to_mpi(reg, mesh1(), env_like=env)
    out = dist(env)
    for k in ref:
        _close(out[k], ref[k])
    assert dist.plan.n_elided == 1, dist.plan.log
    # untouched boundary rows come from the prior copy
    assert float(out["u"][0]) == -1.0 and float(out["u"][m - 1]) == -1.0


def test_region_serial_glue_stage():
    n = 24

    @omp.parallel_for(stop=n, name="g1")
    def g1(i, env):
        return {"tmp": omp.at(i, env["x"][i] * 2.0)}

    glue = omp.serial(lambda env: {"bias": env["bias"] * 0.5},
                      reads=("bias",), name="halve")

    @omp.parallel_for(stop=n, name="g2")
    def g2(i, env):
        return {"y": omp.at(i, env["tmp"][i] + env["bias"][0])}

    reg = omp.region(g1, glue, g2, name="glued")
    env = {"x": jnp.arange(n, dtype=jnp.float32), "tmp": jnp.zeros(n),
           "y": jnp.zeros(n), "bias": jnp.full((1,), 3.0, jnp.float32)}
    ref = reg(env)
    dist = omp.region_to_mpi(reg, mesh1(), env_like=env)
    out = dist(env)
    for k in ref:
        _close(out[k], ref[k])
    # glue only reads 'bias' (replicated): tmp stays resident across it
    assert dist.plan.n_elided == 1, dist.plan.log
    assert dist.plan.n_reshards == 0, dist.plan.log


def test_region_reduction_carrying_chain():
    """Reductions folding a resident buffer, plus the env-merge rule."""
    n = 30

    @omp.parallel_for(stop=n, name="r1")
    def r1(i, env):
        return {"y": omp.at(i, env["x"][i] * env["x"][i])}

    @omp.parallel_for(stop=n, reduction={"s": "+"}, name="r2")
    def r2(i, env):
        return {"s": omp.red(env["y"][i])}

    @omp.parallel_for(stop=n, reduction={"m": "max"}, name="r3")
    def r3(i, env):
        return {"m": omp.red(env["y"][i])}

    reg = omp.region(r1, r2, r3, name="red_chain")
    env = {"x": jnp.arange(n, dtype=jnp.float32), "y": jnp.zeros(n),
           "s": jnp.float32(100.0), "m": jnp.float32(-1.0)}
    ref = reg(env)
    dist = omp.region_to_mpi(reg, mesh1(), env_like=env)
    out = dist(env)
    for k in ref:
        _close(out[k], ref[k], tol=1e-4)
    # y is consumed resident by BOTH reduction loops (no write between)
    assert dist.plan.n_elided == 2, dist.plan.log
    assert float(out["s"]) == pytest.approx(float(ref["s"]), rel=1e-5)


def test_region_scatter_and_put_stages():
    n = 10

    @omp.parallel_for(stop=n, name="c1")
    def c1(i, env):
        return {"z": omp.at(3 * i + 2, env["x"][i])}

    @omp.parallel_for(stop=n, name="c2")
    def c2(i, env):
        return {"w": omp.put(jnp.full((4,), i, jnp.float32))}

    reg = omp.region(c1, c2, name="scatter_put")
    env = {"x": jnp.arange(n, dtype=jnp.float32),
           "z": -jnp.ones(40, jnp.float32), "w": jnp.zeros(4, jnp.float32)}
    ref = reg(env)
    dist = omp.region_to_mpi(reg, mesh1(), env_like=env)
    out = dist(env)
    for k in ref:
        _close(out[k], ref[k])
    assert float(out["w"][0]) == n - 1


def test_region_zero_trip_loop():
    """A stop=0 loop inside a region is a no-op for writes and an
    identity fold for reductions (matches single-block to_mpi)."""
    n = 8

    @omp.parallel_for(stop=0, name="z0")
    def z0(i, env):
        return {"y": omp.at(i, env["x"][i]), "s": omp.red(env["x"][i])}

    @omp.parallel_for(stop=n, name="z1")
    def z1(i, env):
        return {"y": omp.at(i, env["x"][i] + env["s"])}

    z0.reduction = {"s": "+"}
    reg = omp.region(z0, z1, name="zero_trip")
    env = {"x": jnp.arange(n, dtype=jnp.float32), "y": jnp.zeros(n),
           "s": jnp.float32(7.0)}
    ref = reg(env)
    dist = omp.region_to_mpi(reg, mesh1(), env_like=env)
    out = dist(env)
    for k in ref:
        _close(out[k], ref[k])
    assert float(out["s"]) == 7.0


def test_region_staged_fallbacks_match():
    reg, env = _chain3()
    ref = reg(env)
    out = omp.region_to_mpi(reg, mesh1(), fuse=False)(env)
    for k in ref:
        _close(out[k], ref[k])


def test_region_report_mentions_residency():
    reg, env = _chain3()
    dist = omp.region_to_mpi(reg, mesh1(), env_like=env)
    text = dist.report()
    for needle in ("ParallelRegion", "RESIDENT", "residency summary",
                   "stage roster", "chunk-cyclic"):
        assert needle in text, needle


def test_region_rejects_bad_stages():
    with pytest.raises(ValueError):
        omp.region(name="empty")
    with pytest.raises(TypeError):
        omp.region(lambda e: e)
    with pytest.raises(ValueError):
        omp.region(omp.serial(lambda e: {}, name="only_glue"))


def test_region_single_parallel_for_wrapped():
    n = 16

    @omp.parallel_for(stop=n, name="solo")
    def solo(i, env):
        return {"y": omp.at(i, env["x"][i] + 5.0)}

    env = {"x": jnp.arange(n, dtype=jnp.float32), "y": jnp.zeros(n)}
    out = omp.region_to_mpi(solo, mesh1())(env)
    _close(out["y"], solo(env)["y"])


def test_region_eight_devices_and_traffic(multidevice):
    """Real 8-device run: fused region matches the reference and moves
    strictly fewer collective ops + wire bytes than the paper's per-loop
    master/worker staging (the acceptance experiment of EXPERIMENTS.md
    §Perf-C)."""
    out = multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import omp
        from repro.compat import make_mesh
        from repro.launch import hlo_analysis as ha

        mesh = make_mesh((8,), ("data",))
        n = 53

        @omp.parallel_for(stop=n, name="l1")
        def l1(i, env):
            return {"tmp": omp.at(i, env["x"][i] * 2.0)}

        @omp.parallel_for(stop=n, name="l2")
        def l2(i, env):
            return {"y": omp.at(i, env["tmp"][i] + 1.0)}

        @omp.parallel_for(stop=n, reduction={"tot": "+"}, name="l3")
        def l3(i, env):
            return {"tot": omp.red(env["y"][i])}

        reg = omp.region(l1, l2, l3, name="chain")
        env = {"x": jnp.arange(n, dtype=jnp.float32),
               "tmp": jnp.zeros(n), "y": jnp.zeros(n),
               "tot": jnp.float32(0)}
        ref = reg(env)
        dist = omp.region_to_mpi(reg, mesh, env_like=env)
        got = dist(env)
        for k in ref:
            assert np.allclose(np.asarray(got[k]), np.asarray(ref[k]),
                               atol=1e-4), k
        assert dist.plan.n_elided == 2, dist.plan.log

        avals = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                 for k, v in env.items()}

        def cost(fn):
            co = jax.jit(lambda e: fn(e)).lower(avals).compile()
            rep = ha.analyze_hlo(co.as_text(), num_devices=8)
            return (sum(c.multiplier for c in rep.collectives),
                    rep.total_wire_bytes)

        f_ops, f_bytes = cost(dist)
        m_ops, m_bytes = cost(
            omp.region_to_mpi(reg, mesh, lowering="master_worker"))
        assert f_ops < m_ops, (f_ops, m_ops)
        assert f_bytes < m_bytes, (f_bytes, m_bytes)
        print("OKREGION8", f_ops, m_ops, f_bytes, m_bytes)
    """)
    assert "OKREGION8" in out
