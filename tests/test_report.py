"""The report module renders the paper's Tables-2/3-style artifact."""
import jax
import jax.numpy as jnp

from repro import omp
from repro.compat import make_mesh


def test_report_contains_paper_concepts():
    @omp.parallel_for(stop=40, schedule=omp.dynamic(),
                      reduction={"total": "+"})
    def block(i, env):
        v = env["x"][i] * 2.0
        return {"y": omp.at(i, v), "total": omp.red(v)}

    mesh = make_mesh((1,), ("data",))
    env = {"x": jnp.zeros(40), "y": jnp.zeros(40), "total": jnp.float32(0)}
    dist = omp.to_mpi(block, mesh, env_like=env)
    text = dist.report()
    for needle in ("partSize", "IN", "OUT", "REDUCTION", "cyclic",
                   "communication summary", "Context Analysis"):
        assert needle in text, needle


def test_report_master_worker_costs_more():
    @omp.parallel_for(stop=64)
    def block(i, env):
        return {"y": omp.at(i, env["x"][i] + 1.0)}

    env = {"x": jnp.zeros(64), "y": jnp.zeros(64)}
    from repro.core.plan import make_plan
    from repro.core.report import _comm_summary

    p_col = make_plan(block, env, 8, lowering="collective")
    p_mw = make_plan(block, env, 8, lowering="master_worker")

    def total(plan):
        line = _comm_summary(plan)[-1]
        return int(line.split("~")[1].split()[0])

    assert total(p_mw) > total(p_col)
