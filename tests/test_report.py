"""The report module renders the paper's Tables-2/3-style artifact."""
import jax
import jax.numpy as jnp

from repro import omp
from repro.compat import make_mesh


def test_report_contains_paper_concepts():
    @omp.parallel_for(stop=40, schedule=omp.dynamic(),
                      reduction={"total": "+"})
    def block(i, env):
        v = env["x"][i] * 2.0
        return {"y": omp.at(i, v), "total": omp.red(v)}

    mesh = make_mesh((1,), ("data",))
    env = {"x": jnp.zeros(40), "y": jnp.zeros(40), "total": jnp.float32(0)}
    dist = omp.to_mpi(block, mesh, env_like=env)
    text = dist.report()
    for needle in ("partSize", "IN", "OUT", "REDUCTION", "cyclic",
                   "communication summary", "Context Analysis"):
        assert needle in text, needle


def test_report_master_worker_costs_more():
    @omp.parallel_for(stop=64)
    def block(i, env):
        return {"y": omp.at(i, env["x"][i] + 1.0)}

    env = {"x": jnp.zeros(64), "y": jnp.zeros(64)}
    from repro.core.plan import make_plan
    from repro.core.report import _comm_summary

    p_col = make_plan(block, env, 8, lowering="collective")
    p_mw = make_plan(block, env, 8, lowering="master_worker")

    def total(plan):
        line = _comm_summary(plan)[-1]
        return int(line.split("~")[1].split()[0])

    assert total(p_mw) > total(p_col)


def _golden_heat2d():
    n, m, c = 64, 48, 8

    def sweep(src, dst, name):
        @omp.parallel_for(start=(1, 1), stop=(n - 1, m - 1), collapse=2,
                          schedule=omp.static(c), name=name)
        def body(i, j, env):
            v = 0.25 * (env[src][i - 1, j] + env[src][i + 1, j]
                        + env[src][i, j - 1] + env[src][i, j + 1])
            return {dst: omp.at((i, j), v)}
        return body

    reg = omp.region(sweep("a", "b", "s1"), sweep("b", "a", "s2"),
                     name="heat2d_golden")
    env = {"a": jnp.zeros((n, m), jnp.float32),
           "b": jnp.zeros((n, m), jnp.float32)}
    return reg, env


def test_report_2d_region_golden():
    """Golden output for a 2-D boundary plan: the rendered region report
    must name the chosen op, the modeled bytes and the rejected
    alternative — numbers pinned against the comm cost model (64x48
    grid, 8x8 tiles, 2x2 mesh: 32 chunk pairs x [(1+1)*8 + 10*(1+1)]
    cells x 4 B = 6912 B halo vs padded 64x48 x 4 B x 3 = 36864 B
    all-gather)."""
    from repro.core.report import render_region

    reg, env = _golden_heat2d()
    rp = omp.plan_region(reg, env, (2, 2), axis=("i", "j"))
    text = render_region(rp)
    golden_lines = [
        "=== ParallelRegion transformation report: heat2d_golden ===",
        "s1  loop nest t=62x46 chunks=8x8 (8x6 tiles cyclic)",
        "s2: 'b' HALO-EXCHANGED 2-D (shifts ((-1, 1), (-1, 1)), "
        "4 ppermute hop(s), ~6912 B on the wire vs ~36864 B all-gather)",
        "s2 <- 'b': halo (payload ~1728 B/device, wire ~6912 B, hops=4) "
        "[rejected: all_gather~36864 B]",
        "why: row+column neighbor shifts move 6912 B vs 36864 B for the "
        "gather",
        "planned wire total: ~6912 B (all-gather-only baseline: "
        "~36864 B)",
        "residency summary: 0 resident handoff(s) elided, 1 halo "
        "ppermute exchange(s), 0 minimal reshard collective(s) inserted",
        "a: 2-D chunk-cyclic slab rows [1, 63) x cols [1, 47) "
        "(reassembled by layout at exit)",
    ]
    for needle in golden_lines:
        assert needle in text, f"missing golden line: {needle!r}\n---\n{text}"


def test_report_2d_plan_golden():
    """Golden output for a single collapse=2 block plan: per-axis loop
    and chunk lines, per-axis read/write maps and halo windows."""
    from repro.core.plan import make_plan
    from repro.core.report import render_plan

    reg, env = _golden_heat2d()
    plan = make_plan(reg.loops[0], env, (2, 2), axis=("i", "j"),
                     shard_inputs=True)
    text = render_plan(plan)
    for needle in [
        "mesh axes       : ('i', 'j') (2 x 2 compute ranks, "
        "2-D decomposition)",
        "loop axis i     : for i in range(1, 63, 1)  [62 iterations]",
        "chunk axis i    : partSize=8, 8 chunks total (4 per rank), "
        "cyclic chunk q -> rank q % 2",
        "loop axis j     : for j in range(1, 47, 1)  [46 iterations]",
        "read map : x[1*ki+0, 1*kj+1]",
        "write map: x[1*ki+1, 1*kj+1]",
        "halo     : axis0 [0, 2], axis1 [0, 2]",
        "in: 2-D chunk windows 19200 B total (vs 49152 B broadcast)",
        "out: chunk tiles 12288 B total",
    ]:
        assert needle in text, f"missing golden line: {needle!r}\n---\n{text}"
