"""Tensor planner: divisibility-aware first-fit mesh-axis assignment."""
from jax.sharding import PartitionSpec as P

from repro.core import tensor_plan as tp


def _plan(zero3=False):
    return tp.make_train_plan(("data", "model"), (16, 16), zero3=zero3)


def test_tp_shards_heads_and_ff():
    plan = _plan()
    assert plan.spec((8192, 64, 128),
                     (tp.D_MODEL, tp.HEADS, tp.HEAD_DIM)) == P(None, "model")
    assert plan.spec((8192, 49152), (tp.D_MODEL, tp.D_FF)) == \
        P(None, "model")


def test_divisibility_fallback_replicates():
    plan = _plan()
    # GQA kv=8 cannot shard over 16-way model axis
    assert plan.spec((8192, 8, 128),
                     (tp.D_MODEL, tp.KV_HEADS, tp.HEAD_DIM)) == P()
    # 60 experts cannot shard over 16
    spec = plan.spec((60, 2048, 1408),
                     (tp.EXPERTS, tp.D_MODEL, tp.D_EXPERT))
    assert spec[0] is None


def test_each_mesh_axis_used_once():
    plan = _plan(zero3=True)
    spec = plan.spec((128, 7168, 4864),
                     (tp.EXPERTS, tp.D_MODEL, tp.D_EXPERT))
    used = [s for s in spec if s is not None]
    flat = []
    for s in used:
        flat.extend(s if isinstance(s, tuple) else (s,))
    assert len(flat) == len(set(flat))
    # experts -> model, d_model -> data (zero3)
    assert spec == P("model", "data")


def test_batch_uses_dp_axes():
    plan = tp.make_train_plan(("pod", "data", "model"), (2, 16, 16))
    spec = plan.spec((256, 4096), (tp.BATCH, None))
    assert spec == P(("pod", "data"))


def test_serve_plan_seq_sharding():
    plan = tp.make_serve_plan(("data", "model"), (16, 16), shard_seq=True)
    spec = plan.spec((1, 524288, 8, 128),
                     (tp.BATCH, tp.SEQ_KV, tp.KV_HEADS, tp.HEAD_DIM))
    # long-context KV shards the sequence over every available axis
    assert spec[1] == ("data", "model")


def test_serve_plan_2d_expert_sharding():
    plan = tp.make_serve_plan(("data", "model"), (16, 16), shard_seq=True)
    spec = plan.spec((16, 8192, 24576),
                     (tp.EXPERTS, tp.D_MODEL, tp.D_EXPERT))
    # expert weights shard 2D: experts over model, d_model over data
    assert spec[0] == "model"
    assert spec[1] in ("data", ("data",))
    used = [s for s in spec if s is not None]
    assert len(used) >= 2


def test_slab_spec_rank1_and_rank2():
    """Loop slabs are ordinary sharded tensors: one device dim for a
    rank-1 slab, two (every third dim) for a rank-2 nest over a 2-D
    mesh — the bridge between loop residency and model sharding."""
    assert tp.slab_spec("data") == P(None, "data")
    assert tp.slab_spec(("i", "j")) == P(None, "i", None, None, "j", None)
    import pytest

    with pytest.raises(ValueError):
        tp.slab_spec(("i", "j", "k"))
