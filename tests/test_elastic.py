"""Elastic re-mesh planning edge cases and resharding round-trips."""
import os

import pytest

from repro.runtime.elastic import plan_elastic_remesh

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_plan_keeps_model_parallel_when_divisible():
    p = plan_elastic_remesh(12, model_parallel=4)
    assert p.new_shape == (3, 4) and p.note == ""
    assert p.axes == ("data", "model")


def test_plan_odd_chip_count_shrinks_tp_to_one():
    p = plan_elastic_remesh(7, model_parallel=2)
    assert p.new_shape == (7, 1)
    assert "shrunk" in p.note


def test_plan_halves_tp_until_divisible():
    p = plan_elastic_remesh(6, model_parallel=4)
    assert p.new_shape == (3, 2)
    assert "shrunk to 2" in p.note


def test_plan_fewer_chips_than_tp():
    p = plan_elastic_remesh(2, model_parallel=16)
    assert p.new_shape == (1, 2)


def test_plan_single_chip():
    p = plan_elastic_remesh(1, model_parallel=8)
    assert p.new_shape == (1, 1)


def test_plan_zero_chips_is_an_error():
    with pytest.raises(ValueError, match="no valid mesh factoring"):
        plan_elastic_remesh(0, model_parallel=4)


def test_plan_custom_axis_names():
    p = plan_elastic_remesh(8, model_parallel=2, axes=("i", "j"))
    assert p.axes == ("i", "j")


def run_reshard_roundtrip() -> None:
    """Subprocess entry (8 virtual devices): shard a tree over the full
    mesh, lose a device, re-place on the 7-device plan — values intact,
    every leaf addressable under the shrunk mesh."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.runtime.elastic import plan_elastic_remesh, reshard_tree

    devs = jax.devices()
    assert len(devs) == 8
    big = Mesh(np.asarray(devs).reshape(8), ("data",))
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            "b": jnp.arange(8, dtype=jnp.float32)}
    sharded = reshard_tree(tree, {"w": P("data"), "b": P()}, big)
    assert sharded["w"].sharding.mesh.devices.size == 8

    plan = plan_elastic_remesh(7, model_parallel=1)
    n_new = plan.new_shape[0] * plan.new_shape[1]
    assert n_new == 7
    small = Mesh(np.asarray(devs[:n_new]).reshape(n_new), ("data",))
    # 8 rows over 7 devices: shard-by-rows no longer divides evenly, so
    # the elastic invariant re-places replicated (GSPMD re-slices on use)
    back = reshard_tree(sharded, {"w": P(), "b": P()}, small)
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(tree["w"]))
    np.testing.assert_array_equal(np.asarray(back["b"]),
                                  np.asarray(tree["b"]))
    assert back["w"].sharding.mesh.devices.size == 7
    print("OKRESHARD")


def test_reshard_roundtrip_on_shrunk_mesh(multidevice):
    out = multidevice(f"""
        import sys
        sys.path.insert(0, {REPO!r})
        from tests.test_elastic import run_reshard_roundtrip
        run_reshard_roundtrip()
    """, n_devices=8)
    assert "OKRESHARD" in out
