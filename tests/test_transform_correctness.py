"""The paper's central claim — "the generated code is correct by
construction" — validated as transform(program)(env) == program(env).

Single-device mesh runs exercise all codegen paths cheaply; hypothesis
generates random affine loop programs; a subprocess test covers real
8-device execution for both lowerings.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro import omp
from repro.compat import make_mesh


def mesh1():
    return make_mesh((1,), ("data",))


def _close(a, b, tol=1e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=tol, atol=tol)


def _check_all(program, env, schedules=("static", "dynamic", "guided")):
    ref = program(env)
    for kind in schedules:
        program.schedule = omp.Schedule(kind)
        out = omp.to_mpi(program, mesh1())(env)
        for k in ref:
            _close(out[k], ref[k])
    return ref


def test_identity_write():
    @omp.parallel_for(stop=23)
    def b(i, env):
        return {"y": omp.at(i, env["x"][i] * 2.0 + i)}

    _check_all(b, {"x": jnp.arange(23, dtype=jnp.float32),
                   "y": jnp.zeros(23)})


def test_shard_inputs_matches():
    @omp.parallel_for(stop=23)
    def b(i, env):
        return {"y": omp.at(i, env["x"][i] * 2.0)}

    env = {"x": jnp.arange(23, dtype=jnp.float32), "y": jnp.zeros(23)}
    ref = b(env)
    out = omp.to_mpi(b, mesh1(), shard_inputs=True)(env)
    _close(out["y"], ref["y"])


def test_strided_write_and_partial():
    @omp.parallel_for(stop=10)
    def b(i, env):
        return {"y": omp.at(3 * i + 2, env["x"][i])}

    env = {"x": jnp.arange(10, dtype=jnp.float32),
           "y": -jnp.ones(40, jnp.float32)}
    _check_all(b, env)

    @omp.parallel_for(stop=10)
    def b2(i, env):
        return {"y": omp.at(i + 4, env["x"][i])}

    _check_all(b2, {"x": jnp.arange(10, dtype=jnp.float32),
                    "y": -jnp.ones(20, jnp.float32)})


def test_put_last_iteration_wins():
    @omp.parallel_for(stop=9)
    def b(i, env):
        return {"z": omp.put(jnp.full((5,), i, jnp.float32))}

    ref = _check_all(b, {"z": jnp.zeros(5)})
    assert float(ref["z"][0]) == 8.0


def test_nonaffine_write_rejected():
    @omp.parallel_for(stop=8)
    def b(i, env):
        return {"y": omp.at(i * i, env["x"][i])}

    env = {"x": jnp.zeros(64), "y": jnp.zeros(64)}
    with pytest.raises(omp.LoopNotCanonical):
        omp.to_mpi(b, mesh1(), env_like=env)


def test_concurrent_write_rejected():
    @omp.parallel_for(stop=8)
    def b(i, env):
        return {"y": omp.at(0 * i, env["x"][i])}

    env = {"x": jnp.zeros(8), "y": jnp.zeros(8)}
    with pytest.raises(omp.LoopNotCanonical):
        omp.to_mpi(b, mesh1(), env_like=env)


def test_multiblock_pipeline_2mm_style():
    """Two chained blocks (2mm): the output of block 1 feeds block 2."""
    m, k, n = 12, 8, 10
    rng = np.random.default_rng(1)
    A = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    B = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))

    @omp.parallel_for(stop=m, name="mm1")
    def mm1(i, env):
        return {"tmp": omp.at(i, env["A"][i] @ env["B"])}

    @omp.parallel_for(stop=m, name="mm2")
    def mm2(i, env):
        return {"D": omp.at(i, env["tmp"][i] @ env["C"])}

    env = {"A": A, "B": B, "C": C,
           "tmp": jnp.zeros((m, n)), "D": jnp.zeros((m, k))}
    ref = mm2(mm1(env))
    d1 = omp.to_mpi(mm1, mesh1())
    d2 = omp.to_mpi(mm2, mesh1())
    out = d2(d1(env))
    _close(out["D"], ref["D"], tol=1e-4)


# ---------------------------------------------------------------------------
# Property-based: random affine programs
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    t=st.integers(1, 40),
    a=st.integers(1, 3),
    b=st.integers(0, 5),
    chunk=st.one_of(st.none(), st.integers(1, 7)),
    kind=st.sampled_from(["static", "dynamic", "guided"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_affine_write(t, a, b, chunk, kind, seed):
    size = a * (t - 1) + b + 1
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=t).astype(np.float32))
    y = jnp.asarray(rng.normal(size=size).astype(np.float32))

    @omp.parallel_for(stop=t, schedule=omp.Schedule(kind, chunk))
    def prog(i, env):
        return {"y": omp.at(a * i + b, env["x"][i] * 3.0 - 1.0)}

    env = {"x": x, "y": y}
    ref = prog(env)
    out = omp.to_mpi(prog, mesh1())(env)
    _close(out["y"], ref["y"])


@settings(max_examples=15, deadline=None)
@given(
    t=st.integers(1, 30),
    op=st.sampled_from(["+", "max", "min", "*"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_reductions(t, op, seed):
    rng = np.random.default_rng(seed)
    # keep '*' well-conditioned
    x = jnp.asarray((1.0 + 0.01 * rng.normal(size=t)).astype(np.float32))

    @omp.parallel_for(stop=t, reduction={"r": op})
    def prog(i, env):
        return {"r": omp.red(env["x"][i])}

    env = {"x": x, "r": jnp.float32(1.5)}
    ref = prog(env)
    out = omp.to_mpi(prog, mesh1())(env)
    _close(out["r"], ref["r"], tol=1e-4)


# ---------------------------------------------------------------------------
# Real multi-device execution (subprocess with 8 virtual devices)
# ---------------------------------------------------------------------------


def test_eight_device_both_lowerings(multidevice):
    out = multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import omp
        from repro.compat import make_mesh

        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        N = 53
        x = jnp.asarray(rng.normal(size=N).astype(np.float32))

        @omp.parallel_for(stop=N, schedule=omp.dynamic(),
                          reduction={"tot": "+"})
        def prog(i, env):
            v = env["x"][i] * 2.0
            return {"y": omp.at(i, v), "tot": omp.red(v)}

        env = {"x": x, "y": jnp.zeros(N), "tot": jnp.float32(0)}
        ref = prog(env)
        for lowering in ("collective", "master_worker"):
            out = omp.to_mpi(prog, mesh, lowering=lowering)(env)
            for k in ref:
                assert np.allclose(out[k], ref[k], atol=1e-5), (lowering, k)
        print("OK8")
    """)
    assert "OK8" in out


def test_stencil_halo_sharded_inputs():
    """jacobi-style stencil with shard_inputs: the halo path must match
    the shared-memory reference (beyond-paper slice+halo transfer)."""
    n = 41
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=n).astype(np.float32))

    @omp.parallel_for(start=1, stop=n - 1)
    def jac(i, env):
        v = (env["x"][i - 1] + env["x"][i] + env["x"][i + 1]) / 3.0
        return {"y": omp.at(i, v)}

    env = {"x": x, "y": jnp.zeros(n, jnp.float32)}
    ref = jac(env)
    dist = omp.to_mpi(jac, mesh1(), shard_inputs=True)
    out = dist(env)
    assert dist.plan.vars["x"].in_strategy == "shard_halo"
    _close(out["y"], ref["y"])


def test_stencil_halo_eight_devices(multidevice):
    out = multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import omp
        from repro.compat import make_mesh

        mesh = make_mesh((8,), ("data",))
        n = 67
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.normal(size=(n, 5)).astype(np.float32))

        @omp.parallel_for(start=2, stop=n - 2)
        def sten(i, env):
            v = (env["x"][i - 2] + env["x"][i] + env["x"][i + 2]) / 3.0
            return {"y": omp.at(i, v)}

        env = {"x": x, "y": jnp.zeros((n, 5), jnp.float32)}
        ref = sten(env)
        dist = omp.to_mpi(sten, mesh, shard_inputs=True)
        got = dist(env)
        assert dist.plan.vars["x"].in_strategy == "shard_halo", \
            dist.plan.vars["x"].in_strategy
        assert np.allclose(np.asarray(got["y"]), np.asarray(ref["y"]),
                           atol=1e-5)
        print("OKHALO")
    """)
    assert "OKHALO" in out
