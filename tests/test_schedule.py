"""Workload distribution math (paper §3.1.3)."""
import pytest

from repro import omp
from repro.core.loop import analyze_loop
from repro.core.schedule import (
    make_chunk_plan,
    paper_chunk_size,
)


def test_paper_table2_formula():
    """partSize = N / (size-1) / 10 — Table 2 line 4."""
    assert paper_chunk_size(1000, 11, master_excluded=True) == 10
    assert paper_chunk_size(1000, 11, master_excluded=False) == 9
    # floors at 1
    assert paper_chunk_size(5, 64, master_excluded=True) == 1


@pytest.mark.parametrize("t,p,sched", [
    (100, 8, omp.dynamic()),
    (100, 8, omp.static()),
    (100, 8, omp.guided()),
    (100, 8, omp.static(7)),
    (3, 8, omp.dynamic()),
    (1, 1, omp.dynamic()),
    (1000, 16, omp.dynamic(1)),
])
def test_chunk_plan_covers_iteration_space(t, p, sched):
    loop = analyze_loop(0, t, 1)
    plan = make_chunk_plan(loop, sched, p)
    assert plan.padded_trip >= t
    assert plan.num_chunks % p == 0
    assert plan.local_chunks * p == plan.num_chunks
    # every iteration owned by exactly one device, cyclically
    owners = [plan.owner_of_iteration(k) for k in range(t)]
    assert all(0 <= o < p for o in owners)
    # chunk j -> device j % p
    for k in range(t):
        assert owners[k] == (k // plan.chunk) % p


def test_static_is_one_block_per_device():
    loop = analyze_loop(0, 64, 1)
    plan = make_chunk_plan(loop, omp.static(), 8)
    assert plan.chunk == 8
    assert plan.local_chunks == 1


def test_dynamic_overdecomposes_10x():
    loop = analyze_loop(0, 1600, 1)
    plan = make_chunk_plan(loop, omp.dynamic(), 8)
    assert plan.chunk == 1600 // 8 // 10
    assert plan.num_chunks >= 80


def test_owner_of_last_iteration():
    loop = analyze_loop(0, 100, 1)
    plan = make_chunk_plan(loop, omp.dynamic(), 8)
    assert plan.owner_of_last_iteration() == plan.owner_of_iteration(99)
