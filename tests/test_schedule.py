"""Workload distribution math (paper §3.1.3), including the zero-trip
and trip_count < num_devices edge cases the boundary lowering must
survive (previously only exercised implicitly)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import omp
from repro.core.loop import analyze_loop
from repro.core.schedule import (
    make_chunk_plan,
    paper_chunk_size,
)


def test_paper_table2_formula():
    """partSize = N / (size-1) / 10 — Table 2 line 4."""
    assert paper_chunk_size(1000, 11, master_excluded=True) == 10
    assert paper_chunk_size(1000, 11, master_excluded=False) == 9
    # floors at 1
    assert paper_chunk_size(5, 64, master_excluded=True) == 1


@pytest.mark.parametrize("t,p,sched", [
    (100, 8, omp.dynamic()),
    (100, 8, omp.static()),
    (100, 8, omp.guided()),
    (100, 8, omp.static(7)),
    (3, 8, omp.dynamic()),
    (1, 1, omp.dynamic()),
    (1000, 16, omp.dynamic(1)),
])
def test_chunk_plan_covers_iteration_space(t, p, sched):
    loop = analyze_loop(0, t, 1)
    plan = make_chunk_plan(loop, sched, p)
    assert plan.padded_trip >= t
    assert plan.num_chunks % p == 0
    assert plan.local_chunks * p == plan.num_chunks
    # every iteration owned by exactly one device, cyclically
    owners = [plan.owner_of_iteration(k) for k in range(t)]
    assert all(0 <= o < p for o in owners)
    # chunk j -> device j % p
    for k in range(t):
        assert owners[k] == (k // plan.chunk) % p


def test_static_is_one_block_per_device():
    loop = analyze_loop(0, 64, 1)
    plan = make_chunk_plan(loop, omp.static(), 8)
    assert plan.chunk == 8
    assert plan.local_chunks == 1


def test_dynamic_overdecomposes_10x():
    loop = analyze_loop(0, 1600, 1)
    plan = make_chunk_plan(loop, omp.dynamic(), 8)
    assert plan.chunk == 1600 // 8 // 10
    assert plan.num_chunks >= 80


def test_owner_of_last_iteration():
    loop = analyze_loop(0, 100, 1)
    plan = make_chunk_plan(loop, omp.dynamic(), 8)
    assert plan.owner_of_last_iteration() == plan.owner_of_iteration(99)


# ---------------------------------------------------------------------------
# Edge cases: zero-trip loops and trip_count < num_devices
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p", [1, 2, 8, 16])
@pytest.mark.parametrize("sched", [
    omp.dynamic(), omp.static(), omp.guided(), omp.static(5), omp.dynamic(3),
])
def test_zero_trip_chunk_plan_invariants(p, sched):
    """A zero-trip loop still yields a well-formed (degenerate) plan:
    positive chunk, padded layout divisible by the device count, no
    iteration owners to assign."""
    plan = make_chunk_plan(analyze_loop(0, 0, 1), sched, p)
    assert plan.trip_count == 0
    assert plan.chunk >= 1
    assert plan.num_chunks % p == 0 and plan.num_chunks >= p
    assert plan.local_chunks * p == plan.num_chunks
    assert plan.padded_trip == plan.num_chunks * plan.chunk
    assert plan.padding == plan.padded_trip
    assert plan.owner_of_last_iteration() == 0


@pytest.mark.parametrize("t,p", [(1, 8), (3, 8), (7, 8), (2, 16), (5, 6)])
@pytest.mark.parametrize("sched", [omp.dynamic(), omp.static(), omp.static(2)])
def test_small_trip_chunk_plan_invariants(t, p, sched):
    """trip_count < num_devices: chunks stay >= 1 iteration, the cyclic
    assignment covers every iteration exactly once, and idle devices get
    only padding chunks."""
    plan = make_chunk_plan(analyze_loop(0, t, 1), sched, p)
    assert 1 <= plan.chunk <= max(1, t)
    assert plan.padded_trip >= t
    owners = [plan.owner_of_iteration(k) for k in range(t)]
    assert all(0 <= o < p for o in owners)
    # devices beyond the populated chunks own no real iteration
    busy = set(owners)
    assert len(busy) == min(p, -(-t // plan.chunk))


def _mesh1():
    import jax
    from jax.sharding import Mesh

    return Mesh(np.asarray(jax.devices()[:1]), ("data",))


def test_zero_trip_execution_matches_reference():
    """t == 0 is a no-op for writes; a declared reduction key already in
    env keeps its value, and the key-set of reference and transformed
    outputs agree."""
    @omp.parallel_for(start=5, stop=5, reduction={"s": "+"}, name="z")
    def z(i, env):
        return {"y": omp.at(i, env["x"][i]), "s": omp.red(env["x"][i])}

    env = {"x": jnp.arange(4, dtype=jnp.float32),
           "y": -jnp.ones(4, jnp.float32), "s": jnp.float32(3.5)}
    ref = omp.run_reference(z, env)
    out = omp.to_mpi(z, _mesh1())(env)
    assert sorted(ref) == sorted(out)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]))
    assert float(out["s"]) == 3.5 and float(out["y"][0]) == -1.0


def test_zero_trip_fresh_reduction_key_consistent():
    """A zero-trip loop whose reduction output is NOT in env defines it
    as the op identity in BOTH executors (the reference used to drop the
    key while the distributed program emitted it)."""
    @omp.parallel_for(stop=0, reduction={"s": "+", "m": "max"}, name="zf")
    def zf(i, env):
        return {"s": omp.red(env["x"][i]), "m": omp.red(env["x"][i])}

    env = {"x": jnp.arange(4, dtype=jnp.float32)}
    ref = omp.run_reference(zf, env)
    out = omp.to_mpi(zf, _mesh1())(env)
    assert sorted(ref) == sorted(out) == ["m", "s", "x"]
    assert float(ref["s"]) == float(out["s"]) == 0.0
    # max identity: -inf (or the dtype minimum, depending on the op table)
    assert float(ref["m"]) == float(out["m"])
    assert float(ref["m"]) <= float(np.finfo(np.float32).min)


def test_small_trip_execution_all_strategies():
    """trip_count < num_chunks-worth-of-devices: identity, strided
    scatter, put and reduction outputs all survive the padding chunks."""
    t = 3

    @omp.parallel_for(stop=t, schedule=omp.dynamic(), reduction={"s": "+"},
                      name="small")
    def small(i, env):
        return {"y": omp.at(i, env["x"][i] * 2.0),
                "z": omp.at(2 * i + 1, env["x"][i]),
                "w": omp.put(jnp.full((4,), 1.0, jnp.float32) * i),
                "s": omp.red(env["x"][i])}

    env = {"x": jnp.arange(t, dtype=jnp.float32),
           "y": jnp.zeros(t, jnp.float32),
           "z": -jnp.ones(8, jnp.float32),
           "w": jnp.zeros(4, jnp.float32), "s": jnp.float32(1.0)}
    ref = omp.run_reference(small, env)
    for kw in (dict(), dict(shard_inputs=True)):
        out = omp.to_mpi(small, _mesh1(), **kw)(env)
        for k in ref:
            np.testing.assert_allclose(np.asarray(out[k]),
                                       np.asarray(ref[k]), err_msg=str(kw))


# ---------------------------------------------------------------------------
# Rank-2 nests: per-axis chunk plans and their degenerate edges
# ---------------------------------------------------------------------------


def _nest(bounds):
    from repro.core.nest import LoopNest

    return LoopNest(tuple(analyze_loop(s, e, t) for s, e, t in bounds))


@pytest.mark.parametrize("trips,devs", [
    ((0, 0), (2, 2)),     # both axes degenerate
    ((0, 8), (2, 2)),     # axis i degenerate
    ((8, 0), (4, 2)),     # axis j degenerate
    ((0, 0), (1, 1)),
])
@pytest.mark.parametrize("sched", [omp.dynamic(), omp.static(), omp.static(3)])
def test_nest_chunk_plans_zero_trip_axes(trips, devs, sched):
    """Per-axis plans stay well-formed when either (or both) axes have a
    zero-trip iteration space: positive chunks, padded layout divisible
    by that axis's device count — the invariants the 2-D slab reshape
    (n, P, c per axis) relies on."""
    from repro.core.schedule import make_nest_chunk_plans

    nest = _nest(((0, trips[0], 1), (0, trips[1], 1)))
    plans = make_nest_chunk_plans(nest, (sched, sched), devs)
    assert len(plans) == 2
    for plan, t, p in zip(plans, trips, devs):
        assert plan.trip_count == t
        assert plan.chunk >= 1
        assert plan.num_chunks % p == 0 and plan.num_chunks >= p
        assert plan.local_chunks * p == plan.num_chunks
        assert plan.padded_trip == plan.num_chunks * plan.chunk
        assert plan.padded_trip >= t


@pytest.mark.parametrize("trips,devs", [
    ((1, 1), (4, 2)),     # both axes below their rank counts
    ((3, 16), (8, 2)),    # axis i below, axis j above
    ((16, 1), (2, 4)),    # axis j below
])
def test_nest_chunk_plans_small_trip_axes(trips, devs):
    """trip < ranks per axis: every iteration is owned exactly once
    under the per-axis cyclic assignment and idle ranks get only
    padding chunks (mirroring the 1-D pins above)."""
    from repro.core.schedule import make_nest_chunk_plans

    nest = _nest(((0, trips[0], 1), (0, trips[1], 1)))
    plans = make_nest_chunk_plans(
        nest, (omp.dynamic(), omp.dynamic()), devs)
    for plan, t, p in zip(plans, trips, devs):
        assert 1 <= plan.chunk <= max(1, t)
        owners = [plan.owner_of_iteration(k) for k in range(t)]
        assert all(0 <= o < p for o in owners)
        assert len(set(owners)) == min(p, -(-t // plan.chunk))


def test_nest_chunk_plans_rank_mismatch_rejected():
    from repro.core.schedule import make_nest_chunk_plans

    nest = _nest(((0, 4, 1), (0, 4, 1)))
    with pytest.raises(ValueError):
        make_nest_chunk_plans(nest, (omp.dynamic(),), (2, 2))


def _mesh2():
    from repro.compat import make_mesh

    return make_mesh((1, 1), ("i", "j"))


def test_zero_trip_2d_execution_matches_reference():
    """A collapse=2 nest with one zero-trip axis writes nothing; a
    declared reduction still defines its variable as the op identity in
    BOTH executors (both-axes-degenerate and one-axis-degenerate)."""
    for stop in ((0, 0), (0, 5), (5, 0)):
        @omp.parallel_for(stop=stop, collapse=2,
                          reduction={"s": "+"}, name="z2")
        def z2(i, j, env):
            return {"y": omp.at((i, j), env["x"][i, j]),
                    "s": omp.red(env["x"][i, j])}

        env = {"x": jnp.arange(20, dtype=jnp.float32).reshape(4, 5),
               "y": -jnp.ones((4, 5), jnp.float32)}
        ref = omp.run_reference(z2, env)
        out = omp.to_mpi(z2, _mesh2())(env)
        assert sorted(ref) == sorted(out) == ["s", "x", "y"]
        for k in ref:
            np.testing.assert_allclose(np.asarray(out[k]),
                                       np.asarray(ref[k]), err_msg=str(stop))
        assert float(out["s"]) == 0.0
        assert float(out["y"][0, 0]) == -1.0


def test_small_trip_2d_execution():
    """(2, 1) trips on a 1x1 2-D mesh: identity writes and reductions
    survive per-axis padding chunks."""
    @omp.parallel_for(stop=(2, 1), collapse=2, schedule=omp.dynamic(),
                      reduction={"s": "+"}, name="small2")
    def small2(i, j, env):
        v = env["x"][i, j] * 2.0
        return {"y": omp.at((i, j), v), "s": omp.red(v)}

    env = {"x": jnp.arange(2, dtype=jnp.float32).reshape(2, 1),
           "y": jnp.zeros((2, 1), jnp.float32), "s": jnp.float32(1.0)}
    ref = omp.run_reference(small2, env)
    for kw in (dict(), dict(shard_inputs=True)):
        out = omp.to_mpi(small2, _mesh2(), **kw)(env)
        for k in ref:
            np.testing.assert_allclose(np.asarray(out[k]),
                                       np.asarray(ref[k]), err_msg=str(kw))
