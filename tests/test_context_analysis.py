"""Context Analysis (paper §3.1.1): IN/OUT/INOUT classification and the
affine index-map recovery, straight from traced jaxprs."""
import jax.numpy as jnp
import pytest

from repro import omp
from repro.core.context import ReadKind, VarClass, WriteKind, analyze_context
from repro.core.loop import LoopNotCanonical, analyze_loop

N = 16


def _ctx(program, env):
    loop = analyze_loop(program.start, program.stop, program.step)
    return analyze_context(program, env, loop)


def test_paper_figure3_classification():
    """The paper's Fig. 3: x is IN, sum is OUT."""

    @omp.parallel_for(stop=N)
    def block(i, env):
        x = env["x"]
        return {"sum": omp.at(i, 4.0 / (1.0 + x * x))}

    ctx = _ctx(block, {"x": jnp.float32(3.0), "sum": jnp.zeros(N)})
    assert ctx.vars["x"].klass == VarClass.IN
    assert ctx.vars["sum"].klass == VarClass.OUT
    assert ctx.vars["sum"].write.kind == WriteKind.AT
    assert (ctx.vars["sum"].write.affine.a,
            ctx.vars["sum"].write.affine.b) == (1, 0)


def test_inout_and_sliced_reads():
    @omp.parallel_for(stop=N)
    def block(i, env):
        row = env["a"][i] * 2.0 + env["c"][i]
        return {"c": omp.at(i, row)}

    env = {"a": jnp.zeros(N), "c": jnp.zeros(N)}
    ctx = _ctx(block, env)
    assert ctx.vars["a"].klass == VarClass.IN
    assert ctx.vars["a"].read.kind == ReadKind.SLICED
    assert ctx.vars["c"].klass == VarClass.INOUT
    assert ctx.vars["c"].read.kind == ReadKind.SLICED


def test_affine_read_map_detected():
    @omp.parallel_for(stop=N)
    def block(i, env):
        return {"y": omp.at(i, env["x"][2 * i + 1])}

    env = {"x": jnp.zeros(2 * N + 2), "y": jnp.zeros(N)}
    ctx = _ctx(block, env)
    r = ctx.vars["x"].read
    assert r.kind == ReadKind.SLICED
    assert (r.affine.a, r.affine.b) == (2, 1)


def test_whole_read_when_not_sliced():
    @omp.parallel_for(stop=N, reduction={"s": "+"})
    def block(i, env):
        return {"s": omp.red(jnp.sum(env["x"]) + 0.0 * i)}

    ctx = _ctx(block, {"x": jnp.zeros(N), "s": jnp.float32(0)})
    assert ctx.vars["x"].read.kind == ReadKind.WHOLE
    assert ctx.vars["s"].klass == VarClass.REDUCTION


def test_unused_variable():
    @omp.parallel_for(stop=N)
    def block(i, env):
        return {"y": omp.at(i, 1.0 + 0.0 * i)}

    ctx = _ctx(block, {"unused": jnp.zeros(3), "y": jnp.zeros(N)})
    assert ctx.vars["unused"].klass == VarClass.UNUSED


def test_stencil_reads_classified():
    """Multiple unit-stride slice maps (i-1, i, i+1) -> STENCIL (halo
    exchange; a beyond-paper extension of the slice-transfer rule)."""

    @omp.parallel_for(start=1, stop=N - 1)
    def block(i, env):
        v = env["x"][i - 1] + env["x"][i] + env["x"][i + 1]
        return {"y": omp.at(i, v / 3.0)}

    env = {"x": jnp.zeros(N), "y": jnp.zeros(N)}
    ctx = _ctx(block, env)
    r = ctx.vars["x"].read
    assert r.kind == ReadKind.STENCIL
    assert [(a.a, a.b) for a in r.affines] == [(1, -1), (1, 0), (1, 1)]


def test_red_without_clause_rejected():
    @omp.parallel_for(stop=N)
    def block(i, env):
        return {"s": omp.red(env["x"][i])}

    with pytest.raises(LoopNotCanonical):
        _ctx(block, {"x": jnp.zeros(N), "s": jnp.float32(0)})


def test_put_classification():
    @omp.parallel_for(stop=N)
    def block(i, env):
        return {"z": omp.put(jnp.full((4,), i, jnp.float32))}

    ctx = _ctx(block, {"z": jnp.zeros(4)})
    assert ctx.vars["z"].write.kind == WriteKind.PUT
