"""Straggler-weighted chunk schedules: slot layout, validation, and the
differential pin that weighted outputs match unweighted bit-for-bit.

A weighted schedule changes *who runs which chunk*, never *what is
computed*: `make_chunk_plan(weights=...)` re-deals chunk ownership via
`rebalance_chunks` and records the permutation in `ChunkPlan.slot_map`;
staging/reassembly gather through it.  Element-wise and stencil outputs
are therefore bit-identical to the cyclic deal; reductions regroup
their per-device partial folds and match to float tolerance.
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro import omp
from repro.compat import make_mesh
from repro.core.loop import analyze_loop
from repro.core.schedule import make_chunk_plan


def _plan(trip_count, chunk, num_devices, weights=None):
    return make_chunk_plan(analyze_loop(0, trip_count, 1), omp.static(chunk),
                           num_devices, weights=weights)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ----------------------------------------------------------- plan layout --


def test_weighted_plan_slot_map_is_a_padded_permutation():
    ch = _plan(37, 3, 4, weights=[4.0, 1.0, 1.0, 1.0])
    k = ch.real_chunks
    assert k == 13
    real = [j for j in ch.slot_map if j < k]
    assert sorted(real) == list(range(k))          # every chunk exactly once
    assert all(j == k for j in ch.slot_map if j >= k)   # sentinel = k
    assert ch.num_chunks == ch.local_chunks * 4
    assert ch.padded_trip == ch.num_chunks * ch.chunk
    # heaviest device owns the most chunks
    counts = [ch.owners.count(d) for d in range(4)]
    assert counts[0] == max(counts) and counts[0] > counts[1]


def test_equal_weights_reproduce_cyclic_deal():
    cyc = _plan(29, 2, 4)
    eq = _plan(29, 2, 4, weights=[1.0, 1.0, 1.0, 1.0])
    assert eq.owners == tuple(j % 4 for j in range(eq.real_chunks))
    assert eq.local_chunks == cyc.local_chunks
    assert eq.num_chunks == cyc.num_chunks
    # slot q*P+d holds global chunk q*P+d — the cyclic identity
    k = eq.real_chunks
    for s, j in enumerate(eq.slot_map):
        assert j == (s if s < k else k)


def test_weighted_plan_owner_lookup():
    ch = _plan(20, 2, 2, weights=[3.0, 1.0])
    for it in range(20):
        j = it // 2
        assert ch.owner_of_iteration(it) == ch.owners[j]


def test_weighted_roundtrip_pad_unpad():
    from repro.core import nest

    ch = _plan(23, 3, 4, weights=[2.0, 1.0, 0.5, 1.0])
    x = np.arange(23, dtype=np.float32) * 1.5
    staged = nest.pad_reshape(jnp.asarray(x), ch)
    assert staged.shape == (ch.local_chunks, ch.num_devices, ch.chunk)
    back = nest.unpad_flat(staged, ch, 23)
    np.testing.assert_array_equal(np.asarray(back), x)


# ------------------------------------------------------------ validation --


def test_weights_rejected_for_wrong_lowerings():
    n = 8

    @omp.parallel_for(stop=n, name="wv")
    def blk(i, env):
        return {"y": omp.at(i, env["x"][i] + 1.0)}

    with pytest.raises(omp.CompileError, match="chunk_weights"):
        omp.Options(lowering="master_worker", chunk_weights=[1.0, 1.0])
    with pytest.raises(omp.CompileError, match="chunk_weights"):
        omp.Options(lowering="pallas", chunk_weights=[1.0, 1.0])

    @omp.parallel_for(stop=n, name="wv2")
    def blk2(i, env):
        return {"z": omp.at(i, env["y"][i] * 2.0)}

    reg = omp.region(blk, blk2, name="wvreg")
    mesh = make_mesh((1,), ("data",))
    with pytest.raises(omp.CompileError, match="COLLECTIVE"):
        omp.compile(reg, mesh, lowering="fused", chunk_weights=[1.0])


def test_weights_length_must_match_mesh():
    n = 8

    @omp.parallel_for(stop=n, name="wl")
    def blk(i, env):
        return {"y": omp.at(i, env["x"][i] + 1.0)}

    mesh = make_mesh((1,), ("data",))
    with pytest.raises(omp.CompileError, match="entries"):
        omp.compile(blk, mesh, lowering="collective",
                    chunk_weights=[1.0, 2.0],
                    env_like={"x": jnp.zeros(n), "y": jnp.zeros(n)})


def test_degenerate_weight_values_rejected():
    with pytest.raises(omp.CompileError):
        omp.Options(chunk_weights=[1.0, 0.0])
    with pytest.raises(omp.CompileError):
        omp.Options(chunk_weights=[1.0, -1.0])
    with pytest.raises(omp.CompileError):
        omp.Options(chunk_weights=[])
    with pytest.raises(omp.CompileError):
        omp.Options(chunk_weights=[float("nan"), 1.0])


# ---------------------------------------------------------- differential --


def run_weighted_sweep() -> None:
    """Subprocess entry (8 virtual devices): weighted compiles of every
    rank-1 and rank-2 family match the unweighted reference."""
    from tests.test_differential import FAMILIES, FAMILIES2, make_case, make_case2

    W8 = [2.0, 1.0, 1.0, 0.5, 1.0, 3.0, 1.0, 0.25]

    def red_keys(prog):
        stages = getattr(prog, "stages", None)
        loops = prog.loops if stages is not None else (prog,)
        keys = set()
        for lp in loops:
            keys |= set(getattr(lp, "reduction", {}) or {})
        return keys

    def check(prog, env, mesh, weights, tag):
        ref = prog(env)
        out = omp.compile(prog, mesh, lowering="collective",
                          chunk_weights=weights)(env)
        reds = red_keys(prog)
        for k in ref:
            if k in reds:
                np.testing.assert_allclose(
                    np.asarray(out[k]), np.asarray(ref[k]),
                    rtol=1e-5, atol=1e-6, err_msg=f"{tag} key={k!r}")
            else:
                np.testing.assert_array_equal(
                    np.asarray(out[k]), np.asarray(ref[k]),
                    err_msg=f"{tag} key={k!r}")

    mesh = make_mesh((8,), ("data",))
    for fi, fam in enumerate(FAMILIES):
        prog, env, fam = make_case(8800 + fi, family=fam)
        check(prog, env, mesh, W8, f"r1:{fam}")
    print("weighted1:", ",".join(FAMILIES))

    mesh2 = make_mesh((4, 2), ("i", "j"))
    per_axis = ([3.0, 1.0, 1.0, 1.0], None)
    for fj, fam in enumerate(FAMILIES2):
        prog, env, fam = make_case2(8900 + fj, family=fam)
        check(prog, env, mesh2, per_axis, f"r2:{fam}")
        check(prog, env, mesh2, ([1.0, 1.0, 2.0, 1.0], [1.0, 4.0]),
              f"r2b:{fam}")
    print("weighted2:", ",".join(FAMILIES2))
    print("OKWEIGHTED")


def test_weighted_schedule_differential(multidevice):
    out = multidevice(f"""
        import sys
        sys.path.insert(0, {REPO!r})
        from tests.test_weighted_schedule import run_weighted_sweep
        run_weighted_sweep()
    """, n_devices=8)
    assert "OKWEIGHTED" in out
    assert "weighted1:" in out and "weighted2:" in out


def test_weighted_schedule_changes_ownership_in_plan():
    """The weights land in the emitted program: the schedule pass
    artifact carries the re-dealt owners."""
    n = 24

    @omp.parallel_for(stop=n, name="wplan", schedule=omp.dynamic(2))
    def blk(i, env):
        return {"y": omp.at(i, env["x"][i] * 2.0)}

    env = {"x": jnp.arange(n, dtype=jnp.float32),
           "y": jnp.zeros(n, jnp.float32)}
    mesh = make_mesh((1,), ("data",))
    c = omp.compile(blk, mesh, lowering="collective",
                    chunk_weights=[1.0], env_like=env)
    (ch,) = c.passes[1].output
    assert ch.weights == (1.0,)
    assert ch.owners is not None and ch.slot_map is not None
