"""Persistent AOT store: fingerprints, on-disk format, corruption
tolerance, and the cross-process warm start."""
import glob
import os
import struct
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import omp
from repro.compat import make_mesh
from repro.core import aot_store
from repro.core.aot_store import AOTStore, fingerprint


def mesh1():
    return make_mesh((len(jax.devices()),), ("data",))


def _block(scale=2.0, n=16):
    @omp.parallel_for(stop=n, name="aotb")
    def block(i, env):
        return {"y": omp.at(i, env["x"][i] * scale + 1.0)}

    env = {"x": jnp.arange(n, dtype=jnp.float32),
           "y": jnp.zeros(n, jnp.float32)}
    return block, env


@pytest.fixture(autouse=True)
def _isolate_cache(tmp_path):
    """Each test gets a fresh cache state and no lingering store."""
    omp.disable_persistent_cache()
    omp.clear_compile_cache()
    yield
    omp.disable_persistent_cache()
    omp.clear_compile_cache()


# ---------------------------------------------------------------------------
# fingerprint
# ---------------------------------------------------------------------------


def _make_fn(scale):
    def body(i, env):
        return {"y": omp.at(i, env["x"][i] * scale + 1.0)}
    return body


def test_fingerprint_stable_across_equal_definitions():
    """Two separately-created closures with identical code + captured
    values hash identically — the property ``id()`` keys lack and the
    cross-process store requires."""
    assert fingerprint(_make_fn(2.0)) == fingerprint(_make_fn(2.0))


def test_fingerprint_diverges_on_closure_and_code():
    base = fingerprint(_make_fn(2.0))
    assert fingerprint(_make_fn(3.0)) != base      # captured value

    def other(i, env):
        return {"y": omp.at(i, env["x"][i] - 1.0)}

    assert fingerprint(other) != base              # bytecode


def test_fingerprint_handles_arrays_and_containers():
    a = np.arange(6, dtype=np.float32)
    assert fingerprint({"k": a, "t": (1, 2)}) == \
        fingerprint({"k": a.copy(), "t": (1, 2)})
    assert fingerprint({"k": a}) != fingerprint({"k": a + 1})


def test_stable_program_token_matches_across_recreation():
    from repro.core.api import _stable_program_token

    b1, _ = _block(2.0)
    b2, _ = _block(2.0)
    b3, _ = _block(5.0)
    assert _stable_program_token(b1) == _stable_program_token(b2)
    assert _stable_program_token(b1) != _stable_program_token(b3)


# ---------------------------------------------------------------------------
# store format: save/load, corruption, skew
# ---------------------------------------------------------------------------


def _compiled_exe():
    """A real jax.stages.Compiled to exercise serialization."""
    fn = jax.jit(lambda x: x * 2.0 + 1.0)
    aval = jax.ShapeDtypeStruct((8,), jnp.float32)
    return fn.lower(aval).compile()


def test_save_load_round_trip(tmp_path):
    store = AOTStore(str(tmp_path))
    exe = _compiled_exe()
    assert store.save("k1", exe) is True
    assert store.stats["disk_bytes_written"] > 0
    assert store.entries() == ["k1"]
    loaded = store.load("k1")
    assert loaded is not None
    x = jnp.arange(8, dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(loaded(x)),
                                  np.asarray(x * 2.0 + 1.0))
    assert store.stats["disk_hits"] == 1
    assert store.stats["disk_errors"] == 0


def test_load_missing_key_is_a_plain_miss(tmp_path):
    store = AOTStore(str(tmp_path))
    assert store.load("absent") is None
    assert store.stats == {**aot_store.empty_stats(), "disk_misses": 1}


def test_corrupt_entry_falls_back_and_unlinks(tmp_path):
    store = AOTStore(str(tmp_path))
    store.save("k1", _compiled_exe())
    path = store._entry_path("k1")
    blob = bytearray(open(path, "rb").read())
    blob[-10] ^= 0xFF                              # flip a body byte
    open(path, "wb").write(bytes(blob))
    assert store.load("k1") is None                # never raises
    assert store.stats["disk_errors"] == 1
    assert store.stats["disk_misses"] == 1
    assert not os.path.exists(path)                # bad entry removed
    assert store.load("k1") is None                # now a plain miss
    assert store.stats["disk_errors"] == 1


def test_truncated_and_bad_magic_entries(tmp_path):
    store = AOTStore(str(tmp_path))
    open(store._entry_path("trunc"), "wb").write(b"RPRO")
    open(store._entry_path("junk"), "wb").write(b"\x00" * 64)
    assert store.load("trunc") is None
    assert store.load("junk") is None
    assert store.stats["disk_errors"] == 2
    assert store.entries() == []


def test_version_skew_is_a_miss(tmp_path):
    store = AOTStore(str(tmp_path))
    store.save("k1", _compiled_exe())
    # rewrite the header with a bumped store_version, keeping the rest
    path = store._entry_path("k1")
    blob = open(path, "rb").read()
    off = len(aot_store._MAGIC)
    (hlen,) = struct.unpack_from("<I", blob, off)
    header = blob[off + 4:off + 4 + hlen].replace(
        b'"store_version": 1', b'"store_version": 999')
    rest = blob[off + 4 + hlen:]
    open(path, "wb").write(
        aot_store._MAGIC + struct.pack("<I", len(header)) + header + rest)
    assert store.load("k1") is None
    assert store.stats["disk_errors"] == 1


# ---------------------------------------------------------------------------
# size-capped eviction
# ---------------------------------------------------------------------------


def _entry_size(tmp_path) -> int:
    probe = AOTStore(str(tmp_path / "probe"))
    probe.save("p", _compiled_exe())
    return os.path.getsize(probe._entry_path("p"))


def test_eviction_sweeps_oldest_beyond_cap(tmp_path):
    size = _entry_size(tmp_path)
    store = AOTStore(str(tmp_path / "s"), max_bytes=2 * size + size // 2)
    for i, key in enumerate(["k0", "k1", "k2"]):
        store.save(key, _compiled_exe())
        os.utime(store._entry_path(key), (1000.0 + i, 1000.0 + i))
    store.save("k3", _compiled_exe())              # sweeps the oldest
    assert store.stats["evictions"] >= 1
    assert store.stats["evicted_bytes"] >= size
    left = store.entries()
    assert "k3" in left and "k0" not in left
    assert sum(os.path.getsize(store._entry_path(k)) for k in left) \
        <= store.max_bytes


def test_eviction_is_lru_load_refreshes_recency(tmp_path):
    size = _entry_size(tmp_path)
    store = AOTStore(str(tmp_path / "s"), max_bytes=2 * size + size // 2)
    store.save("old", _compiled_exe())
    store.save("new", _compiled_exe())
    os.utime(store._entry_path("old"), (1000.0, 1000.0))
    os.utime(store._entry_path("new"), (2000.0, 2000.0))
    assert store.load("old") is not None           # touch: now the MRU
    assert os.path.getmtime(store._entry_path("old")) > 2000.0
    store.save("k3", _compiled_exe())
    left = store.entries()
    assert "old" in left and "new" not in left


def test_never_evicts_the_just_written_entry(tmp_path):
    size = _entry_size(tmp_path)
    store = AOTStore(str(tmp_path / "s"), max_bytes=size // 2)  # < 1 entry
    assert store.save("only", _compiled_exe()) is True
    assert store.entries() == ["only"]             # protected from itself
    store.save("next", _compiled_exe())
    assert "next" in store.entries()               # prior entry swept
    assert "only" not in store.entries()


def test_unbounded_store_never_evicts(tmp_path):
    store = AOTStore(str(tmp_path))
    for key in ("a", "b", "c", "d"):
        store.save(key, _compiled_exe())
    assert store.stats["evictions"] == 0
    assert store.entries() == ["a", "b", "c", "d"]


def test_max_bytes_env_knob(tmp_path, monkeypatch):
    size = _entry_size(tmp_path)
    monkeypatch.setenv(aot_store.ENV_MAX_BYTES, str(size + size // 2))
    store = AOTStore(str(tmp_path / "s"))
    assert store.max_bytes == size + size // 2
    store.save("k0", _compiled_exe())
    os.utime(store._entry_path("k0"), (1000.0, 1000.0))
    store.save("k1", _compiled_exe())
    assert store.entries() == ["k1"]
    monkeypatch.setenv(aot_store.ENV_MAX_BYTES, "not-a-number")
    assert AOTStore(str(tmp_path / "s2")).max_bytes is None
    monkeypatch.setenv(aot_store.ENV_MAX_BYTES, "0")
    assert AOTStore(str(tmp_path / "s3")).max_bytes is None


def test_eviction_tolerates_foreign_and_vanishing_files(tmp_path):
    size = _entry_size(tmp_path)
    store = AOTStore(str(tmp_path / "s"), max_bytes=size + size // 2)
    # non-.aot debris must be ignored, not counted or deleted
    debris = os.path.join(store.path, "README.txt")
    open(debris, "w").write("not an entry")
    store.save("k0", _compiled_exe())
    os.utime(store._entry_path("k0"), (1000.0, 1000.0))
    store.save("k1", _compiled_exe())
    assert os.path.exists(debris)
    assert store.entries() == ["k1"]


# ---------------------------------------------------------------------------
# end-to-end through omp.compile
# ---------------------------------------------------------------------------


def test_enable_persistent_cache_round_trip(tmp_path):
    omp.enable_persistent_cache(str(tmp_path))
    blk, env = _block(2.0)
    mesh = mesh1()
    c1 = omp.compile(blk, mesh, env_like=env)
    want = np.asarray(c1(env)["y"])
    assert glob.glob(str(tmp_path / "*.aot")), "cold compile must persist"
    written = omp.compile_cache_stats()["disk_bytes_written"]
    assert written > 0

    # simulate a fresh process: drop all in-memory state, same disk
    omp.clear_compile_cache()
    omp.enable_persistent_cache(str(tmp_path))
    b2, env2 = _block(2.0)
    c2 = omp.compile(b2, mesh, env_like=env2)
    assert c2.restored is True
    np.testing.assert_array_equal(np.asarray(c2(env2)["y"]), want)
    stats = omp.compile_cache_stats()
    assert stats["disk_hits"] == 1 and stats["disk_errors"] == 0


def test_restored_artifact_rebuilds_passes_lazily(tmp_path):
    omp.enable_persistent_cache(str(tmp_path))
    blk, env = _block(3.0)
    mesh = mesh1()
    omp.compile(blk, mesh, env_like=env)._ensure(env)

    omp.clear_compile_cache()
    omp.enable_persistent_cache(str(tmp_path))
    b2, env2 = _block(3.0)
    c2 = omp.compile(b2, mesh, env_like=env2)
    c2._ensure(env2)
    assert c2.restored
    # inspection still works: passes rebuild deterministically on demand
    assert [p.name for p in c2.passes] and c2.plan is not None
    np.testing.assert_array_equal(np.asarray(c2(env2)["y"]),
                                  np.asarray(b2(env2)["y"]))


def test_corrupt_store_entry_recompiles_cold(tmp_path):
    omp.enable_persistent_cache(str(tmp_path))
    blk, env = _block(4.0)
    mesh = mesh1()
    omp.compile(blk, mesh, env_like=env)._ensure(env)
    (entry,) = glob.glob(str(tmp_path / "*.aot"))
    open(entry, "wb").write(b"garbage")

    omp.clear_compile_cache()
    omp.enable_persistent_cache(str(tmp_path))
    b2, env2 = _block(4.0)
    c2 = omp.compile(b2, mesh, env_like=env2)
    c2._ensure(env2)
    assert c2.restored is False                    # fell back to planned build
    np.testing.assert_array_equal(np.asarray(c2(env2)["y"]),
                                  np.asarray(b2(env2)["y"]))
    stats = omp.compile_cache_stats()
    assert stats["disk_errors"] >= 1


_CHILD = textwrap.dedent("""
    import json, sys
    import jax, jax.numpy as jnp, numpy as np
    from repro import omp
    from repro.compat import make_mesh

    scale = float(sys.argv[1])

    @omp.parallel_for(stop=16, name="xproc")
    def block(i, env):
        return {"y": omp.at(i, env["x"][i] * scale + 1.0)}

    env = {"x": jnp.arange(16, dtype=jnp.float32),
           "y": jnp.zeros(16, jnp.float32)}
    mesh = make_mesh((len(jax.devices()),), ("data",))
    c = omp.compile(block, mesh, env_like=env)
    out = c(env)
    s = omp.compile_cache_stats()
    print(json.dumps({"y": np.asarray(out["y"]).tolist(),
                      "restored": c.restored,
                      "disk_hits": s["disk_hits"],
                      "disk_misses": s["disk_misses"]}))
""")


def test_cross_process_warm_start(tmp_path):
    """A second *process* pointed at the same store restores the
    executable instead of recompiling (the Perf-I headline)."""
    import json

    env = dict(os.environ,
               REPRO_AOT_CACHE_DIR=str(tmp_path),
               PYTHONPATH="src")
    runs = []
    for _ in range(2):
        proc = subprocess.run(
            [sys.executable, "-c", _CHILD, "2.5"], env=env,
            capture_output=True, text=True, cwd=os.path.dirname(
                os.path.dirname(os.path.abspath(__file__))))
        assert proc.returncode == 0, proc.stderr
        runs.append(json.loads(proc.stdout.strip().splitlines()[-1]))
    cold, warm = runs
    assert cold["restored"] is False and cold["disk_hits"] == 0
    assert warm["restored"] is True and warm["disk_hits"] == 1
    assert warm["y"] == cold["y"]
