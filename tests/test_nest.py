"""The loop-nest IR (core/nest.py): the single owner of window
geometry, slab slicing and env substitution.

These tests pin the tentpole invariant of ISSUE 3: the three formerly
divergent copies (``transform._halo_slabs`` / ``region._local_slabs`` /
``comm`` window geometry) are gone and every layer addresses the one
implementation in :mod:`repro.core.nest`.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm, nest, region, transform
from repro.core.loop import LoopNotCanonical, analyze_loop
from repro.core.nest import LoopNest, NestAffine, ShiftedWindow
from repro.core.schedule import ChunkPlan


def _ch(t=60, p=4, c=4):
    k = -(-max(1, t) // c)
    k_pad = -(-k // p) * p
    return ChunkPlan(trip_count=t, num_devices=p, chunk=c, num_chunks=k_pad,
                     local_chunks=k_pad // p, padded_trip=k_pad * c)


# ---------------------------------------------------------------------------
# Single ownership: every layer uses nest.py's geometry
# ---------------------------------------------------------------------------


def test_geometry_has_one_owner():
    """comm re-exports nest's geometry functions (same objects), and the
    old private copies in transform/region are gone."""
    assert comm.window_rows is nest.window_rows
    assert comm.window_extent is nest.window_extent
    assert comm.device_window_rows is nest.device_window_rows
    for mod, names in ((transform, ("_halo_slabs", "_pad_reshape",
                                    "_unpad_flat", "_ShiftedArray")),
                       (region, ("_local_slabs",)),
                       (comm, ())):
        for name in names:
            assert not hasattr(mod, name), f"{mod.__name__}.{name} came back"


def test_staging_and_local_windows_agree_rank1():
    """halo_slabs (jit-level staging) and local_slabs (in-shard_map
    slicing of a replicated copy) must produce identical windows."""
    ch = _ch(t=60, p=4, c=4)
    x = jnp.arange(60, dtype=jnp.float32) * 1.5
    for halo in ((0, 0), (0, 2), (1, 1), (2, 3)):
        staged = nest.halo_slabs(x, ch, halo)       # (n_loc, P, w, ...)
        for d in range(ch.num_devices):
            local = nest.local_slabs(x, ch, halo, d)
            np.testing.assert_array_equal(np.asarray(staged[:, d]),
                                          np.asarray(local))


def test_staging_and_local_windows_agree_rank2():
    ch_i, ch_j = _ch(t=24, p=2, c=4), _ch(t=18, p=2, c=3)
    x = jnp.arange(24 * 18, dtype=jnp.float32).reshape(24, 18)
    halos = ((0, 2), (1, 1))
    staged = nest.halo_slabs2(x, (ch_i, ch_j), halos)
    for di in range(2):
        for dj in range(2):
            local = nest.local_slabs2(x, (ch_i, ch_j), halos, (di, dj))
            np.testing.assert_array_equal(
                np.asarray(staged[:, di, :, :, dj]), np.asarray(local))


def test_pad_reshape_roundtrip():
    ch = _ch(t=10, p=4, c=2)
    x = jnp.arange(10, dtype=jnp.float32)
    slab = nest.pad_reshape(x, ch)
    assert slab.shape == (ch.local_chunks, ch.num_devices, ch.chunk)
    np.testing.assert_array_equal(np.asarray(nest.unpad_flat(slab, ch, 10)),
                                  np.asarray(x))


def test_unpad_flat2_roundtrip():
    ch_i, ch_j = _ch(t=5, p=2, c=2), _ch(t=3, p=2, c=1)
    x = jnp.arange(5 * 3, dtype=jnp.float32).reshape(5, 3)
    slab = nest.halo_slabs2(x, (ch_i, ch_j), ((0, 0), (0, 0)))
    flat = nest.unpad_flat2(slab, (ch_i, ch_j), (5, 3))
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(x))


# ---------------------------------------------------------------------------
# The nest IR itself
# ---------------------------------------------------------------------------


def test_loop_nest_ranks_and_trips():
    n1 = LoopNest((analyze_loop(0, 10, 2),))
    assert n1.rank == 1 and n1.trip_counts == (5,) and n1.total_trip == 5
    n2 = LoopNest((analyze_loop(1, 7, 1), analyze_loop(0, 4, 1)))
    assert n2.rank == 2 and n2.trip_counts == (6, 4)
    assert n2.total_trip == 24
    with pytest.raises(LoopNotCanonical):
        LoopNest((analyze_loop(0, 2, 1),) * 3)


def test_nest_affine_algebra_and_k_space():
    a = NestAffine((1, 0), 0)
    b = NestAffine((0, 1), 2)
    s = a + b.scale(3)
    assert s == NestAffine((1, 3), 6)
    assert (a - a).is_const
    # i in range(2, 20, 3): i-1 reads position 3*ki + 1 in k-space
    n2 = LoopNest((analyze_loop(2, 20, 3), analyze_loop(0, 4, 1)))
    k = (a + NestAffine((0, 0), -1)).k_space(n2)
    assert k == NestAffine((3, 0), 1)
    assert NestAffine((0, 1), 5).k_space(n2) == NestAffine((0, 1), 5)
    assert NestAffine((1, 0), 0).k_space(
        LoopNest((analyze_loop(0, 8, 1), analyze_loop(0, 8, 1)))
    ).unit_axis() == 0
    assert NestAffine((1, 1), 0).unit_axis() is None


def test_shifted_window_serves_offsets():
    win = jnp.arange(12, dtype=jnp.float32).reshape(4, 3)
    sw = ShiftedWindow(win, (10,), (100, 3), jnp.float32)
    np.testing.assert_array_equal(np.asarray(sw[11]), np.asarray(win[1]))
    assert float(sw[12, 2]) == float(win[2, 2])
    sw2 = ShiftedWindow(win, (10, 20), (100, 100), jnp.float32)
    assert float(sw2[11, 21]) == float(win[1, 1])
    with pytest.raises(nest.SubstitutionFailed):
        sw2[5]          # needs both leading indices
    with pytest.raises(nest.SubstitutionFailed):
        sw + 1          # non-getitem use


def test_window_rows_matches_device_rows():
    ch = _ch(t=60, p=4, c=4)
    for halo in ((0, 0), (0, 2), (1, 1), (2, 3)):
        stat = nest.window_rows(ch, halo, 60)
        width = nest.window_extent(ch.chunk, halo)
        assert stat.shape == (ch.num_chunks, width)
        for d in range(ch.num_devices):
            dev = np.asarray(nest.device_window_rows(ch, halo, d, 60))
            expect = stat.reshape(ch.local_chunks, ch.num_devices,
                                  width)[:, d]
            np.testing.assert_array_equal(dev, expect)
