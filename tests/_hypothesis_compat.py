"""Minimal fallback for ``hypothesis`` (property-based testing).

The container does not ship hypothesis and nothing may be pip-installed,
so this vendors the tiny subset the suite uses: ``given``/``settings``
plus the ``integers``/``sampled_from``/``one_of``/``none`` strategies.
Draws are seeded (deterministic across runs) and each ``given`` test runs
``max_examples`` sampled combinations — no shrinking, no database, but
the same coverage intent as the real library at these example counts.

``from tests._hypothesis_compat import given, settings, strategies``
resolves to the real hypothesis when it is importable.
"""
from __future__ import annotations

import random

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: random.Random):
            return self._draw(rng)

    class strategies:  # noqa: N801 - mirrors the hypothesis namespace
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(options):
            opts = list(options)
            return _Strategy(lambda rng: rng.choice(opts))

        @staticmethod
        def none():
            return _Strategy(lambda rng: None)

        @staticmethod
        def one_of(*strats):
            return _Strategy(lambda rng: rng.choice(strats).draw(rng))

    def settings(max_examples: int = 20, **_ignored):
        def wrap(fn):
            fn._max_examples = max_examples
            return fn

        return wrap

    def given(**strats):
        def wrap(fn):
            def run():
                # settings() may be applied after given(); read the
                # attribute off the wrapper at call time.
                n = getattr(run, "_max_examples", 20)
                rng = random.Random(0xA5A5)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strats.items()}
                    fn(**drawn)

            # No functools.wraps: pytest must see a zero-arg signature,
            # not the original one (drawn args are not fixtures).
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            run._max_examples = getattr(fn, "_max_examples", 20)
            return run

        return wrap
