"""The staged compiler API: ``omp.compile`` / ``omp.Options``.

Pins the ISSUE 4 redesign surface:

* Options validation — typed enums, actionable ``CompileError``s, the
  one diagnostics path for option × program mismatches (master_worker ×
  rank-2, ``keep_sharded``, slice × master_worker),
* the legacy ``to_mpi`` / ``region_to_mpi`` shims — they must emit
  ``DeprecationWarning`` and produce results identical to
  ``omp.compile`` on representative programs,
* compilation-cache semantics — hits on structural repeats, misses on
  distinct meshes / mutated env shapes / different options,
* ``.passes`` artifact integrity — the analyze → schedule → plan →
  plan_comm → lower chain with real artifacts at every stage.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import omp
from repro.compat import make_mesh
from repro.core.plan import DistPlan
from repro.core.region import RegionPlan


def mesh1():
    return make_mesh((len(jax.devices()),), ("data",))


def _map_block(n=16, name="mapb"):
    @omp.parallel_for(stop=n, schedule=omp.dynamic(), name=name)
    def block(i, env):
        return {"y": omp.at(i, env["x"][i] * 2.0 + 1.0)}

    env = {"x": jnp.arange(n, dtype=jnp.float32),
           "y": jnp.zeros(n, jnp.float32)}
    return block, env


def _chain_region(n=16):
    @omp.parallel_for(stop=n, name="c1")
    def l1(i, env):
        return {"tmp": omp.at(i, env["x"][i] * 2.0)}

    @omp.parallel_for(stop=n, reduction={"tot": "+"}, name="c2")
    def l2(i, env):
        return {"tot": omp.red(env["tmp"][i])}

    reg = omp.region(l1, l2, name="chain")
    env = {"x": jnp.arange(n, dtype=jnp.float32),
           "tmp": jnp.zeros(n, jnp.float32), "tot": jnp.float32(0)}
    return reg, env


def _nest2(n=6, m=6):
    @omp.parallel_for(stop=(n, m), collapse=2, name="nest2")
    def block(i, j, env):
        return {"C": omp.at((i, j), env["A"][i, j] + 1.0)}

    env = {"A": jnp.arange(n * m, dtype=jnp.float32).reshape(n, m),
           "C": jnp.zeros((n, m), jnp.float32)}
    return block, env


# ---------------------------------------------------------------------------
# Options validation
# ---------------------------------------------------------------------------


def test_options_accepts_strings_and_enums():
    o = omp.Options(lowering="collective", comm="gather", shard="slice")
    assert o.lowering is omp.Lowering.COLLECTIVE
    assert o.comm is omp.CommMode.GATHER
    assert o.shard is omp.ShardPolicy.SLICE
    o2 = omp.Options(lowering=omp.Lowering.MASTER_WORKER)
    assert o2.lowering is omp.Lowering.MASTER_WORKER
    assert o2.schedule is None


def test_options_rejects_unknown_values_with_valid_list():
    with pytest.raises(omp.CompileError, match="fused"):
        omp.Options(lowering="bogus")
    with pytest.raises(omp.CompileError, match="gather"):
        omp.Options(comm="bcast")
    with pytest.raises(omp.CompileError, match="slice"):
        omp.Options(shard=7)
    with pytest.raises(omp.CompileError, match="Schedule"):
        omp.Options(schedule=42)
    with pytest.raises(omp.CompileError, match="axis"):
        omp.Options(axis=("i", "i"))
    with pytest.raises(omp.CompileError, match="axis"):
        omp.Options(axis=3)


def test_compile_error_is_loop_not_canonical_and_value_error():
    # the one diagnostics path must satisfy every legacy except clause
    assert issubclass(omp.CompileError, omp.LoopNotCanonical)
    assert issubclass(omp.CompileError, ValueError)


def test_options_schedule_override_changes_chunking():
    block, env = _map_block()
    c = omp.compile(block, mesh1(), env_like=env,
                    options=None, schedule=omp.static(4))
    assert c.plan.chunks.chunk == 4
    c2 = omp.compile(block, mesh1(), env_like=env)
    assert c2.plan.chunks.chunk != 4   # dynamic default: N/P/10 -> 1
    # results unchanged — schedules only move work, never values
    np.testing.assert_allclose(np.asarray(c(env)["y"]),
                               np.asarray(c2(env)["y"]))


def test_options_and_overrides_are_exclusive():
    block, env = _map_block()
    with pytest.raises(omp.CompileError, match="not both"):
        omp.compile(block, mesh1(), omp.Options(), lowering="collective")


def test_compile_rejects_non_programs():
    with pytest.raises(omp.CompileError, match="ParallelFor"):
        omp.compile(lambda e: e, mesh1())


def test_master_worker_rank2_single_diagnostics_path():
    block, env = _nest2()
    mesh = make_mesh((1, 1), ("i", "j"))
    with pytest.raises(omp.CompileError, match="rank-1 only"):
        omp.compile(block, mesh, lowering="master_worker")


def test_master_worker_slice_rejected():
    block, env = _map_block()
    with pytest.raises(omp.CompileError, match="SLICE"):
        omp.compile(block, mesh1(), lowering="master_worker",
                    shard="slice")


# ---------------------------------------------------------------------------
# keep_sharded kwargs drift (ISSUE 4 satellite): one behavior, loudly
# ---------------------------------------------------------------------------


def test_keep_sharded_rejected_uniformly():
    block, env = _map_block()
    # at Options construction ...
    with pytest.raises(omp.CompileError, match="keep_sharded"):
        omp.Options(keep_sharded=True)
    # ... and through the legacy shim, which used to silently ignore it
    with pytest.warns(DeprecationWarning):
        with pytest.raises(omp.CompileError, match="keep_sharded"):
            omp.to_mpi(block, mesh1(), keep_sharded=True)
    # region_to_mpi never grew the kwarg; the unified surface has one
    # sharded-exit story for both program kinds (the FUSED lowering)
    with pytest.raises(omp.CompileError, match="FUSED"):
        omp.Options(keep_sharded=True)


# ---------------------------------------------------------------------------
# Legacy shims: DeprecationWarning + output equivalence
# ---------------------------------------------------------------------------


def test_to_mpi_shim_warns_and_matches_compile():
    block, env = _map_block()
    mesh = mesh1()
    with pytest.warns(DeprecationWarning, match="omp.compile"):
        legacy = omp.to_mpi(block, mesh, shard_inputs=True)
    new = omp.compile(block, mesh, lowering="collective", shard="slice")
    np.testing.assert_allclose(np.asarray(legacy(env)["y"]),
                               np.asarray(new(env)["y"]))
    # the shim returns the unified artifact with the translated options
    assert isinstance(legacy, omp.Compiled)
    assert legacy.options.shard is omp.ShardPolicy.SLICE
    assert legacy.options.lowering is omp.Lowering.COLLECTIVE


def test_region_to_mpi_shim_warns_and_matches_compile():
    reg, env = _chain_region()
    mesh = mesh1()
    with pytest.warns(DeprecationWarning, match="omp.compile"):
        legacy = omp.region_to_mpi(reg, mesh, env_like=env)
    new = omp.compile(reg, mesh, env_like=env)
    for k in ("tmp", "tot"):
        np.testing.assert_allclose(np.asarray(legacy(env)[k]),
                                   np.asarray(new(env)[k]), rtol=1e-6)
    assert legacy.options.lowering is omp.Lowering.FUSED
    # and the legacy fuse=False spelling maps onto COLLECTIVE staging
    with pytest.warns(DeprecationWarning):
        staged = omp.region_to_mpi(reg, mesh, fuse=False)
    assert staged.options.lowering is omp.Lowering.COLLECTIVE
    np.testing.assert_allclose(np.asarray(staged(env)["tot"]),
                               np.asarray(new(env)["tot"]), rtol=1e-6)


def test_region_to_mpi_shim_rejects_unknown_lowering():
    reg, env = _chain_region()
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="unknown lowering"):
            omp.region_to_mpi(reg, mesh1(), lowering="collectve")


def test_staged_region_host_side_glue_still_runs():
    """The staged lowering executes serial glue eagerly on concrete
    arrays, so host-side glue (numpy round trip) must keep working:
    shape tracing fails at plan time and the remaining stages fall back
    to the historical run-time planning."""
    n = 8

    @omp.parallel_for(stop=n, name="hg1")
    def l1(i, env):
        return {"tmp": omp.at(i, env["x"][i] * 2.0)}

    def glue_fn(env):
        # deliberately not traceable: concrete numpy conversion
        total = float(np.asarray(env["tmp"]).sum())
        return {"bias": jnp.full((1,), total, jnp.float32)}

    glue = omp.serial(glue_fn, reads=("tmp",), name="hostglue")

    @omp.parallel_for(stop=n, name="hg2")
    def l2(i, env):
        return {"y": omp.at(i, env["tmp"][i] + env["bias"][0])}

    reg = omp.region(l1, glue, l2, name="hostglue_region")
    env = {"x": jnp.arange(n, dtype=jnp.float32),
           "tmp": jnp.zeros(n, jnp.float32),
           "bias": jnp.zeros(1, jnp.float32),
           "y": jnp.zeros(n, jnp.float32)}
    ref = reg(env)
    c = omp.compile(reg, mesh1(), env_like=env, lowering="collective")
    np.testing.assert_allclose(np.asarray(c(env)["y"]),
                               np.asarray(ref["y"]), rtol=1e-6)
    # the plan pass records the deferral instead of failing the compile
    assert "not shape-traceable" in c._pass("plan").input
    assert c._pass("lower").output.stage_plans is None


def test_region_to_mpi_shim_wraps_bare_parallel_for():
    block, env = _map_block()
    with pytest.warns(DeprecationWarning):
        legacy = omp.region_to_mpi(block, mesh1())
    ref = block(env)
    np.testing.assert_allclose(np.asarray(legacy(env)["y"]),
                               np.asarray(ref["y"]))


def test_engine_internals_are_shim_free():
    """Compiling and running through omp.compile must not touch the
    deprecated entry points anywhere inside src/."""
    block, env = _map_block()
    reg, renv = _chain_region()
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        omp.compile(block, mesh1(), env_like=env)(env)
        omp.compile(reg, mesh1(), env_like=renv)(renv)
        omp.compile(reg, mesh1(), lowering="collective")(renv)


# ---------------------------------------------------------------------------
# Compilation cache
# ---------------------------------------------------------------------------


def test_cache_hit_and_miss_semantics():
    omp.clear_compile_cache()
    block, env = _map_block()
    mesh = mesh1()

    c1 = omp.compile(block, mesh, env_like=env)
    assert c1.cache_hit is False
    c2 = omp.compile(block, mesh, env_like=env)
    assert c2.cache_hit is True
    stats = omp.compile_cache_stats()
    assert stats["hits"] == 1 and stats["misses"] == 1

    # distinct mesh (same device, different axis name) → miss
    other = make_mesh((len(jax.devices()),), ("rows",))
    c3 = omp.compile(block, other, axis="rows", env_like=env)
    assert c3.cache_hit is False

    # mutated env shapes → miss (the plan depends on buffer shapes)
    env_wide = {"x": jnp.arange(32, dtype=jnp.float32),
                "y": jnp.zeros(32, jnp.float32)}

    @omp.parallel_for(stop=32, schedule=omp.dynamic(), name="mapb32")
    def block32(i, env):
        return {"y": omp.at(i, env["x"][i] * 2.0 + 1.0)}

    c4 = omp.compile(block32, mesh, env_like=env_wide)
    assert c4.cache_hit is False

    # different options → miss
    c5 = omp.compile(block, mesh, env_like=env, schedule=omp.static(2))
    assert c5.cache_hit is False

    # warm repeat of every variant above → all hits
    for build in (
        lambda: omp.compile(block, mesh, env_like=env),
        lambda: omp.compile(block, other, axis="rows", env_like=env),
        lambda: omp.compile(block32, mesh, env_like=env_wide),
        lambda: omp.compile(block, mesh, env_like=env,
                            schedule=omp.static(2)),
    ):
        assert build().cache_hit is True


def test_cache_mutated_schedule_clause_misses():
    """The polybench example mutates prog.schedule in place — the
    structural signature must see it."""
    omp.clear_compile_cache()
    block, env = _map_block(name="mut")
    mesh = mesh1()
    omp.compile(block, mesh, env_like=env)
    block.schedule = omp.static(2)
    c = omp.compile(block, mesh, env_like=env)
    assert c.cache_hit is False
    assert c.plan.chunks.chunk == 2


def test_cache_same_env_different_values_hits():
    omp.clear_compile_cache()
    block, env = _map_block()
    mesh = mesh1()
    omp.compile(block, mesh, env_like=env)
    env2 = {k: v + 1.0 for k, v in env.items()}
    c = omp.compile(block, mesh, env_like=env2)   # same shapes/dtypes
    assert c.cache_hit is True
    # and the cached plan still computes the right answer
    np.testing.assert_allclose(np.asarray(c(env2)["y"]),
                               np.asarray(block(env2)["y"]))


def test_lazy_compile_builds_through_cache_on_first_call():
    omp.clear_compile_cache()
    block, env = _map_block()
    c = omp.compile(block, mesh1())
    assert c.cache_hit is None
    with pytest.raises(omp.CompileError, match="env_like"):
        _ = c.passes
    out = c(env)
    np.testing.assert_allclose(np.asarray(out["y"]),
                               np.asarray(block(env)["y"]))
    assert c.cache_hit is False and len(c.passes) == 6


# ---------------------------------------------------------------------------
# Pass pipeline artifacts
# ---------------------------------------------------------------------------


def test_passes_artifact_integrity_block():
    block, env = _map_block()
    c = omp.compile(block, mesh1(), env_like=env)
    names = [p.name for p in c.passes]
    assert names == ["analyze", "schedule", "plan", "plan_comm",
                     "schedule_comm", "lower"]
    assert all(p.output is not None for p in c.passes)

    nest, ctx = c._pass("analyze").output
    assert nest.rank == 1 and "x" in ctx.vars
    chunks_axes = c._pass("schedule").output
    assert len(chunks_axes) == 1 and chunks_axes[0].num_devices >= 1
    plan = c._pass("plan").output
    assert isinstance(plan, DistPlan)
    # the plan pass consumed exactly the artifacts the earlier passes made
    assert plan.context is ctx
    assert plan.chunks is chunks_axes[0]
    assert c._pass("plan_comm").output == ()
    exe = c._pass("lower").output
    assert callable(exe) and exe.plan is plan
    assert c.plan is plan and c.boundaries == ()


def test_passes_artifact_integrity_fused_region():
    reg, env = _chain_region()
    c = omp.compile(reg, mesh1(), env_like=env)
    names = [p.name for p in c.passes]
    assert names == ["analyze", "schedule", "plan", "plan_comm",
                     "schedule_comm", "lower"]
    rp = c.plan
    assert isinstance(rp, RegionPlan)
    analyzed = dict(c._pass("analyze").output)
    assert set(analyzed) == {"c1", "c2"}
    assert tuple(c._pass("plan_comm").output) == tuple(rp.comms)
    assert c.boundaries == tuple(rp.comms)
    assert c._pass("lower").output.plan is rp


def test_passes_artifact_integrity_staged_region():
    reg, env = _chain_region()
    c = omp.compile(reg, mesh1(), env_like=env, lowering="collective")
    names = [p.name for p in c.passes]
    assert names == ["analyze", "schedule", "plan", "plan_comm",
                     "schedule_comm", "lower"]
    plans = dict(c._pass("plan").output)
    assert set(plans) == {"c1", "c2"}
    assert all(isinstance(p, DistPlan) for p in plans.values())
    assert c.boundaries == ()
    # the staged executor runs the very plans the pipeline recorded
    exe = c._pass("lower").output
    assert exe.stage_plans is c._pass("plan").output


def test_report_and_cost_summary_from_unified_artifact():
    block, env = _map_block()
    c = omp.compile(block, mesh1(), env_like=env)
    text = c.report()
    assert "omp.compile" in text
    assert ("analyze -> schedule -> plan -> plan_comm -> "
            "schedule_comm -> lower") in text
    assert "OMP2MPI transformation report" in text
    cs = c.cost_summary()
    assert cs["kind"] == "block" and cs["modeled_bytes"] > 0

    reg, renv = _chain_region()
    cr = omp.compile(reg, mesh1(), env_like=renv)
    rtext = cr.report()
    assert "ParallelRegion transformation report" in rtext
    rcs = cr.cost_summary()
    assert rcs["kind"] == "region"
    assert {"planned_wire_bytes", "gather_wire_bytes",
            "n_elided"} <= set(rcs)

    cstag = omp.compile(reg, mesh1(), env_like=renv, lowering="collective")
    assert cstag.cost_summary()["kind"] == "region_staged"
    assert "staged lowering" in cstag.report()


def test_compile_rank2_region_and_block():
    block, env = _nest2()
    mesh = make_mesh((1, 1), ("i", "j"))
    ref = block(env)
    c = omp.compile(block, mesh, env_like=env, shard="slice")
    np.testing.assert_allclose(np.asarray(c(env)["C"]),
                               np.asarray(ref["C"]))
    assert c.axis == ("i", "j") and c.plan.rank == 2

    reg = omp.ParallelRegion((block,), name="r2")
    cr = omp.compile(reg, mesh, env_like=env)
    np.testing.assert_allclose(np.asarray(cr(env)["C"]),
                               np.asarray(ref["C"]))
    assert isinstance(cr.plan, RegionPlan) and cr.plan.rank == 2


# ---------------------------------------------------------------------------
# schedule_comm pass (ISSUE 5): Options.comm_schedule + the artifact
# ---------------------------------------------------------------------------


def test_options_comm_schedule_validation():
    assert omp.Options().comm_schedule == "aggregate"
    assert omp.Options(comm_schedule="INLINE").comm_schedule == "inline"
    with pytest.raises(omp.CompileError, match="comm_schedule"):
        omp.Options(comm_schedule="packed")
    with pytest.raises(omp.CompileError, match="comm_schedule"):
        omp.Options(comm_schedule=7)


def test_schedule_comm_pass_artifact():
    reg, env = _chain_region()
    c = omp.compile(reg, mesh1(), env_like=env)
    sched = c.comm_schedule
    assert isinstance(sched, omp.CommSchedule)
    assert sched.mode == "aggregate"
    assert c._pass("schedule_comm").output is sched
    assert c.plan.comm_sched is sched
    # launch accounting lands in the cost summary
    cs = c.cost_summary()
    assert cs["comm_schedule"] == "aggregate"
    assert cs["launches_scheduled"] <= cs["launches_inline"]
    # inline mode records the same events with no grouping
    ci = omp.compile(reg, mesh1(), env_like=env, comm_schedule="inline")
    assert ci.comm_schedule.mode == "inline"
    assert ci.comm_schedule.groups == ()
    assert (ci.comm_schedule.launches_scheduled
            == ci.comm_schedule.launches_inline)
    # blocks and staged regions have nothing region-wide to schedule
    block, benv = _map_block()
    assert omp.compile(block, mesh1(), env_like=benv).comm_schedule == ()
    cstag = omp.compile(reg, mesh1(), env_like=env, lowering="collective")
    assert cstag.comm_schedule == ()


# ---------------------------------------------------------------------------
# Lowering.PALLAS option surface (PR 6): combinations the tiled-kernel
# backend cannot serve must fail loudly at Options construction, and
# host-side serial glue must fail loudly at compile — never silently
# fall back to a different lowering.
# ---------------------------------------------------------------------------


def test_pallas_options_reject_unroll_chunks():
    with pytest.raises(omp.CompileError, match="unroll_chunks"):
        omp.Options(lowering="pallas", unroll_chunks=True)


def test_pallas_options_reject_master_worker_knob():
    # paper_master_excluded stages through a master rank; pallas never
    # does, in either direction of the flag
    with pytest.raises(omp.CompileError, match="paper_master_excluded"):
        omp.Options(lowering="pallas", paper_master_excluded=True)
    with pytest.raises(omp.CompileError, match="paper_master_excluded"):
        omp.Options(lowering="pallas", paper_master_excluded=False)


def test_pallas_interpret_requires_pallas_lowering():
    with pytest.raises(omp.CompileError, match="pallas_interpret"):
        omp.Options(lowering="master_worker", pallas_interpret=True)
    with pytest.raises(omp.CompileError, match="pallas_interpret"):
        omp.Options(pallas_interpret=False)     # default lowering
    # valid combinations construct fine
    o = omp.Options(lowering="pallas", pallas_interpret=True)
    assert o.lowering is omp.Lowering.PALLAS and o.pallas_interpret is True
    assert omp.Options(lowering="pallas").pallas_interpret is None


def test_pallas_rejects_host_side_glue_loudly():
    """The staged path defers host-glue planning to run time
    (test_staged_region_host_side_glue_still_runs); pallas has no such
    fallback — everything must trace, so the compile fails loudly."""
    n = 8

    @omp.parallel_for(stop=n, name="pg1")
    def l1(i, env):
        return {"tmp": omp.at(i, env["x"][i] * 2.0)}

    def glue_fn(env):
        total = float(np.asarray(env["tmp"]).sum())
        return {"bias": jnp.full((1,), total, jnp.float32)}

    glue = omp.serial(glue_fn, reads=("tmp",), name="hostglue")

    @omp.parallel_for(stop=n, name="pg2")
    def l2(i, env):
        return {"y": omp.at(i, env["tmp"][i] + env["bias"][0])}

    reg = omp.region(l1, glue, l2, name="hostglue_pallas")
    env = {"x": jnp.arange(n, dtype=jnp.float32),
           "tmp": jnp.zeros(n, jnp.float32),
           "bias": jnp.zeros(1, jnp.float32),
           "y": jnp.zeros(n, jnp.float32)}
    with pytest.raises(omp.CompileError,
                       match="PALLAS cannot compile region"):
        omp.compile(reg, mesh1(), env_like=env, lowering="pallas")


def test_pallas_pass_pipeline_gains_one_pass():
    """The 6-pass pipeline is pinned elsewhere; PALLAS appends exactly
    one 'pallas' pass (after schedule_comm, before lower) whose output
    is the KernelPlan artifact."""
    block, env = _map_block()
    c = omp.compile(block, mesh1(), env_like=env, lowering="pallas")
    names = [p.name for p in c.passes]
    assert names == ["analyze", "schedule", "plan", "plan_comm",
                     "schedule_comm", "pallas", "lower"]
    assert isinstance(c.kernel_plan, omp.KernelPlan)
    assert c._pass("pallas").output is c.kernel_plan


# ---------------------------------------------------------------------------
# Cache eviction at _CACHE_CAP (ISSUE 7)
# ---------------------------------------------------------------------------


def _distinct_block(tag, n=16):
    """A structurally distinct program per ``tag`` (distinct consts)."""
    scale = float(sum(ord(ch) for ch in str(tag)))

    @omp.parallel_for(stop=n, name=f"evict{tag}")
    def block(i, env):
        return {"y": omp.at(i, env["x"][i] * scale + 1.0)}

    env = {"x": jnp.arange(n, dtype=jnp.float32),
           "y": jnp.zeros(n, jnp.float32)}
    return block, env


def test_cache_eviction_lru_order(monkeypatch):
    """At _CACHE_CAP the *least recently used* entry leaves: a hit
    refreshes recency, so the evictee is the untouched key."""
    from repro.core import api

    omp.clear_compile_cache()
    monkeypatch.setattr(api, "_CACHE_CAP", 2)
    mesh = mesh1()
    a, env_a = _distinct_block("a")
    b, env_b = _distinct_block("b")
    c, env_c = _distinct_block("c")

    omp.compile(a, mesh, env_like=env_a)           # miss
    omp.compile(b, mesh, env_like=env_b)           # miss
    assert omp.compile(a, mesh, env_like=env_a).cache_hit  # refresh a
    omp.compile(c, mesh, env_like=env_c)           # miss -> evicts b (LRU)

    stats = omp.compile_cache_stats()
    assert stats["size"] == 2
    assert stats["hits"] == 1 and stats["misses"] == 3
    assert omp.compile(a, mesh, env_like=env_a).cache_hit is True
    assert omp.compile(c, mesh, env_like=env_c).cache_hit is True
    # b was evicted: recompiles (miss) ...
    cb = omp.compile(b, mesh, env_like=env_b)
    assert cb.cache_hit is False
    stats = omp.compile_cache_stats()
    assert stats["misses"] == 4 and stats["size"] == 2
    # ... and the recompiled entry still computes the right answer
    np.testing.assert_array_equal(np.asarray(cb(env_b)["y"]),
                                  np.asarray(b(env_b)["y"]))


def test_cache_eviction_stats_stay_consistent(monkeypatch):
    """Filling far past the cap keeps size == cap and every probe of a
    live key a hit."""
    from repro.core import api

    omp.clear_compile_cache()
    monkeypatch.setattr(api, "_CACHE_CAP", 3)
    mesh = mesh1()
    blocks = [_distinct_block(i) for i in range(8)]
    for blk, env in blocks:
        omp.compile(blk, mesh, env_like=env)
    stats = omp.compile_cache_stats()
    assert stats["size"] == 3 and stats["misses"] == 8
    # the 3 most recent survive; older ones are gone
    for blk, env in blocks[-3:]:
        assert omp.compile(blk, mesh, env_like=env).cache_hit is True
    for blk, env in blocks[:2]:
        assert omp.compile(blk, mesh, env_like=env).cache_hit is False


# ---------------------------------------------------------------------------
# Cache thread-safety (ISSUE 7: concurrent server prerequisite)
# ---------------------------------------------------------------------------


def test_cache_thread_hammer_exact_stats_and_no_corruption():
    """Many threads hammering warm keys (lock-free hits) while a writer
    inserts fresh keys (locked misses): counters stay *exact* — the
    historical ``_STATS[k] += 1`` lost increments — and every result
    stays correct."""
    import random
    import threading

    omp.clear_compile_cache()
    mesh = mesh1()
    warm = [_distinct_block(f"w{i}") for i in range(4)]
    for blk, env in warm:
        omp.compile(blk, mesh, env_like=env)        # 4 misses

    n_threads, n_iters, n_fresh = 8, 40, 6
    errors = []
    barrier = threading.Barrier(n_threads + 1)

    def hammer(tid):
        rng = random.Random(tid)
        try:
            barrier.wait()
            for _ in range(n_iters):
                blk, env = warm[rng.randrange(len(warm))]
                comp = omp.compile(blk, mesh, env_like=env)
                assert comp.cache_hit is True
                np.testing.assert_array_equal(
                    np.asarray(comp(env)["y"]), np.asarray(blk(env)["y"]))
        except Exception as e:       # pragma: no cover - failure path
            errors.append(e)

    def writer():
        try:
            barrier.wait()
            for i in range(n_fresh):
                blk, env = _distinct_block(f"f{i}")
                assert omp.compile(blk, mesh,
                                   env_like=env).cache_hit is False
        except Exception as e:       # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=hammer, args=(t,))
               for t in range(n_threads)] + [threading.Thread(target=writer)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    stats = omp.compile_cache_stats()
    assert stats["hits"] == n_threads * n_iters
    assert stats["misses"] == len(warm) + n_fresh
    assert stats["size"] == len(warm) + n_fresh


def test_env_signature_never_touches_the_device():
    """Cache probes must not device-put python scalars/lists (it made
    every probe of a scalar-bearing env a transfer); the derived dtypes
    still match what jnp.asarray would have produced."""
    from repro.core.api import _env_signature

    env = {"a": np.zeros((2, 3), np.float32), "b": 1.5, "c": 7,
           "d": [1.0, 2.0], "e": True, "f": jnp.zeros((4,), jnp.int32)}
    with jax.transfer_guard("disallow"):
        sig = _env_signature(env)
    assert dict((k, (s, d)) for k, s, d in sig) == {
        "a": ((2, 3), "float32"),
        "b": ((), str(jnp.asarray(1.5).dtype)),
        "c": ((), str(jnp.asarray(7).dtype)),
        "d": ((2,), "float32"),
        "e": ((), "bool"),
        "f": ((4,), "int32"),
    }
