"""Pipeline parallelism: correctness vs sequential stage application,
differentiability, and the expected collective-permute schedule."""
import numpy as np
import pytest


def test_pipeline_matches_sequential_and_grads(multidevice):
    out = multidevice("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh
        from repro.core.pipeline import make_pipeline, pipeline_apply

        S, M, MB, D = 8, 6, 4, 16
        mesh = make_mesh((S,), ("stage",))
        rng = np.random.default_rng(0)

        def stage_fn(p, x):
            return jnp.tanh(x @ p["w"] + p["b"])

        stacked = {
            "w": jnp.asarray(rng.normal(size=(S, D, D)).astype(np.float32))
            * 0.3,
            "b": jnp.asarray(rng.normal(size=(S, D)).astype(np.float32))
            * 0.1,
        }
        x = jnp.asarray(rng.normal(size=(M, MB, D)).astype(np.float32))

        # sequential reference: stage 0..S-1 applied in order
        def seq(stacked, x):
            y = x
            for s in range(S):
                y = stage_fn({"w": stacked["w"][s], "b": stacked["b"][s]},
                             y)
            return y

        want = jax.vmap(lambda xm: seq(stacked, xm))(x)
        run = make_pipeline(stage_fn, mesh, axis="stage")
        got = run(stacked, x)
        assert np.allclose(np.asarray(got), np.asarray(want), atol=1e-5), \
            np.abs(np.asarray(got) - np.asarray(want)).max()

        # differentiable end to end
        def loss(stacked):
            return jnp.sum(run(stacked, x) ** 2)

        g = jax.grad(loss)(stacked)
        gn = sum(float(jnp.sum(jnp.abs(t)))
                 for t in jax.tree_util.tree_leaves(g))
        assert np.isfinite(gn) and gn > 0

        # HLO: the stage hop is a collective-permute inside the tick loop
        from repro.launch import hlo_analysis as ha
        co = jax.jit(run).lower(
            jax.tree_util.tree_map(
                lambda t: jax.ShapeDtypeStruct(t.shape, t.dtype), stacked),
            jax.ShapeDtypeStruct(x.shape, x.dtype)).compile()
        rep = ha.analyze_hlo(co.as_text(), num_devices=S)
        kinds = rep.by_kind()
        assert "collective-permute" in kinds, kinds
        print("OKPIPE", kinds)
    """)
    assert "OKPIPE" in out
