"""Fault tolerance: checkpoint/restart recovery, stragglers, elasticity."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.runtime import FaultTolerantLoop, StragglerMonitor
from repro.runtime.elastic import plan_elastic_remesh
from repro.runtime.straggler import rebalance_chunks


def test_recovery_reproduces_uninterrupted_run(tmp_path):
    """A run with an injected failure must produce the same final state
    as a run without failures (deterministic data keyed by step)."""

    def step_fn(state, step):
        return {"x": state["x"] + (step + 1) * 0.5}

    def run(with_failure: bool):
        ck = Checkpointer(str(tmp_path / ("f" if with_failure else "c")))
        failed = {"done": False}

        def failure_hook(step):
            if with_failure and step == 7 and not failed["done"]:
                failed["done"] = True
                raise RuntimeError("simulated device loss")

        loop = FaultTolerantLoop(
            step_fn=step_fn, checkpointer=ck, checkpoint_every=2,
            max_retries=2, backoff_s=0.0, failure_hook=failure_hook)
        return loop.run({"x": jnp.float32(0)}, start_step=0, num_steps=12), \
            loop

    clean, _ = run(False)
    recovered, loop = run(True)
    assert loop.restores == 1
    np.testing.assert_allclose(np.asarray(clean["x"]),
                               np.asarray(recovered["x"]))


def test_retry_budget_exhaustion(tmp_path):
    def step_fn(state, step):
        raise RuntimeError("always broken")

    loop = FaultTolerantLoop(
        step_fn=step_fn, checkpointer=Checkpointer(str(tmp_path)),
        max_retries=2, backoff_s=0.0)
    with pytest.raises(RuntimeError, match="retry budget"):
        loop.run({"x": jnp.float32(0)}, start_step=0, num_steps=3)


def test_straggler_monitor_detects_and_escalates():
    mon = StragglerMonitor(spike_factor=2.0, spike_budget=3)
    for _ in range(10):
        assert mon.observe(1.0) == "ok"
    assert mon.observe(5.0) == "spike"
    assert mon.observe(5.0) == "spike"
    assert mon.observe(5.0) == "evict"


def test_straggler_recovers_after_transient():
    mon = StragglerMonitor(spike_factor=2.0, spike_budget=3)
    for _ in range(5):
        mon.observe(1.0)
    assert mon.observe(3.0) == "spike"
    for _ in range(5):
        assert mon.observe(1.0) == "ok"
    assert mon.spikes == 0


def test_rebalance_chunks_proportional():
    owners = rebalance_chunks(100, [1.0, 1.0, 0.5, 1.5])
    counts = [owners.count(d) for d in range(4)]
    assert sum(counts) == 100
    assert counts[3] > counts[0] > counts[2]
    # cyclic-ish: no device starves
    assert min(counts) >= 1


def test_elastic_remesh_plan():
    p = plan_elastic_remesh(512, model_parallel=16)
    assert p.new_shape == (32, 16)
    p2 = plan_elastic_remesh(240, model_parallel=16)
    assert p2.new_shape == (15, 16)
    p3 = plan_elastic_remesh(8, model_parallel=16)   # shrink TP
    assert p3.new_shape[1] <= 8 and 8 % p3.new_shape[1] == 0
