"""Fault tolerance: checkpoint/restart recovery, stragglers, elasticity."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.runtime import FaultTolerantLoop, StragglerMonitor
from repro.runtime.elastic import plan_elastic_remesh
from repro.runtime.straggler import rebalance_chunks


def test_recovery_reproduces_uninterrupted_run(tmp_path):
    """A run with an injected failure must produce the same final state
    as a run without failures (deterministic data keyed by step)."""

    def step_fn(state, step):
        return {"x": state["x"] + (step + 1) * 0.5}

    def run(with_failure: bool):
        ck = Checkpointer(str(tmp_path / ("f" if with_failure else "c")))
        failed = {"done": False}

        def failure_hook(step):
            if with_failure and step == 7 and not failed["done"]:
                failed["done"] = True
                raise RuntimeError("simulated device loss")

        loop = FaultTolerantLoop(
            step_fn=step_fn, checkpointer=ck, checkpoint_every=2,
            max_retries=2, backoff_s=0.0, failure_hook=failure_hook)
        return loop.run({"x": jnp.float32(0)}, start_step=0, num_steps=12), \
            loop

    clean, _ = run(False)
    recovered, loop = run(True)
    assert loop.restores == 1
    np.testing.assert_allclose(np.asarray(clean["x"]),
                               np.asarray(recovered["x"]))


def test_retry_budget_exhaustion(tmp_path):
    def step_fn(state, step):
        raise RuntimeError("always broken")

    loop = FaultTolerantLoop(
        step_fn=step_fn, checkpointer=Checkpointer(str(tmp_path)),
        max_retries=2, backoff_s=0.0)
    with pytest.raises(RuntimeError, match="retry budget"):
        loop.run({"x": jnp.float32(0)}, start_step=0, num_steps=3)


def test_retry_budget_resets_after_progress(tmp_path):
    """Two isolated transient failures, each within the budget, must
    both be survivable: the budget is per incident, rearming once the
    loop makes real progress past the failed step.  (Regression: the
    counter used to be cumulative over the whole run, so a long run
    died on its max_retries+1'th isolated blip.)"""

    def step_fn(state, step):
        return {"x": state["x"] + (step + 1) * 0.5}

    ck = Checkpointer(str(tmp_path))
    failed = set()

    def failure_hook(step):
        if step in (3, 7) and step not in failed:
            failed.add(step)
            raise RuntimeError(f"blip at {step}")

    loop = FaultTolerantLoop(
        step_fn=step_fn, checkpointer=ck, checkpoint_every=2,
        max_retries=1, backoff_s=0.0, failure_hook=failure_hook)
    out = loop.run({"x": jnp.float32(0)}, start_step=0, num_steps=12)
    assert loop.restores == 2
    # bit-match the uninterrupted run
    clean = {"x": jnp.float32(0)}
    for s in range(12):
        clean = step_fn(clean, s)
    np.testing.assert_allclose(np.asarray(out["x"]), np.asarray(clean["x"]))


def test_retry_budget_does_not_rearm_on_replayed_steps(tmp_path):
    """A deterministically-failing step must still exhaust the budget:
    the successful *replayed* steps before the failure point (restored
    checkpoint -> failure) must not reset the counter, or the loop
    would livelock retrying forever."""

    def step_fn(state, step):
        return {"x": state["x"] + 1.0}

    def failure_hook(step):
        if step == 5:
            raise RuntimeError("deterministic failure")

    loop = FaultTolerantLoop(
        step_fn=step_fn, checkpointer=Checkpointer(str(tmp_path)),
        checkpoint_every=2, max_retries=3, backoff_s=0.0,
        failure_hook=failure_hook)
    with pytest.raises(RuntimeError, match="retry budget"):
        loop.run({"x": jnp.float32(0)}, start_step=0, num_steps=12)
    assert loop.retries_used == loop.max_retries + 1


def test_pre_checkpoint_failure_restarts_from_initial_state(tmp_path):
    """A failure before the first checkpoint must rewind the STATE, not
    just the step counter.  (Regression: the no-checkpoint branch reset
    ``step`` to start_step but kept the mutated state, double-applying
    every step already run.)"""

    def step_fn(state, step):
        return {"x": state["x"] + (step + 1) * 0.5}

    failed = set()

    def failure_hook(step):
        if step == 1 and step not in failed:
            failed.add(step)
            raise RuntimeError("blip before any checkpoint")

    loop = FaultTolerantLoop(
        step_fn=step_fn, checkpointer=Checkpointer(str(tmp_path)),
        checkpoint_every=4, max_retries=1, backoff_s=0.0,
        failure_hook=failure_hook)
    out = loop.run({"x": jnp.float32(0)}, start_step=0, num_steps=6)
    clean = {"x": jnp.float32(0)}
    for s in range(6):
        clean = step_fn(clean, s)
    np.testing.assert_allclose(np.asarray(out["x"]), np.asarray(clean["x"]))


def test_restore_drains_inflight_async_save(tmp_path):
    """The restore path must wait for the background checkpoint writer:
    a slow async save racing the failure must still be discovered, not
    silently skipped in favour of an older (or no) checkpoint."""
    import time

    class SlowCheckpointer(Checkpointer):
        def _write(self, step, names, leaves, extra):
            time.sleep(0.3)
            return super()._write(step, names, leaves, extra)

    def step_fn(state, step):
        return {"x": state["x"] + (step + 1) * 0.5}

    failed = set()

    def failure_hook(step):
        # fails right after the step-2 checkpoint was *issued* async
        if step == 3 and step not in failed:
            failed.add(step)
            raise RuntimeError("blip racing the writer")

    seen = []
    loop = FaultTolerantLoop(
        step_fn=step_fn, checkpointer=SlowCheckpointer(str(tmp_path)),
        checkpoint_every=2, max_retries=1, backoff_s=0.0,
        failure_hook=failure_hook,
        on_restore=lambda s: (seen.append(float(s["x"])), s)[1])
    out = loop.run({"x": jnp.float32(0)}, start_step=0, num_steps=6)
    # restored from the step-2 checkpoint (x after steps 0,1 = 1.5),
    # not from scratch
    assert seen == [1.5]
    clean = {"x": jnp.float32(0)}
    for s in range(6):
        clean = step_fn(clean, s)
    np.testing.assert_allclose(np.asarray(out["x"]), np.asarray(clean["x"]))


def test_straggler_monitor_detects_and_escalates():
    mon = StragglerMonitor(spike_factor=2.0, spike_budget=3)
    for _ in range(10):
        assert mon.observe(1.0) == "ok"
    assert mon.observe(5.0) == "spike"
    assert mon.observe(5.0) == "spike"
    assert mon.observe(5.0) == "evict"


def test_straggler_recovers_after_transient():
    mon = StragglerMonitor(spike_factor=2.0, spike_budget=3)
    for _ in range(5):
        mon.observe(1.0)
    assert mon.observe(3.0) == "spike"
    for _ in range(5):
        assert mon.observe(1.0) == "ok"
    assert mon.spikes == 0


def test_rebalance_chunks_proportional():
    owners = rebalance_chunks(100, [1.0, 1.0, 0.5, 1.5])
    counts = [owners.count(d) for d in range(4)]
    assert sum(counts) == 100
    assert counts[3] > counts[0] > counts[2]
    # cyclic-ish: no device starves
    assert min(counts) >= 1


def test_rebalance_fewer_chunks_than_devices_terminates():
    """Regression: num_chunks < len(weights) used to loop forever in
    the largest-remainder trim (every quota already at the floor of 1).
    With fewer chunks than devices the floor drops to 0 and the deal
    terminates, assigning the chunks to the heaviest devices."""
    owners = rebalance_chunks(1, [1.0, 1.0])
    assert len(owners) == 1 and owners[0] in (0, 1)
    owners = rebalance_chunks(2, [1.0, 1.0, 1.0, 5.0])
    assert len(owners) == 2
    assert 3 in owners          # the dominant device gets work


def test_rebalance_equal_weights_is_cyclic():
    owners = rebalance_chunks(13, [1.0] * 4)
    assert owners == [j % 4 for j in range(13)]


def test_rebalance_rejects_degenerate_inputs():
    with pytest.raises(ValueError):
        rebalance_chunks(0, [1.0, 1.0])
    with pytest.raises(ValueError):
        rebalance_chunks(4, [])
    with pytest.raises(ValueError):
        rebalance_chunks(4, [1.0, 0.0])
    with pytest.raises(ValueError):
        rebalance_chunks(4, [1.0, -2.0])
    with pytest.raises(ValueError):
        rebalance_chunks(4, [1.0, float("nan")])
    with pytest.raises(ValueError):
        rebalance_chunks(4, [1.0, float("inf")])


def test_elastic_remesh_plan():
    p = plan_elastic_remesh(512, model_parallel=16)
    assert p.new_shape == (32, 16)
    p2 = plan_elastic_remesh(240, model_parallel=16)
    assert p2.new_shape == (15, 16)
    p3 = plan_elastic_remesh(8, model_parallel=16)   # shrink TP
    assert p3.new_shape[1] <= 8 and 8 % p3.new_shape[1] == 0
