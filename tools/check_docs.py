"""Docs consistency check (CI `docs` job).

Asserts the documentation set exists and that every repo-relative file
path referenced from it resolves — so the architecture map, the paper
map and the experiment protocols cannot silently rot as the tree moves.

Run: python tools/check_docs.py  (from the repo root or anywhere)
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

REQUIRED = [
    "README.md",
    "EXPERIMENTS.md",
    "docs/PAPER_MAP.md",
    "ROADMAP.md",
    "CHANGES.md",
]

# Sections/markers each doc must keep (guards against silently dropping
# the subsystem docs when files are rewritten).
REQUIRED_SECTIONS = {
    "README.md": ["## Compiling",
                  "## Communication planning",
                  "## Communication scheduling",
                  "## Nested loops & 2-D meshes",
                  "## Pallas kernels",
                  "## Serving",
                  "## Fault tolerance",
                  "omp.compile"],
    "EXPERIMENTS.md": ["## Perf-D", "## Perf-E", "## Perf-G",
                       "## Perf-H", "## Perf-I", "## Perf-J"],
    "docs/PAPER_MAP.md": ["core/comm.py", "`collapse(2)`", "LoopNest",
                          "core/nest.py", "core/api.py", "`omp.compile`",
                          "plan_comm", "core/comm_schedule.py",
                          "schedule_comm",
                          "further optimized by software engineers",
                          "core/pallas_lower.py", "`Lowering.pallas`",
                          "serving/compile_service.py",
                          "core/aot_store.py",
                          "runtime/resilient.py",
                          "runtime/fault_injection.py",
                          "chunk_weights"],
}

# repo-relative path tokens inside backticks, e.g. `src/repro/core/plan.py`
# (optionally followed by ::symbol or (symbols) which we strip)
_PATH_RE = re.compile(
    r"`((?:src|tests|benchmarks|examples|docs|tools|\.github)"
    r"/[\w./\-]+\.(?:py|md|yml))")


def main() -> int:
    missing_docs = [p for p in REQUIRED
                    if not os.path.isfile(os.path.join(REPO, p))]
    if missing_docs:
        print(f"MISSING DOCS: {missing_docs}")
        return 1

    bad: list[tuple[str, str]] = []
    missing_sections: list[tuple[str, str]] = []
    checked = 0
    for doc in REQUIRED:
        text = open(os.path.join(REPO, doc), encoding="utf-8").read()
        for ref in set(_PATH_RE.findall(text)):
            checked += 1
            if not os.path.isfile(os.path.join(REPO, ref)):
                bad.append((doc, ref))
        for needle in REQUIRED_SECTIONS.get(doc, ()):
            if needle not in text:
                missing_sections.append((doc, needle))
    rc = 0
    if bad:
        for doc, ref in sorted(bad):
            print(f"BROKEN PATH: {doc} -> {ref}")
        rc = 1
    if missing_sections:
        for doc, needle in sorted(missing_sections):
            print(f"MISSING SECTION: {doc} must contain {needle!r}")
        rc = 1
    if rc == 0:
        n_sections = sum(len(v) for v in REQUIRED_SECTIONS.values())
        print(f"docs ok: {len(REQUIRED)} documents, "
              f"{checked} referenced paths resolve, "
              f"{n_sections} required sections present")
    return rc


if __name__ == "__main__":
    sys.exit(main())
